# Developer entry points. `pythonpath = src` in pyproject.toml covers pytest;
# benchmark/launch modules still need src (and the repo root for the
# `benchmarks` namespace package) on PYTHONPATH.
PY ?= python
PP := PYTHONPATH=src:.

.PHONY: test test-fast bench-smoke bench lint train-smoke chaos-smoke multihost-smoke

test:
	$(PY) -m pytest -x -q

test-fast:  ## skip the slow jax end-to-end modules
	$(PY) -m pytest -x -q --ignore=tests/test_system.py --ignore=tests/test_train.py --ignore=tests/test_models.py --ignore=tests/test_kernels.py

bench-smoke:  ## streaming data path + layout + kernel + serving + fault benchmarks (CPU)
	$(PP) $(PY) -m benchmarks.run --streaming
	$(PP) $(PY) -m benchmarks.run --layout
	$(PP) $(PY) -m benchmarks.run --kernels
	$(PP) $(PY) -m benchmarks.run --serving
	$(PP) $(PY) -m benchmarks.run --faults
	$(PP) $(PY) -m benchmarks.run --multihost
	$(MAKE) telemetry-smoke

chaos-smoke:  ## deterministic fault-injection scenarios (BENCH_faults.json rails)
	$(PP) $(PY) -m benchmarks.run --faults

multihost-smoke:  ## sharded-window digest rails + simulated multi-host train lane
	$(PP) $(PY) -m benchmarks.run --multihost
	XLA_FLAGS=--xla_force_host_platform_device_count=4 JAX_PLATFORMS=cpu \
	  $(PP) $(PY) -m repro.launch.train --arch qwen3_0_6b --smoke --steps 6 \
	  --world 4 --hosts 2 --l-max 1024 --buffer 32 --prefetch 8 \
	  --data-scale 0.0005

telemetry-smoke:  ## telemetry-enabled train + serve smoke (metrics.json / trace.json)
	$(PP) $(PY) -m repro.launch.train --arch qwen3_0_6b --smoke --steps 6 \
	  --world 2 --l-max 1024 --buffer 32 --prefetch 8 --data-scale 0.0005 \
	  --telemetry artifacts/telemetry/train
	$(PP) $(PY) -m repro.launch.serve --arch qwen3_0_6b --smoke --requests 12 \
	  --slots 4 --max-len 192 --l-max 768 \
	  --telemetry artifacts/telemetry/serve

bench:  ## full benchmark harness (all paper tables)
	$(PP) $(PY) -m benchmarks.run

lint:  ## no third-party linter in the container: syntax-check everything
	$(PY) -m compileall -q src tests benchmarks examples

train-smoke:
	$(PP) $(PY) -m repro.launch.train --arch qwen3_0_6b --smoke --steps 8 \
	  --world 2 --l-max 1024 --buffer 32 --prefetch 8 --data-scale 0.0005
	$(PP) $(PY) -m repro.launch.train --arch qwen3_0_6b --smoke --steps 8 \
	  --world 2 --l-max 1024 --buffer 32 --prefetch 8 --data-scale 0.0005 \
	  --num-workers 2
