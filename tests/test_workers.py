"""Multi-process realization workers (DESIGN.md §14).

The four acceptance properties of the worker subsystem:

  1. **Bit-exactness** — with ``num_workers > 0`` the delivered step stream
     (arrays included, dense and packed) is identical to the in-process
     path, so Theorem-1 coverage and rank-aligned SPMD shapes are
     worker-count-agnostic;
  2. **Resumability** — a mid-epoch checkpoint taken under workers resumes
     into the identical remaining sequence under a *different* worker count
     (the pool holds no checkpointable state);
  3. **Fault tolerance** — a SIGKILLed worker never hangs the stream or
     drops a sample: its in-flight tasks re-execute in-process, and losing
     every worker degrades to in-process execution;
  4. **Ring invariants** — at most ``slots`` steps are in flight (free-slot
     backpressure), a slot recycles only when the consumer releases the
     delivered step, and a step too large for a slot falls back to inline
     delivery rather than failing.
"""

from __future__ import annotations

import dataclasses
import os
import random
import signal
import time

import numpy as np
import pytest

from repro import obs
from repro.core import OdbConfig
from repro.data.datasets import DatasetSpec, _records_from_lengths
from repro.data.loader import OnlineDynamicLoader
from repro.data.pipeline import PipelinePolicy
from repro.stream import StreamExecutor, WorkerPool
from repro.stream.workers import _decode_step, _encode_step


def make_records(n: int, seed: int = 0, lo: int = 16, hi: int = 900):
    rng = random.Random(seed)
    return _records_from_lengths([rng.randint(lo, hi) for _ in range(n)])


def small_cfg(**kw) -> OdbConfig:
    base = dict(l_max=1024, buffer_size=16, prefetch_factor=8, num_workers=1)
    base.update(kw)
    return OdbConfig(**base)


POLICY = PipelinePolicy(cutoff_len=2048)


def _loader(world=2, layout="dense", n=60, **cfg_kw) -> OnlineDynamicLoader:
    records = make_records(n, 13, lo=16, hi=700)
    spec = DatasetSpec(
        name="worker-test",
        size=len(records),
        policy=POLICY,
        make_records=lambda size, seed: records[:size],
    )
    return OnlineDynamicLoader(
        spec, world, small_cfg(**cfg_kw), layout=layout, seed=3, vocab_size=512
    )


def _digest(steps):
    """Bit-exact fingerprint of a delivered step stream (metadata + arrays)."""
    out = []
    for ls in steps:
        cells = []
        for b in ls.batches:
            cells.append((
                b.tokens.tobytes(), b.positions.tobytes(), b.segments.tobytes(),
                b.loss_mask.tobytes(), b.lengths.tobytes(),
                b.real_samples, b.real_tokens,
            ))
        out.append((ls.metadata, tuple(cells)))
    return out


def _consume(loader, **kw):
    """Run a full streaming epoch and digest it (copies out of shm slots
    before they recycle, as tobytes() does)."""
    return _digest(loader.streaming_epoch(0, **kw))


def _executor_tasks(loader, count=None):
    """Pull aligned-step tasks straight off a fresh executor."""
    ex = StreamExecutor(
        loader.dataset.records(loader.seed), loader.policy,
        loader.world_size, loader.config, seed=loader.seed, epoch=0,
    )
    tasks = []
    while count is None or len(tasks) < count:
        task = ex.next_task()
        if task is None:
            break
        tasks.append(task)
    return tasks


class TestBitExactEquivalence:
    @pytest.mark.parametrize("layout", ["dense", "packed"])
    def test_worker_stream_identical_to_in_process(self, layout):
        ref = _consume(_loader(layout=layout))
        got = _consume(_loader(layout=layout), num_workers=2)
        assert got == ref

    def test_worker_stream_identical_under_prefetch(self):
        ref = _consume(_loader())
        got = _consume(
            _loader(), num_workers=2, prefetch=True, prefetch_depth=3
        )
        assert got == ref

    def test_audit_and_accounting_match(self):
        a, b = _loader(), _loader()
        ref = _consume(a)
        got = _consume(b, num_workers=2)
        assert got == ref
        assert b.last_audit.eta_identity == a.last_audit.eta_identity == 0.0
        assert b.accounting.steps == a.accounting.steps
        assert b.accounting.emitted_tokens == a.accounting.emitted_tokens
        assert b.accounting.device_tokens == a.accounting.device_tokens
        stats = b.last_worker_stats
        assert stats.completed == stats.submitted == len(ref)
        assert stats.worker_failures == 0

    def test_step_codec_roundtrip(self):
        tasks = _executor_tasks(_loader(), count=3)
        for _, step in tasks:
            assert _decode_step(_encode_step(step)) == step


class TestResume:
    @pytest.mark.parametrize("head_nw,tail_nw", [(2, 0), (0, 2), (2, 3)])
    def test_checkpoint_resume_across_worker_counts(self, head_nw, tail_nw):
        """A checkpoint taken mid-epoch under one worker count resumes the
        identical remaining sequence under another: worker state is never
        part of the checkpoint, and the submitted-but-unconsumed tail rolls
        back into the executor on close."""
        loader = _loader()
        it = loader.streaming_epoch(
            0, num_workers=head_nw, finalize_audit=False
        )
        head = _digest(next(it) for _ in range(3))
        it.close()  # pool torn down + staged tail requeued here
        ck = loader.last_executor.checkpoint()

        resumed = _loader()
        tail = _consume(resumed, num_workers=tail_nw, resume_from=ck)
        full = _consume(_loader())
        assert head + tail == full
        assert resumed.last_audit.eta_identity == 0.0

    def test_prefetch_close_rolls_back_worker_runahead(self):
        loader = _loader()
        it = loader.streaming_epoch(
            0, num_workers=2, prefetch=True, prefetch_depth=4,
            finalize_audit=False,
        )
        head = _digest(next(it) for _ in range(2))
        it.close()
        ck = loader.last_executor.checkpoint()

        tail = _consume(_loader(), resume_from=ck)
        assert head + tail == _consume(_loader())


@dataclasses.dataclass(frozen=True)
class SlowLayout:
    """Picklable layout wrapper that holds every build open for ``delay``
    seconds — keeps a worker's claim window open so a SIGKILL deterministically
    lands on an in-flight task."""

    inner: object
    delay: float = 0.5

    def build_step(self, step):
        time.sleep(self.delay)
        return self.inner.build_step(step)


class TestFaultTolerance:
    def test_sigkill_all_workers_mid_epoch_stream_survives(self):
        """The hard-failure drill from DESIGN.md §14: every worker SIGKILLed
        mid-epoch, and the epoch still completes, in order, bit-exact —
        nothing hangs, nothing is dropped."""
        import multiprocessing as mp

        ref = _consume(_loader(layout="packed"))
        reg = obs.default_registry()
        reg.reset()
        reg.enable()
        loader = _loader(layout="packed")
        got = []
        with pytest.warns(RuntimeWarning):
            for i, ls in enumerate(loader.streaming_epoch(0, num_workers=2)):
                if i == 0:
                    victims = [
                        p for p in mp.active_children()
                        if p.name.startswith("odb-worker-")
                    ]
                    assert len(victims) == 2
                    for p in victims:
                        os.kill(p.pid, signal.SIGKILL)
                    for p in victims:
                        p.join(timeout=10)
                got.extend(_digest([ls]))
        assert got == ref  # complete, ordered, bit-exact — nothing dropped
        stats = loader.last_worker_stats
        assert stats.worker_failures == 2
        assert stats.reexecuted > 0
        assert reg.counter("odb_worker_failures_total").value >= 2
        reg.reset()

    def test_sigkill_claimed_task_reexecutes_in_process(self):
        loader = _loader()
        tasks = _executor_tasks(loader, count=2)
        layout = SlowLayout(loader.layout, delay=1.0)
        with pytest.warns(RuntimeWarning, match="in-flight"):
            with WorkerPool(layout, 2, poll_interval=0.05) as pool:
                for index, step in tasks:
                    pool.submit(index, step)
                # Wait for a worker to claim seq 0, then kill it while the
                # (slowed) build holds the claim open.
                deadline = time.time() + 15
                while pool._pending[0].claimed_by is None:
                    pool._drain_results(timeout=0.05)
                    assert time.time() < deadline, "seq 0 never claimed"
                victim = pool._procs[pool._pending[0].claimed_by]
                os.kill(victim.pid, signal.SIGKILL)
                victim.join(timeout=10)
                results = [pool.take() for _ in tasks]
        assert [r.index for r in results] == [t[0] for t in tasks]
        for r, (_, step) in zip(results, tasks):
            expected = loader.layout.build_step(step)
            for got, want in zip(r.batches, expected):
                np.testing.assert_array_equal(got.tokens, want.tokens)
        assert pool.stats.worker_failures >= 1
        assert pool.stats.reexecuted >= 1

    def test_lost_task_message_escalates_after_stall(self):
        """A task queue message can vanish without a trace (a worker dies
        between reading it and announcing the claim; here we steal it from
        the parent side).  take() must escalate after stall_timeout and
        re-execute in-process — never block forever on a task nobody owns."""
        loader = _loader()
        tasks = _executor_tasks(loader, count=2)
        layout = SlowLayout(loader.layout, delay=1.0)
        with pytest.warns(RuntimeWarning, match="stalled"):
            with WorkerPool(
                layout, 1, poll_interval=0.05, stall_timeout=2.0
            ) as pool:
                for index, step in tasks:
                    pool.submit(index, step)
                # Wait until the worker owns seq 0 (and is parked in its
                # slowed build), then steal seq 1's message off the queue.
                deadline = time.time() + 15
                while pool._pending[0].claimed_by is None:
                    pool._drain_results(timeout=0.05)
                    assert time.time() < deadline, "seq 0 never claimed"
                stolen = pool._task_q.get(timeout=5)
                assert stolen[0] == "task" and stolen[1] == 1
                results = [pool.take() for _ in tasks]
        assert [r.index for r in results] == [t[0] for t in tasks]
        for r, (_, step) in zip(results, tasks):
            expected = loader.layout.build_step(step)
            for got, want in zip(r.batches, expected):
                np.testing.assert_array_equal(got.tokens, want.tokens)
        assert pool.stats.reexecuted >= 1
        assert pool.stats.worker_failures == 0  # worker is fine; message died

    def test_worker_death_reexecutes_unclaimed_orphan_suspect(self):
        """On a worker death with survivors, the oldest unclaimed task is
        treated as a possible orphan (the dead worker may have consumed its
        message pre-claim) and re-executed with its slot quarantined; a
        surviving worker's late duplicate is dropped and frees the slot."""
        loader = _loader()
        tasks = _executor_tasks(loader, count=3)
        layout = SlowLayout(loader.layout, delay=1.0)
        with pytest.warns(RuntimeWarning, match="in-flight"):
            with WorkerPool(layout, 2, poll_interval=0.05) as pool:
                for index, step in tasks:
                    pool.submit(index, step)
                deadline = time.time() + 20
                while (
                    pool._pending[0].claimed_by is None
                    or pool._pending[1].claimed_by is None
                ):
                    pool._drain_results(timeout=0.05)
                    assert time.time() < deadline, "seq 0/1 never claimed"
                victim = pool._procs[pool._pending[0].claimed_by]
                os.kill(victim.pid, signal.SIGKILL)
                victim.join(timeout=10)
                results = [pool.take() for _ in tasks]
        assert [r.index for r in results] == [t[0] for t in tasks]
        for r, (_, step) in zip(results, tasks):
            expected = loader.layout.build_step(step)
            for got, want in zip(r.batches, expected):
                np.testing.assert_array_equal(got.tokens, want.tokens)
        assert pool.stats.worker_failures == 1
        # The dead worker's claimed step re-ran, plus the orphan-suspect —
        # unless a survivor had already claimed it by audit time.
        assert 1 <= pool.stats.reexecuted <= 2

    def test_all_workers_dead_degrades_in_process(self):
        loader = _loader()
        tasks = _executor_tasks(loader, count=4)
        with pytest.warns(RuntimeWarning, match="degraded|in-flight"):
            with WorkerPool(loader.layout, 2, poll_interval=0.05) as pool:
                # Kill the whole pool before it can pick anything up.
                for p in pool._procs:
                    os.kill(p.pid, signal.SIGKILL)
                for p in pool._procs:
                    p.join(timeout=10)
                for index, step in tasks:
                    assert pool.can_submit()
                    pool.submit(index, step)
                results = [pool.take() for _ in tasks]
        assert [r.index for r in results] == [t[0] for t in tasks]
        for r, (_, step) in zip(results, tasks):
            expected = loader.layout.build_step(step)
            for got, want in zip(r.batches, expected):
                np.testing.assert_array_equal(got.tokens, want.tokens)
        assert pool.stats.worker_failures == 2
        assert pool.stats.reexecuted == len(tasks)
        # Degraded pool keeps accepting work (in-process) — never a hang.
        assert pool.alive_workers == 0


class TestRingInvariants:
    def test_backpressure_bounded_by_slots(self):
        loader = _loader()
        tasks = _executor_tasks(loader, count=6)
        with WorkerPool(loader.layout, 1, slots=2) as pool:
            submitted = 0
            for index, step in tasks:
                if not pool.can_submit():
                    break
                pool.submit(index, step)
                submitted += 1
            assert submitted == 2  # free-slot gate = at most `slots` in flight
            assert pool.inflight == 2
            with pytest.raises(RuntimeError, match="can_submit"):
                pool.submit(*tasks[submitted])

            res = pool.take()
            # Delivered but unreleased: the slot must NOT be reusable yet —
            # the consumer may still be reading the zero-copy views.
            assert not pool._free_slots
            tokens_before = res.batches[0].tokens.copy()
            res.release()
            assert len(pool._free_slots) == 1
            res.release()  # idempotent
            assert len(pool._free_slots) == 1
            np.testing.assert_array_equal(tokens_before, tokens_before)
            assert pool.can_submit()

    def test_slot_overflow_falls_back_inline(self):
        loader = _loader()
        tasks = _executor_tasks(loader, count=3)
        reference = [loader.layout.build_step(step) for _, step in tasks]
        # 128-byte slots: every realized step overflows -> inline delivery.
        with WorkerPool(loader.layout, 1, slots=2, slot_bytes=128) as pool:
            results = []
            pending = list(tasks)
            while pending or pool.inflight:
                while pending and pool.can_submit():
                    pool.submit(*pending.pop(0))
                res = pool.take()
                if res is not None:
                    results.append(res)
                    res.release()
        assert pool.stats.inline_results == len(tasks)
        assert pool.stats.shm_results == 0
        for r, want in zip(results, reference):
            for got, exp in zip(r.batches, want):
                np.testing.assert_array_equal(got.tokens, exp.tokens)
                np.testing.assert_array_equal(got.loss_mask, exp.loss_mask)

    def test_shm_results_delivered_zero_copy(self):
        loader = _loader()
        tasks = _executor_tasks(loader, count=2)
        with WorkerPool(loader.layout, 1) as pool:
            pool.submit(*tasks[0])
            res = pool.take()
            assert pool.stats.shm_results == 1
            # The delivered arrays are views over the shm ring, not copies.
            assert not res.batches[0].tokens.flags.owndata
            res.release()

    def test_worker_obs_counters_merge_into_parent(self):
        reg = obs.default_registry()
        reg.reset()
        reg.enable()
        loader = _loader(layout="packed")
        _consume(loader, num_workers=2)
        # Layout realization ran only in workers; the parent still reports
        # the layout counters via the cross-process merge (DESIGN.md §14).
        snap = reg.snapshot()
        layout_metrics = {
            name for name in snap if name.startswith("odb_layout_")
        }
        assert layout_metrics, sorted(snap)
        reg.reset()
