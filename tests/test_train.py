"""Trainer integration: loss decreases, checkpoints, compression, shardmap DP."""

import dataclasses
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import BucketSpec, OdbConfig
from repro.data import OnlineDynamicLoader, get_dataset
from repro.data.datasets import DatasetSpec
from repro.data.pipeline import PipelinePolicy, RawRecord
from repro.models import LM
from repro.train import checkpoint as ckpt
from repro.train.optimizer import (
    OptimizerConfig,
    adamw_update,
    cosine_lr,
    global_norm,
    init_opt_state,
)
from repro.train.trainer import Trainer, TrainerConfig, global_batch_arrays


def tiny_dataset(n=96):
    def make(size, seed):
        import random
        rng = random.Random(seed)
        from repro.data.datasets import _records_from_lengths
        return _records_from_lengths([rng.randint(8, 120) for _ in range(size)])
    return DatasetSpec(
        name="tiny", size=n, policy=PipelinePolicy(cutoff_len=256), make_records=make
    )


class TestOptimizer:
    def test_cosine_schedule(self):
        cfg = OptimizerConfig(lr=1e-3, warmup_ratio=0.1, total_steps=100)
        lrs = [float(cosine_lr(jnp.float32(s), cfg)) for s in (0, 5, 10, 50, 100)]
        assert lrs[0] < lrs[1] < lrs[2]  # warmup
        assert lrs[2] >= lrs[3] >= lrs[4]  # decay
        assert lrs[4] >= cfg.lr * cfg.min_lr_fraction * 0.99

    def test_adamw_reduces_quadratic(self):
        cfg = OptimizerConfig(lr=0.1, warmup_ratio=0.0, total_steps=50, weight_decay=0.0)
        params = {"w": jnp.ones((4,)) * 3.0}
        opt = init_opt_state(params, cfg)
        for _ in range(50):
            grads = {"w": 2 * params["w"]}
            params, opt, _ = adamw_update(params, grads, opt, cfg)
        assert float(jnp.abs(params["w"]).max()) < 1.0

    def test_grad_clip(self):
        cfg = OptimizerConfig(grad_clip=1.0)
        params = {"w": jnp.zeros((3,))}
        opt = init_opt_state(params, cfg)
        _, _, metrics = adamw_update(params, {"w": jnp.ones((3,)) * 100}, opt, cfg)
        assert float(metrics["grad_norm"]) > 1.0  # reported pre-clip

    def test_bf16_moments(self):
        cfg = OptimizerConfig(moment_dtype="bfloat16")
        params = {"w": jnp.ones((4,))}
        opt = init_opt_state(params, cfg)
        assert opt["m"]["w"].dtype == jnp.bfloat16


class TestEndToEnd:
    def test_odb_training_loss_decreases(self):
        cfg = dataclasses.replace(get_smoke_config("qwen3_0_6b"), vocab_size=256)
        model = LM(cfg)
        loader = OnlineDynamicLoader(
            tiny_dataset(), world_size=4,
            config=OdbConfig(l_max=256, buffer_size=16, prefetch_factor=8, num_workers=2),
            bucket_spec=BucketSpec(min_len=32, max_len=256, align=32, max_count=64),
            vocab_size=256,
        )
        trainer = Trainer(
            model, loader,
            OptimizerConfig(lr=3e-3, total_steps=60, warmup_ratio=0.05),
            TrainerConfig(log_every=1),
        )
        state = trainer.init_state(jax.random.PRNGKey(0))
        state, steps = trainer.train_epoch(state, epoch=0)
        state, steps = trainer.train_epoch(state, epoch=1, start_step=steps)
        losses = [h["loss"] for h in trainer.history]
        assert steps >= 4
        assert losses[-1] < losses[0], losses
        audit = loader.last_audit
        assert audit.eta_identity == 0.0  # join-mode coverage held during training

    def test_global_batch_assembly_unifies_shapes(self):
        from repro.core.layout import DeviceBatch

        def db(rows, t):
            return DeviceBatch(
                tokens=np.ones((rows, t), np.int32),
                positions=np.zeros((rows, t), np.int32),
                segments=np.ones((rows, t), np.int32),
                loss_mask=np.ones((rows, t), np.float32),
                lengths=np.full((rows,), t, np.int32),
                real_samples=rows, real_tokens=rows * t,
            )

        out = global_batch_arrays([db(2, 8), db(4, 16)])
        assert out["tokens"].shape == (8, 16)
        assert out["loss_mask"][:2, 8:].sum() == 0  # re-padded region masked
        assert out["segments"][:2, 8:].sum() == 0  # grown region is padding


class TestCheckpoint:
    def test_roundtrip_and_rotation(self, tmp_path):
        state = {
            "params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
            "opt": {"step": jnp.array(7, jnp.int32)},
        }
        for s in (1, 2, 3, 4):
            ckpt.save_checkpoint(tmp_path, s, state, keep=2)
        assert ckpt.latest_step(tmp_path) == 4
        assert len(list(pathlib.Path(tmp_path).glob("step_*.npz"))) == 2
        like = jax.tree.map(lambda x: jnp.zeros_like(x), state)
        restored, step = ckpt.restore_checkpoint(tmp_path, like)
        assert step == 4
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["w"]), np.asarray(state["params"]["w"])
        )

    def test_shape_mismatch_rejected(self, tmp_path):
        state = {"w": jnp.zeros((2, 3))}
        ckpt.save_checkpoint(tmp_path, 1, state)
        with pytest.raises(ValueError):
            ckpt.restore_checkpoint(tmp_path, {"w": jnp.zeros((3, 3))})

    def test_corrupt_latest_falls_back_to_previous(self, tmp_path):
        """DESIGN.md §15.6: a torn latest checkpoint (truncated npz) must be
        detected, warned about, and skipped in favor of the previous keep-k
        checkpoint — never crash the restart loop, never half-apply."""
        like = {"w": jnp.zeros((2, 3)), "step": jnp.zeros((), jnp.int32)}
        for s in (1, 2):
            state = {
                "w": jnp.full((2, 3), float(s)),
                "step": jnp.array(s, jnp.int32),
            }
            ckpt.save_checkpoint(tmp_path, s, state, keep=3)
        latest = pathlib.Path(tmp_path) / "step_00000002.npz"
        data = latest.read_bytes()
        latest.write_bytes(data[: len(data) // 2])  # torn write
        with pytest.warns(RuntimeWarning, match="step_00000002"):
            restored, step = ckpt.restore_checkpoint(tmp_path, like)
        assert step == 1
        np.testing.assert_array_equal(
            np.asarray(restored["w"]), np.full((2, 3), 1.0)
        )

    def test_corrupt_explicit_step_never_falls_back(self, tmp_path):
        """Asking for a specific step and silently getting a different one
        would be corruption: explicit requests fail hard."""
        for s in (1, 2):
            ckpt.save_checkpoint(tmp_path, s, {"w": jnp.full((2,), float(s))})
        latest = pathlib.Path(tmp_path) / "step_00000002.npz"
        latest.write_bytes(latest.read_bytes()[:10])
        with pytest.raises(Exception):
            ckpt.restore_checkpoint(tmp_path, {"w": jnp.zeros((2,))}, step=2)

    def test_all_checkpoints_corrupt_raises_with_candidates(self, tmp_path):
        ckpt.save_checkpoint(tmp_path, 1, {"w": jnp.zeros((2,))})
        p = pathlib.Path(tmp_path) / "step_00000001.npz"
        p.write_bytes(b"\x00" * 16)
        with pytest.warns(RuntimeWarning):
            with pytest.raises(FileNotFoundError, match="step_00000001"):
                ckpt.restore_checkpoint(tmp_path, {"w": jnp.zeros((2,))})

    def test_trainer_resume(self, tmp_path):
        cfg = dataclasses.replace(get_smoke_config("olmo_1b"), vocab_size=128)
        model = LM(cfg)
        loader = OnlineDynamicLoader(
            tiny_dataset(48), world_size=2,
            config=OdbConfig(l_max=256, buffer_size=8, prefetch_factor=4, num_workers=2),
            bucket_spec=BucketSpec(min_len=32, max_len=256, align=32, max_count=64),
            vocab_size=128,
        )
        tcfg = TrainerConfig(checkpoint_dir=str(tmp_path), checkpoint_every=2, log_every=1)
        trainer = Trainer(model, loader, OptimizerConfig(), tcfg)
        state, start = trainer.restore_or_init(jax.random.PRNGKey(0))
        assert start == 0
        state, steps = trainer.train_epoch(state, 0)
        assert ckpt.latest_step(tmp_path) is not None
        # simulate crash + restart
        trainer2 = Trainer(model, loader, OptimizerConfig(), tcfg)
        state2, start2 = trainer2.restore_or_init(jax.random.PRNGKey(0))
        assert start2 > 0


class TestCompression:
    def test_error_feedback_unbiased_over_steps(self):
        from repro.train.compression import compress_decompress, init_error_state
        g = {"w": jnp.full((256,), 1.0 + 2.0 ** -12)}  # not bf16-representable
        err = init_error_state(g)
        acc = jnp.zeros((256,))
        for _ in range(64):
            gq, err = compress_decompress(g, err)
            acc = acc + gq["w"].astype(jnp.float32)
        mean = acc / 64
        np.testing.assert_allclose(np.asarray(mean), np.asarray(g["w"]), rtol=1e-4)


class TestPackedEmission:
    """First-class packed-segment layout (DESIGN.md §10)."""

    def test_packed_layout_trains_with_segment_masking(self):
        cfg = dataclasses.replace(get_smoke_config("qwen3_0_6b"), vocab_size=256)
        model = LM(cfg)
        loader = OnlineDynamicLoader(
            tiny_dataset(48), world_size=2,
            config=OdbConfig(l_max=512, buffer_size=16, prefetch_factor=8, num_workers=2),
            layout="packed", vocab_size=256,
        )
        params = model.init(jax.random.PRNGKey(0))
        from repro.train.trainer import assemble_model_batch
        steps = 0
        for ls in loader.epoch(0):
            assert len(ls.batches) == 2
            batch = assemble_model_batch(ls, loader.layout)
            assert "segments" in batch and "positions" in batch
            loss_sum, tc = model.loss_sums(params, batch)
            assert bool(jnp.isfinite(loss_sum))
            steps += 1
            if steps >= 2:
                break
        assert steps >= 1

    def test_packed_trainer_end_to_end(self):
        cfg = dataclasses.replace(get_smoke_config("qwen3_0_6b"), vocab_size=256)
        model = LM(cfg)
        loader = OnlineDynamicLoader(
            tiny_dataset(), world_size=2,
            config=OdbConfig(l_max=256, buffer_size=16, prefetch_factor=8, num_workers=2),
            layout="packed", vocab_size=256,
        )
        trainer = Trainer(
            model, loader,
            OptimizerConfig(lr=3e-3, total_steps=40, warmup_ratio=0.05),
            TrainerConfig(log_every=1),
        )
        state = trainer.init_state(jax.random.PRNGKey(0))
        state, steps = trainer.train_epoch(state, epoch=0)
        losses = [h["loss"] for h in trainer.history]
        assert steps >= 2
        assert losses[-1] < losses[0], losses
        assert loader.last_audit.eta_identity == 0.0


class TestElasticReshard:
    def test_restore_into_new_topology(self, tmp_path):
        """Checkpoint under one mesh, restore sharded for another (elastic)."""
        import os
        state = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        ckpt.save_checkpoint(tmp_path, 5, state)
        devs = jax.devices()
        if len(devs) > 1:
            from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
            mesh = Mesh(np.array(devs[: len(devs) // 2 * 2]).reshape(2, -1), ("a", "b"))
            sh = {"w": NamedSharding(mesh, P("a", None))}
            restored, step = ckpt.restore_checkpoint(tmp_path, state, shardings=sh)
            assert restored["w"].sharding == sh["w"]
        else:
            restored, step = ckpt.restore_checkpoint(tmp_path, state)
        assert step == 5
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))
