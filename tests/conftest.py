"""Test-session bootstrap: make ``hypothesis`` importable everywhere.

When the real hypothesis package is present (the ``[dev]`` extra) it is used
untouched; otherwise the deterministic mini-implementation in
``_hypothesis_compat.py`` is registered so the property-test modules collect
and run instead of killing the whole tier-1 session with collection errors.
"""

from __future__ import annotations

import importlib.util
import pathlib
import sys


def _ensure_hypothesis() -> None:
    try:
        import hypothesis  # noqa: F401

        return
    except ModuleNotFoundError:
        pass
    path = pathlib.Path(__file__).with_name("_hypothesis_compat.py")
    spec = importlib.util.spec_from_file_location("_hypothesis_compat", path)
    module = importlib.util.module_from_spec(spec)
    sys.modules.setdefault("_hypothesis_compat", module)
    spec.loader.exec_module(module)
    module.install()


_ensure_hypothesis()
