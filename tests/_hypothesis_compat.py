"""Minimal deterministic stand-in for ``hypothesis`` (satellite of PR 1).

The container ships without the real ``hypothesis`` package, which made all
six property-test modules fail at *collection* (the worst failure mode: the
whole tier-1 run dies).  This shim implements exactly the API surface the
test-suite uses — ``given``, ``settings``, and the ``integers`` / ``lists`` /
``floats`` / ``booleans`` / ``composite`` strategies — driven by a seeded
``random.Random`` so runs are reproducible.

It is NOT hypothesis: no shrinking, no database, no health checks.  The first
two examples of every ``@given`` use each strategy's min/max boundary values,
the rest are uniform draws.  When the real package is installed (the ``dev``
extra in pyproject.toml), ``tests/conftest.py`` leaves it alone and this
module is inert.
"""

from __future__ import annotations

import functools
import inspect
import random
import struct
import sys
import types
import zlib

DEFAULT_MAX_EXAMPLES = 50


class _Strategy:
    """Base strategy: ``do_draw(rnd)`` plus optional boundary examples."""

    def do_draw(self, rnd: random.Random):
        raise NotImplementedError

    def boundary(self, which: str):
        """'min' / 'max' boundary example; None = no special boundary."""
        return None

    # hypothesis strategies expose .map/.filter; implement the tiny subset
    # cheaply in case future tests use them.
    def map(self, f):
        return _MappedStrategy(self, f)

    def example(self):  # debugging aid, mirrors hypothesis
        return self.do_draw(random.Random(0))


class _MappedStrategy(_Strategy):
    def __init__(self, base, f):
        self.base = base
        self.f = f

    def do_draw(self, rnd):
        return self.f(self.base.do_draw(rnd))

    def boundary(self, which):
        b = self.base.boundary(which)
        return None if b is None else self.f(b)


class _Integers(_Strategy):
    def __init__(self, min_value=None, max_value=None):
        self.min_value = -(2**31) if min_value is None else min_value
        self.max_value = 2**31 if max_value is None else max_value

    def do_draw(self, rnd):
        return rnd.randint(self.min_value, self.max_value)

    def boundary(self, which):
        return self.min_value if which == "min" else self.max_value


class _Booleans(_Strategy):
    def do_draw(self, rnd):
        return rnd.random() < 0.5

    def boundary(self, which):
        return which == "max"


class _Floats(_Strategy):
    def __init__(
        self,
        min_value=0.0,
        max_value=1.0,
        *,
        allow_nan=True,
        allow_infinity=None,
        width=64,
    ):
        self.min_value = float(min_value)
        self.max_value = float(max_value)
        self.width = width

    def _cast(self, x: float) -> float:
        if self.width == 32:  # round-trip through float32 precision
            return struct.unpack("f", struct.pack("f", x))[0]
        return x

    def do_draw(self, rnd):
        return self._cast(rnd.uniform(self.min_value, self.max_value))

    def boundary(self, which):
        return self._cast(self.min_value if which == "min" else self.max_value)


class _Lists(_Strategy):
    def __init__(self, elements, *, min_size=0, max_size=None, unique=False):
        self.elements = elements
        self.min_size = min_size
        self.max_size = min_size + 20 if max_size is None else max_size
        self.unique = unique

    def do_draw(self, rnd):
        size = rnd.randint(self.min_size, self.max_size)
        out = []
        seen = set()
        attempts = 0
        while len(out) < size and attempts < size * 20 + 20:
            v = self.elements.do_draw(rnd)
            attempts += 1
            if self.unique:
                if v in seen:
                    continue
                seen.add(v)
            out.append(v)
        return out

    def boundary(self, which):
        if which == "min":
            b = self.elements.boundary("min")
            if b is None:
                return None
            return [b] * max(self.min_size, 1 if self.min_size else 0) or []
        b = self.elements.boundary("max")
        if b is None:
            return None
        return [b] * min(self.max_size, max(self.min_size, 3))


class _SampledFrom(_Strategy):
    def __init__(self, options):
        self.options = list(options)

    def do_draw(self, rnd):
        return rnd.choice(self.options)

    def boundary(self, which):
        return self.options[0] if which == "min" else self.options[-1]


class _Just(_Strategy):
    def __init__(self, value):
        self.value = value

    def do_draw(self, rnd):
        return self.value

    def boundary(self, which):
        return self.value


class _Tuples(_Strategy):
    def __init__(self, *parts):
        self.parts = parts

    def do_draw(self, rnd):
        return tuple(p.do_draw(rnd) for p in self.parts)


class _Composite(_Strategy):
    def __init__(self, fn, args, kwargs):
        self.fn = fn
        self.args = args
        self.kwargs = kwargs

    def do_draw(self, rnd):
        def draw(strategy):
            return strategy.do_draw(rnd)

        return self.fn(draw, *self.args, **self.kwargs)


def composite(fn):
    @functools.wraps(fn)
    def builder(*args, **kwargs):
        return _Composite(fn, args, kwargs)

    return builder


def integers(min_value=None, max_value=None):
    return _Integers(min_value, max_value)


def booleans():
    return _Booleans()


def floats(min_value=0.0, max_value=1.0, **kwargs):
    return _Floats(min_value, max_value, **kwargs)


def lists(elements, *, min_size=0, max_size=None, unique=False):
    return _Lists(elements, min_size=min_size, max_size=max_size, unique=unique)


def sampled_from(options):
    return _SampledFrom(options)


def just(value):
    return _Just(value)


def tuples(*parts):
    return _Tuples(*parts)


class settings:
    """Decorator recording (max_examples, deadline); consumed by ``given``."""

    def __init__(self, max_examples=DEFAULT_MAX_EXAMPLES, deadline=None, **_):
        self.max_examples = max_examples
        self.deadline = deadline

    def __call__(self, fn):
        fn._compat_settings = self
        return fn


class HealthCheck:
    too_slow = "too_slow"
    filter_too_much = "filter_too_much"
    data_too_large = "data_too_large"

    @classmethod
    def all(cls):
        return [cls.too_slow, cls.filter_too_much, cls.data_too_large]


class _Rejected(Exception):
    pass


def assume(condition) -> bool:
    """Reject the current example when the assumption fails."""
    if not condition:
        raise _Rejected()
    return True


def given(*strategies, **kw_strategies):
    def decorate(fn):
        cfg = getattr(fn, "_compat_settings", None) or settings()

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):  # signature fixed up below for pytest
            # Deterministic seed per test function so failures reproduce —
            # crc32, not hash(): str hashing is salted per process.
            name = getattr(fn, "__qualname__", fn.__name__)
            seed_base = zlib.crc32(name.encode()) & 0x7FFFFFFF
            executed = 0
            example_index = 0
            while executed < cfg.max_examples:
                attempt = example_index  # boundary examples on attempts 0/1;
                # a boundary rejected by assume() falls through to random
                # draws instead of retrying the identical value forever.
                rnd = random.Random(seed_base * 1_000_003 + example_index)
                example_index += 1
                if example_index > cfg.max_examples * 10 + 20:
                    if executed == 0:
                        raise AssertionError(
                            "assume() rejected every generated example; "
                            "property was never exercised (hypothesis would "
                            "raise FailedHealthCheck.filter_too_much)"
                        )
                    break  # enough examples ran; assume() is just picky
                try:
                    if attempt == 0:
                        drawn = [
                            s.boundary("min")
                            if s.boundary("min") is not None
                            else s.do_draw(rnd)
                            for s in strategies
                        ]
                        drawn_kw = {
                            k: (
                                s.boundary("min")
                                if s.boundary("min") is not None
                                else s.do_draw(rnd)
                            )
                            for k, s in kw_strategies.items()
                        }
                    elif attempt == 1:
                        drawn = [
                            s.boundary("max")
                            if s.boundary("max") is not None
                            else s.do_draw(rnd)
                            for s in strategies
                        ]
                        drawn_kw = {
                            k: (
                                s.boundary("max")
                                if s.boundary("max") is not None
                                else s.do_draw(rnd)
                            )
                            for k, s in kw_strategies.items()
                        }
                    else:
                        drawn = [s.do_draw(rnd) for s in strategies]
                        drawn_kw = {
                            k: s.do_draw(rnd) for k, s in kw_strategies.items()
                        }
                    fn(*args, *drawn, **kwargs, **drawn_kw)
                except _Rejected:
                    continue
                except Exception as exc:
                    raise AssertionError(
                        f"property falsified on example {executed} "
                        f"(seed={seed_base * 1_000_003 + example_index - 1}): "
                        f"args={drawn!r} kwargs={drawn_kw!r}"
                    ) from exc
                executed += 1

        # pytest must not see the strategy-filled parameters as fixtures:
        # drop the __wrapped__ introspection link and narrow the visible
        # signature to the parameters given() does NOT supply.
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        keep = params[: len(params) - len(strategies) - len(kw_strategies)]
        keep = [p for p in keep if p.name not in kw_strategies]
        wrapper.__signature__ = sig.replace(parameters=keep)
        return wrapper

    return decorate


def install() -> None:
    """Register this module as ``hypothesis`` + ``hypothesis.strategies``."""
    if "hypothesis" in sys.modules:
        return
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.HealthCheck = HealthCheck
    hyp.example = lambda *a, **k: (lambda fn: fn)  # @example(...) no-op
    strategies = types.ModuleType("hypothesis.strategies")
    for name in (
        "integers",
        "booleans",
        "floats",
        "lists",
        "sampled_from",
        "just",
        "tuples",
        "composite",
    ):
        setattr(strategies, name, globals()[name])
    hyp.strategies = strategies
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strategies
