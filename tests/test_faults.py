"""Fault-tolerant DGAP runtime (DESIGN.md §15).

Three layers under test:

  * :class:`ResilientCollective` — per-round deadlines, bounded retry with
    deterministic backoff, typed failures (unit tests against a scripted
    injector; no engine needed);
  * sample quarantine — realization failures become the accounted
    component X of the No-Leak invariant (executor-level, via the pipeline
    fault hook) and ride checkpoints;
  * degraded-mode closure — an unrecoverable gather failure raises
    :class:`EpochAborted` carrying a valid, resumable checkpoint;

plus the end-to-end chaos scenarios (``repro.chaos``), parametrized over
every fault kind at the seed given by ``CHAOS_SEED`` (the CI chaos lane's
matrix axis).
"""

from __future__ import annotations

import os

import pytest

from repro.chaos import (
    FAULT_KINDS,
    SCENARIOS,
    ChaosPlan,
    CollectiveInjector,
    poison_samples,
    stream_digest,
)
from repro.chaos.harness import N_RECORDS, POLICY, WORLD, base_config, drain, make_records
from repro.core import IDLE
from repro.core.comm import (
    Collective,
    LoopbackCollective,
    ProtocolDesyncError,
    RankTimeoutError,
    ResilientCollective,
)
from repro.data.pipeline import SampleCorruptionError
from repro.stream import EpochAborted, StreamCheckpoint, StreamExecutor

CHAOS_SEED = int(os.environ.get("CHAOS_SEED", "0"))


class ScriptedInjector:
    """Faults from an explicit {(round, attempt, rank): fault} script."""

    def __init__(self, script):
        self.script = script
        self.calls = []

    def on_gather(self, round_index, attempt, rank, tag):
        self.calls.append((round_index, attempt, rank, tag))
        return self.script.get((round_index, attempt, rank))


def _resilient(inner, injector=None, **kw):
    kw.setdefault("deadline_s", 0.1)
    kw.setdefault("max_retries", 2)
    kw.setdefault("backoff_base_s", 0.001)
    kw.setdefault("sleep_fn", lambda s: None)
    return ResilientCollective(inner, injector=injector, **kw)


class TestResilientCollective:
    def test_transient_drop_recovers_with_payloads_memoized(self):
        """A retried round must NOT re-run the protocol's side-effecting
        payload closures: payloads materialize once, only the transport
        attempt repeats, and the inner collective sees exactly one call."""
        inner = LoopbackCollective(4)
        rc = _resilient(inner, ScriptedInjector({(0, 0, 1): "drop"}))
        closure_calls = []

        def payload(rank):
            closure_calls.append(rank)
            return {"rank": rank}

        out = rc.gather_round(payload)
        assert out == [{"rank": r} for r in range(4)]
        assert closure_calls == [0, 1, 2, 3]  # once per rank despite retry
        assert rc.retries == 1 and rc.recovered == 1
        assert inner.stats.rounds == 1  # one audited transport round

    def test_timeout_after_retry_budget_is_typed_and_leaves_inner_untouched(self):
        inner = LoopbackCollective(4)
        script = {(0, a, 2): "drop" for a in range(10)}  # hard fault, rank 2
        rc = _resilient(inner, ScriptedInjector(script), max_retries=2)
        with pytest.raises(RankTimeoutError) as ei:
            rc.gather_round(lambda r: r)
        err = ei.value
        assert err.rank == 2
        assert err.round_index == 0
        assert err.attempts == 3  # initial + 2 retries
        assert not isinstance(err, ProtocolDesyncError)
        # Nothing reached the transport: rank state is intact by construction.
        assert inner.stats.rounds == 0

    def test_desync_is_never_retried(self):
        class DesyncInner(Collective):
            def __init__(self):
                super().__init__(2)
                self.calls = 0

            def gather_round(self, payload_fn, *, tag="primary"):
                self.calls += 1
                raise ProtocolDesyncError("uniform-call invariant violated")

        inner = DesyncInner()
        rc = _resilient(inner)
        with pytest.raises(ProtocolDesyncError):
            rc.gather_round(lambda r: r)
        assert inner.calls == 1  # retrying a protocol bug can only deepen it

    def test_sub_deadline_latency_is_not_a_fault(self):
        inner = LoopbackCollective(2)
        rc = _resilient(
            inner, ScriptedInjector({(0, 0, 0): 0.05}), deadline_s=0.1
        )
        assert rc.gather_round(lambda r: r) == [0, 1]
        assert rc.retries == 0 and rc.recovered == 0

    def test_backoff_is_deterministic_in_seed(self):
        def run(seed):
            sleeps = []
            script = {(0, a, 0): "drop" for a in (0, 1)}  # recover on 3rd
            rc = _resilient(
                LoopbackCollective(2),
                ScriptedInjector(script),
                backoff_base_s=0.01,
                sleep_fn=sleeps.append,
                seed=seed,
            )
            rc.gather_round(lambda r: r)
            return sleeps

        a, b = run(7), run(7)
        assert a == b and len(a) == 2  # same seed -> same retry trajectory
        assert run(8) != a
        # jitter in [0.5, 1.5) over base * 2^(attempt-1), capped
        assert 0.005 <= a[0] < 0.015
        assert 0.010 <= a[1] < 0.030

    def test_round_counter_tracks_primary_gathers_only(self):
        rc = _resilient(LoopbackCollective(2))
        rc.gather_round(lambda r: r, tag="primary")
        rc.gather_round(lambda r: r, tag="secondary")
        rc.gather_round(lambda r: r, tag="primary")
        assert rc._round_counter == 2

    def test_constructor_validation(self):
        inner = LoopbackCollective(2)
        with pytest.raises(ValueError):
            ResilientCollective(inner, deadline_s=0.0)
        with pytest.raises(ValueError):
            ResilientCollective(inner, max_retries=-1)


class TestQuarantine:
    POISON = frozenset({3, 17, 42})

    def test_strict_default_reraises(self):
        records = make_records(N_RECORDS, seed=0)
        with poison_samples({records[5].identity}):
            ex = StreamExecutor(records, POLICY, WORLD, base_config(), seed=0)
            with pytest.raises(SampleCorruptionError):
                drain(ex)

    def test_budget_quarantines_and_accounts(self):
        records = make_records(N_RECORDS, seed=0)
        config = base_config(max_quarantine=3)
        with poison_samples(self.POISON):
            ex = StreamExecutor(records, POLICY, WORLD, config, seed=0)
            steps = drain(ex)
        assert steps  # epoch completed through the failures
        assert ex.runner.quarantined_ids == set(self.POISON)
        assert ex.runner.quarantined_views == 3
        audit = ex.audit()
        assert audit.quarantined_identities == 3
        assert audit.quarantined_views == 3
        assert audit.coverage_accounted  # emitted U quarantined covers all
        assert ex.window_stats().quarantined == 3
        # Quarantined identities never appear in the delivered stream.
        emitted = set()
        for step in steps:
            for group in step:
                if group is IDLE or group is None:
                    continue
                emitted.update(s.identity for s in group.samples)
        assert not emitted & self.POISON

    def test_over_budget_reraises(self):
        records = make_records(N_RECORDS, seed=0)
        config = base_config(max_quarantine=2)
        with poison_samples(self.POISON):  # 3 failures, budget 2
            ex = StreamExecutor(records, POLICY, WORLD, config, seed=0)
            with pytest.raises(SampleCorruptionError):
                drain(ex)

    def test_non_join_terminates_on_effective_quota(self):
        """Non-join closure waits for the quota; quarantined views can never
        emit, so the quota must shrink by |X| or the epoch deadlocks."""
        records = make_records(N_RECORDS, seed=2)
        config = base_config(max_quarantine=3, join_mode=False)
        with poison_samples(self.POISON):
            ex = StreamExecutor(records, POLICY, WORLD, config, seed=2)
            drain(ex)  # termination IS the assertion
        assert ex.runner.quarantined_ids == set(self.POISON)
        # Catch-up iterations may re-meet a poison identity (more views in X,
        # same identities — exempt from the budget, never re-counted).
        assert ex.runner.quarantined_views >= 3
        assert ex.runner.effective_quota == ex.runner.n - 3
        audit = ex.audit()
        assert audit.quarantined_identities == 3
        # Non-join trades identity coverage for the eager stop even
        # fault-free (the paper's eta_identity gap), so the join-mode
        # coverage_accounted rail does not apply here; the quota rail does.
        assert audit.emitted_views >= ex.runner.effective_quota

    def test_quarantine_rides_checkpoint_resume(self):
        records = make_records(N_RECORDS, seed=1)
        config = base_config(max_quarantine=3)
        with poison_samples(self.POISON):
            ref = StreamExecutor(records, POLICY, WORLD, config, seed=1)
            ref_steps = drain(ref)

            ex = StreamExecutor(records, POLICY, WORLD, config, seed=1)
            steps = [ex.step() for _ in range(3)]
            ck = StreamCheckpoint.from_json(ex.checkpoint().to_json())
            resumed = StreamExecutor.resume(ck, records, POLICY)
            assert resumed.runner.quarantined_ids == ex.runner.quarantined_ids
            assert resumed.runner.quarantined_views == ex.runner.quarantined_views
            steps += drain(resumed)
        assert stream_digest(steps) == stream_digest(ref_steps)
        assert resumed.runner.quarantined_ids == set(self.POISON)
        assert resumed.audit().coverage_accounted


class TestEpochAborted:
    def test_abort_latches_and_resume_is_bit_exact(self):
        records = make_records(N_RECORDS, seed=3)
        config = base_config(round_retries=1)
        ref = drain(StreamExecutor(records, POLICY, WORLD, config, seed=3))

        injector = CollectiveInjector(
            ChaosPlan(3, WORLD), kind="gather_drop", at_round=2
        )
        ex = StreamExecutor(
            records, POLICY, WORLD, config, seed=3, fault_injector=injector
        )
        steps = []
        with pytest.raises(EpochAborted) as ei:
            while True:
                s = ex.step()
                if s is None:
                    break
                steps.append(s)
        exc = ei.value
        assert isinstance(exc.cause, RankTimeoutError)
        assert ex.aborted
        with pytest.raises(EpochAborted):
            ex.step()  # latched: recovery is checkpoint + resume

        ck = StreamCheckpoint.from_json(exc.checkpoint().to_json())
        resumed = StreamExecutor.resume(ck, records, POLICY)
        steps += drain(resumed)
        assert stream_digest(steps) == stream_digest(ref)
        assert resumed.audit().coverage_accounted

    def test_abort_checkpoint_is_lazy_and_stable(self):
        records = make_records(N_RECORDS, seed=4)
        injector = CollectiveInjector(
            ChaosPlan(4, WORLD), kind="gather_drop", at_round=1
        )
        ex = StreamExecutor(
            records, POLICY, WORLD,
            base_config(round_retries=0),
            seed=4, fault_injector=injector,
        )
        with pytest.raises(EpochAborted) as ei:
            drain(ex)
        first = ei.value.checkpoint()
        assert ei.value.checkpoint() is first  # computed once, cached


class TestChaosScenarios:
    """Every fault kind, at the CI matrix seed (CHAOS_SEED, default 0)."""

    @pytest.mark.parametrize("kind", FAULT_KINDS)
    def test_scenario_rails(self, kind):
        res = SCENARIOS[kind](CHAOS_SEED)
        assert res.terminated, res.as_dict()
        assert res.within_bound, res.as_dict()
        assert res.ok, res.as_dict()
        if kind == "gather_drop":
            assert res.details["aborted"]  # the outage actually fired
        if kind == "poison_sample":
            assert not res.bit_exact and res.accounted
        else:
            assert res.bit_exact
