"""End-to-end behaviour tests for the full ODB system."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.core import BucketSpec, OdbConfig
from repro.data import OnlineDynamicLoader, get_dataset
from repro.models import LM
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig


def test_full_system_train_and_serve():
    """Dataset -> online pipeline -> DGAP protocol -> bucketed batches ->
    jitted train steps -> decode, with guarantees audited along the way."""
    cfg = dataclasses.replace(get_smoke_config("qwen3_0_6b"), vocab_size=512)
    model = LM(cfg)
    loader = OnlineDynamicLoader(
        get_dataset("bimodal"),
        world_size=4,
        config=OdbConfig(l_max=1024, buffer_size=64, prefetch_factor=16, num_workers=4),
        bucket_spec=BucketSpec(min_len=64, max_len=4096, align=64, max_count=128),
        vocab_size=cfg.vocab_size,
    )
    trainer = Trainer(
        model, loader, OptimizerConfig(lr=1e-3, total_steps=50),
        TrainerConfig(log_every=1, max_steps=8),
    )
    state = trainer.init_state(jax.random.PRNGKey(0))
    state, steps = trainer.train_epoch(state)
    assert steps >= 4
    assert all(jnp.isfinite(h["loss"]).item() for h in trainer.history)

    # the protocol guarantees held during training (Theorem 1)
    audit = loader.last_audit
    assert audit.eta_identity == 0.0 and audit.eta_quota == 0.0

    # padding stayed far below fixed-batch levels on bimodal data
    assert loader.accounting.padding_fraction < 0.25

    # the trained params serve: prefill + decode produce finite logits
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 1, cfg.vocab_size)
    logits, caches = model.prefill(state["params"], toks, max_len=16)
    assert bool(jnp.isfinite(logits).all())
    lg, caches = model.decode_step(
        state["params"], caches, toks[:, -1:], jnp.array(12, jnp.int32)
    )
    assert lg.shape[0] == 2 and bool(jnp.isfinite(lg).all())


def test_benchmark_harness_importable():
    """benchmarks.run exposes a main() per the harness contract."""
    import benchmarks.run as run
    assert callable(run.main)
