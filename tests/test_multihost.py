"""Distributed multi-host admission window (DESIGN.md §16).

The tentpole contract: partitioning the W DGAP ranks over P sharded host
windows changes NOTHING the protocol can observe.  Concretely:

  1. **Digest identity** — the delivered step stream of a P-host executor is
     bit-identical to the 1-process W-rank loopback stream for every tested
     (P, W, lookahead, quota) cell, Theorem-1 identity coverage included;
  2. **Theorem-4 termination** — sharded rounds stay inside the same
     envelope the single-process property suite proves;
  3. **Elastic resume** — a checkpoint taken at host count P resumes at any
     other divisor host count (including 1) with a bit-identical tail, the
     v4 per-rank window schema's whole point;
  4. **Payload fold** — every round's gather payload carries the per-rank
     window summary, and quarantine identities absorbed from it shrink
     non-join closure by the merged |X|.
"""

from __future__ import annotations

import os
import pathlib
import random
import subprocess
import sys
import textwrap

import pytest

from repro.chaos import poison_samples, stream_digest
from repro.chaos.harness import round_bound
from repro.core import OdbConfig
from repro.data.datasets import _records_from_lengths
from repro.data.pipeline import PipelinePolicy
from repro.data.sampler import SamplerSpec
from repro.stream import (
    AdmissionWindow,
    QuarantineLedger,
    ShardedWindow,
    StreamCheckpoint,
    StreamExecutor,
    WindowRouter,
    host_rank_blocks,
    split_lookahead,
)

POLICY = PipelinePolicy()
REPO = pathlib.Path(__file__).resolve().parents[1]


def make_records(n: int, seed: int = 0, lo: int = 16, hi: int = 900):
    rng = random.Random(seed)
    return _records_from_lengths([rng.randint(lo, hi) for _ in range(n)])


def small_cfg(**kw) -> OdbConfig:
    base = dict(
        l_max=1024, buffer_size=16, prefetch_factor=8, num_workers=1
    )
    base.update(kw)
    return OdbConfig(**base)


def drain(ex: StreamExecutor) -> list:
    steps = []
    while True:
        step = ex.step()
        if step is None:
            return steps
        steps.append(step)


def make_spec(n: int, world: int, seed: int = 0) -> SamplerSpec:
    return SamplerSpec(dataset_size=n, world_size=world, seed=seed)


# -----------------------------------------------------------------------------
# Per-rank decomposition primitives
# -----------------------------------------------------------------------------


class TestDecomposition:
    def test_split_lookahead_partitions_budget(self):
        for lookahead in (4, 7, 9, 32, 101):
            for world in (1, 2, 4, 7):
                if lookahead < world:
                    continue
                budgets = split_lookahead(lookahead, world)
                assert sum(budgets) == lookahead
                assert len(budgets) == world
                assert min(budgets) >= 1  # per-rank liveness floor
                assert max(budgets) - min(budgets) <= 1

    def test_host_rank_blocks_contiguous_partition(self):
        blocks = host_rank_blocks(8, 4)
        assert blocks == [(0, 1), (2, 3), (4, 5), (6, 7)]
        flat = [r for b in host_rank_blocks(12, 3) for r in b]
        assert flat == list(range(12))

    def test_host_rank_blocks_uneven_partition(self):
        """W % P != 0: remainder spreads over the first W % P hosts, blocks
        stay contiguous and differ in size by at most one."""
        assert host_rank_blocks(6, 4) == [(0, 1), (2, 3), (4,), (5,)]
        assert host_rank_blocks(5, 2) == [(0, 1, 2), (3, 4)]
        assert host_rank_blocks(8, 3) == [(0, 1, 2), (3, 4, 5), (6, 7)]
        for world in (5, 6, 7, 11):
            for hosts in range(1, world + 1):
                blocks = host_rank_blocks(world, hosts)
                flat = [r for b in blocks for r in b]
                assert flat == list(range(world))
                sizes = [len(b) for b in blocks]
                assert min(sizes) >= 1
                assert max(sizes) - min(sizes) <= 1

    def test_host_rank_blocks_rejects_empty_blocks(self):
        with pytest.raises(ValueError):
            host_rank_blocks(4, 5)  # some host would own no rank
        with pytest.raises(ValueError):
            host_rank_blocks(8, 0)

    def test_lookahead_below_world_size_rejected(self):
        records = make_records(40)
        with pytest.raises(ValueError, match="lookahead"):
            AdmissionWindow(
                records, POLICY, make_spec(40, 4), shuffle_epoch=0, lookahead=3
            )

    def test_executor_rejects_out_of_range_host_count(self):
        records = make_records(40)
        with pytest.raises(ValueError, match="num_hosts"):
            StreamExecutor(records, POLICY, 4, small_cfg(), num_hosts=5)
        with pytest.raises(ValueError, match="num_hosts"):
            StreamExecutor(records, POLICY, 4, small_cfg(), num_hosts=0)


# -----------------------------------------------------------------------------
# Sharded window / router contracts
# -----------------------------------------------------------------------------


def make_router(records, world: int, hosts: int, **kw) -> WindowRouter:
    spec = make_spec(len(records), world)
    ledger = QuarantineLedger(kw.pop("max_quarantine", 0))
    return WindowRouter(
        [
            ShardedWindow(
                records, POLICY, spec,
                host=h, num_hosts=hosts, shuffle_epoch=0, ledger=ledger, **kw,
            )
            for h in range(hosts)
        ]
    )


class TestShardedWindow:
    def test_foreign_rank_raises(self):
        records = make_records(40)
        router = make_router(records, 4, 2)
        shard0 = router.windows[0]  # owns ranks (0, 1)
        assert shard0.host_ranks == (0, 1)
        with pytest.raises(ValueError, match="rank 2"):
            shard0.take(2, 1)
        with pytest.raises(ValueError, match="rank 3"):
            shard0.shard_state(3)

    def test_router_requires_full_disjoint_coverage(self):
        records = make_records(40)
        spec = make_spec(40, 4)
        kw = dict(shuffle_epoch=0, ledger=QuarantineLedger(0))
        half = ShardedWindow(
            records, POLICY, spec, host=0, num_hosts=2, **kw
        )
        with pytest.raises(ValueError, match="cover"):
            WindowRouter([half])  # ranks 2, 3 unowned
        with pytest.raises(ValueError, match="two host"):
            WindowRouter([half, half])

    def test_union_of_shard_streams_matches_plain_window(self):
        """Rank-by-rank, the sharded windows deliver the plain window's
        exact sample sequence — the per-rank decomposition invariant."""
        records = make_records(60, seed=3)
        spec = make_spec(60, 4)
        plain = AdmissionWindow(records, POLICY, spec, shuffle_epoch=0)
        router = make_router(records, 4, 2)
        for rank in range(4):
            while True:
                a = plain.take(rank, 3)
                b = router.take(rank, 3)
                assert a == b
                assert plain.remaining(rank) == router.remaining(rank)
                assert plain.exhausted(rank) == router.exhausted(rank)
                if not a:
                    break

    def test_shard_state_schema(self):
        records = make_records(40)
        router = make_router(records, 4, 2)
        router.take(2, 2)
        state = router.shard_state(2)
        assert state["host"] == 1
        assert state["cursor"] == 2
        assert state["delivered"] == 2
        assert state["staged"] == 0
        assert state["resident"] == 0
        assert state["quarantined_ids"] == []

    def test_absorb_gathered_merges_remote_quarantine(self):
        """Separate per-host ledgers (the real-deployment regime): an
        identity charged on host 0 must reach host 1 through the gather
        payload, fire on_remote_quarantine exactly once, and be idempotent
        on replay."""
        records = make_records(40)
        spec = make_spec(40, 4)
        a = ShardedWindow(
            records, POLICY, spec, host=0, num_hosts=2, shuffle_epoch=0,
            max_quarantine=2,
        )
        b = ShardedWindow(
            records, POLICY, spec, host=1, num_hosts=2, shuffle_epoch=0,
            max_quarantine=2,
        )
        assert a.ledger.admit_failure(0, 17, RuntimeError("injected"))
        seen: list[int] = []
        b.on_remote_quarantine = seen.append
        states = [a.shard_state(0), b.shard_state(2)]
        b.absorb_gathered(states)
        b.absorb_gathered(states)  # replay: idempotent
        assert seen == [17]
        assert b.remote_quarantined == {17}
        # The charging host itself never re-absorbs its own charge.
        a.absorb_gathered(states)
        assert a.remote_quarantined == set()


# -----------------------------------------------------------------------------
# Digest identity matrix (the acceptance bar)
# -----------------------------------------------------------------------------


MATRIX = [
    # (n, world, hosts, lookahead, join_mode, max_quarantine)
    (60, 4, 2, None, True, 0),
    (60, 4, 4, None, True, 0),
    (97, 4, 2, None, False, 0),
    (60, 4, 2, 8, True, 0),      # tight lookahead: throttling partition-invariant
    (60, 4, 4, 4, True, 0),      # minimum legal lookahead (= W)
    (64, 8, 2, 16, True, 0),
    (64, 8, 8, None, False, 0),
    (90, 6, 3, 12, True, 0),
    (90, 6, 4, 12, True, 0),     # uneven W % P: blocks (0,1) (2,3) (4,) (5,)
    (75, 5, 2, None, True, 0),   # uneven W % P: blocks (0,1,2) (3,4)
    (75, 5, 2, 10, False, 0),    # uneven + non-join + tight lookahead
    (60, 4, 2, None, False, 3),  # quarantine cell (poisoned below)
]


class TestDigestIdentity:
    @pytest.mark.parametrize(
        "n,world,hosts,lookahead,join_mode,quarantine", MATRIX
    )
    def test_sharded_stream_bit_identical(
        self, n, world, hosts, lookahead, join_mode, quarantine
    ):
        records = make_records(n, seed=5)
        cfg = small_cfg(join_mode=join_mode, max_quarantine=quarantine)
        poison = (
            {records[3].identity, records[11].identity, records[19].identity}
            if quarantine
            else set()
        )
        with poison_samples(poison):
            ref = StreamExecutor(
                records, POLICY, world, cfg, seed=7, lookahead=lookahead
            )
            ref_steps = drain(ref)
            ex = StreamExecutor(
                records, POLICY, world, cfg, seed=7, lookahead=lookahead,
                num_hosts=hosts,
            )
            steps = drain(ex)
        assert stream_digest(steps) == stream_digest(ref_steps)
        audit = ex.audit()
        assert audit == ref.audit()
        assert ex.runner.rounds <= round_bound(ex)  # Theorem 4 envelope
        if quarantine:
            # Theorem 1 under faults: emitted U quarantined covers all.
            assert audit.coverage_accounted
            assert ex.runner.quarantined_ids == poison
            assert ex.runner.effective_quota == ex.runner.n - len(poison)
        else:
            assert audit.eta_identity == 0.0  # Theorem 1 under sharding

    def test_window_stats_aggregate_across_hosts(self):
        records = make_records(60, seed=5)
        ref = StreamExecutor(records, POLICY, 4, small_cfg(), seed=7)
        drain(ref)
        ex = StreamExecutor(
            records, POLICY, 4, small_cfg(), seed=7, num_hosts=2
        )
        drain(ex)
        a, b = ref.window_stats(), ex.window_stats()
        assert (a.realized, a.delivered, a.quarantined) == (
            b.realized, b.delivered, b.quarantined
        )


# -----------------------------------------------------------------------------
# Payload fold: the gather carries window state every round
# -----------------------------------------------------------------------------


class TestPayloadFold:
    def test_gather_payload_carries_window_summary(self):
        records = make_records(60, seed=5)
        ex = StreamExecutor(
            records, POLICY, 4, small_cfg(), seed=7, num_hosts=2
        )
        assert ex.step() is not None  # engine built lazily on first step
        engine = ex.runner.engine
        seen: list[list[dict]] = []
        inner = engine.collective.gather_round

        def spy(payload_fn, *, tag="primary"):
            out = inner(payload_fn, tag=tag)
            if tag == "primary":
                seen.append(out)
            return out

        engine.collective.gather_round = spy
        # Later steps may drain pre-aligned ready queues without a new
        # round; drive until the spied collective sees one.
        while not seen and ex.step() is not None:
            pass
        assert seen
        for payloads in seen:
            assert len(payloads) == 4
            for rank, p in enumerate(payloads):
                window = p["window"]
                assert window["host"] == (0 if rank < 2 else 1)
                for key in (
                    "cursor", "staged", "delivered", "resident",
                    "quarantined_ids",
                ):
                    assert key in window


# -----------------------------------------------------------------------------
# Elastic resume: checkpoint at P hosts, resume at P' hosts
# -----------------------------------------------------------------------------


class TestResumeAcrossHostCounts:
    @pytest.mark.parametrize("resume_hosts", [1, 2, 4])
    def test_bit_identical_tail(self, resume_hosts):
        records = make_records(64, seed=9)
        cfg = small_cfg()
        ref = drain(
            StreamExecutor(records, POLICY, 4, cfg, seed=4, lookahead=24)
        )
        ex = StreamExecutor(
            records, POLICY, 4, cfg, seed=4, lookahead=24, num_hosts=2
        )
        cut = max(2, len(ref) // 3)
        head = [ex.step() for _ in range(cut)]
        ck = ex.checkpoint()
        assert ck.payload["version"] == 4
        assert ck.payload["num_hosts"] == 2
        resumed = StreamExecutor.resume(
            StreamCheckpoint.from_json(ck.to_json()),
            records,
            POLICY,
            num_hosts=resume_hosts,
        )
        assert resumed.num_hosts == resume_hosts
        tail = drain(resumed)
        assert stream_digest(head + tail) == stream_digest(ref)
        assert resumed.audit().eta_identity == 0.0

    @pytest.mark.parametrize("resume_hosts", [1, 2, 4, 6])
    def test_bit_identical_tail_uneven_world(self, resume_hosts):
        """W=6 over uneven host counts (P=4 leaves two singleton blocks):
        the v4 per-rank checkpoint schema repartitions onto ANY host count
        in [1, W], divisor or not."""
        records = make_records(72, seed=11)
        cfg = small_cfg()
        ref = drain(
            StreamExecutor(records, POLICY, 6, cfg, seed=4, lookahead=18)
        )
        ex = StreamExecutor(
            records, POLICY, 6, cfg, seed=4, lookahead=18, num_hosts=4
        )
        cut = max(2, len(ref) // 3)
        head = [ex.step() for _ in range(cut)]
        resumed = StreamExecutor.resume(
            StreamCheckpoint.from_json(ex.checkpoint().to_json()),
            records,
            POLICY,
            num_hosts=resume_hosts,
        )
        assert resumed.num_hosts == resume_hosts
        tail = drain(resumed)
        assert stream_digest(head + tail) == stream_digest(ref)
        assert resumed.audit().eta_identity == 0.0

    def test_resume_defaults_to_checkpointed_host_count(self):
        records = make_records(40, seed=9)
        ex = StreamExecutor(
            records, POLICY, 4, small_cfg(), seed=4, num_hosts=4
        )
        ex.step()
        resumed = StreamExecutor.resume(ex.checkpoint(), records, POLICY)
        assert resumed.num_hosts == 4

    def test_mid_quarantine_resume_keeps_merged_x(self):
        """Checkpoint after a quarantine at P=2, resume at P=1: the
        component-X accounting (and the non-join effective quota) must
        survive the repartition."""
        records = make_records(60, seed=1)
        cfg = small_cfg(join_mode=False, max_quarantine=2)
        poison = {records[7].identity}
        with poison_samples(poison):
            ref = drain(
                StreamExecutor(records, POLICY, 4, cfg, seed=2)
            )
            ex = StreamExecutor(
                records, POLICY, 4, cfg, seed=2, num_hosts=2
            )
            head = []
            while ex.runner.quarantined_views == 0:
                head.append(ex.step())
            resumed = StreamExecutor.resume(
                ex.checkpoint(), records, POLICY, num_hosts=1
            )
            assert resumed.runner.quarantined_ids == poison
            tail = drain(resumed)
        assert stream_digest(head + tail) == stream_digest(ref)
        assert resumed.runner.effective_quota == resumed.runner.n - 1


# -----------------------------------------------------------------------------
# Simulated multi-host device lane (XLA host-platform devices)
# -----------------------------------------------------------------------------


MULTIHOST_SCRIPT = textwrap.dedent(
    """
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    W, HOSTS = 4, 2
    assert jax.device_count() == W, (
        f"host platform exposed {jax.device_count()} devices, want {W}")

    from repro.chaos import stream_digest
    from repro.core import OdbConfig
    from repro.core.comm import ResilientCollective
    from repro.core.layout import make_layout
    from repro.data.pipeline import PipelinePolicy, RawRecord
    from repro.launch.mesh import dp_axes, make_sim_multihost_mesh
    from repro.launch.sharding import batch_specs
    from repro.stream import StreamExecutor

    records = [
        RawRecord(identity=i, chars=int(40 + (i * 977) % 2600), turns=1 + i % 3)
        for i in range(96)
    ]
    policy = PipelinePolicy()
    # round_deadline_s routes every gather through ResilientCollective, so
    # the sharded lane runs under PR-8's deadline/retry semantics.
    cfg = OdbConfig(
        l_max=1024, buffer_size=16, prefetch_factor=8, num_workers=2,
        round_deadline_s=30.0,
    )
    layout = make_layout("packed", vocab_size=512)
    mesh = make_sim_multihost_mesh(HOSTS)  # ("host": 2, "data": 2, "model": 1)
    assert dp_axes(mesh) == ("host", "data")

    ref = StreamExecutor(records, policy, W, cfg, seed=3, lookahead=32)
    ref_steps = list(ref.steps())

    ex = StreamExecutor(
        records, policy, W, cfg, seed=3, lookahead=32, num_hosts=HOSTS
    )
    steps = []
    resilient_seen = False
    sum_jit = jax.jit(lambda x: x.sum())
    while True:
        step = ex.step()
        if step is None:
            break
        if ex.runner.engine is not None:
            resilient_seen = resilient_seen or isinstance(
                ex.runner.engine.collective, ResilientCollective
            )
        steps.append(step)
        batches = layout.build_step(step)
        shapes = {b.tokens.shape for b in batches}
        assert len(shapes) == 1, f"ranks disagree on step shape: {shapes}"
        global_tokens = jnp.asarray(
            np.concatenate([b.tokens for b in batches], 0)
        )
        spec = batch_specs({"tokens": global_tokens}, mesh)["tokens"]
        sharded = jax.device_put(global_tokens, NamedSharding(mesh, spec))
        assert len(sharded.sharding.device_set) == W
        assert int(sum_jit(sharded)) == int(global_tokens.sum())
    assert resilient_seen, "gathers never routed through ResilientCollective"
    assert stream_digest(steps) == stream_digest(ref_steps)
    assert ex.audit().eta_identity == 0.0
    print("MULTIHOST-OK", len(steps), "steps x", HOSTS, "hosts")
    """
)


def test_multihost_simulated_devices():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=4 "
        + env.get("XLA_FLAGS", "")
    ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [str(REPO / "src"), env.get("PYTHONPATH", "")]
    ).rstrip(os.pathsep)
    proc = subprocess.run(
        [sys.executable, "-c", MULTIHOST_SCRIPT],
        env=env,
        cwd=REPO,
        capture_output=True,
        text=True,
        timeout=540,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "MULTIHOST-OK" in proc.stdout
