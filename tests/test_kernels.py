"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import segment_flash_attention
from repro.kernels.ops import flash_attention, ssd_chunked_scan
from repro.kernels.ref import segment_flash_attention_ref, ssd_scan_ref
from repro.kernels.ssd_scan import ssd_scan


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


def make_qkv(key, b, s, h, kv, d, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, kv, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, kv, d)).astype(dtype)
    return q, k, v


def make_segments(key, b, s, max_segs=4):
    """Random packed layout with a padding tail."""
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 1 << 30)))
    seg = np.zeros((b, s), np.int32)
    for i in range(b):
        cuts = sorted(rng.choice(np.arange(8, s - 8), size=max_segs - 1, replace=False))
        bounds = [0] + list(cuts) + [s - rng.integers(0, s // 8)]
        for j in range(len(bounds) - 1):
            if bounds[j + 1] > bounds[j]:
                seg[i, bounds[j] : bounds[j + 1]] = j + 1
    return jnp.asarray(seg)


SHAPE_SWEEP = [
    # (B, S, H, KV, D, block_q, block_kv)
    (1, 128, 1, 1, 64, 64, 64),
    (2, 256, 4, 2, 64, 128, 64),
    (1, 512, 8, 8, 32, 128, 128),  # MHA
    (2, 256, 8, 1, 64, 64, 128),  # MQA
    (1, 384, 6, 2, 128, 128, 128),  # non-pow2 length multiple
]


class TestFlashAttention:
    @pytest.mark.parametrize("shape", SHAPE_SWEEP)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("causal", [True, False])
    def test_vs_ref(self, shape, dtype, causal):
        b, s, h, kv, d, bq, bk = shape
        q, k, v = make_qkv(jax.random.PRNGKey(0), b, s, h, kv, d, dtype)
        out = segment_flash_attention(
            q, k, v, None, causal=causal, block_q=bq, block_kv=bk, interpret=True
        )
        ref = segment_flash_attention_ref(q, k, v, None, causal=causal)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
        )

    @pytest.mark.parametrize("shape", SHAPE_SWEEP[:3])
    @pytest.mark.parametrize("causal", [True, False])
    def test_segments_vs_ref(self, shape, causal):
        b, s, h, kv, d, bq, bk = shape
        q, k, v = make_qkv(jax.random.PRNGKey(1), b, s, h, kv, d, jnp.float32)
        seg = make_segments(jax.random.PRNGKey(2), b, s)
        out = segment_flash_attention(
            q, k, v, seg, causal=causal, block_q=bq, block_kv=bk, interpret=True
        )
        ref = segment_flash_attention_ref(q, k, v, seg, causal=causal)
        valid = np.asarray(seg > 0)[:, :, None, None]
        np.testing.assert_allclose(
            np.where(valid, np.asarray(out), 0.0),
            np.where(valid, np.asarray(ref), 0.0),
            atol=3e-5, rtol=3e-5,
        )

    def test_no_cross_segment_contamination(self):
        """Changing tokens of segment 2 must not change segment 1 outputs."""
        b, s, h, kv, d = 1, 128, 2, 2, 32
        q, k, v = make_qkv(jax.random.PRNGKey(3), b, s, h, kv, d, jnp.float32)
        seg = jnp.asarray(np.repeat([[1] * 64 + [2] * 64], b, axis=0), jnp.int32)
        out1 = segment_flash_attention(q, k, v, seg, interpret=True, block_q=64, block_kv=64)
        k2 = k.at[:, 64:].set(jax.random.normal(jax.random.PRNGKey(9), (b, 64, kv, d)))
        v2 = v.at[:, 64:].set(jax.random.normal(jax.random.PRNGKey(10), (b, 64, kv, d)))
        out2 = segment_flash_attention(q, k2, v2, seg, interpret=True, block_q=64, block_kv=64)
        np.testing.assert_allclose(
            np.asarray(out1[:, :64]), np.asarray(out2[:, :64]), atol=1e-6
        )

    def test_custom_vjp_grads(self):
        b, s, h, kv, d = 1, 128, 2, 1, 32
        q, k, v = make_qkv(jax.random.PRNGKey(4), b, s, h, kv, d, jnp.float32)

        def f(q, k, v):
            return jnp.sum(flash_attention(q, k, v) ** 2)

        def f_ref(q, k, v):
            return jnp.sum(segment_flash_attention_ref(q, k, v) ** 2)

        g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b_ in zip(g, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-4, rtol=1e-4)


SSD_SWEEP = [
    # (B, S, H, P, N, chunk)
    (1, 64, 1, 8, 16, 16),
    (2, 128, 3, 8, 16, 32),
    (1, 256, 2, 16, 32, 64),
    (2, 96, 4, 8, 8, 32),  # ragged chunk boundary (96 % 32 == 0)
]


class TestSSDScan:
    @pytest.mark.parametrize("shape", SSD_SWEEP)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_vs_sequential_ref(self, shape, dtype):
        b, s, h, p, n, chunk = shape
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        x = (jax.random.normal(ks[0], (b, s, h, p)) * 0.5).astype(dtype)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))).astype(jnp.float32)
        a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
        bp = (jax.random.normal(ks[3], (b, s, n)) * 0.4).astype(dtype)
        cp = (jax.random.normal(ks[4], (b, s, n)) * 0.4).astype(dtype)
        y = ssd_scan(
            x.astype(jnp.float32), a[None, None, :] * dt, dt,
            bp.astype(jnp.float32), cp.astype(jnp.float32),
            chunk=chunk, interpret=True,
        )
        y_ref, _ = ssd_scan_ref(
            x.astype(jnp.float32), dt, a,
            bp.astype(jnp.float32), cp.astype(jnp.float32),
        )
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(y_ref), atol=1e-4, rtol=1e-3
        )

    def test_ops_wrapper(self):
        b, s, h, p, n = 1, 64, 2, 8, 16
        ks = jax.random.split(jax.random.PRNGKey(1), 5)
        x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.2)
        bp = jax.random.normal(ks[3], (b, s, n)) * 0.4
        cp = jax.random.normal(ks[4], (b, s, n)) * 0.4
        y = ssd_chunked_scan(x, dt, a, bp, cp, chunk=32)
        y_ref, _ = ssd_scan_ref(x, dt, a, bp, cp)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4, rtol=1e-3)

    def test_model_ssd_matches_kernel(self):
        """models.ssm chunked impl and the kernel agree (same math)."""
        from repro.models.ssm import ssd_chunked
        b, s, h, p, n = 2, 128, 3, 8, 16
        ks = jax.random.split(jax.random.PRNGKey(2), 5)
        x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.2)
        bp = jax.random.normal(ks[3], (b, s, n)) * 0.4
        cp = jax.random.normal(ks[4], (b, s, n)) * 0.4
        y_model, _ = ssd_chunked(x, dt, a, bp, cp, chunk=32)
        y_kernel = ssd_chunked_scan(x, dt, a, bp, cp, chunk=32)
        np.testing.assert_allclose(
            np.asarray(y_model), np.asarray(y_kernel), atol=1e-4, rtol=1e-3
        )
