"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps (interpret mode).

The flash-attention section also proves the *training* contract
(DESIGN.md §11): the custom-vjp backward runs the dedicated Pallas dq/dkv
kernels (never the jnp reference), and the kernel route through
``models/attention`` matches the XLA blockwise path — loss and gradients —
on packed batches with GQA, segments, and fully-masked padding rows (the
l == 0 denominator)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import (
    live_tile_counts,
    resolve_blocks,
    segment_flash_attention,
    segment_flash_attention_bwd,
    select_block,
)
from repro.kernels.ops import flash_attention, ssd_chunked_scan
from repro.kernels.ref import segment_flash_attention_ref, ssd_scan_ref
from repro.kernels.ssd_scan import ssd_scan


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


def make_qkv(key, b, s, h, kv, d, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, kv, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, kv, d)).astype(dtype)
    return q, k, v


def make_segments(key, b, s, max_segs=4):
    """Random packed layout with a padding tail."""
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 1 << 30)))
    seg = np.zeros((b, s), np.int32)
    for i in range(b):
        cuts = sorted(rng.choice(np.arange(8, s - 8), size=max_segs - 1, replace=False))
        bounds = [0] + list(cuts) + [s - rng.integers(0, s // 8)]
        for j in range(len(bounds) - 1):
            if bounds[j + 1] > bounds[j]:
                seg[i, bounds[j] : bounds[j + 1]] = j + 1
    return jnp.asarray(seg)


SHAPE_SWEEP = [
    # (B, S, H, KV, D, block_q, block_kv)
    (1, 128, 1, 1, 64, 64, 64),
    (2, 256, 4, 2, 64, 128, 64),
    (1, 512, 8, 8, 32, 128, 128),  # MHA
    (2, 256, 8, 1, 64, 64, 128),  # MQA
    (1, 384, 6, 2, 128, 128, 128),  # non-pow2 length multiple
]


class TestFlashAttention:
    @pytest.mark.parametrize("shape", SHAPE_SWEEP)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("causal", [True, False])
    def test_vs_ref(self, shape, dtype, causal):
        b, s, h, kv, d, bq, bk = shape
        q, k, v = make_qkv(jax.random.PRNGKey(0), b, s, h, kv, d, dtype)
        out = segment_flash_attention(
            q, k, v, None, causal=causal, block_q=bq, block_kv=bk, interpret=True
        )
        ref = segment_flash_attention_ref(q, k, v, None, causal=causal)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
        )

    @pytest.mark.parametrize("shape", SHAPE_SWEEP[:3])
    @pytest.mark.parametrize("causal", [True, False])
    def test_segments_vs_ref(self, shape, causal):
        b, s, h, kv, d, bq, bk = shape
        q, k, v = make_qkv(jax.random.PRNGKey(1), b, s, h, kv, d, jnp.float32)
        seg = make_segments(jax.random.PRNGKey(2), b, s)
        out = segment_flash_attention(
            q, k, v, seg, causal=causal, block_q=bq, block_kv=bk, interpret=True
        )
        ref = segment_flash_attention_ref(q, k, v, seg, causal=causal)
        valid = np.asarray(seg > 0)[:, :, None, None]
        np.testing.assert_allclose(
            np.where(valid, np.asarray(out), 0.0),
            np.where(valid, np.asarray(ref), 0.0),
            atol=3e-5, rtol=3e-5,
        )

    def test_no_cross_segment_contamination(self):
        """Changing tokens of segment 2 must not change segment 1 outputs."""
        b, s, h, kv, d = 1, 128, 2, 2, 32
        q, k, v = make_qkv(jax.random.PRNGKey(3), b, s, h, kv, d, jnp.float32)
        seg = jnp.asarray(np.repeat([[1] * 64 + [2] * 64], b, axis=0), jnp.int32)
        out1 = segment_flash_attention(q, k, v, seg, interpret=True, block_q=64, block_kv=64)
        k2 = k.at[:, 64:].set(jax.random.normal(jax.random.PRNGKey(9), (b, 64, kv, d)))
        v2 = v.at[:, 64:].set(jax.random.normal(jax.random.PRNGKey(10), (b, 64, kv, d)))
        out2 = segment_flash_attention(q, k2, v2, seg, interpret=True, block_q=64, block_kv=64)
        np.testing.assert_allclose(
            np.asarray(out1[:, :64]), np.asarray(out2[:, :64]), atol=1e-6
        )

    def test_custom_vjp_grads(self):
        b, s, h, kv, d = 1, 128, 2, 1, 32
        q, k, v = make_qkv(jax.random.PRNGKey(4), b, s, h, kv, d, jnp.float32)

        def f(q, k, v):
            return jnp.sum(flash_attention(q, k, v) ** 2)

        def f_ref(q, k, v):
            return jnp.sum(segment_flash_attention_ref(q, k, v) ** 2)

        g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b_ in zip(g, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-4, rtol=1e-4)


def packed_test_segments(b: int, s: int):
    """Packed rows exercising every backward edge: multiple segments per
    row, a padding tail, and one fully-masked row (l == 0 everywhere)."""
    seg = np.zeros((b, s), np.int32)
    bounds = [0, int(s * 0.3), int(s * 0.55), int(s * 0.9)]
    for i in range(b - 1):
        for j in range(len(bounds) - 1):
            seg[i, bounds[j] : bounds[j + 1]] = j + 1
    # last row stays all-zero: an IDLE / all-padding row
    return jnp.asarray(seg)


class TestFlashBackward:
    """Pallas dq/dkv kernels vs the jnp oracle — the training contract."""

    def _masked_losses(self, seg):
        valid = (np.asarray(seg) > 0)[:, :, None, None].astype(np.float32)
        vm = jnp.asarray(valid)

        def loss_flash(q, k, v, *, bq=64, bk=64):
            out = flash_attention(q, k, v, seg, True, bq, bk)
            return jnp.sum((out.astype(jnp.float32) * vm) ** 2)

        def loss_ref(q, k, v):
            out = segment_flash_attention_ref(q, k, v, seg)
            return jnp.sum((out.astype(jnp.float32) * vm) ** 2)

        return loss_flash, loss_ref

    @pytest.mark.parametrize("shape", [(2, 256, 4, 2, 32), (2, 128, 8, 1, 64)])
    def test_segment_grads_vs_ref(self, shape):
        """GQA + segments + an all-padding row (l == 0 denominator)."""
        b, s, h, kv, d = shape
        q, k, v = make_qkv(jax.random.PRNGKey(7), b, s, h, kv, d, jnp.float32)
        seg = packed_test_segments(b, s)
        loss_flash, loss_ref = self._masked_losses(seg)
        g = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b_ in zip(g, gr):
            assert np.all(np.isfinite(np.asarray(a)))
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b_), atol=2e-4, rtol=2e-4
            )

    def test_bwd_never_recomputes_through_jnp_reference(self, monkeypatch):
        """The training backward must run the Pallas kernels, not ref.py."""
        from repro.kernels import ref as ref_mod

        def boom(*a, **kw):  # pragma: no cover - failure path
            raise AssertionError("jnp reference called inside the backward")

        monkeypatch.setattr(ref_mod, "segment_flash_attention_ref", boom)
        b, s, h, kv, d = 1, 128, 2, 1, 32
        q, k, v = make_qkv(jax.random.PRNGKey(8), b, s, h, kv, d, jnp.float32)
        grads = jax.grad(
            lambda *a: jnp.sum(flash_attention(*a) ** 2), argnums=(0, 1, 2)
        )(q, k, v)
        assert all(np.all(np.isfinite(np.asarray(g))) for g in grads)

    def test_bwd_entry_point_direct(self):
        """segment_flash_attention_bwd == vjp of the oracle (fp32, mixed
        block shapes for the two passes)."""
        b, s, h, kv, d = 1, 256, 4, 4, 32
        q, k, v = make_qkv(jax.random.PRNGKey(9), b, s, h, kv, d, jnp.float32)
        out, lse = segment_flash_attention(
            q, k, v, None, interpret=True, return_residuals=True
        )
        g = jax.random.normal(jax.random.PRNGKey(10), out.shape)
        dq, dk, dv = segment_flash_attention_bwd(
            q, k, v, None, out, lse, g,
            block_q=128, block_kv=64, interpret=True,
        )
        _, vjp = jax.vjp(lambda *a: segment_flash_attention_ref(*a), q, k, v)
        rq, rk, rv = vjp(g)
        np.testing.assert_allclose(np.asarray(dq), np.asarray(rq), atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(dk), np.asarray(rk), atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(dv), np.asarray(rv), atol=1e-4, rtol=1e-4)

    def test_ragged_sequence_blocks(self):
        """Satellite: no s % block assert — ragged S drops to the largest
        dividing block and still matches the oracle fwd + bwd."""
        assert select_block(384, 128) == 128
        assert select_block(200, 128) == 40  # sublane-aligned beats 100
        assert select_block(96, 128) == 96
        assert select_block(101, 128) == 101  # prime: any divisor fallback
        b, s, h, kv, d = 1, 200, 2, 2, 32
        q, k, v = make_qkv(jax.random.PRNGKey(11), b, s, h, kv, d, jnp.float32)
        out = segment_flash_attention(q, k, v, None, interpret=True)
        ref = segment_flash_attention_ref(q, k, v, None)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)
        g = jax.grad(lambda *a: jnp.sum(flash_attention(*a) ** 2), argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(
            lambda *a: jnp.sum(segment_flash_attention_ref(*a) ** 2), argnums=(0, 1, 2)
        )(q, k, v)
        for a, b_ in zip(g, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-4, rtol=1e-4)

    def test_block_skip_is_lossless(self):
        """Rows built so whole (q, kv) tile pairs are segment-disjoint: the
        skip must change the tile census, not the numbers."""
        b, s, h, kv, d = 1, 256, 2, 2, 32
        q, k, v = make_qkv(jax.random.PRNGKey(12), b, s, h, kv, d, jnp.float32)
        # segment ids aligned to 64-blocks: blocks 0..3 hold segs 1,2,3,pad
        seg = np.zeros((b, s), np.int32)
        seg[:, 0:64] = 1
        seg[:, 64:128] = 2
        seg[:, 128:192] = 3
        segj = jnp.asarray(seg)
        census = live_tile_counts(seg, s, 64, 64)
        assert census["segment_live"] < census["causal_live"]
        out = segment_flash_attention(q, k, v, segj, interpret=True, block_q=64, block_kv=64)
        ref = segment_flash_attention_ref(q, k, v, segj)
        valid = (seg > 0)[:, :, None, None]
        np.testing.assert_allclose(
            np.where(valid, np.asarray(out), 0.0),
            np.where(valid, np.asarray(ref), 0.0),
            atol=3e-5, rtol=3e-5,
        )


class TestKernelRouting:
    """models/attention routing: flash vs XLA blockwise parity end to end."""

    def _packed_batch(self, vocab=512, b=2, s=256):
        from repro.models.model import shift_labels

        rng = np.random.default_rng(0)
        tokens = np.zeros((b, s), np.int32)
        seg = np.zeros((b, s), np.int32)
        pos = np.zeros((b, s), np.int32)
        mask = np.zeros((b, s), np.float32)
        bounds = [(0, 100), (100, 230)]  # two packed samples + pad tail
        for sid, (a, e) in enumerate(bounds, start=1):
            tokens[0, a:e] = rng.integers(1, vocab, e - a)
            seg[0, a:e] = sid
            pos[0, a:e] = np.arange(e - a)
            mask[0, a:e] = 1.0
        # row 1 stays fully padding (IDLE row: the l == 0 path in training)
        batch = {
            "tokens": jnp.asarray(tokens),
            "positions": jnp.asarray(pos),
            "segments": jnp.asarray(seg),
        }
        labels, m = shift_labels(
            batch["tokens"], jnp.asarray(mask), segments=batch["segments"]
        )
        batch["labels"], batch["loss_mask"] = labels, m
        return batch

    def test_lm_loss_and_grads_match_xla_path(self):
        """Acceptance: Pallas-path loss AND gradients == XLA blockwise path
        on packed aligned groups (interpret mode on CPU)."""
        from repro.configs import get_smoke_config
        from repro.models import LM

        cfg = dataclasses.replace(get_smoke_config("qwen3_0_6b"), vocab_size=512)
        batch = self._packed_batch()
        results = {}
        for impl in ("xla", "flash"):
            model = LM(dataclasses.replace(cfg, attn_impl=impl))
            params = model.init(jax.random.PRNGKey(0))

            def loss(p):
                ls, t = model.loss_sums(p, batch)
                return ls / jnp.maximum(t, 1.0)

            results[impl] = jax.value_and_grad(loss)(params)
        loss_x, grads_x = results["xla"]
        loss_f, grads_f = results["flash"]
        np.testing.assert_allclose(float(loss_x), float(loss_f), rtol=1e-6)
        for gx, gf in zip(
            jax.tree_util.tree_leaves(grads_x), jax.tree_util.tree_leaves(grads_f)
        ):
            np.testing.assert_allclose(
                np.asarray(gx, np.float32), np.asarray(gf, np.float32),
                atol=5e-6, rtol=5e-4,
            )

    def test_resolve_attn_impl_matrix(self):
        from repro.configs import get_smoke_config
        from repro.train.trainer import resolve_attn_impl

        cfg = get_smoke_config("qwen3_0_6b")
        assert cfg.attn_impl == "auto"
        # auto: flash only for packed layouts on a Pallas-compiling backend
        assert resolve_attn_impl(cfg, packed=True, backend="tpu") == "flash"
        assert resolve_attn_impl(cfg, packed=False, backend="tpu") == "xla"
        assert resolve_attn_impl(cfg, packed=True, backend="cpu") == "xla"
        # explicit pins win regardless of layout/backend
        pinned = dataclasses.replace(cfg, attn_impl="flash")
        assert resolve_attn_impl(pinned, packed=False, backend="cpu") == "flash"
        # MLA never routes to the kernel
        mla = get_smoke_config("deepseek_v3_671b")
        assert mla.attn_kind == "mla"
        assert resolve_attn_impl(mla, packed=True, backend="tpu") == "xla"

    def test_flash_pin_rejected_for_mla(self):
        from repro.configs import get_smoke_config
        from repro.models import LM

        mla = dataclasses.replace(
            get_smoke_config("deepseek_v3_671b"), attn_impl="flash"
        )
        with pytest.raises(ValueError, match="flash"):
            LM(mla)

    def test_autotune_blocks_cached_and_valid(self, tmp_path):
        from repro.kernels.autotune import autotune_blocks, candidate_blocks

        cache = tmp_path / "attn_blocks.json"
        picked = autotune_blocks(
            1, 128, 2, 1, 32, has_segments=True, repeats=1, cache_path=cache,
        )
        assert picked in candidate_blocks(128)
        assert 128 % picked[0] == 0 and 128 % picked[1] == 0
        assert cache.exists()
        # second call is a pure cache hit (same pick, no new probe)
        again = autotune_blocks(
            1, 128, 2, 1, 32, has_segments=True, repeats=1, cache_path=cache,
        )
        assert again == picked


class TestPrunedGrid:
    """Scalar-prefetch grid (DESIGN.md §17): DMA-level pruning must change
    the fetch census, never the numbers — bit-exact vs the dense grid."""

    def _packed(self, key, b=2, s=256, h=4, kv=2, d=32):
        q, k, v = make_qkv(key, b, s, h, kv, d, jnp.float32)
        seg = packed_test_segments(b, s)  # GQA + pad tail + all-padding row
        return q, k, v, seg

    def test_liveness_tables_match_tile_census(self):
        from repro.kernels.liveness import build_liveness_tables

        seg = packed_test_segments(3, 256)
        census = live_tile_counts(np.asarray(seg), 256, 64, 64)
        tables = build_liveness_tables(seg, block_q=64, block_kv=64)
        assert int(jnp.sum(tables.kv_count)) == census["segment_live"]
        assert int(jnp.sum(tables.q_count)) == census["segment_live"]
        # Row index lists live blocks ascending, clamped past the count.
        kv_idx = np.asarray(tables.kv_idx)
        kv_cnt = np.asarray(tables.kv_count)
        for ib in range(kv_idx.shape[0]):
            for qb in range(kv_idx.shape[1]):
                cnt = int(kv_cnt[ib, qb])
                row = kv_idx[ib, qb]
                assert list(row[:cnt]) == sorted(set(row[:cnt]))
                if cnt:
                    assert np.all(row[cnt:] == row[cnt - 1])
                else:
                    assert np.all(row == 0)

    @pytest.mark.parametrize("blocks", [(64, 64), (128, 32), (128, 128)])
    def test_pruned_fwd_bitexact(self, blocks):
        bq, bk = blocks
        q, k, v, seg = self._packed(jax.random.PRNGKey(20))
        dense = flash_attention(q, k, v, seg, True, bq, bk, grid="dense")
        pruned = flash_attention(q, k, v, seg, True, bq, bk, grid="pruned")
        assert np.array_equal(np.asarray(dense), np.asarray(pruned))

    def test_pruned_fwd_bitexact_ragged_blocks(self):
        """S=200 resolves to block 40: pruning survives ragged grids."""
        q, k, v = make_qkv(jax.random.PRNGKey(21), 1, 200, 2, 2, 32, jnp.float32)
        seg = np.zeros((1, 200), np.int32)
        seg[0, :90] = 1
        seg[0, 90:170] = 2  # 30-token padding tail
        seg = jnp.asarray(seg)
        dense = flash_attention(q, k, v, seg, grid="dense")
        pruned = flash_attention(q, k, v, seg, grid="pruned")
        assert np.array_equal(np.asarray(dense), np.asarray(pruned))

    def test_pruned_grads_bitexact(self):
        q, k, v, seg = self._packed(jax.random.PRNGKey(22))
        valid = jnp.asarray((np.asarray(seg) > 0)[:, :, None, None], jnp.float32)

        def loss(grid):
            def f(q, k, v):
                out = flash_attention(q, k, v, seg, True, 64, 64, grid=grid)
                return jnp.sum((out.astype(jnp.float32) * valid) ** 2)

            return f

        gd = jax.grad(loss("dense"), argnums=(0, 1, 2))(q, k, v)
        gp = jax.grad(loss("pruned"), argnums=(0, 1, 2))(q, k, v)
        for a, b_ in zip(gd, gp):
            assert np.all(np.isfinite(np.asarray(b_)))
            assert np.array_equal(np.asarray(a), np.asarray(b_))

    def test_bwd_pruned_entry_direct(self):
        from repro.kernels.flash_attention import (
            segment_flash_attention_bwd_pruned,
            segment_flash_attention_pruned,
        )

        q, k, v, seg = self._packed(jax.random.PRNGKey(23))
        out, lse = segment_flash_attention_pruned(
            q, k, v, seg, interpret=True, return_residuals=True,
            block_q=64, block_kv=64,
        )
        ref_out, ref_lse = segment_flash_attention(
            q, k, v, seg, interpret=True, return_residuals=True,
            block_q=64, block_kv=64,
        )
        assert np.array_equal(np.asarray(out), np.asarray(ref_out))
        assert np.array_equal(np.asarray(lse), np.asarray(ref_lse))
        g = jax.random.normal(jax.random.PRNGKey(24), out.shape)
        pruned = segment_flash_attention_bwd_pruned(
            q, k, v, seg, out, lse, g, block_q=64, block_kv=64, interpret=True
        )
        dense = segment_flash_attention_bwd(
            q, k, v, seg, out, lse, g, block_q=64, block_kv=64, interpret=True
        )
        for a, b_ in zip(dense, pruned):
            assert np.array_equal(np.asarray(a), np.asarray(b_))

    def test_resolve_grid_matrix(self):
        from repro.kernels.ops import resolve_grid

        seg = jnp.ones((1, 8), jnp.int32)
        assert resolve_grid("pruned", None) == "dense"  # nothing to prune
        assert resolve_grid("dense", seg) == "dense"
        assert resolve_grid("pruned", seg) == "pruned"
        assert resolve_grid(None, None) == "dense"
        expected = "pruned" if jax.default_backend() == "tpu" else "dense"
        assert resolve_grid("auto", seg) == expected
        with pytest.raises(ValueError, match="grid"):
            resolve_grid("sparse", seg)

    def test_no_segments_degrades_to_dense(self):
        q, k, v = make_qkv(jax.random.PRNGKey(25), 1, 128, 2, 2, 32, jnp.float32)
        a = flash_attention(q, k, v, None, grid="pruned")
        b_ = flash_attention(q, k, v, None, grid="dense")
        assert np.array_equal(np.asarray(a), np.asarray(b_))

    def test_fetch_census_pruned_below_dense(self):
        from repro.kernels.liveness import fetched_tile_counts

        seg = packed_test_segments(3, 256)
        census = fetched_tile_counts(
            np.asarray(seg), 256, 64, 64, heads=4, kv_heads=2, head_dim=32
        )
        assert census["pruned_fetches"] < census["dense_fetches"]
        assert census["pruned_fetched_fraction"] < census["dense_fetched_fraction"]
        assert census["live_tiles"] <= census["pruned_fetches"]
        assert census["dense_fetches"] * census["kv_tile_bytes"] == (
            census["dense_fetched_bytes"]
        )

    def test_resolved_blocks_pinned_and_asserted(self):
        """select_block is not idempotent on raw requests; expect_resolved
        catches any pass fed an unresolved pair."""
        assert select_block(120, 15) == 8  # the non-idempotence witness
        bq, bk = 15, 15
        r = resolve_blocks(120, bq, bk)
        assert resolve_blocks(120, *r) == r  # fixed point after one pass
        q, k, v, seg = self._packed(jax.random.PRNGKey(26), s=120)
        with pytest.raises(AssertionError, match="not resolved"):
            segment_flash_attention(
                q, k, v, seg, block_q=15, block_kv=15,
                interpret=True, expect_resolved=True,
            )

    def test_autotune_rekeyed_by_grid(self, tmp_path):
        from repro.kernels.autotune import autotune_blocks, shape_key

        assert shape_key(1, 128, 2, 1, 32, has_segments=True) != shape_key(
            1, 128, 2, 1, 32, has_segments=True, grid="pruned"
        )
        cache = tmp_path / "attn_blocks.json"
        a = autotune_blocks(
            1, 128, 2, 1, 32, has_segments=True, repeats=1,
            cache_path=cache, grid="dense",
        )
        b_ = autotune_blocks(
            1, 128, 2, 1, 32, has_segments=True, repeats=1,
            cache_path=cache, grid="pruned",
        )
        import json

        entries = json.loads(cache.read_text())
        keys = set(entries)
        assert any("grid.dense" in key for key in keys)
        assert any("grid.pruned" in key for key in keys)
        assert 128 % a[0] == 0 and 128 % b_[1] == 0

    def test_sharded_compile_cell(self):
        """validate_flash_sharded on the host mesh: both grid variants
        lower + compile under shard_map (the §17 dry-run contract)."""
        from repro.launch.flash_dryrun import validate_flash_sharded
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh()
        for grid in ("dense", "pruned"):
            rec = validate_flash_sharded(
                mesh, grid, rows_per_shard=1, seq=128, heads=2, kv_heads=1,
                head_dim=32, block_q=64, block_kv=64,
            )
            assert rec["status"] == "ok", rec.get("traceback")
            assert rec["compile_s"] > 0


SSD_SWEEP = [
    # (B, S, H, P, N, chunk)
    (1, 64, 1, 8, 16, 16),
    (2, 128, 3, 8, 16, 32),
    (1, 256, 2, 16, 32, 64),
    (2, 96, 4, 8, 8, 32),  # ragged chunk boundary (96 % 32 == 0)
]


class TestSSDScan:
    @pytest.mark.parametrize("shape", SSD_SWEEP)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_vs_sequential_ref(self, shape, dtype):
        b, s, h, p, n, chunk = shape
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        x = (jax.random.normal(ks[0], (b, s, h, p)) * 0.5).astype(dtype)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))).astype(jnp.float32)
        a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
        bp = (jax.random.normal(ks[3], (b, s, n)) * 0.4).astype(dtype)
        cp = (jax.random.normal(ks[4], (b, s, n)) * 0.4).astype(dtype)
        y = ssd_scan(
            x.astype(jnp.float32), a[None, None, :] * dt, dt,
            bp.astype(jnp.float32), cp.astype(jnp.float32),
            chunk=chunk, interpret=True,
        )
        y_ref, _ = ssd_scan_ref(
            x.astype(jnp.float32), dt, a,
            bp.astype(jnp.float32), cp.astype(jnp.float32),
        )
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(y_ref), atol=1e-4, rtol=1e-3
        )

    def test_ops_wrapper(self):
        b, s, h, p, n = 1, 64, 2, 8, 16
        ks = jax.random.split(jax.random.PRNGKey(1), 5)
        x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.2)
        bp = jax.random.normal(ks[3], (b, s, n)) * 0.4
        cp = jax.random.normal(ks[4], (b, s, n)) * 0.4
        y = ssd_chunked_scan(x, dt, a, bp, cp, chunk=32)
        y_ref, _ = ssd_scan_ref(x, dt, a, bp, cp)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4, rtol=1e-3)

    def test_model_ssd_matches_kernel(self):
        """models.ssm chunked impl and the kernel agree (same math)."""
        from repro.models.ssm import ssd_chunked
        b, s, h, p, n = 2, 128, 3, 8, 16
        ks = jax.random.split(jax.random.PRNGKey(2), 5)
        x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.2)
        bp = jax.random.normal(ks[3], (b, s, n)) * 0.4
        cp = jax.random.normal(ks[4], (b, s, n)) * 0.4
        y_model, _ = ssd_chunked(x, dt, a, bp, cp, chunk=32)
        y_kernel = ssd_chunked_scan(x, dt, a, bp, cp, chunk=32)
        np.testing.assert_allclose(
            np.asarray(y_model), np.asarray(y_kernel), atol=1e-4, rtol=1e-3
        )
