"""Collective transport contracts (DESIGN.md §15, §16).

Direct coverage for the pieces the engine-level suites only exercise
implicitly:

  * ``JaxProcessCollective`` — the rank-driven multi-host backend: real
    ``process_allgather`` path at world_size=1, a forced multi-process
    simulated lane (stubbed transport), and the same uniform-call audit /
    desync semantics ``LoopbackCollective`` enforces;
  * the int64 wire codec that flattens the round payload (including the
    §16 window summary) for the rank-driven transport;
  * ``ResilientCollective`` on the rank-driven path: watchdog deadline over
    a wedged gather, and the full failed-rank list on exhaustion —
    threaded through ``EpochAborted`` and the ``RoundTimeline`` abort
    census (the straggler-reporting bugfix).
"""

from __future__ import annotations

import time

import numpy as np
import pytest
from jax.experimental import multihost_utils

from repro import obs
from repro.core.comm import (
    JaxProcessCollective,
    LoopbackCollective,
    ProtocolDesyncError,
    RankTimeoutError,
    ResilientCollective,
    decode_round_payload,
    encode_round_payload,
    round_payload_length,
)
from repro.core import OdbConfig
from repro.data.datasets import _records_from_lengths
from repro.data.pipeline import PipelinePolicy
from repro.stream import EpochAborted, StreamExecutor

POLICY = PipelinePolicy()


def make_records(n: int, seed: int = 0):
    import random

    rng = random.Random(seed)
    return _records_from_lengths([rng.randint(16, 900) for _ in range(n)])


def small_cfg(**kw) -> OdbConfig:
    base = dict(l_max=1024, buffer_size=16, prefetch_factor=8, num_workers=1)
    base.update(kw)
    return OdbConfig(**base)


class ScriptedInjector:
    """Faults from an explicit {(round, attempt, rank): fault} script."""

    def __init__(self, script):
        self.script = script

    def on_gather(self, round_index, attempt, rank, tag):
        return self.script.get((round_index, attempt, rank))


# -----------------------------------------------------------------------------
# Wire codec
# -----------------------------------------------------------------------------


class TestWireCodec:
    PAYLOAD = {
        "idx_budget": 17,
        "n_groups": 2,
        "sizes": [4, 3],
        "tokens": [900, 512],
        "window": {
            "host": 1,
            "cursor": 9,
            "staged": 2,
            "delivered": 7,
            "resident": 5,
            "quarantined_ids": [3, 42],
        },
    }

    def test_roundtrip_with_window(self):
        vec = encode_round_payload(
            self.PAYLOAD, group_capacity=4, quarantine_capacity=4
        )
        assert vec.dtype == np.int64
        assert len(vec) == round_payload_length(4, 4)
        assert decode_round_payload(
            vec, group_capacity=4, quarantine_capacity=4
        ) == self.PAYLOAD

    def test_roundtrip_without_window(self):
        payload = {k: v for k, v in self.PAYLOAD.items() if k != "window"}
        vec = encode_round_payload(payload, group_capacity=4)
        out = decode_round_payload(vec, group_capacity=4)
        assert "window" not in out
        assert out == payload

    def test_negative_status_survives(self):
        """Finished ranks gather n_groups = -1; the codec must not clamp."""
        payload = {"idx_budget": 0, "n_groups": -1, "sizes": [], "tokens": []}
        vec = encode_round_payload(payload, group_capacity=2)
        assert decode_round_payload(vec, group_capacity=2)["n_groups"] == -1

    def test_capacity_overflow_raises(self):
        with pytest.raises(ValueError, match="exceed wire capacity"):
            encode_round_payload(self.PAYLOAD, group_capacity=1)
        with pytest.raises(ValueError, match="quarantined ids"):
            encode_round_payload(
                self.PAYLOAD, group_capacity=4, quarantine_capacity=1
            )

    def test_length_mismatch_raises(self):
        vec = encode_round_payload(self.PAYLOAD, group_capacity=4,
                                   quarantine_capacity=4)
        with pytest.raises(ValueError, match="length"):
            decode_round_payload(vec, group_capacity=5, quarantine_capacity=4)


# -----------------------------------------------------------------------------
# JaxProcessCollective
# -----------------------------------------------------------------------------


class TestJaxProcessCollective:
    def test_world1_real_path(self):
        """Real process_allgather on the single-process runtime."""
        coll = JaxProcessCollective(1)
        payload = encode_round_payload(
            {"idx_budget": 5, "n_groups": 1, "sizes": [2], "tokens": [64]},
            group_capacity=2,
        )
        out = coll.all_gather(0, payload)
        assert len(out) == 1
        assert np.array_equal(np.asarray(out[0]), payload)
        assert coll.stats.rounds == 1
        assert coll.calls_per_tag == {"primary": 1}

    def test_world1_through_resilient_watchdog(self):
        """Satisfies the same wrapper contract as LoopbackCollective."""
        coll = ResilientCollective(JaxProcessCollective(1), deadline_s=30.0)
        out = coll.all_gather(0, np.arange(4, dtype=np.int64))
        assert len(out) == 1
        assert np.array_equal(np.asarray(out[0]), np.arange(4))

    def test_forced_multiprocess_lane(self, monkeypatch):
        """Simulated 3-process runtime: the transport returns one stacked
        payload per process and the collective slices them apart."""
        def fake_allgather(arr):
            return np.stack([np.asarray(arr)] * 3)

        monkeypatch.setattr(multihost_utils, "process_allgather", fake_allgather)
        coll = JaxProcessCollective(3)
        out = coll.all_gather(1, np.array([7, 8], dtype=np.int64))
        assert len(out) == 3
        assert all(np.array_equal(np.asarray(o), [7, 8]) for o in out)

    def test_wrong_world_size_is_desync(self, monkeypatch):
        monkeypatch.setattr(
            multihost_utils,
            "process_allgather",
            lambda arr: np.stack([np.asarray(arr)] * 2),
        )
        coll = JaxProcessCollective(3)
        with pytest.raises(ProtocolDesyncError, match="out of lockstep"):
            coll.all_gather(0, np.array([1], dtype=np.int64))

    def test_uniform_call_audit_across_tags(self, monkeypatch):
        """Lemma 3 mirror: a secondary-tag gather may never outrun the
        primary round count (LoopbackCollective enforces the per-rank
        version of the same invariant)."""
        monkeypatch.setattr(
            multihost_utils,
            "process_allgather",
            lambda arr: np.stack([np.asarray(arr)] * 2),
        )
        coll = JaxProcessCollective(2)
        payload = np.array([1], dtype=np.int64)
        coll.all_gather(0, payload)
        coll.all_gather(0, payload, tag="scale")
        with pytest.raises(ProtocolDesyncError, match="uniform all_gather"):
            coll.all_gather(0, payload, tag="scale")

    def test_watchdog_times_out_wedged_gather(self, monkeypatch):
        """A hung remote surfaces as RankTimeoutError, not an infinite join."""
        monkeypatch.setattr(
            multihost_utils,
            "process_allgather",
            lambda arr: time.sleep(30),
        )
        coll = ResilientCollective(
            JaxProcessCollective(1),
            deadline_s=0.05,
            max_retries=1,
            backoff_base_s=0.001,
        )
        with pytest.raises(RankTimeoutError) as err:
            coll.all_gather(0, np.array([1], dtype=np.int64))
        assert err.value.attempts == 2
        assert err.value.failed_ranks == [0]

    def test_watchdog_propagates_inner_errors(self, monkeypatch):
        def boom(arr):
            raise ProtocolDesyncError("injected")

        monkeypatch.setattr(multihost_utils, "process_allgather", boom)
        coll = ResilientCollective(JaxProcessCollective(1), deadline_s=5.0)
        with pytest.raises(ProtocolDesyncError, match="injected"):
            coll.all_gather(0, np.array([1], dtype=np.int64))


# -----------------------------------------------------------------------------
# Full failed-rank reporting (the straggler-census bugfix)
# -----------------------------------------------------------------------------


class TestFailedRankReporting:
    def drop_script(self, ranks, rounds=1, attempts=8):
        return {
            (rnd, att, rank): "drop"
            for rnd in range(rounds)
            for att in range(attempts)
            for rank in ranks
        }

    def test_exception_carries_every_failed_rank(self):
        inner = LoopbackCollective(4)
        coll = ResilientCollective(
            inner,
            deadline_s=0.5,
            max_retries=1,
            backoff_base_s=0.0,
            injector=ScriptedInjector(self.drop_script({1, 3})),
        )
        with pytest.raises(RankTimeoutError) as err:
            coll.gather_round(lambda r: {"rank": r})
        exc = err.value
        assert exc.failed_ranks == [1, 3]
        assert exc.rank == 1  # backward-compatible first-rank field
        assert [r for r, _ in exc.failures] == [1, 3]
        assert "rank 1" in str(exc) and "rank 3" in str(exc)

    def test_epoch_abort_threads_full_casualty_list(self):
        records = make_records(60, seed=5)
        ex = StreamExecutor(
            records,
            POLICY,
            4,
            small_cfg(round_deadline_s=0.5, round_retries=1,
                      retry_backoff_s=0.0),
            seed=7,
            num_hosts=2,
            fault_injector=ScriptedInjector(self.drop_script({1, 3})),
        )
        with pytest.raises(EpochAborted) as err:
            while ex.step() is not None:
                pass
        assert err.value.failed_ranks == [1, 3]
        # ...into the round audit's abort census...
        assert ex.telemetry.aborts
        abort = ex.telemetry.aborts[-1]
        assert abort["failed_ranks"] == [1, 3]
        assert abort["attempts"] == 2
        # ...and through the checkpoint the abort rides (stream_abort.json).
        ck = err.value.checkpoint()
        timeline = obs.RoundTimeline.from_dict(
            ck.payload["telemetry"]["rounds"]
        )
        assert timeline.aborts[-1]["failed_ranks"] == [1, 3]

    def test_round_timeline_abort_roundtrip(self):
        timeline = obs.RoundTimeline(4)
        timeline.record_abort(
            [3, 1, 1], round_index=9, attempts=3, reason="dropped"
        )
        assert timeline.aborts == [
            {
                "failed_ranks": [1, 3],
                "round_index": 9,
                "attempts": 3,
                "reason": "dropped",
            }
        ]
        back = obs.RoundTimeline.from_dict(timeline.as_dict())
        assert back.aborts == timeline.aborts
        # Pre-v4 serialized timelines carry no aborts key.
        legacy = timeline.as_dict()
        legacy.pop("aborts")
        assert obs.RoundTimeline.from_dict(legacy).aborts == []
