"""Multi-rank simulation lane (ISSUE 4 satellite).

Runs the streaming DGAP executor against N *real* simulated devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=N``) in a subprocess —
the flag must be set before jax initializes, which the test process already
did — and asserts the two SPMD data-path contracts end to end:

  1. every rank's realized :class:`DeviceBatch` shares one step shape (the
     condition for the global array to shard over the ``data`` mesh axis),
     proven by actually forming the global array with a ``NamedSharding``
     over the simulated devices and running a jitted reduction on it;
  2. a mid-epoch checkpoint/resume reproduces the remaining step sequence
     bit-for-bit — tokens, positions, segments, loss masks and per-row
     lengths — on every rank.
"""

from __future__ import annotations

import os
import pathlib
import subprocess
import sys
import textwrap

REPO = pathlib.Path(__file__).resolve().parents[1]

SCRIPT = textwrap.dedent(
    """
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    W = 4
    assert jax.device_count() == W, (
        f"host platform exposed {jax.device_count()} devices, want {W}")
    devices = jax.devices()

    from repro.core import OdbConfig
    from repro.core.layout import make_layout
    from repro.data.pipeline import PipelinePolicy, RawRecord
    from repro.launch.mesh import make_host_mesh
    from repro.stream import StreamCheckpoint, StreamExecutor

    records = [
        RawRecord(identity=i, chars=int(40 + (i * 977) % 2600), turns=1 + i % 3)
        for i in range(96)
    ]
    policy = PipelinePolicy()
    cfg = OdbConfig(l_max=1024, buffer_size=16, prefetch_factor=8, num_workers=2)
    layout = make_layout("packed", vocab_size=512)
    mesh = make_host_mesh(1)  # ("data": W, "model": 1) over simulated devices

    def make_executor():
        return StreamExecutor(records, policy, W, cfg, seed=3, lookahead=32)

    def realize(step):
        batches = layout.build_step(step)
        shapes = {b.tokens.shape for b in batches}
        assert len(shapes) == 1, f"ranks disagree on step shape: {shapes}"
        return batches

    # -- run 1: uninterrupted epoch, every step placed on the W devices -------
    sum_jit = jax.jit(lambda x: x.sum())
    full = []
    ex = make_executor()
    while True:
        step = ex.step()
        if step is None:
            break
        batches = realize(step)
        # Per-rank residency on the simulated devices...
        shards = [jax.device_put(b.tokens, devices[r]) for r, b in enumerate(batches)]
        assert {next(iter(s.devices())) for s in shards} == set(devices)
        # ...and the SPMD view: one global array sharded over the data axis.
        global_tokens = jnp.asarray(np.concatenate([b.tokens for b in batches], 0))
        sharded = jax.device_put(
            global_tokens, NamedSharding(mesh, P("data", None))
        )
        assert len(sharded.sharding.device_set) == W
        host_total = int(np.concatenate([b.tokens for b in batches], 0).sum())
        assert int(sum_jit(sharded)) == host_total
        full.append(batches)
    assert len(full) > 4, f"epoch produced only {len(full)} steps"

    # -- run 2: checkpoint mid-epoch, resume, bit-identical tail --------------
    cut = max(2, len(full) // 3)
    ex2 = make_executor()
    head = [realize(ex2.step()) for _ in range(cut)]
    blob = ex2.checkpoint().to_json()
    resumed = StreamExecutor.resume(StreamCheckpoint.from_json(blob), records, policy)
    tail = [realize(s) for s in resumed.steps()]
    assert len(head) + len(tail) == len(full), (len(head), len(tail), len(full))
    for reference, replay in zip(full, head + tail):
        for rank in range(W):
            a, b = reference[rank], replay[rank]
            assert a.tokens.shape == b.tokens.shape
            for field in ("tokens", "positions", "segments", "loss_mask", "lengths"):
                assert np.array_equal(getattr(a, field), getattr(b, field)), (
                    f"rank {rank} field {field} diverged after resume")
    audit = resumed.audit()
    assert audit.eta_identity == 0.0  # Theorem 1 across the preemption
    print("MULTIRANK-OK", len(full), "steps x", W, "ranks")
    """
)


def test_multirank_simulated_devices():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=4 "
        + env.get("XLA_FLAGS", "")
    ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [str(REPO / "src"), env.get("PYTHONPATH", "")]
    ).rstrip(os.pathsep)
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        env=env,
        cwd=REPO,
        capture_output=True,
        text=True,
        timeout=540,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "MULTIRANK-OK" in proc.stdout
