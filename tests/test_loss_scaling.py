"""Loss scaling (Eq. 2, App. B/N) — exactness and mode contracts."""

import hypothesis.strategies as st
import numpy as np
from hypothesis import given, settings

from repro.core import (
    RankLossStats,
    ddp_scaled_loss,
    reference_per_token_loss,
)


def stats_from(per_rank_token_losses):
    out = []
    for losses in per_rank_token_losses:
        arr = np.asarray(losses, dtype=np.float64)
        out.append(
            RankLossStats(
                loss_sum=float(arr.sum()),
                tokens=len(arr),
                samples=max(1, len(arr) // 7),
            )
        )
    return out


@st.composite
def rank_losses(draw, max_world=8):
    world = draw(st.integers(1, max_world))
    return [
        draw(
            st.lists(
                st.floats(0.0, 20.0, allow_nan=False, width=32),
                min_size=1,
                max_size=200,
            )
        )
        for _ in range(world)
    ]


class TestEq2Exactness:
    @given(rank_losses())
    @settings(max_examples=80, deadline=None)
    def test_exact_token_equals_reference_bitwise(self, per_rank):
        stats = stats_from(per_rank)
        scaled = ddp_scaled_loss(stats, "exact_token")
        ref = reference_per_token_loss(stats)
        # stable-form prescale: W·ℓ_sum_r/T_tok then mean == Σℓ_sum/T_tok
        assert scaled == ref or abs(scaled - ref) <= 4 * np.finfo(np.float64).eps * max(abs(ref), 1.0)

    @given(rank_losses(max_world=6))
    @settings(max_examples=60, deadline=None)
    def test_naive_average_biased_unless_equal_tokens(self, per_rank):
        stats = stats_from(per_rank)
        naive = float(np.mean([s.mean_loss for s in stats]))
        ref = reference_per_token_loss(stats)
        tokens = {s.tokens for s in stats}
        if len(tokens) == 1:
            assert abs(naive - ref) < 1e-9  # degenerate case t_r ≡ T/W

    def test_sample_level_exact_only_when_tokens_per_sample_constant(self):
        # equal t_r/n_r: exact
        stats = [
            RankLossStats(loss_sum=10.0, tokens=10, samples=2),
            RankLossStats(loss_sum=40.0, tokens=20, samples=4),
        ]
        assert abs(
            ddp_scaled_loss(stats, "sample") - reference_per_token_loss(stats)
        ) < 1e-12
        # unequal t_r/n_r: biased
        stats = [
            RankLossStats(loss_sum=10.0, tokens=10, samples=2),  # 5 tok/sample
            RankLossStats(loss_sum=60.0, tokens=40, samples=2),  # 20 tok/sample
        ]
        assert abs(
            ddp_scaled_loss(stats, "sample") - reference_per_token_loss(stats)
        ) > 1e-3

    def test_idle_rank_annihilated(self):
        """IDLE batch (t_r = 0) must contribute exactly zero (DESIGN.md §2)."""
        stats = [
            RankLossStats(loss_sum=30.0, tokens=15, samples=3),
            RankLossStats(loss_sum=0.0, tokens=0, samples=0),  # IDLE
        ]
        assert ddp_scaled_loss(stats, "exact_token") == 2.0
        assert reference_per_token_loss(stats) == 2.0

    def test_approx_mode_uses_prealignment_means(self):
        stats = [
            RankLossStats(
                loss_sum=30.0, tokens=12, samples=3,
                tokens_pre_alignment=40, samples_pre_alignment=10,  # t̄=4
            ),
            RankLossStats(
                loss_sum=10.0, tokens=10, samples=2,
                tokens_pre_alignment=25, samples_pre_alignment=5,  # t̄=5
            ),
        ]
        # approx token counts: 3*4=12, 2*5=10 -> equals exact here
        exact = ddp_scaled_loss(stats, "exact_token")
        approx = ddp_scaled_loss(stats, "approx_token")
        assert abs(exact - approx) < 1e-12

    def test_all_idle_step(self):
        stats = [RankLossStats(loss_sum=0.0, tokens=0, samples=0)] * 4
        for mode in ("sample", "approx_token", "exact_token"):
            assert ddp_scaled_loss(stats, mode) == 0.0


class TestJaxParity:
    def test_prescale_factor_matches_numpy_path(self):
        import jax.numpy as jnp

        from repro.core import prescale_factor

        stats = [
            RankLossStats(loss_sum=7.0, tokens=7, samples=2),
            RankLossStats(loss_sum=24.0, tokens=12, samples=3),
        ]
        t_tok = sum(s.tokens for s in stats)
        w = len(stats)
        vals = []
        for s in stats:
            f = prescale_factor(jnp.float32(s.tokens), jnp.float32(t_tok), w)
            vals.append(float(f) * s.mean_loss)
        assert abs(sum(vals) / w - reference_per_token_loss(stats)) < 1e-5
