"""DGAP protocol — Theorems 1–4, Lemmas 1/3/4, App. C.5/C.6/F audits."""

import math
import random

import hypothesis.strategies as st
import pytest
from hypothesis import given, settings

from repro.core import (
    IDLE,
    OdbConfig,
    OdbProtocolEngine,
    Sample,
    run_epoch,
)
from repro.data.datasets import SYNTHETIC_DISTRIBUTIONS
from repro.data.sampler import SamplerSpec, shard_views


def make_views_factory(n, world, lengths=None, seed=0):
    spec = SamplerSpec(dataset_size=n, world_size=world, seed=seed)
    if lengths is None:
        rng = random.Random(seed)
        lengths = [rng.randint(8, 800) for _ in range(n)]

    def make_views(iteration):
        return shard_views(spec, iteration, lengths, view_id_base=iteration * 10**7)

    return make_views


small_cfg = lambda join, **kw: OdbConfig(
    l_max=kw.pop("l_max", 1024),
    buffer_size=kw.pop("buffer_size", 32),
    prefetch_factor=kw.pop("prefetch_factor", 16),
    num_workers=kw.pop("num_workers", 2),
    join_mode=join,
    **kw,
)


class TestTheorem1JoinMode:
    """Strict zero-discard: emitted view multiset == sampler multiset M."""

    @given(
        st.integers(3, 400),  # N
        st.integers(1, 8),  # W
        st.integers(4, 64),  # buffer
        st.booleans(),
    )
    @settings(max_examples=40, deadline=None)
    def test_identity_coverage_and_multiset(self, n, world, buffer, small_lmax):
        cfg = small_cfg(True, buffer_size=buffer, l_max=256 if small_lmax else 4096)
        audit = run_epoch(make_views_factory(n, world), n, cfg)
        m = world * math.ceil(n / world)
        assert audit.emitted_views == m  # full multiset (Thm 1)
        assert audit.emitted_identities == n  # identity projection covers N
        assert audit.eta_identity == 0.0
        assert audit.surplus_emits == m - n  # deterministic padding P
        assert audit.logical_iterations == 1

    def test_eta_logical_zero_by_construction(self):
        cfg = small_cfg(True)
        make_views = make_views_factory(257, 8)
        engine = OdbProtocolEngine(make_views(0), cfg)
        engine.run_iteration()
        # drain-then-signal: outstanding sets empty at termination
        assert all(r.outstanding == 0 for r in engine.ranks)


class TestTheorem2NonJoin:
    """No-leak + sample-quota closure N <= S_emit <= N + S_max."""

    @given(st.integers(3, 300), st.integers(1, 8), st.integers(4, 48))
    @settings(max_examples=40, deadline=None)
    def test_quota_closure(self, n, world, buffer):
        cfg = small_cfg(False, buffer_size=buffer)
        steps = []
        audit = run_epoch(
            make_views_factory(n, world), n, cfg, on_step=steps.append
        )
        assert audit.eta_quota == 0.0
        s_max = max(
            sum(g.size for g in step if g is not IDLE) for step in steps
        )
        assert n <= audit.emitted_views <= n + s_max

    def test_corollary1_terminal_epoch(self):
        """Cor. 1: terminal epoch rounds to 1.0000/1.0001-style overshoot."""
        for name, ds in SYNTHETIC_DISTRIBUTIONS.items():
            lengths = ds.lengths()
            cfg = small_cfg(False, buffer_size=64, l_max=2048)
            audit = run_epoch(
                make_views_factory(len(lengths), 8, lengths), len(lengths), cfg
            )
            assert audit.eta_quota == 0.0, name
            assert 1.0 <= audit.terminal_epoch < 1.2, (name, audit.terminal_epoch)


class TestLemma1NoLeak:
    @given(st.integers(8, 200), st.integers(2, 6))
    @settings(max_examples=30, deadline=None)
    def test_component_conservation_every_round(self, n, world):
        cfg = small_cfg(True, buffer_size=16)
        views = make_views_factory(n, world)(0)
        engine = OdbProtocolEngine(views, cfg)
        total = sum(len(v) for v in views)
        while True:
            rec = engine.run_round()
            engine.check_no_leak(total)  # raises on violation
            if all(s == -1 for s in rec.statuses):
                break


class TestTheorem3and4Termination:
    @given(st.integers(8, 400), st.integers(1, 8), st.booleans())
    @settings(max_examples=40, deadline=None)
    def test_round_bound(self, n, world, join):
        cfg = small_cfg(join, buffer_size=16, prefetch_factor=8)
        engine = OdbProtocolEngine(make_views_factory(n, world)(0), cfg)
        result = engine.run_iteration()  # raises BoundedTerminationError if over
        q = math.ceil(n / world)
        assert result.rounds <= q + cfg.depth + 64

    def test_lyapunov_monotone_on_emit_rounds(self):
        cfg = small_cfg(True, buffer_size=16)
        engine = OdbProtocolEngine(make_views_factory(200, 4)(0), cfg)
        result = engine.run_iteration()
        prev = None
        for rec in result.records:
            if prev is not None:
                if rec.emitted_views > 0:
                    assert rec.potential < prev  # Lemma 2(a): strict decrease
                else:
                    assert rec.potential <= prev  # skip rounds don't increase
            prev = rec.potential

    def test_straggler_liveness(self):
        """Slow ranks (drain_rate=1) must not deadlock or break alignment."""
        cfg = small_cfg(True, buffer_size=8, prefetch_factor=4)
        audit = run_epoch(
            make_views_factory(120, 4), 120, cfg,
            drain_rates=[1, None, None, 3],
        )
        assert audit.eta_identity == 0.0


class TestLemma3UniformGather:
    def test_single_gather_per_round_all_ranks(self):
        cfg = small_cfg(True, buffer_size=16, exact_token_scaling=False)
        engine = OdbProtocolEngine(make_views_factory(100, 4)(0), cfg)
        result = engine.run_iteration()
        assert engine.collective.stats.rounds == result.rounds

    def test_second_gather_all_or_none(self):
        cfg = small_cfg(True, buffer_size=16, exact_token_scaling=True)
        engine = OdbProtocolEngine(make_views_factory(100, 4)(0), cfg)
        result = engine.run_iteration()
        secondary = sum(1 for r in result.records if r.second_gather)
        assert engine.collective.stats.secondary_rounds == secondary


class TestLemma4DiscardEnvelope:
    @given(st.integers(50, 300), st.integers(2, 8))
    @settings(max_examples=25, deadline=None)
    def test_abandoned_bounded_by_wd(self, n, world):
        cfg = small_cfg(False, buffer_size=16, prefetch_factor=8)
        engine = OdbProtocolEngine(make_views_factory(n, world)(0), cfg)
        result = engine.run_iteration()
        assert result.abandoned_views <= world * cfg.depth
        for r in engine.ranks:
            assert r.outstanding <= cfg.depth


# ---------------------------------------------------------------------------
# Generated-scenario properties (ISSUE 4): the Theorem-1 / Theorem-2 /
# Theorem-4 contracts over randomized length *distributions* (uniform,
# long-tail, bimodal, constant, adversarially sorted), rank counts, quota
# settings (N above/below/at W, non-divisible remainders) and straggler drain
# rates — superseding the fixed uniform-lengths + single-straggler-combo
# coverage above with the whole scenario space.
# ---------------------------------------------------------------------------

DISTRIBUTIONS = ("uniform", "longtail", "bimodal", "constant", "sorted")


def synth_lengths(dist: str, n: int, seed: int) -> list[int]:
    rng = random.Random(seed)
    if dist == "constant":
        return [rng.randint(8, 800)] * n
    if dist == "uniform":
        return [rng.randint(8, 800) for _ in range(n)]
    if dist == "longtail":
        return [min(int(rng.paretovariate(1.3) * 16) + 8, 4000) for _ in range(n)]
    if dist == "bimodal":
        return [
            rng.randint(8, 64) if rng.random() < 0.8 else rng.randint(1200, 4000)
            for _ in range(n)
        ]
    if dist == "sorted":  # adversarial: monotone lengths defeat shuffling luck
        return sorted(rng.randint(8, 800) for _ in range(n))
    raise AssertionError(dist)


@st.composite
def dgap_scenarios(draw):
    n = draw(st.integers(3, 300))
    world = draw(st.integers(1, 8))
    seed = draw(st.integers(0, 1 << 16))
    scenario = {
        "n": n,
        "world": world,
        "lengths": synth_lengths(draw(st.sampled_from(DISTRIBUTIONS)), n, seed),
        "seed": seed,
        "buffer": draw(st.integers(4, 64)),
        "l_max": draw(st.sampled_from([256, 1024, 4096])),
        "prefetch": draw(st.integers(1, 32)),
        "workers": draw(st.integers(1, 4)),
        # Straggler mix: per-rank Q→B drain throttles (None = full rate).
        "drain_rates": [
            draw(st.sampled_from([None, None, 1, 3])) for _ in range(world)
        ],
    }
    return scenario


def scenario_cfg(sc: dict, join: bool) -> OdbConfig:
    return OdbConfig(
        l_max=sc["l_max"],
        buffer_size=sc["buffer"],
        prefetch_factor=sc["prefetch"],
        num_workers=sc["workers"],
        join_mode=join,
    )


class TestPropertyDGAP:
    @given(dgap_scenarios())
    @settings(max_examples=30, deadline=None)
    def test_theorem1_join_identity_coverage(self, sc):
        """Thm 1 over the scenario space: exact multiset + identity cover."""
        make_views = make_views_factory(
            sc["n"], sc["world"], sc["lengths"], seed=sc["seed"]
        )
        audit = run_epoch(
            make_views, sc["n"], scenario_cfg(sc, True),
            drain_rates=sc["drain_rates"],
        )
        m = sc["world"] * math.ceil(sc["n"] / sc["world"])
        assert audit.emitted_views == m
        assert audit.emitted_identities == sc["n"]
        assert audit.eta_identity == 0.0
        assert audit.surplus_emits == m - sc["n"]
        assert audit.logical_iterations == 1

    @given(dgap_scenarios())
    @settings(max_examples=30, deadline=None)
    def test_theorem2_nonjoin_quota_closure(self, sc):
        """Thm 2 over the scenario space: N <= S_emit <= N + S_max."""
        make_views = make_views_factory(
            sc["n"], sc["world"], sc["lengths"], seed=sc["seed"]
        )
        steps = []
        audit = run_epoch(
            make_views, sc["n"], scenario_cfg(sc, False),
            on_step=steps.append, drain_rates=sc["drain_rates"],
        )
        assert audit.eta_quota == 0.0
        s_max = max(sum(g.size for g in step if g is not IDLE) for step in steps)
        assert sc["n"] <= audit.emitted_views <= sc["n"] + s_max

    @given(dgap_scenarios(), st.booleans())
    @settings(max_examples=30, deadline=None)
    def test_theorem4_bounded_deadlock_free_termination(self, sc, join):
        """Thm 3/4: every scenario terminates inside the round envelope with
        positionally aligned output queues after every round — stragglers,
        adversarial length orderings and W > N included."""
        cfg = scenario_cfg(sc, join)
        views = make_views_factory(
            sc["n"], sc["world"], sc["lengths"], seed=sc["seed"]
        )(0)
        engine = OdbProtocolEngine(views, cfg)
        for rank, rate in zip(engine.ranks, sc["drain_rates"]):
            rank.drain_rate = rate
        while True:
            record = engine.run_round()  # BoundedTerminationError on overrun
            engine.check_no_leak(sum(len(v) for v in views))
            assert len({len(r.out_queue) for r in engine.ranks}) == 1
            done = (
                all(s == -1 for s in record.statuses)
                if join
                else any(s == -1 for s in record.statuses)
            )
            if done:
                break
        q = math.ceil(sc["n"] / sc["world"])
        assert engine._round_index <= q + cfg.depth + 64 + (
            0 if all(r is None for r in sc["drain_rates"]) else q * cfg.depth
        )


class TestAppFEmptyRank:
    """Empty-rank liveness audit (outside the equal-quota premise)."""

    def test_empty_rank_terminates_clean(self):
        n, world = 90, 6
        spec = SamplerSpec(dataset_size=n, world_size=world - 1, seed=1)
        rng = random.Random(0)
        lengths = [rng.randint(8, 500) for _ in range(n)]
        views = shard_views(spec, 0, lengths)
        views.append([])  # rank 5 = exhausted empty rank
        cfg = small_cfg(True, buffer_size=16)
        engine = OdbProtocolEngine(views, cfg)
        result = engine.run_iteration()  # must not deadlock
        assert result.terminated_by == "join_all_finished"
        steps = list(engine.aligned_steps())
        # empty rank emitted zero real batches, others emitted all views
        assert all(step[world - 1] is IDLE for step in steps)
        emitted = sum(g.size for step in steps for g in step if g is not IDLE)
        assert emitted == sum(len(v) for v in views)

    def test_idle_positions_step_aligned(self):
        views = make_views_factory(40, 3)(0)
        views[1] = views[1][:2]  # unequal quotas
        engine = OdbProtocolEngine(views, small_cfg(True, buffer_size=8))
        engine.run_iteration()
        lengths = {len(r.out_queue) for r in engine.ranks}
        assert len(lengths) == 1  # queues stay positionally aligned
