"""Streaming executor: eager equivalence, bounded admission, resume, prefetch.

The three acceptance properties of the stream subsystem (DESIGN.md §9):

  1. **Equivalence** — with lookahead >= M the streaming executor reproduces
     the eager ``odb_schedule`` step sequence bit-for-bit, audit included;
  2. **Bounded admission** — with lookahead = k, peak realized-lengths
     resident in the window never exceeds k, while Theorem 1 coverage
     (η_identity = 0) still holds;
  3. **Resumability** — a checkpoint taken between any two steps, serialized
     through JSON, resumes into the *identical* remaining step sequence, so
     exact-identity coverage survives mid-epoch preemption.
"""

from __future__ import annotations

import random
import threading
import time

import numpy as np
import pytest

from repro.core import IDLE, OdbConfig
from repro.data.datasets import _records_from_lengths
from repro.data.loader import OnlineDynamicLoader, odb_schedule
from repro.data.pipeline import PipelinePolicy, realize_lengths
from repro.stream import (
    AdmissionWindow,
    PrefetchIterator,
    StreamCheckpoint,
    StreamExecutor,
)


def test_stream_package_imports_standalone():
    """repro.stream must be importable as the FIRST repro import (a resume
    tool starts from StreamCheckpoint.load, not from repro.data)."""
    import os
    import pathlib
    import subprocess
    import sys

    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    env = dict(os.environ, PYTHONPATH=src)
    proc = subprocess.run(
        [sys.executable, "-c",
         "from repro.stream import StreamExecutor, StreamCheckpoint"],
        capture_output=True,
        text=True,
        env=env,
    )
    assert proc.returncode == 0, proc.stderr


def make_records(n: int, seed: int = 0, lo: int = 16, hi: int = 900):
    rng = random.Random(seed)
    return _records_from_lengths([rng.randint(lo, hi) for _ in range(n)])


def small_cfg(join_mode: bool = True, **kw) -> OdbConfig:
    base = dict(
        l_max=1024, buffer_size=16, prefetch_factor=8, num_workers=1,
        join_mode=join_mode,
    )
    base.update(kw)
    return OdbConfig(**base)


POLICY = PipelinePolicy()


class TestEagerEquivalence:
    @pytest.mark.parametrize(
        "n,world,seed,epoch",
        [(40, 1, 0, 0), (150, 4, 3, 2), (97, 3, 7, 0), (64, 8, 1, 1)],
    )
    def test_full_lookahead_bitwise(self, n, world, seed, epoch):
        records = make_records(n, seed)
        lengths = realize_lengths(records, POLICY, epoch)
        cfg = small_cfg()
        steps, audit = odb_schedule(lengths, world, cfg, seed=seed, epoch=epoch)
        ex = StreamExecutor(records, POLICY, world, cfg, seed=seed, epoch=epoch)
        assert list(ex.steps()) == steps  # Group/Sample are frozen: deep ==
        assert ex.audit() == audit

    def test_nonjoin_step_sequence(self):
        records = make_records(140, 11)
        lengths = realize_lengths(records, POLICY, 0)
        cfg = small_cfg(join_mode=False)
        steps, audit = odb_schedule(lengths, 4, cfg, seed=5)
        ex = StreamExecutor(records, POLICY, 4, cfg, seed=5)
        assert list(ex.steps()) == steps
        a = ex.audit()
        assert a.emitted_views == audit.emitted_views
        assert a.emitted_identities == audit.emitted_identities
        assert a.logical_iterations == audit.logical_iterations

    def test_incremental_delivery_starts_before_epoch_rounds_finish(self):
        """The first step must appear with only O(window) views realized."""
        records = make_records(400, 2)
        ex = StreamExecutor(records, POLICY, 4, small_cfg(), seed=1, lookahead=64)
        first = ex.step()
        assert first is not None
        stats = ex.window_stats()
        assert stats.realized < len(records)  # epoch NOT fully realized


class TestBoundedAdmission:
    @pytest.mark.parametrize("lookahead", [4, 10, 32])
    def test_peak_resident_within_lookahead(self, lookahead):
        records = make_records(200, 9)
        cfg = small_cfg()
        ex = StreamExecutor(
            records, POLICY, 4, cfg, seed=2, lookahead=lookahead
        )
        steps = list(ex.steps())
        stats = ex.window_stats()
        assert stats.peak_resident <= lookahead
        assert stats.peak_resident < len(records)
        # Theorem 1 under throttled admission: strict identity coverage.
        audit = ex.audit()
        assert audit.eta_identity == 0.0
        assert audit.emitted_views == audit.sampler_views  # full multiset M
        assert all(len(s) == 4 for s in steps)

    def test_lookahead_below_world_rejected(self):
        records = make_records(20, 0)
        with pytest.raises(ValueError):
            StreamExecutor(records, POLICY, 4, small_cfg(), lookahead=3)

    def test_output_capacity_rejected(self):
        # Incremental draining would make the C_r envelope a silent no-op
        # (schedule divergence from eager); refuse it loudly instead.
        records = make_records(20, 0)
        with pytest.raises(ValueError, match="output_capacity"):
            StreamExecutor(records, POLICY, 2, small_cfg(output_capacity=4))

    def test_window_delivers_sampler_order(self):
        from repro.data.sampler import SamplerSpec, shard_views

        records = make_records(50, 4)
        lengths = realize_lengths(records, POLICY, 0)
        spec = SamplerSpec(dataset_size=50, world_size=3, seed=4)
        expected = shard_views(spec, 17, lengths)
        window = AdmissionWindow(
            records, POLICY, spec, shuffle_epoch=17, lookahead=1000
        )
        got = [[] for _ in range(3)]
        while not all(window.exhausted(r) for r in range(3)):
            for r in range(3):
                got[r].extend(window.take(r, 7))
        assert got == expected


class TestResume:
    @pytest.mark.parametrize(
        "lookahead,cut", [(None, 1), (None, 7), (12, 1), (12, 23)]
    )
    def test_checkpoint_resume_identical_sequence(self, lookahead, cut):
        records = make_records(140, 11)
        cfg = small_cfg()
        reference = StreamExecutor(
            records, POLICY, 4, cfg, seed=5, epoch=1, lookahead=lookahead
        )
        full = list(reference.steps())

        ex = StreamExecutor(
            records, POLICY, 4, cfg, seed=5, epoch=1, lookahead=lookahead
        )
        head = [ex.step() for _ in range(cut)]
        assert all(s is not None for s in head)
        blob = ex.checkpoint().to_json()  # JSON round-trip, as a real job would
        resumed = StreamExecutor.resume(
            StreamCheckpoint.from_json(blob), records, POLICY
        )
        tail = list(resumed.steps())
        assert head + tail == full
        # Theorem 1 across the preemption boundary: exact identity coverage.
        audit = resumed.audit()
        assert audit.eta_identity == 0.0
        assert audit.emitted_views == audit.sampler_views
        assert audit == reference.audit()

    def test_resume_rejects_changed_policy(self):
        records = make_records(40, 3)
        ex = StreamExecutor(records, POLICY, 2, small_cfg(), seed=1)
        ex.step()
        ck = ex.checkpoint()
        drifted = PipelinePolicy(chars_per_token=4.2)
        with pytest.raises(ValueError, match="policy"):
            StreamExecutor.resume(ck, records, drifted)

    def test_resume_rejects_wrong_version(self):
        records = make_records(20, 3)
        ex = StreamExecutor(records, POLICY, 2, small_cfg(), seed=1)
        payload = ex.checkpoint().payload
        payload["version"] = 999
        import json

        with pytest.raises(ValueError, match="version"):
            StreamCheckpoint.from_json(json.dumps(payload))


class TestPrefetch:
    def test_order_and_completeness(self):
        src = list(range(57))
        with PrefetchIterator(iter(src), depth=3) as it:
            assert list(it) == src
        assert it.stats.consumed == 57
        assert it.stats.produced == 57

    def test_backpressure_bounds_producer(self):
        produced = []

        def gen():
            for i in range(100):
                produced.append(i)
                yield i

        depth = 2
        it = PrefetchIterator(gen(), depth=depth)
        try:
            got = []
            for _ in range(5):
                got.append(next(it))
                time.sleep(0.05)  # slow consumer; producer must be throttled
                # bounded queue: consumed + staged (depth) + one in flight
                assert len(produced) <= len(got) + depth + 1
            assert got == list(range(5))
        finally:
            it.close()

    def test_producer_error_propagates(self):
        def gen():
            yield 1
            yield 2
            raise RuntimeError("pipeline exploded")

        with PrefetchIterator(gen(), depth=2) as it:
            assert next(it) == 1
            assert next(it) == 2
            with pytest.raises(RuntimeError, match="pipeline exploded"):
                next(it)

    def test_producer_error_with_full_queue_terminates_and_propagates(self):
        """Regression (DESIGN.md §15): a producer that raises while the
        bounded queue is full must still terminate — the END sentinel is
        forced past maxsize — and its exception must surface to the
        consumer after the buffered items, never hang or be swallowed."""

        def gen():
            yield 1
            yield 2
            raise ValueError("late corruption")

        it = PrefetchIterator(gen(), depth=1)
        try:
            assert next(it) == 1
            # Without consuming further, the producer must still exit: its
            # queue is full (item 2 staged) when the source raises.
            deadline = time.time() + 5.0
            while it.producer_alive and time.time() < deadline:
                time.sleep(0.005)
            assert not it.producer_alive, "producer wedged on a full queue"
            assert next(it) == 2  # buffered item delivered before the error
            with pytest.raises(ValueError, match="late corruption"):
                next(it)
            with pytest.raises(StopIteration):
                next(it)  # error is one-shot; afterwards it is exhaustion
        finally:
            it.close()

    def test_close_unblocks_full_queue(self):
        def gen():
            i = 0
            while True:
                yield i
                i += 1

        it = PrefetchIterator(gen(), depth=1)
        assert next(it) == 0
        it.close()
        assert not it.producer_alive

    def test_close_wakes_blocked_producer_immediately(self):
        """Shutdown latency is condition-handoff time, not a poll interval:
        a producer parked on a full queue must exit well inside the old
        0.05 s put-poll period."""
        parked = threading.Event()

        def gen():
            yield 0
            parked.set()  # next put blocks: queue (depth=1) is full
            while True:
                yield 1

        it = PrefetchIterator(gen(), depth=1)
        assert parked.wait(timeout=5.0)
        time.sleep(0.02)  # let the producer actually block in put()
        t0 = time.perf_counter()
        it.close()
        elapsed = time.perf_counter() - t0
        assert not it.producer_alive
        assert elapsed < 0.04, f"close took {elapsed:.3f}s (poll-like latency)"

    def test_next_after_close_raises_stopiteration(self):
        it = PrefetchIterator(iter(range(10)), depth=2)
        assert next(it) == 0
        it.close()
        with pytest.raises(StopIteration):
            next(it)  # must not hang on an empty queue with a dead producer

    def test_next_after_exhaustion_keeps_raising(self):
        it = PrefetchIterator(iter([1]), depth=2)
        assert list(it) == [1]
        with pytest.raises(StopIteration):
            next(it)


def _loader(world=2, **cfg_kw) -> OnlineDynamicLoader:
    from repro.data.datasets import DatasetSpec

    records = make_records(90, 21, lo=16, hi=700)
    spec = DatasetSpec(
        name="stream-test",
        size=len(records),
        policy=PipelinePolicy(cutoff_len=2048),
        make_records=lambda size, seed: records[:size],
    )
    return OnlineDynamicLoader(
        spec, world, small_cfg(**cfg_kw), seed=3, vocab_size=512
    )


class TestLoaderIntegration:
    @pytest.mark.parametrize("prefetch", [False, True])
    def test_streaming_epoch_matches_eager_epoch(self, prefetch):
        eager = list(_loader().epoch(epoch=0))
        stream = list(
            _loader().streaming_epoch(epoch=0, prefetch=prefetch)
        )
        assert len(eager) == len(stream)
        for a, b in zip(eager, stream):
            assert a.metadata == b.metadata
            for ba, bb in zip(a.batches, b.batches):
                np.testing.assert_array_equal(ba.tokens, bb.tokens)
                np.testing.assert_array_equal(ba.loss_mask, bb.loss_mask)

    def test_streaming_epoch_publishes_audit_and_stats(self):
        loader = _loader()
        steps = list(loader.streaming_epoch(epoch=0, prefetch=True))
        assert steps
        assert loader.last_audit is not None
        assert loader.last_audit.eta_identity == 0.0
        assert loader.last_prefetch_stats is not None
        assert loader.last_prefetch_stats.consumed == len(steps)

    def test_finalize_audit_opt_out_skips_drain(self):
        loader = _loader()
        it = loader.streaming_epoch(epoch=0, finalize_audit=False)
        for _ in range(3):
            next(it)
        it.close()
        # Audit reflects only the delivered prefix; no full-epoch drain ran.
        assert loader.last_audit is not None
        assert not loader.last_executor.done
        assert loader.last_audit.emitted_views < loader.last_audit.sampler_views

        loader2 = _loader()
        it2 = loader2.streaming_epoch(epoch=0)  # default: drain on close
        for _ in range(3):
            next(it2)
        it2.close()
        assert loader2.last_audit.eta_identity == 0.0
        assert loader2.last_audit.emitted_views == loader2.last_audit.sampler_views

    def test_requeued_quota_crossing_step_counts_one_iteration(self):
        """Redelivering a rolled-back quota-crossing step must not close the
        logical iteration twice (Theorem-2 audit regression)."""
        records = make_records(80, 17)
        cfg = small_cfg(join_mode=False)
        reference = StreamExecutor(records, POLICY, 2, cfg, seed=4)
        list(reference.steps())

        ex = StreamExecutor(records, POLICY, 2, cfg, seed=4)
        steps = list(ex.steps())
        ex.requeue(steps[-2:])  # prefetch-abandonment rollback of the tail
        redelivered = list(ex.steps())
        assert redelivered == steps[-2:]
        assert ex.audit() == reference.audit()

    def test_resume_preserves_window_stats_aggregate(self):
        records = make_records(120, 13)
        cfg = small_cfg(join_mode=False)
        ex = StreamExecutor(records, POLICY, 4, cfg, seed=2, lookahead=16)
        full = list(ex.steps())
        assert full
        reference = ex.window_stats()

        ex2 = StreamExecutor(records, POLICY, 4, cfg, seed=2, lookahead=16)
        for _ in range(5):
            ex2.step()
        resumed = StreamExecutor.resume(ex2.checkpoint(), records, POLICY)
        list(resumed.steps())
        got = resumed.window_stats()
        assert got.realized == reference.realized
        assert got.delivered == reference.delivered

    def test_prefetch_close_rolls_back_staged_tail(self):
        """Close-then-checkpoint under prefetch must resume exactly at the
        consumer's frontier: the staged-but-unconsumed tail is rolled back,
        so no sample is skipped (coverage) or replayed (duplication)."""
        def fresh():
            return _loader()

        loader = fresh()
        it = loader.streaming_epoch(
            0, lookahead=16, prefetch=True, prefetch_depth=4,
            finalize_audit=False,
        )
        head = [next(it) for _ in range(3)]
        it.close()  # rollback happens here
        ck = loader.last_executor.checkpoint()

        resumed_loader = fresh()
        tail = list(resumed_loader.streaming_epoch(0, resume_from=ck))
        full = list(fresh().streaming_epoch(0, lookahead=16))
        assert len(head) + len(tail) == len(full)
        for a, b in zip(head + tail, full):
            assert a.metadata.samples_per_rank == b.metadata.samples_per_rank
            assert a.metadata.tokens_per_rank == b.metadata.tokens_per_rank
        assert resumed_loader.last_audit.eta_identity == 0.0

    def test_accounting_counts_only_consumed_steps(self):
        loader = _loader()
        it = loader.streaming_epoch(
            0, prefetch=True, prefetch_depth=4, finalize_audit=False
        )
        for _ in range(3):
            next(it)
        it.close()
        # The producer padded ahead, but only consumed steps are accounted.
        assert loader.accounting.steps == 3

    def test_resume_rejects_mismatched_arguments(self):
        loader = _loader()
        it = loader.streaming_epoch(0, lookahead=16)
        next(it)
        ck = loader.last_executor.checkpoint()
        it.close()
        with pytest.raises(ValueError, match="lookahead"):
            next(_loader().streaming_epoch(0, lookahead=32, resume_from=ck))
        with pytest.raises(ValueError, match="epoch"):
            next(_loader().streaming_epoch(5, resume_from=ck))

    def test_mid_epoch_checkpoint_through_loader(self):
        loader = _loader()
        it = loader.streaming_epoch(epoch=0, lookahead=16)
        head = [next(it) for _ in range(4)]
        ck = loader.last_executor.checkpoint()
        it.close()

        resumed_loader = _loader()
        tail = list(
            resumed_loader.streaming_epoch(epoch=0, resume_from=ck)
        )
        full = list(_loader().streaming_epoch(epoch=0, lookahead=16))
        assert len(head) + len(tail) == len(full)
        for a, b in zip(head + tail, full):
            assert a.metadata == b.metadata


class TestEmittedLedgerBitmap:
    """ROADMAP "checkpoint size": the serialized emitted ledger is a count
    plus an identity bitmap — O(N/8) bytes total, not O(quota) triples per
    logical iteration — and the shrink is invisible to resume identity."""

    def test_codec_roundtrip(self):
        from repro.stream.state import bitmap_to_identities, identities_to_bitmap

        for ids in (set(), {0}, {7}, {8}, {0, 1, 63, 64, 1000}, set(range(0, 500, 3))):
            assert bitmap_to_identities(identities_to_bitmap(ids)) == ids

    def test_checkpoint_carries_no_per_sample_ledger(self):
        records = make_records(60, 3)
        ex = StreamExecutor(records, POLICY, 2, small_cfg(), seed=1)
        for _ in range(5):
            ex.step()
        payload = ex.checkpoint().payload
        assert "emitted_ids" not in payload["runner"]
        assert isinstance(payload["runner"]["emitted_bitmap"], str)
        for rank_state in payload["engine"]["ranks"]:
            assert "emitted" not in rank_state
            assert isinstance(rank_state["emitted_count"], int)

    def test_bitmap_resume_preserves_identity_coverage(self):
        records = make_records(80, 9)
        cfg = small_cfg()
        reference = StreamExecutor(records, POLICY, 3, cfg, seed=2)
        ref_steps = list(reference.steps())

        ex = StreamExecutor(records, POLICY, 3, cfg, seed=2)
        head = [ex.step() for _ in range(6)]
        blob = ex.checkpoint().to_json()
        resumed = StreamExecutor.resume(
            StreamCheckpoint.from_json(blob), records, POLICY
        )
        tail = list(resumed.steps())
        assert head + tail == ref_steps
        audit = resumed.audit()
        assert audit == reference.audit()
        assert audit.eta_identity == 0.0

    def test_bitmap_is_fixed_size_in_identities(self):
        """The serialized ledger must not grow with surplus emits: its size
        is bounded by N/4 hex chars however many views were emitted."""
        records = make_records(64, 5)
        ex = StreamExecutor(records, POLICY, 2, small_cfg(), seed=0)
        list(ex.steps())
        bitmap = ex.checkpoint().payload["runner"]["emitted_bitmap"]
        n = ex.spec.dataset_size
        assert len(bitmap) <= 2 * ((n + 7) // 8)
        assert ex.runner.emitted_total >= n  # quota met, ledger still O(N/8)


class TestTelemetry:
    """One streaming step must emit the documented span + metric set
    (DESIGN.md §13): the CI artifact checks assert over full runs; this is
    the per-round unit contract."""

    def test_one_step_emits_documented_spans_and_metrics(self):
        from repro import obs

        reg, tracer = obs.default_registry(), obs.default_tracer()
        reg.reset()
        tracer.reset()
        tracer.enable()
        try:
            # Constructed AFTER reset/enable: instruments are cached at
            # construction and must bind to the live registry.
            ex = StreamExecutor(
                make_records(60, 9), POLICY, 2, small_cfg(), seed=2
            )
            assert ex.step() is not None
            flat = reg.flat()
            assert flat["odb_stream_steps_total"] == 1
            assert flat["odb_protocol_rounds_total"] >= 1
            assert flat["odb_window_realized_total"] > 0
            assert flat["odb_window_delivered_total"] > 0
            assert (
                flat["odb_protocol_round_duration_seconds_count"]
                == flat["odb_protocol_rounds_total"]
            )
            # The executor's round audit and the registry agree.
            assert ex.telemetry.rounds == int(flat["odb_protocol_rounds_total"])
            names = {e["name"] for e in tracer.events()}
            assert {"stream/step", "dgap/round"} <= names
            # Protocol rounds nest inside the stream/step span (containment).
            step = [e for e in tracer.events() if e["name"] == "stream/step"][-1]
            rounds = [e for e in tracer.events() if e["name"] == "dgap/round"]
            assert any(
                step["ts"] <= r["ts"]
                and r["ts"] + r["dur"] <= step["ts"] + step["dur"] + 1e-3
                for r in rounds
            )
        finally:
            reg.reset()
            reg.enable()
            tracer.reset()
            tracer.disable()

    def test_resident_gauge_set_on_admit_and_quarantine_paths(self):
        """Regression: only take() used to set ``odb_window_resident``, so
        occupancy sampled between takes under-reported admissions and
        quarantine skips.  Both _admit_one outcomes must refresh the gauge."""
        from repro import obs
        from repro.chaos import poison_samples
        from repro.data.sampler import SamplerSpec

        reg = obs.default_registry()
        reg.reset()
        reg.enable()
        try:
            records = make_records(20, 3)
            spec = SamplerSpec(dataset_size=20, world_size=2, seed=0)
            window = AdmissionWindow(
                records, POLICY, spec, shuffle_epoch=0, max_quarantine=1
            )
            gauge = reg.gauge("odb_window_resident")
            window._admit_one(0)  # admit path, before any take()
            assert gauge.value == 1
            window._admit_one(1)
            assert gauge.value == 2
            # Quarantine path: resident is unchanged (nothing staged), but
            # the gauge must still be *written* — poison it to prove the
            # refresh happens rather than a stale value surviving.
            gauge.set(99)
            poison = {window.order[window.rank_position(0)]}
            with poison_samples(poison):
                window._admit_one(0)
            assert window.stats.quarantined == 1
            assert gauge.value == 2
            # And take() keeps the gauge at the delivered-adjusted value.
            got = window.take(1, 1)
            assert len(got) == 1
            assert gauge.value == 1
        finally:
            reg.reset()
            reg.enable()
