"""TPU shape bucketing + packed emission (hardware adaptation layer)."""

import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import (
    BucketSpec,
    Group,
    PackedBucketSpec,
    Sample,
    greedy_group,
    idle_batch,
    pack_group,
    pad_group,
)
from repro.core.buckets import bucket_padding_stats


def group_of(lengths, start=0):
    return Group(
        samples=tuple(
            Sample(view_id=start + i, identity=start + i, length=l)
            for i, l in enumerate(lengths)
        )
    )


class TestBucketSpec:
    def test_grids_aligned(self):
        spec = BucketSpec(min_len=128, max_len=8192, align=128)
        assert all(g % 128 == 0 for g in spec.length_grid())
        assert spec.length_grid()[0] == 128 and spec.length_grid()[-1] == 8192

    @given(st.integers(1, 8192), st.integers(1, 512))
    @settings(max_examples=80, deadline=None)
    def test_bucket_dominates(self, length, count):
        spec = BucketSpec(min_len=128, max_len=8192, max_count=512)
        nb, lb = spec.bucket_shape(count, length)
        assert nb >= count and lb >= length

    @given(st.integers(128, 8192))
    @settings(max_examples=60, deadline=None)
    def test_length_overhead_bounded(self, length):
        spec = BucketSpec(min_len=128, max_len=8192, use_midpoints=True)
        lb = spec.bucket_length(length)
        assert lb / length <= 2.0 + 1e-9  # geometric grid bound
        if length >= 256:
            assert lb / length <= 1.6  # with 1.5x midpoints

    def test_bounded_compile_count(self):
        spec = BucketSpec(min_len=128, max_len=32768, max_count=4096)
        assert spec.num_shapes() < 400


class TestPadGroup:
    def test_contents_and_mask(self):
        g = group_of([5, 9])
        spec = BucketSpec(min_len=8, max_len=64, align=8, max_count=8)
        pb = pad_group(g, spec)
        assert pb.shape == (2, 16)
        assert pb.real_samples == 2 and pb.real_tokens == 14
        np.testing.assert_array_equal(pb.loss_mask.sum(axis=1), [5, 9])
        assert pb.tokens[0, 5:].sum() == 0  # padded region

    def test_idle_batch_zero(self):
        ib = idle_batch((4, 16))
        assert ib.real_tokens == 0 and ib.loss_mask.sum() == 0


class TestPackedEmission:
    def test_segments_and_positions(self):
        g = group_of([5, 3, 7])
        spec = PackedBucketSpec(min_tokens=16, max_tokens=64, align=8)
        pk = pack_group(g, spec)
        seg = pk.segment_ids[0]
        assert list(seg[:5]) == [1] * 5
        assert list(seg[5:8]) == [2] * 3
        assert list(seg[8:15]) == [3] * 7
        assert seg[15:].sum() == 0  # padding segment 0
        np.testing.assert_array_equal(pk.positions[0, 5:8], [0, 1, 2])
        assert pk.real_tokens == 15

    def test_vocab_size_bounds_synthesized_ids(self):
        """pack_group used to hardcode vocab 32000 while pad_group threaded
        it through — both now share one synthesis helper."""
        g = group_of([9, 17])
        packed = pack_group(
            g, PackedBucketSpec(min_tokens=16, max_tokens=64, align=8),
            vocab_size=101,
        )
        padded = pad_group(
            g, BucketSpec(min_len=8, max_len=64, align=8, max_count=8),
            vocab_size=101,
        )
        assert int(packed.tokens.max()) < 101
        assert int(padded.tokens.max()) < 101
        real = packed.tokens[packed.segment_ids > 0]
        np.testing.assert_array_equal(
            np.sort(real), np.sort(padded.tokens[padded.loss_mask > 0])
        )

    def test_packed_padding_below_padded(self):
        """Packed emission strictly dominates per-sample padding on ragged groups."""
        lengths = [37, 101, 64, 512, 48, 222, 90, 33]
        groups = greedy_group(
            [Sample(i, i, l) for i, l in enumerate(lengths)], 1024
        )
        pad_spec = BucketSpec(min_len=128, max_len=1024, max_count=64)
        packed_spec = PackedBucketSpec(min_tokens=128, max_tokens=2048)
        padded = bucket_padding_stats(groups, pad_spec)["bucket_padding_fraction"]
        packed_frac = 1 - sum(g.real_tokens for g in groups) / sum(
            pack_group(g, packed_spec).tokens.shape[1] for g in groups
        )
        assert packed_frac <= padded + 1e-9
