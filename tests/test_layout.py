"""Batch-layout engine (DESIGN.md §10): invariants, equivalence, contamination.

The four acceptance properties of the layout refactor:

  1. **Packing invariants** — first-fit rows never split a sample, never
     exceed the row capacity, and the capacity always fits the longest
     sample while staying on the bounded grid;
  2. **Loss equivalence** — the same aligned groups produce the same
     ``loss_sums`` (within fp tolerance) through the dense and packed
     layouts, end-to-end through the real loader path;
  3. **Contamination** — segment masking isolates co-packed samples: logits
     of one sample are bit-independent of its row-neighbours' tokens, and
     the segment-aware label shift never targets a neighbour's first token;
  4. **Resume identity** — a mid-epoch streaming checkpoint under
     ``layout="packed"`` resumes into the identical DeviceBatch sequence.
"""

from __future__ import annotations

import dataclasses
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import (
    BucketSpec,
    Group,
    OdbConfig,
    PackedBucketSpec,
    Sample,
    greedy_group,
    make_layout,
)
from repro.core.layout import (
    DenseLayout,
    PackedLayout,
    device_padding_stats,
    global_batch_arrays,
)
from repro.data import OnlineDynamicLoader
from repro.data.datasets import DatasetSpec, _records_from_lengths
from repro.data.pipeline import PipelinePolicy
from repro.models import LM
from repro.models.model import shift_labels


def tiny_dataset(n=72, lo=8, hi=160, cutoff=256, seed=0):
    def make(size, _seed):
        rng = random.Random(seed)
        return _records_from_lengths([rng.randint(lo, hi) for _ in range(size)])

    return DatasetSpec(
        name="layout-test", size=n, policy=PipelinePolicy(cutoff_len=cutoff),
        make_records=make,
    )


def make_loader(layout: str, *, n=72, world=2, l_max=256, **ds_kw):
    return OnlineDynamicLoader(
        tiny_dataset(n, **ds_kw), world_size=world,
        config=OdbConfig(l_max=l_max, buffer_size=16, prefetch_factor=8, num_workers=2),
        bucket_spec=BucketSpec(min_len=32, max_len=512, align=32, max_count=64),
        layout=layout, vocab_size=256,
    )


def group_of(lengths, start=0):
    return Group(
        samples=tuple(
            Sample(view_id=start + i, identity=start + i, length=l)
            for i, l in enumerate(lengths)
        )
    )


PACKED_SPEC = PackedBucketSpec(min_tokens=64, max_tokens=2048, align=8, max_rows=64)


class TestPackingInvariants:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_first_fit_rows_conserve_and_bound(self, seed):
        rng = random.Random(seed)
        lengths = [rng.randint(5, 700) for _ in range(40)]
        layout = PackedLayout(spec=PACKED_SPEC, vocab_size=128)
        for group in greedy_group(
            [Sample(i, i, l) for i, l in enumerate(lengths)], 1024
        ):
            cap, rows = layout.plan_rows(group)
            assert cap >= group.max_length
            assert cap in PACKED_SPEC.grid()
            packed_ids = [s.view_id for row in rows for s in row]
            assert sorted(packed_ids) == sorted(s.view_id for s in group.samples)
            for row in rows:
                assert sum(s.length for s in row) <= cap

    def test_build_segments_positions_and_mask(self):
        layout = PackedLayout(spec=PACKED_SPEC, vocab_size=128)
        group = group_of([37, 101, 64, 48, 9])
        db = layout.build(group)
        # mask/segments agree; every real token has a segment
        np.testing.assert_array_equal(db.loss_mask > 0, db.segments > 0)
        assert int((db.segments > 0).sum()) == group.real_tokens == db.real_tokens
        # per row: segment ids are contiguous blocks 1..k, positions restart
        for r in range(db.shape[0]):
            seg = db.segments[r]
            ids = [s for s in np.unique(seg) if s > 0]
            for sid in ids:
                idx = np.where(seg == sid)[0]
                assert np.array_equal(idx, np.arange(idx[0], idx[-1] + 1))
                np.testing.assert_array_equal(
                    db.positions[r, idx], np.arange(len(idx))
                )
            assert db.lengths[r] == int((seg > 0).sum())

    def test_row_count_bucketed_and_bounded(self):
        layout = PackedLayout(spec=PACKED_SPEC, vocab_size=128)
        group = group_of([8] * 50)
        db = layout.build(group)
        assert db.shape[0] in PACKED_SPEC.row_grid()
        assert db.shape[1] in PACKED_SPEC.grid()
        # a pile of tiny samples must not inflate to one giant row
        assert db.shape[1] <= 512

    def test_single_sample_too_long_raises(self):
        layout = PackedLayout(spec=PACKED_SPEC)
        with pytest.raises(ValueError, match="does not fit the packed grid"):
            layout.plan_rows(group_of([4096]))

    def test_narrow_cap_over_max_rows_skipped_not_fatal(self):
        """A candidate capacity whose first-fit needs more than max_rows rows
        must be skipped in favour of a wider one, not abort the plan."""
        layout = PackedLayout(
            spec=PackedBucketSpec(min_tokens=64, max_tokens=2048, align=8,
                                  max_rows=4)
        )
        cap, rows = layout.plan_rows(group_of([60] * 8))  # 8 rows at cap=64
        assert len(rows) <= 4
        assert cap >= 120  # at least two samples per row

    def test_step_batches_share_one_spmd_shape(self):
        """build_step plans one (rows, cap) across ranks: the accounted
        device area IS the shipped area (no post-hoc unify inflation)."""
        layout = PackedLayout(spec=PACKED_SPEC, vocab_size=128)
        step = [group_of([700, 30]), None, group_of([9, 9, 9], start=10)]
        row = layout.build_step(step)
        assert len({b.shape for b in row}) == 1
        assert row[1].real_tokens == 0  # IDLE stayed a zero batch

    def test_unified_token_synthesis_across_layouts(self):
        """The vocab_size fix: both layouts draw the same bounded ids from
        the one shared synthesis helper for the same sample."""
        dense = DenseLayout(spec=BucketSpec(min_len=32, max_len=512, align=32),
                            vocab_size=199)
        packed = PackedLayout(spec=PACKED_SPEC, vocab_size=199)
        group = group_of([57], start=11)  # one sample: row 0 in both layouts
        d, p = dense.build(group), packed.build(group)
        assert int(d.tokens.max()) < 199 and int(p.tokens.max()) < 199
        np.testing.assert_array_equal(d.tokens[0, :57], p.tokens[0, :57])

    def test_make_layout_unknown_raises(self):
        with pytest.raises(KeyError, match="unknown batch layout"):
            make_layout("ragged")

    def test_unify_grows_to_step_max(self):
        layout = PackedLayout(spec=PACKED_SPEC, vocab_size=128)
        a = layout.build(group_of([30, 20]))
        b = layout.build(group_of([700, 500, 300]))
        ua, ub = layout.unify([a, b])
        assert ua.shape == ub.shape
        assert ua.real_tokens == a.real_tokens  # accounting preserved
        arrays = global_batch_arrays([a, b], layout)
        assert arrays["tokens"].shape[0] == ua.shape[0] * 2


class TestLossEquivalence:
    def test_dense_vs_packed_loss_sums_agree(self):
        from repro.train.trainer import assemble_model_batch

        cfg = dataclasses.replace(get_smoke_config("qwen3_0_6b"), vocab_size=256)
        model = LM(cfg)
        params = model.init(jax.random.PRNGKey(0))
        dense_loader = make_loader("dense")
        packed_loader = make_loader("packed")
        n_steps = 0
        for dls, pls in zip(dense_loader.epoch(0), packed_loader.epoch(0)):
            assert dls.metadata == pls.metadata  # same aligned schedule
            db = assemble_model_batch(dls, dense_loader.layout)
            pb = assemble_model_batch(pls, packed_loader.layout)
            dl, dt = model.loss_sums(params, db)
            plo, pt = model.loss_sums(params, pb)
            assert int(dt) == int(pt)  # identical valid-target counts
            np.testing.assert_allclose(
                float(dl), float(plo), rtol=2e-4,
                err_msg=f"step {n_steps}: dense/packed loss_sums diverge",
            )
            n_steps += 1
            if n_steps >= 4:
                break
        assert n_steps >= 2

    def test_device_padding_packed_not_worse_through_loader(self):
        dense_loader = make_loader("dense", lo=8, hi=240, cutoff=512)
        packed_loader = make_loader("packed", lo=8, hi=240, cutoff=512)
        list(dense_loader.epoch(0))
        list(packed_loader.epoch(0))
        assert (
            packed_loader.accounting.device_padding_fraction
            <= dense_loader.accounting.device_padding_fraction + 1e-9
        )


class TestContamination:
    def _packed_multiseg_step(self):
        """A real loader step whose first rank batch co-packs >= 2 samples."""
        loader = make_loader("packed", n=48, l_max=512)
        for ls in loader.epoch(0):
            for db in ls.batches:
                if any(db.segments[r].max() >= 2 for r in range(db.shape[0])):
                    return db
        pytest.skip("no co-packed row produced by this schedule")

    def test_neighbour_tokens_do_not_leak_into_logits(self):
        db = self._packed_multiseg_step()
        cfg = dataclasses.replace(get_smoke_config("qwen3_0_6b"), vocab_size=256)
        model = LM(cfg)
        params = model.init(jax.random.PRNGKey(0))

        def forward(tokens):
            batch = {
                "tokens": jnp.asarray(tokens),
                "positions": jnp.asarray(db.positions),
                "segments": jnp.asarray(db.segments),
            }
            return np.asarray(model.forward(params, batch))

        base = forward(db.tokens)
        row = next(r for r in range(db.shape[0]) if db.segments[r].max() >= 2)
        perturbed = db.tokens.copy()
        victim = db.segments[row] == 2
        perturbed[row, victim] = (perturbed[row, victim] + 7) % 256
        got = forward(perturbed)
        keep = db.segments[row] == 1
        np.testing.assert_allclose(
            got[row][keep], base[row][keep], rtol=1e-5, atol=1e-5,
            err_msg="segment-1 logits moved when segment-2 tokens changed",
        )

    def test_segment_aware_label_shift_masks_boundaries(self):
        tokens = jnp.asarray([[1, 2, 3, 4, 5, 0, 0, 0]], jnp.int32)
        mask = jnp.asarray([[1, 1, 1, 1, 1, 0, 0, 0]], jnp.float32)
        segs = jnp.asarray([[1, 1, 1, 2, 2, 0, 0, 0]], jnp.int32)
        _, shifted = shift_labels(tokens, mask, segments=segs)
        # position 2 is segment 1's last token: its next token belongs to
        # segment 2 -> masked; without segments it would leak.
        np.testing.assert_array_equal(
            np.asarray(shifted[0]), [1, 1, 0, 1, 0, 0, 0, 0]
        )
        _, unsegmented = shift_labels(tokens, mask)
        assert float(unsegmented[0, 2]) == 1.0  # the contamination this fixes

    def test_valid_target_counts_match_dense(self):
        # each sample contributes length-1 targets in both layouts
        layout = PackedLayout(spec=PACKED_SPEC, vocab_size=128)
        group = group_of([37, 101, 64, 48])
        db = layout.build(group)
        _, mask = shift_labels(
            jnp.asarray(db.tokens), jnp.asarray(db.loss_mask),
            segments=jnp.asarray(db.segments),
        )
        expected = sum(s.length - 1 for s in group.samples)
        assert int(np.asarray(mask).sum()) == expected


class TestStreamingAndResume:
    def test_streaming_matches_eager_packed(self):
        eager = list(make_loader("packed").epoch(0))
        stream = list(make_loader("packed").streaming_epoch(0))
        assert len(eager) == len(stream)
        for a, b in zip(eager, stream):
            for ba, bb in zip(a.batches, b.batches):
                np.testing.assert_array_equal(ba.tokens, bb.tokens)
                np.testing.assert_array_equal(ba.segments, bb.segments)

    def test_resume_identity_under_packed_layout(self):
        full = list(make_loader("packed").streaming_epoch(0, lookahead=16))

        loader = make_loader("packed")
        it = loader.streaming_epoch(0, lookahead=16)
        head = [next(it) for _ in range(3)]
        ck = loader.last_executor.checkpoint()
        it.close()

        resumed = make_loader("packed")
        tail = list(resumed.streaming_epoch(0, resume_from=ck))
        assert len(head) + len(tail) == len(full)
        for a, b in zip(head + tail, full):
            for ba, bb in zip(a.batches, b.batches):
                np.testing.assert_array_equal(ba.tokens, bb.tokens)
                np.testing.assert_array_equal(ba.segments, bb.segments)
                np.testing.assert_array_equal(ba.positions, bb.positions)

    @pytest.mark.parametrize("prefetch", [False, True])
    def test_device_put_stages_device_arrays(self, prefetch):
        loader = make_loader("packed")
        steps = list(
            loader.streaming_epoch(0, prefetch=prefetch, device_put=True)
        )
        assert steps
        for ls in steps[:3]:
            assert ls.device is not None
            host = global_batch_arrays(ls.batches, loader.layout)
            for key, val in host.items():
                assert isinstance(ls.device[key], jax.Array)
                np.testing.assert_array_equal(np.asarray(ls.device[key]), val)

    def test_device_put_trains(self):
        from repro.train.optimizer import OptimizerConfig
        from repro.train.trainer import Trainer, TrainerConfig

        cfg = dataclasses.replace(get_smoke_config("qwen3_0_6b"), vocab_size=256)
        model = LM(cfg)
        loader = make_loader("packed")
        trainer = Trainer(
            model, loader, OptimizerConfig(total_steps=20),
            TrainerConfig(log_every=1, max_steps=3, device_put=True),
        )
        state = trainer.init_state(jax.random.PRNGKey(0))
        _, steps = trainer.train_epoch(state, 0)
        assert steps == 3
        assert all(np.isfinite(h["loss"]) for h in trainer.history)


class TestRoundsAudit:
    def test_incremental_nonjoin_reports_offline_reference_rounds(self):
        from repro.data.loader import odb_schedule
        from repro.data.pipeline import realize_lengths
        from repro.stream import StreamExecutor

        records = _records_from_lengths(
            [random.Random(7).randint(16, 600) for _ in range(120)]
        )
        policy = PipelinePolicy()
        cfg = OdbConfig(
            l_max=1024, buffer_size=16, prefetch_factor=8, num_workers=1,
            join_mode=False,
        )
        lengths = realize_lengths(records, policy, 0)
        _, offline = odb_schedule(lengths, 4, cfg, seed=5)
        ex = StreamExecutor(records, policy, 4, cfg, seed=5)
        list(ex.steps())
        audit = ex.audit()
        # the eager win: fewer rounds actually run than the offline engine
        assert audit.rounds <= audit.rounds_offline
        # and the audit no longer undercounts the offline reference
        assert audit.rounds_offline == offline.rounds == offline.rounds_offline

    def test_join_mode_rounds_equal(self):
        from repro.stream import StreamExecutor

        records = _records_from_lengths(
            [random.Random(3).randint(16, 400) for _ in range(60)]
        )
        cfg = OdbConfig(l_max=512, buffer_size=8, prefetch_factor=4, num_workers=1)
        ex = StreamExecutor(records, PipelinePolicy(), 2, cfg, seed=1)
        list(ex.steps())
        audit = ex.audit()
        assert audit.rounds == audit.rounds_offline

    def test_rounds_offline_survives_checkpoint_resume(self):
        from repro.stream import StreamCheckpoint, StreamExecutor

        records = _records_from_lengths(
            [random.Random(11).randint(16, 600) for _ in range(100)]
        )
        policy = PipelinePolicy()
        cfg = OdbConfig(
            l_max=1024, buffer_size=16, prefetch_factor=8, num_workers=1,
            join_mode=False,
        )
        reference = StreamExecutor(records, policy, 2, cfg, seed=9)
        list(reference.steps())

        ex = StreamExecutor(records, policy, 2, cfg, seed=9)
        for _ in range(4):
            ex.step()
        blob = ex.checkpoint().to_json()
        resumed = StreamExecutor.resume(
            StreamCheckpoint.from_json(blob), records, policy
        )
        list(resumed.steps())
        assert resumed.audit() == reference.audit()
