"""Per-arch smoke tests (reduced configs) + decode-consistency checks."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import LM, shift_labels


def make_batch(cfg, b=2, s=32, seed=1):
    rng = jax.random.PRNGKey(seed)
    if cfg.input_embeds:
        return {
            "embeds": jax.random.normal(rng, (b, s, cfg.d_model), jnp.float32),
            "labels": jax.random.randint(rng, (b, s), 0, cfg.vocab_size),
            "loss_mask": jnp.ones((b, s), jnp.float32),
        }
    toks = jax.random.randint(rng, (b, s), 1, cfg.vocab_size)
    labels, mask = shift_labels(toks, jnp.ones((b, s), jnp.float32))
    return {"tokens": toks, "labels": labels, "loss_mask": mask}


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = get_smoke_config(arch)
        model = LM(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = make_batch(cfg)
        logits = model.forward(params, batch)
        b, s = batch["labels"].shape
        from repro.models.model import padded_vocab
        assert logits.shape == (b, s, padded_vocab(cfg.vocab_size))
        assert bool(jnp.isfinite(logits).all())

    def test_train_step_grad_finite(self, arch):
        cfg = get_smoke_config(arch)
        model = LM(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = make_batch(cfg)

        def loss_fn(p):
            ls, tc = model.loss_sums(p, batch)
            return ls / tc

        loss, grads = jax.value_and_grad(loss_fn)(params)
        assert bool(jnp.isfinite(loss))
        leaves = jax.tree_util.tree_leaves(grads)
        assert all(bool(jnp.isfinite(g).all()) for g in leaves)

    def test_full_config_matches_spec(self, arch):
        cfg = get_config(arch)
        spec = {
            "chameleon_34b": (48, 8192, 65536),
            "qwen3_0_6b": (28, 1024, 151936),
            "olmo_1b": (16, 2048, 50304),
            "deepseek_7b": (30, 4096, 102400),
            "yi_34b": (60, 7168, 64000),
            "deepseek_v3_671b": (61, 7168, 129280),
            "arctic_480b": (35, 7168, 32000),
            "jamba_1_5_large": (72, 8192, 65536),
            "mamba2_130m": (24, 768, 50280),
            "hubert_xlarge": (48, 1280, 504),
        }[arch]
        assert (cfg.n_layers, cfg.d_model, cfg.vocab_size) == spec


class TestParamCountsMatchPublished:
    @pytest.mark.parametrize("arch,total_b,active_b,tol", [
        ("chameleon_34b", 34.3, None, 0.1),
        ("yi_34b", 34.4, None, 0.1),
        ("deepseek_v3_671b", 671.0, 37.5, 0.03),
        ("arctic_480b", 477.0, 15.6, 0.1),
        ("jamba_1_5_large", 398.0, 93.3, 0.05),
        ("deepseek_7b", 6.9, None, 0.1),
    ])
    def test_param_count(self, arch, total_b, active_b, tol):
        cfg = get_config(arch)
        assert abs(cfg.param_count() / 1e9 - total_b) / total_b < tol
        if active_b:
            assert abs(cfg.active_param_count() / 1e9 - active_b) / active_b < tol


class TestDecodeConsistency:
    """prefill+decode must reproduce the full forward (teacher-forced)."""

    @pytest.mark.parametrize("arch", ["qwen3_0_6b", "deepseek_v3_671b", "mamba2_130m", "jamba_1_5_large"])
    def test_decode_matches_forward(self, arch):
        # MoE capacity drops are a function of the *batch* composition, so
        # teacher-forced decode == full-forward only holds dropless: raise
        # capacity_factor for the consistency check.
        cfg = dataclasses.replace(get_smoke_config(arch), capacity_factor=64.0)
        model = LM(cfg)
        params = model.init(jax.random.PRNGKey(0))
        b, s = 2, 16
        toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 1, cfg.vocab_size)
        full = model.forward(params, {"tokens": toks})

        split = s // 2
        _, caches = model.prefill(params, toks[:, :split], max_len=s)
        logits_steps = []
        idx = jnp.array(split, jnp.int32)
        for t in range(split, s):
            lg, caches = model.decode_step(params, caches, toks[:, t : t + 1], idx)
            logits_steps.append(lg)
            idx = idx + 1
        dec = jnp.concatenate(logits_steps, axis=1)
        ref = full[:, split:s, : dec.shape[-1]]
        np.testing.assert_allclose(
            np.asarray(dec, np.float32), np.asarray(ref, np.float32),
            atol=2e-3, rtol=2e-3,
        )

    def test_encoder_has_no_decode_cell(self):
        from repro.launch.shapes import applicability
        cfg = get_config("hubert_xlarge")
        ok, reason = applicability(cfg, "decode_32k")
        assert not ok and "encoder" in reason

    def test_long_cells_only_subquadratic(self):
        from repro.launch.shapes import applicability
        assert applicability(get_config("mamba2_130m"), "long_500k")[0]
        assert applicability(get_config("jamba_1_5_large"), "long_500k")[0]
        assert not applicability(get_config("yi_34b"), "long_500k")[0]


class TestBlockwiseAttentionEquivalence:
    def test_block_scan_matches_single_block(self):
        """q-block scanned attention == one-shot attention (same mask)."""
        from repro.models.attention import _block_sdpa
        b, s, kh, g, d = 2, 128, 2, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (b, s, kh, g, d))
        k = jax.random.normal(ks[1], (b, s, kh, d))
        v = jax.random.normal(ks[2], (b, s, kh, d))
        pos = jnp.broadcast_to(jnp.arange(s), (b, s))
        out_blocked = _block_sdpa(q, k, v, pos, pos, None, None, None, True, 0.25, q_block=32)
        out_full = _block_sdpa(q, k, v, pos, pos, None, None, None, True, 0.25, q_block=128)
        np.testing.assert_allclose(
            np.asarray(out_blocked), np.asarray(out_full), atol=1e-5, rtol=1e-5
        )

    def test_matches_kernel_reference(self):
        """XLA path and the Pallas kernel implement the same contract."""
        from repro.kernels.ref import segment_flash_attention_ref
        from repro.models.attention import _block_sdpa
        b, s, kv, g, d = 1, 64, 2, 2, 16
        h = kv * g
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (b, s, h, d))
        k = jax.random.normal(ks[1], (b, s, kv, d))
        v = jax.random.normal(ks[2], (b, s, kv, d))
        pos = jnp.broadcast_to(jnp.arange(s), (b, s))
        out = _block_sdpa(
            q.reshape(b, s, kv, g, d), k, v, pos, pos, None, None, None,
            True, 1.0 / d**0.5, q_block=32,
        ).reshape(b, s, h, d)
        ref = segment_flash_attention_ref(q, k, v, None, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)


class TestMoE:
    def test_ep_dispatch_conserves_routing(self):
        """Scatter dispatch == dense per-expert masked compute (small case)."""
        from repro.models.moe import dispatch_compute_combine, router_topk
        import numpy as onp
        t, d, e, ff, k = 64, 16, 4, 8, 2
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        x = jax.random.normal(ks[0], (t, d))
        router = jax.random.normal(ks[1], (d, e))
        w_in = jax.random.normal(ks[2], (e, d, ff)) * 0.2
        w_gate = jax.random.normal(ks[3], (e, d, ff)) * 0.2
        w_out = jax.random.normal(ks[4], (e, ff, d)) * 0.2
        weights, ids = router_topk(x, router, k)
        y = dispatch_compute_combine(
            x, weights, ids, w_in, w_gate, w_out,
            e_start=0, capacity=t * k, act="silu",
        )
        # dense oracle
        y_ref = onp.zeros((t, d), onp.float32)
        xn, wn, idn = map(onp.asarray, (x, weights, ids))
        for ti in range(t):
            for kk in range(k):
                eidx = int(idn[ti, kk])
                h = xn[ti] @ onp.asarray(w_in)[eidx]
                gate = xn[ti] @ onp.asarray(w_gate)[eidx]
                act = gate / (1 + onp.exp(-gate))
                y_ref[ti] += wn[ti, kk] * ((act * h) @ onp.asarray(w_out)[eidx])
        np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-4, rtol=1e-3)

    def test_capacity_drops_tokens(self):
        from repro.models.moe import dispatch_compute_combine, router_topk
        t, d, e, ff, k = 64, 16, 2, 8, 2
        ks = jax.random.split(jax.random.PRNGKey(1), 5)
        x = jax.random.normal(ks[0], (t, d))
        router = jax.random.normal(ks[1], (d, e))
        w = [jax.random.normal(ks[i], (e, d if i < 4 else ff, ff if i < 4 else d)) * 0.2 for i in (2, 3)]
        w_out = jax.random.normal(ks[4], (e, ff, d)) * 0.2
        weights, ids = router_topk(x, router, k)
        y_small = dispatch_compute_combine(
            x, weights, ids, w[0], w[1], w_out, e_start=0, capacity=8, act="silu"
        )
        y_big = dispatch_compute_combine(
            x, weights, ids, w[0], w[1], w_out, e_start=0, capacity=t * k, act="silu"
        )
        # capacity 8 per expert with ~64 assignments must drop -> different
        assert not np.allclose(np.asarray(y_small), np.asarray(y_big))
