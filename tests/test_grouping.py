"""Grouping (§2.2, Eq. 1, App. D) — unit + property tests."""

import hypothesis.strategies as st
import pytest
from hypothesis import given, settings

from repro.core import Group, Sample, greedy_group, padding_stats, target_group_size


def make_samples(lengths):
    return [Sample(view_id=i, identity=i, length=l) for i, l in enumerate(lengths)]


class TestEq1:
    def test_basic(self):
        assert target_group_size(100, 1000) == 10
        assert target_group_size(1000, 1000) == 1
        assert target_group_size(1500, 1000) == 1  # clamped to 1
        assert target_group_size(333, 1000) == 3

    def test_invalid(self):
        with pytest.raises(ValueError):
            target_group_size(0, 1000)
        with pytest.raises(ValueError):
            target_group_size(10, 0)

    @given(st.integers(1, 10_000), st.integers(1, 100_000))
    @settings(max_examples=100, deadline=None)
    def test_budget_bound(self, l, l_max):
        b = target_group_size(l, l_max)
        assert b >= 1
        # B(l)·l ≈ L_max: the next size would exceed the budget (unless clamped)
        if b > 1:
            assert b * l <= l_max
        assert (b + 1) * l > l_max or b == 1 and l > l_max or (b + 1) * l > l_max


class TestAppDWorkedExample:
    def test_exact_trace(self):
        """App. D: L_max=1000, {100,200,500,800} -> [800],[500],[100,200]."""
        groups = greedy_group(make_samples([100, 200, 500, 800]), 1000)
        assert [sorted(g.lengths()) for g in groups] == [[800], [500], [100, 200]]

    def test_padded_token_costs(self):
        groups = greedy_group(make_samples([100, 200, 500, 800]), 1000)
        assert [g.padded_tokens for g in groups] == [800, 500, 400]


class TestGreedyGroupProperties:
    @given(
        st.lists(st.integers(1, 4096), min_size=1, max_size=300),
        st.integers(64, 16384),
    )
    @settings(max_examples=60, deadline=None)
    def test_conservation(self, lengths, l_max):
        samples = make_samples(lengths)
        groups = greedy_group(samples, l_max)
        out_ids = sorted(s.view_id for g in groups for s in g.samples)
        assert out_ids == sorted(s.view_id for s in samples)

    @given(
        st.lists(st.integers(1, 4096), min_size=1, max_size=300),
        st.integers(64, 16384),
    )
    @settings(max_examples=60, deadline=None)
    def test_group_token_budget(self, lengths, l_max):
        """Non-singleton groups never exceed the padded-area budget beyond
        one threshold step (greedy invariant: size was <= B(shortest))."""
        groups = greedy_group(make_samples(lengths), l_max)
        for g in groups:
            shortest = min(g.lengths())
            assert g.size <= max(target_group_size(shortest, l_max), 1)

    @given(st.lists(st.integers(1, 2048), min_size=2, max_size=200))
    @settings(max_examples=40, deadline=None)
    def test_within_group_homogeneity(self, lengths):
        """Adjacent grouping: every group spans a contiguous length range."""
        l_max = 4096
        groups = greedy_group(make_samples(lengths), l_max)
        spans = sorted((min(g.lengths()), max(g.lengths())) for g in groups)
        for (lo1, hi1), (lo2, hi2) in zip(spans, spans[1:]):
            assert hi1 <= hi2  # sorted-order grouping never interleaves

    def test_uniform_lengths_converge_to_budget(self):
        """'With more samples of similar lengths, each group's padded cost
        approaches L_max' (App. D)."""
        groups = greedy_group(make_samples([128] * 512), 4096)
        full = [g for g in groups[1:-1]]  # interior groups
        for g in full:
            assert g.padded_tokens == 4096  # 32 x 128 exactly

    def test_padding_stats(self):
        groups = greedy_group(make_samples([100, 200, 500, 800]), 1000)
        stats = padding_stats(groups)
        assert stats["samples"] == 4
        assert stats["real_tokens"] == 1600
        assert stats["padded_tokens"] == 1700
        assert 0 < stats["padding_fraction"] < 0.1


class TestGroup:
    def test_empty_group_rejected(self):
        with pytest.raises(ValueError):
            Group(samples=())

    def test_properties(self):
        g = Group(samples=tuple(make_samples([10, 30])))
        assert g.size == 2 and g.max_length == 30
        assert g.real_tokens == 40 and g.padded_tokens == 60
        assert abs(g.padding_fraction - 1 / 3) < 1e-9
