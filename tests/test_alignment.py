"""Max-Based Bidirectional Group Alignment (Alg. 1, Eq. 3) — tests."""

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import (
    Group,
    RankAlignmentState,
    Sample,
    align_all,
    align_rank,
    alignment_target,
    greedy_group,
    overflow_downward,
    split_upward,
)


def groups_of(sizes, start=0):
    out = []
    vid = start
    for n in sizes:
        samples = tuple(
            Sample(view_id=vid + i, identity=vid + i, length=64) for i in range(n)
        )
        out.append(Group(samples=samples))
        vid += n
    return out, vid


def state(sizes, capacity=1 << 30, start=0):
    gs, nxt = groups_of(sizes, start)
    return (
        RankAlignmentState(
            groups=tuple(gs), capacity=capacity, buffered=sum(sizes)
        ),
        nxt,
    )


class TestEq3Target:
    def test_max_based(self):
        s1, n = state([4, 4])  # G=2
        s2, _ = state([2] * 5, start=n)  # G=5
        assert alignment_target([s1, s2]) == 5

    def test_clipped_by_sample_minimum(self):
        s1, n = state([1, 1, 1])  # 3 samples, 3 groups
        s2, _ = state([10] * 8, start=n)  # G=8
        # S_min+ = 3 clips the target
        assert alignment_target([s1, s2]) == 3

    def test_clipped_by_capacity(self):
        s1, n = state([2] * 6, capacity=4)
        s2, _ = state([2] * 8, start=n)
        assert alignment_target([s1, s2]) == 4

    def test_zero_capacity_excluded(self):
        """A zero-capacity rank must not collapse the target (App. A):
        C_min+ is the minimum over *positive* capacities only."""
        s1, n = state([2] * 6, capacity=0)
        s2, _ = state([2] * 8, start=n, capacity=8)
        assert alignment_target([s1, s2]) == 8  # not 1 (rank 1 excluded)

    def test_empty_ranks_ignored(self):
        s1 = RankAlignmentState(groups=(), capacity=10, buffered=0)
        s2, _ = state([3, 3])
        assert alignment_target([s1, s2]) == 2

    def test_no_active(self):
        s1 = RankAlignmentState(groups=(), capacity=10, buffered=0)
        assert alignment_target([s1]) == 0

    def test_floor_one(self):
        s1, _ = state([5])
        assert alignment_target([s1]) == 1


class TestSplitOverflow:
    def test_split_extracts_singletons_from_reverse(self):
        gs, _ = groups_of([3, 2])
        out, splits = split_upward(list(gs), 4)
        assert len(out) == 4 and splits == 2
        # reverse scan: first split takes from the last group (2->1), the
        # second from the first group (3->2)
        assert sorted(g.size for g in out) == [1, 1, 1, 2]

    def test_overflow_keeps_largest(self):
        gs, _ = groups_of([5, 1, 3, 2])
        kept, extras = overflow_downward(list(gs), 2)
        assert [g.size for g in kept] == [5, 3]
        assert len(extras) == 3  # 1 + 2 recirculated

    @given(
        st.lists(st.integers(1, 8), min_size=1, max_size=20),
        st.integers(1, 30),
    )
    @settings(max_examples=80, deadline=None)
    def test_alignment_conserves_samples(self, sizes, target):
        st_, _ = state(sizes)
        res = align_rank(st_, target)
        out_ids = sorted(
            [s.view_id for g in res.groups for s in g.samples]
            + [s.view_id for s in res.recirculated]
        )
        in_ids = sorted(s.view_id for g in st_.groups for s in g.samples)
        assert out_ids == in_ids

    @given(st.lists(st.lists(st.integers(1, 6), min_size=1, max_size=12), min_size=1, max_size=6))
    @settings(max_examples=60, deadline=None)
    def test_all_active_ranks_reach_target(self, per_rank_sizes):
        states = []
        nxt = 0
        for sizes in per_rank_sizes:
            s, nxt = state(sizes, start=nxt)
            states.append(s)
        target, results = align_all(states)
        for s, r in zip(states, results):
            if s.group_count > 0:
                # Eq. 3 guarantees splits suffice: target <= S_min+ <= S_r
                assert len(r.groups) == target
