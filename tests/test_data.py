"""Data substrate: sampler, pipeline, datasets, baselines, oracles."""

import math

import hypothesis.strategies as st
import pytest
from hypothesis import given, settings

from repro.core import Group
from repro.data import (
    DATASET_CLONES,
    SYNTHETIC_DISTRIBUTIONS,
    LengthCache,
    PipelinePolicy,
    SamplerSpec,
    StaleCacheError,
    bmt_schedule,
    get_dataset,
    gmt_schedule,
    hfg_schedule,
    length_cv,
    packing_schedule,
    run_pipeline,
    shard_views,
    sorted_schedule,
    standard_schedule,
)
from repro.data.pipeline import RawRecord


class TestSampler:
    @given(st.integers(1, 500), st.integers(1, 16))
    @settings(max_examples=60, deadline=None)
    def test_multiset_padding(self, n, w):
        spec = SamplerSpec(dataset_size=n, world_size=w, seed=3)
        views = shard_views(spec, 0, [17] * n)
        m = w * math.ceil(n / w)
        assert sum(len(v) for v in views) == m
        assert spec.padding_views == m - n
        identities = [s.identity for v in views for s in v]
        assert set(identities) == set(range(n))  # identity coverage
        # padding views duplicate at most P identities
        from collections import Counter
        dup = sum(c - 1 for c in Counter(identities).values())
        assert dup == m - n

    def test_equal_quotas(self):
        spec = SamplerSpec(dataset_size=103, world_size=8)
        views = shard_views(spec, 0, [5] * 103)
        assert {len(v) for v in views} == {13}


class TestPipeline:
    def test_deterministic(self):
        rec = RawRecord(identity=5, chars=4000, turns=3)
        pol = PipelinePolicy()
        assert run_pipeline(rec, pol, 0) == run_pipeline(rec, pol, 0)

    def test_policy_changes_lengths(self):
        rec = RawRecord(identity=5, chars=4000, turns=3)
        a = run_pipeline(rec, PipelinePolicy(), 0)
        b = run_pipeline(rec, PipelinePolicy(chars_per_token=2.9), 0)
        assert a != b

    def test_augmentation_varies_by_epoch(self):
        rec = RawRecord(identity=9, chars=9000)
        pol = PipelinePolicy(augmentation_strength=0.3)
        lengths = {run_pipeline(rec, pol, e) for e in range(6)}
        assert len(lengths) > 1  # epoch-dependent realized lengths

    def test_visual_expansion(self):
        text = RawRecord(identity=1, chars=300)
        multi = RawRecord(identity=1, chars=300, image_pixels=1_000_000)
        pol = PipelinePolicy()
        assert run_pipeline(multi, pol) > run_pipeline(text, pol) + 500


class TestDatasets:
    @pytest.mark.parametrize("name,target_cv", [
        ("ultrachat", 0.48), ("llava", 0.29), ("sharegpt4o", 1.00), ("mmmix", 0.80),
    ])
    def test_clone_cv(self, name, target_cv):
        ds = get_dataset(name, scale=0.03)
        cv = length_cv(ds.lengths())
        assert abs(cv - target_cv) < 0.15, (name, cv)

    def test_synthetic_families(self):
        assert set(SYNTHETIC_DISTRIBUTIONS) == {
            "uniform_narrow", "uniform_wide", "longtail",
            "bimodal", "all_long", "all_short",
        }
        for name, ds in SYNTHETIC_DISTRIBUTIONS.items():
            lengths = ds.lengths()
            assert len(lengths) == 1000
            assert all(l >= 1 for l in lengths)


def _coverage(steps, n):
    ids = {
        s.identity for step in steps for g in step if g is not None for s in g.samples
    }
    return len(ids) / n


class TestBaselines:
    def test_standard_coverage_and_shape(self):
        lengths = get_dataset("longtail").lengths()
        steps = standard_schedule(lengths, 4, 8)
        assert _coverage(steps, len(lengths)) == 1.0
        sizes = {g.size for step in steps for g in step if g is not None}
        assert max(sizes) == 8

    def test_sorted_reduces_padding(self):
        from repro.core import padding_stats
        lengths = get_dataset("longtail").lengths()
        std = [g for s in standard_schedule(lengths, 4, 8) for g in s if g]
        srt = [g for s in sorted_schedule(lengths, 4, 8, buffer_size=256) for g in s if g]
        assert (
            padding_stats(srt)["padding_fraction"]
            < padding_stats(std)["padding_fraction"]
        )

    def test_packing_fills_windows(self):
        lengths = get_dataset("uniform_narrow").lengths()
        steps = packing_schedule(lengths, 2, 4096)
        for step in steps:
            for g in step:
                if g is not None and g.size > 1:
                    assert g.real_tokens <= 4096


class TestOracles:
    def setup_method(self):
        self.ds = get_dataset("sharegpt4o", scale=0.01)
        self.cache = LengthCache.build(self.ds)

    def test_cache_invalidation(self):
        self.cache.validate(self.ds, self.ds.policy)  # ok
        with pytest.raises(StaleCacheError):
            self.cache.validate(
                self.ds, PipelinePolicy(template="llama3", cutoff_len=16384)
            )

    def test_gmt_feasibility(self):
        budget = 8192
        steps = gmt_schedule(self.cache, 4, budget)
        for step in steps:
            for g in step:
                if g is not None and g.size > 1:
                    assert g.max_length * g.size <= budget  # padded-area rule
        assert _coverage(steps, self.ds.size) == 1.0

    def test_bmt_feasibility_and_coverage(self):
        steps = bmt_schedule(self.cache, 4, 8192, bucket_samples=256)
        for step in steps:
            for g in step:
                if g is not None and g.size > 1:
                    assert g.max_length * g.size <= 8192
        assert _coverage(steps, self.ds.size) == 1.0

    def test_equal_rank_step_counts(self):
        for sched in (
            gmt_schedule(self.cache, 4, 8192),
            bmt_schedule(self.cache, 4, 8192),
            hfg_schedule(self.cache, 4, 8),
        ):
            for step in sched:
                assert len(step) == 4  # wrap-around padding guarantees W cols

    def test_hfg_fixed_batch(self):
        steps = hfg_schedule(self.cache, 4, 8)
        sizes = {g.size for step in steps for g in step if g is not None}
        assert sizes == {8} or sizes == {8, self.ds.size % 8}
