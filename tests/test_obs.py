"""Observability subsystem (DESIGN.md §13).

Contracts under test:

  1. **Registry semantics** — counter monotonicity, gauge last-write,
     histogram explicit-bucket binning, labeled children, kind conflicts;
  2. **Disabled is free** — a disabled registry hands back the one shared
     NULL sink (no allocation), a disabled tracer the one shared NULL_SPAN;
  3. **Views** — Prometheus text exposition golden, flat() naming;
  4. **Trace** — span nesting by containment, bounded ring with accounted
     drops, Chrome trace-event JSON schema validity;
  5. **Checkpoint round-trip** — registry state()/load_state() and the
     RoundTimeline survive JSON; the stream checkpoint carries counters so a
     resumed run continues them instead of restarting at zero.
"""

from __future__ import annotations

import json
import random

import pytest

from repro import obs
from repro.core import OdbConfig
from repro.core.protocol import RoundRecord
from repro.data.datasets import _records_from_lengths
from repro.data.pipeline import PipelinePolicy
from repro.obs import (
    DROPPED_SERIES,
    NULL,
    NULL_SPAN,
    CrossProcessAggregator,
    MetricsRegistry,
    RoundTimeline,
    RunReporter,
    SpanTracer,
)
from repro.stream import StreamCheckpoint, StreamExecutor

POLICY = PipelinePolicy()


def make_records(n: int, seed: int = 0, lo: int = 16, hi: int = 900):
    rng = random.Random(seed)
    return _records_from_lengths([rng.randint(lo, hi) for _ in range(n)])


def small_cfg(**kw) -> OdbConfig:
    base = dict(l_max=1024, buffer_size=16, prefetch_factor=8, num_workers=1)
    base.update(kw)
    return OdbConfig(**base)


@pytest.fixture(autouse=True)
def clean_defaults():
    """Tests below mutate the process-wide registry/tracer: isolate them."""
    reg, tracer = obs.default_registry(), obs.default_tracer()
    reg.reset()
    reg.enable()
    tracer.reset()
    tracer.disable()
    yield
    reg.reset()
    reg.enable()
    tracer.reset()
    tracer.disable()


class TestRegistry:
    def test_counter_monotone(self):
        reg = MetricsRegistry()
        c = reg.counter("x_total")
        c.inc()
        c.inc(2.5)
        assert c.value == 3.5
        with pytest.raises(ValueError, match=">= 0"):
            c.inc(-1)

    def test_gauge_last_write(self):
        g = MetricsRegistry().gauge("x")
        g.set(4)
        g.inc()
        g.dec(2)
        assert g.value == 3.0

    def test_histogram_binning(self):
        h = MetricsRegistry().histogram("lat", buckets=(1.0, 2.0))
        for v in (0.5, 1.0, 2.0, 4.0):  # le semantics: 1.0 lands in le="1"
            h.observe(v)
        assert h.sample() == {
            "count": 4,
            "sum": 7.5,
            "buckets": {"1": 2, "2": 3, "+Inf": 4},
        }

    def test_histogram_rejects_bad_buckets(self):
        with pytest.raises(ValueError, match="increasing"):
            MetricsRegistry().histogram("h", buckets=(2.0, 1.0))
        with pytest.raises(ValueError, match="increasing"):
            MetricsRegistry().histogram("h2", buckets=(1.0, 1.0))

    def test_labels_make_distinct_children(self):
        reg = MetricsRegistry()
        a = reg.counter("req_total", route="a")
        b = reg.counter("req_total", route="b")
        assert a is not b
        assert reg.counter("req_total", route="a") is a  # stable lookup
        a.inc(2)
        b.inc()
        assert reg.flat() == {
            'req_total{route="a"}': 2.0,
            'req_total{route="b"}': 1.0,
        }

    def test_kind_conflict_raises(self):
        reg = MetricsRegistry()
        reg.counter("x")
        with pytest.raises(ValueError, match="already registered"):
            reg.gauge("x")

    def test_disabled_returns_shared_null_sink(self):
        reg = MetricsRegistry(enabled=False)
        c = reg.counter("x_total")
        assert c is NULL  # zero allocation on the disabled path
        c.inc()
        c.observe(1)
        c.set(5)
        assert c.value == 0.0
        assert reg.snapshot() == {}
        reg.enable()
        assert reg.counter("x_total") is not NULL

    def test_prometheus_text_golden(self):
        reg = MetricsRegistry()
        reg.counter("req_total", help="requests", route="a").inc(3)
        reg.gauge("temp").set(1.5)
        h = reg.histogram("lat_seconds", buckets=(1.0, 2.0), help="latency",
                          unit="seconds")
        for v in (0.5, 2.0, 4.0):
            h.observe(v)
        assert reg.prometheus_text() == (
            "# HELP lat_seconds latency\n"
            "# UNIT lat_seconds seconds\n"
            "# TYPE lat_seconds histogram\n"
            'lat_seconds_bucket{le="1"} 1\n'
            'lat_seconds_bucket{le="2"} 2\n'
            'lat_seconds_bucket{le="+Inf"} 3\n'
            "lat_seconds_sum 6.5\n"
            "lat_seconds_count 3\n"
            "# HELP req_total requests\n"
            "# TYPE req_total counter\n"
            'req_total{route="a"} 3\n'
            "# TYPE temp gauge\n"
            "temp 1.5\n"
        )

    def test_state_round_trip_through_json(self):
        reg = MetricsRegistry()
        reg.counter("a_total", lbl="x").inc(7)
        reg.gauge("g").set(-2.5)
        h = reg.histogram("h_seconds", buckets=(0.1, 1.0))
        h.observe(0.05)
        h.observe(0.5)
        h.observe(3.0)
        blob = json.dumps(reg.state())
        fresh = MetricsRegistry()
        fresh.load_state(json.loads(blob))
        assert fresh.flat() == reg.flat()
        # Per-bin counts (not just the flat cumulative view) must survive.
        restored = fresh.histogram("h_seconds", buckets=(0.1, 1.0))
        assert restored.counts == h.counts
        # load_state is a no-op on a disabled registry (nothing to bind to).
        off = MetricsRegistry(enabled=False)
        off.load_state(json.loads(blob))
        assert off.snapshot() == {}

    def test_state_prefix_filter(self):
        reg = MetricsRegistry()
        reg.counter("odb_x_total").inc()
        reg.counter("train_y_total").inc()
        assert set(reg.state(prefix="odb_")) == {"odb_x_total"}


class TestTracer:
    def test_disabled_span_is_shared_null(self):
        tracer = SpanTracer(enabled=False)
        assert tracer.span("x") is NULL_SPAN
        tracer.complete("x", 0.0, 1.0)
        tracer.instant("x")
        assert tracer.events() == []

    def test_nesting_by_containment(self):
        tracer = SpanTracer(enabled=True)
        with tracer.span("outer", cat="t"):
            with tracer.span("inner", cat="t", k=1):
                pass
        events = {e["name"]: e for e in tracer.events()}
        outer, inner = events["outer"], events["inner"]
        assert outer["ts"] <= inner["ts"]
        assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
        assert inner["args"] == {"k": 1}

    def test_ring_overflow_is_bounded_and_accounted(self):
        tracer = SpanTracer(capacity=4, enabled=True)
        for i in range(10):
            tracer.instant(f"e{i}")
        assert len(tracer.events()) == 4
        assert tracer.dropped == 6
        # Oldest dropped: the tail of the run is what survives.
        assert [e["name"] for e in tracer.events()] == ["e6", "e7", "e8", "e9"]
        assert tracer.export()["otherData"]["dropped_events"] == 6

    def test_chrome_trace_schema(self, tmp_path):
        tracer = SpanTracer(enabled=True)
        with tracer.span("a", cat="test"):
            tracer.instant("mark", cat="test", n=3)
        path = tracer.write(tmp_path / "trace.json")
        doc = json.loads(path.read_text())  # must be valid JSON end-to-end
        assert doc["displayTimeUnit"] == "ms"
        assert isinstance(doc["traceEvents"], list) and doc["traceEvents"]
        for e in doc["traceEvents"]:
            assert {"name", "ph", "ts", "pid", "tid"} <= e.keys()
            assert e["ph"] in ("X", "i")
            if e["ph"] == "X":
                assert e["dur"] >= 0
            else:
                assert e["s"] == "t"

    def test_capacity_validated(self):
        with pytest.raises(ValueError, match="capacity"):
            SpanTracer(capacity=0)


class TestRoundTimeline:
    @staticmethod
    def _record(i, target, statuses, views):
        return RoundRecord(
            round_index=i, statuses=tuple(statuses),
            idx_budgets=tuple(0 for _ in statuses), target=target,
            emitted_views=views, skip_output=False, second_gather=False,
            potential=target,
        )

    def test_straggler_census_and_round_trip(self):
        tl = RoundTimeline(world_size=2)
        tl.record_round(self._record(0, 3, (3, 0), 2), 0.002, iteration=0)
        tl.record_round(self._record(1, 0, (0, 0), 0), 0.0001, iteration=0)
        tl.record_closure("join_all_finished", iteration=0, rounds=2)
        d = tl.as_dict()
        # Rank 1 straggled in round 0; the all-zero round is no straggle.
        assert d["straggler_rounds_per_rank"] == [0, 1]
        assert d["rounds"] == 2 and d["emitted_views"] == 2
        assert d["closures"] == [
            {"event": "join_all_finished", "iteration": 0, "iteration_rounds": 2}
        ]
        restored = RoundTimeline.from_dict(json.loads(json.dumps(d)))
        assert restored.as_dict() == d

    def test_records_window_is_bounded(self):
        tl = RoundTimeline(world_size=1, keep_records=3)
        for i in range(5):
            tl.record_round(self._record(i, 1, (1,), 1), 0.001, iteration=0)
        assert len(tl.records) == 3
        assert tl.records_dropped == 2
        assert [r["round"] for r in tl.records] == [2, 3, 4]
        assert tl.rounds == 5  # aggregates keep counting past the window


class TestCheckpointCarriesTelemetry:
    def test_stream_resume_continues_counters(self):
        """The full persistence path: executor counters + round audit ride the
        stream checkpoint through JSON and resume into a fresh registry."""
        reg = obs.default_registry()
        records = make_records(120, 7)
        full = len(list(StreamExecutor(records, POLICY, 2, small_cfg(), seed=5).steps()))
        reg.reset()

        ex = StreamExecutor(records, POLICY, 2, small_cfg(), seed=5)
        for _ in range(3):
            assert ex.step() is not None
        blob = ex.checkpoint().to_json()
        assert reg.flat()["odb_stream_steps_total"] == 3
        rounds_at_cut = ex.telemetry.rounds
        assert rounds_at_cut > 0

        reg.reset()  # simulate a fresh process after preemption
        resumed = StreamExecutor.resume(
            StreamCheckpoint.from_json(blob), records, POLICY
        )
        flat = reg.flat()
        assert flat["odb_stream_steps_total"] == 3  # restored, not zeroed
        assert flat["odb_protocol_rounds_total"] >= rounds_at_cut
        assert resumed.telemetry.rounds == rounds_at_cut
        tail = list(resumed.steps())
        assert reg.flat()["odb_stream_steps_total"] == 3 + len(tail) == full

    def test_round_timeline_rides_checkpoint_payload(self):
        ex = StreamExecutor(make_records(60, 3), POLICY, 2, small_cfg(), seed=1)
        ex.step()
        payload = ex.checkpoint().payload
        assert payload["telemetry"]["rounds"]["rounds"] == ex.telemetry.rounds
        assert "odb_stream_steps_total" in payload["telemetry"]["counters"]


class TestReporter:
    def test_reporter_writes_all_artifacts(self, tmp_path):
        reg = MetricsRegistry()
        tracer = SpanTracer(enabled=True)
        reg.counter("odb_x_total").inc(4)
        with tracer.span("phase"):
            pass
        tl = RoundTimeline(world_size=1)
        reporter = RunReporter(tmp_path, registry=reg, tracer=tracer)
        paths = reporter.write(round_audit=tl, extra={"arch": "t"})
        assert set(paths) == {"metrics", "prometheus", "trace", "rounds"}
        metrics = json.loads((tmp_path / "metrics.json").read_text())
        assert metrics["flat"]["odb_x_total"] == 4.0
        assert metrics["run"] == {"arch": "t"}
        assert "odb_x_total 4" in (tmp_path / "metrics.prom").read_text()
        trace = json.loads((tmp_path / "trace.json").read_text())
        assert [e["name"] for e in trace["traceEvents"]] == ["phase"]
        assert json.loads((tmp_path / "rounds.json").read_text())["rounds"] == 0

    def test_enable_telemetry_switches_defaults_on(self, tmp_path):
        reg, tracer = obs.default_registry(), obs.default_tracer()
        reg.disable()
        assert not tracer.enabled
        reporter = obs.enable_telemetry(tmp_path)
        assert reg.enabled and tracer.enabled
        assert reporter.registry is reg and reporter.tracer is tracer


class TestModuleConveniences:
    def test_module_level_helpers_hit_defaults(self):
        obs.counter("conv_total").inc()
        obs.gauge("conv_g").set(2)
        obs.histogram("conv_h", buckets=(1.0,)).observe(0.5)
        flat = obs.default_registry().flat()
        assert flat["conv_total"] == 1.0
        assert flat["conv_g"] == 2.0
        assert flat["conv_h_count"] == 1
        obs.default_tracer().enable()
        with obs.span("conv/span"):
            obs.instant("conv/mark")
        names = {e["name"] for e in obs.default_tracer().events()}
        assert {"conv/span", "conv/mark"} <= names


class TestCardinalityBudget:
    def test_cap_drops_new_label_sets(self):
        reg = MetricsRegistry(max_label_children=2)
        a = reg.counter("odb_x_total", shard="a")
        b = reg.counter("odb_x_total", shard="b")
        dropped = reg.counter("odb_x_total", shard="c")
        assert dropped is NULL  # refused, not created
        dropped.inc()  # and safe to use as a sink
        a.inc()
        b.inc(2)
        flat = reg.flat()
        assert flat['odb_x_total{shard="a"}'] == 1.0
        assert flat['odb_x_total{shard="b"}'] == 2.0
        assert flat[DROPPED_SERIES] == 1.0
        assert not any("c" in k for k in flat if k.startswith("odb_x_total"))

    def test_existing_children_survive_past_cap(self):
        reg = MetricsRegistry(max_label_children=1)
        first = reg.counter("odb_y_total", layout="dense")
        assert reg.counter("odb_y_total", layout="packed") is NULL
        # The pre-cap child keeps resolving to the same live instrument.
        again = reg.counter("odb_y_total", layout="dense")
        assert again is first

    def test_unlabeled_series_not_budgeted(self):
        reg = MetricsRegistry(max_label_children=1)
        for name in ("a_total", "b_total", "c_total"):
            assert reg.counter(name) is not NULL
        assert DROPPED_SERIES not in reg.flat()

    def test_cap_applies_per_family(self):
        reg = MetricsRegistry(max_label_children=1)
        assert reg.counter("one_total", k="x") is not NULL
        assert reg.counter("two_total", k="y") is not NULL  # separate family
        assert reg.counter("one_total", k="z") is NULL
        assert reg.flat()[DROPPED_SERIES] == 1.0

    def test_cap_disabled_with_none(self):
        reg = MetricsRegistry(max_label_children=None)
        for i in range(512):
            assert reg.counter("odb_free_total", i=str(i)) is not NULL


class TestCrossProcessAggregator:
    def test_counter_deltas_sum_across_dumps(self):
        parent = MetricsRegistry()
        child = MetricsRegistry()
        agg = CrossProcessAggregator(parent)
        child.counter("odb_w_total", layout="dense").inc(3)
        agg.merge("w0", child.state(), timestamp=1.0)
        child.counter("odb_w_total", layout="dense").inc(2)
        agg.merge("w0", child.state(), timestamp=2.0)  # cumulative re-ship
        assert parent.flat()['odb_w_total{layout="dense"}'] == 5.0

    def test_counter_reship_is_idempotent(self):
        parent, child = MetricsRegistry(), MetricsRegistry()
        agg = CrossProcessAggregator(parent)
        child.counter("odb_w_total").inc(4)
        state = child.state()
        agg.merge("w0", state, timestamp=1.0)
        agg.merge("w0", state, timestamp=2.0)  # same dump twice: no double count
        assert parent.flat()["odb_w_total"] == 4.0

    def test_counter_restart_detected(self):
        parent, child = MetricsRegistry(), MetricsRegistry()
        agg = CrossProcessAggregator(parent)
        child.counter("odb_w_total").inc(10)
        agg.merge("w0", child.state(), timestamp=1.0)
        fresh = MetricsRegistry()  # the worker restarted: counters reset
        fresh.counter("odb_w_total").inc(2)
        agg.merge("w0", fresh.state(), timestamp=2.0)
        assert parent.flat()["odb_w_total"] == 12.0

    def test_counters_sum_across_sources(self):
        parent = MetricsRegistry()
        agg = CrossProcessAggregator(parent)
        for source in ("w0", "w1"):
            child = MetricsRegistry()
            child.counter("odb_w_total").inc(3)
            agg.merge(source, child.state(), timestamp=1.0)
        assert parent.flat()["odb_w_total"] == 6.0

    def test_gauge_last_write_by_timestamp_wins(self):
        parent = MetricsRegistry()
        agg = CrossProcessAggregator(parent)
        early, late = MetricsRegistry(), MetricsRegistry()
        early.gauge("odb_depth").set(1)
        late.gauge("odb_depth").set(9)
        agg.merge("w1", late.state(), timestamp=5.0)
        agg.merge("w0", early.state(), timestamp=3.0)  # stale: must not clobber
        assert parent.flat()["odb_depth"] == 9.0

    def test_histogram_bins_merge_by_delta(self):
        parent, child = MetricsRegistry(), MetricsRegistry()
        agg = CrossProcessAggregator(parent)
        h = child.histogram("odb_h", buckets=(1.0, 10.0))
        h.observe(0.5)
        agg.merge("w0", child.state(), timestamp=1.0)
        h.observe(5.0)
        agg.merge("w0", child.state(), timestamp=2.0)
        merged = parent.histogram("odb_h", buckets=(1.0, 10.0))
        assert merged.count == 2
        assert merged.sum == pytest.approx(5.5)
        assert merged.counts[0] == 1 and merged.counts[1] == 1

    def test_kind_collision_skipped_not_raised(self):
        parent, child = MetricsRegistry(), MetricsRegistry()
        parent.gauge("odb_clash").set(7)
        child.counter("odb_clash").inc(3)
        agg = CrossProcessAggregator(parent)
        agg.merge("w0", child.state(), timestamp=1.0)  # must not raise
        assert parent.flat()["odb_clash"] == 7.0

    def test_disabled_parent_is_noop(self):
        parent, child = MetricsRegistry(), MetricsRegistry()
        parent.disable()
        child.counter("odb_w_total").inc(3)
        CrossProcessAggregator(parent).merge("w0", child.state(), 1.0)
        parent.enable()
        assert "odb_w_total" not in parent.flat()


class TestScrapeEndpoint:
    """Live Prometheus scrape server (satellite of DESIGN.md §17 PR)."""

    def test_serves_registry_text(self):
        import urllib.request

        from repro.obs import ScrapeServer

        reg = MetricsRegistry()
        reg.counter("odb_scrape_test_total").inc(3)
        srv = ScrapeServer(registry=reg, port=0).start()
        try:
            with urllib.request.urlopen(srv.url, timeout=5) as resp:
                assert resp.status == 200
                assert "text/plain" in resp.headers["Content-Type"]
                body = resp.read().decode()
            assert "odb_scrape_test_total 3" in body
        finally:
            srv.stop()

    def test_default_registry_resolved_per_request(self):
        """Instruments created AFTER start() must appear in the scrape —
        the registry is read per request, never captured at construction."""
        import urllib.request

        from repro.obs import start_scrape_server

        srv = start_scrape_server(0)
        try:
            obs.counter("odb_scrape_late_total").inc()
            with urllib.request.urlopen(srv.url, timeout=5) as resp:
                body = resp.read().decode()
            assert "odb_scrape_late_total 1" in body
        finally:
            srv.stop()

    def test_unknown_path_404(self):
        import urllib.error
        import urllib.request

        from repro.obs import ScrapeServer

        srv = ScrapeServer(registry=MetricsRegistry(), port=0).start()
        try:
            with pytest.raises(urllib.error.HTTPError) as err:
                urllib.request.urlopen(
                    f"http://127.0.0.1:{srv.port}/nope", timeout=5
                )
            assert err.value.code == 404
        finally:
            srv.stop()

    def test_stop_joins_thread_and_is_idempotent(self):
        import threading

        from repro.obs import ScrapeServer

        srv = ScrapeServer(registry=MetricsRegistry(), port=0).start()
        thread = srv._thread
        assert thread is not None and thread.daemon
        srv.stop()
        assert not thread.is_alive()
        assert "obs-scrape" not in {t.name for t in threading.enumerate()}
        srv.stop()  # second stop: no-op, no raise
