"""Continuous-batching serving engine (DESIGN.md §12).

Four contracts:
  1. **Output equivalence** — the engine's greedy decode for every request
     matches the sequential per-request reference (``model.prefill`` +
     ``model.decode_step``), through packed scatter prefill, slot reuse,
     eviction and mode changes;
  2. **Admission under budget** — Σ projected KV footprints of resident
     requests never exceeds ``l_max``, occupancy never exceeds ``num_slots``;
  3. **Slot lifecycle** — completion/eviction frees slots that later
     admissions reuse without cache clears;
  4. **Compile-once** — the decode step traces exactly once (and each packed
     prefill bucket exactly once) across arbitrary admission/eviction cycles,
     including across engines sharing a step cache.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import LM
from repro.serve import (
    EVICTED,
    FINISHED,
    ContinuousBatchingEngine,
    RequestWindow,
    ServeConfig,
)

# One compiled-step cache for the whole module: every engine below reuses the
# same jitted decode/prefill per cell shape, so the trace counters assert the
# compile-once contract ACROSS engines, not just within one.
STEP_CACHE: dict = {}

CONFIG = ServeConfig(num_slots=4, max_len=128, l_max=384, lookahead=8)


@pytest.fixture(scope="module")
def served():
    cfg = get_smoke_config("qwen3_0_6b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # The sequential oracle's decode is (1, 1)-shaped: jit it once for the
    # whole module so the reference loops don't dominate the test wall time.
    return cfg, model, params, jax.jit(model.decode_step)


def make_engine(served, config=CONFIG):
    model, params = served[1], served[2]
    return ContinuousBatchingEngine(
        model, params, config, step_cache=STEP_CACHE
    )


def synth_requests(cfg, n, seed=0, prompt_lo=4, prompt_hi=40, new_lo=2, new_hi=16):
    rng = np.random.default_rng(seed)
    return [
        (
            rng.integers(1, cfg.vocab_size, size=int(rng.integers(prompt_lo, prompt_hi))).astype(np.int32),
            int(rng.integers(new_lo, new_hi)),
        )
        for _ in range(n)
    ]


def reference_decode(served, prompt, max_new, eos_id=None):
    """Sequential per-request greedy decode — the correctness oracle."""
    cfg, model, params, decode = served
    logits, caches = model.prefill(
        params, jnp.asarray(prompt)[None, :], CONFIG.max_len
    )
    toks = [int(jnp.argmax(logits[0, -1, : cfg.vocab_size]))]
    idx = len(prompt)
    while len(toks) < max_new and not (eos_id is not None and toks[-1] == eos_id):
        logits, caches = decode(
            params, caches,
            jnp.asarray([[toks[-1]]], jnp.int32), jnp.asarray(idx, jnp.int32),
        )
        toks.append(int(jnp.argmax(logits[0, 0, : cfg.vocab_size])))
        idx += 1
    return toks


class TestOutputEquivalence:
    def test_engine_matches_sequential_reference(self, served):
        cfg = served[0]
        engine = make_engine(served)
        trace = synth_requests(cfg, 10, seed=1)
        rids = [engine.submit(p, n) for p, n in trace]
        outputs = engine.run()
        for rid, (prompt, new) in zip(rids, trace):
            assert list(outputs[rid]) == reference_decode(served, prompt, new)
        assert engine.stats.finished == len(trace)

    def test_eos_terminates_early(self, served):
        cfg = served[0]
        engine = make_engine(served)
        trace = synth_requests(cfg, 6, seed=2, new_lo=8, new_hi=16)
        # Use each request's own first reference token as a cheap "eos" so at
        # least the single-token case exercises the eos path; others stop on
        # budget exactly like the reference loop.
        refs, rids = [], []
        for prompt, new in trace:
            full = reference_decode(served, prompt, new)
            eos = full[min(2, len(full) - 1)]
            refs.append(reference_decode(served, prompt, new, eos_id=eos))
            rids.append(engine.submit(prompt, new, eos_id=eos))
        outputs = engine.run()
        for rid, ref in zip(rids, refs):
            assert list(outputs[rid]) == ref

    def test_static_mode_same_tokens_more_steps(self, served):
        cfg = served[0]
        trace = synth_requests(cfg, 12, seed=3, new_lo=2, new_hi=24)
        results = {}
        steps = {}
        for continuous in (True, False):
            engine = make_engine(
                served, dataclasses.replace(CONFIG, continuous=continuous)
            )
            rids = [engine.submit(p, n) for p, n in trace]
            out = engine.run()
            results[continuous] = [list(out[r]) for r in rids]
            steps[continuous] = engine.stats.decode_steps
        # Scheduling changes; the math must not.
        assert results[True] == results[False]
        # Static drains each batch to its slowest member: strictly more
        # device steps on a heterogeneous profile.
        assert steps[False] > steps[True]


class TestAdmission:
    def test_budget_and_slot_invariants_every_tick(self, served):
        cfg = served[0]
        engine = make_engine(served)
        for p, n in synth_requests(cfg, 14, seed=4):
            engine.submit(p, n)
        engine.window.close()
        while not engine.done:
            engine.tick()
            assert engine.slots.projected_in_flight() <= CONFIG.l_max
            assert engine.slots.active_count <= CONFIG.num_slots
            assert engine.slots.cached_in_flight() <= engine.slots.projected_in_flight()
        assert engine.stats.peak_projected_tokens <= CONFIG.l_max
        assert engine.stats.finished == 14

    def test_oversized_request_rejected_at_submit(self, served):
        engine = make_engine(served)
        with pytest.raises(ValueError, match="never be admitted"):
            engine.submit(np.arange(1, 120, dtype=np.int32), 100)
        with pytest.raises(ValueError, match="empty prompt"):
            engine.submit(np.zeros((0,), np.int32), 4)
        with pytest.raises(ValueError, match="positive"):
            engine.submit(np.ones((4,), np.int32), 0)

    def test_lookahead_bounds_realization(self, served):
        cfg = served[0]
        engine = make_engine(
            served, dataclasses.replace(CONFIG, lookahead=2, num_slots=1, l_max=128)
        )
        for p, n in synth_requests(cfg, 10, seed=5, prompt_lo=4, prompt_hi=16, new_lo=2, new_hi=6):
            engine.submit(p, n)
        engine.run()
        # Never more than `lookahead` realized-but-unscheduled requests.
        assert engine.window.stats.peak_resident <= 2
        assert engine.stats.finished == 10

    def test_request_window_is_fifo_and_closable(self):
        window = RequestWindow(lookahead=4)
        from repro.serve.requests import Request

        for i in range(6):
            window.submit(Request(rid=i, prompt=np.ones((3,), np.int32), max_new_tokens=2))
        got = [s.identity for s in window.take(0, 3)]
        assert got == [0, 1, 2]
        assert not window.exhausted(0)  # still open: more may arrive
        window.close()
        with pytest.raises(RuntimeError):
            window.submit(Request(rid=9, prompt=np.ones((3,), np.int32), max_new_tokens=2))
        got += [s.identity for s in window.take(0, 10)]
        assert got == [0, 1, 2, 3, 4, 5]
        assert window.exhausted(0)


class TestSlotLifecycle:
    def test_slots_reused_across_completions(self, served):
        cfg = served[0]
        engine = make_engine(
            served, dataclasses.replace(CONFIG, num_slots=2, l_max=256)
        )
        trace = synth_requests(cfg, 8, seed=6, new_lo=2, new_hi=8)
        rids = [engine.submit(p, n) for p, n in trace]
        outputs = engine.run()
        assert len(outputs) == 8
        slots_used = [s for s, _ in engine.slots.assignments]
        assert len(slots_used) == 8  # every request got a slot
        assert set(slots_used) == {0, 1}  # out of only two slots
        # Reused slots still decode correctly (stale K/V is masked, not cleared).
        for rid, (prompt, new) in zip(rids, trace):
            assert list(outputs[rid]) == reference_decode(served, prompt, new)

    def test_eviction_frees_slot_and_preserves_others(self, served):
        cfg = served[0]
        engine = make_engine(served)
        trace = synth_requests(cfg, 8, seed=7, new_lo=6, new_hi=12)
        rids = [engine.submit(p, n) for p, n in trace]
        engine.window.close()
        victim = None
        while not engine.done:
            engine.tick()
            if victim is None and engine.slots.active_count == CONFIG.num_slots:
                victim = next(
                    rid for slot, rid in engine.slots.assignments
                    if engine.requests[rid].state == "running"
                )
                freed_before = engine.slots.free_count
                engine.evict(victim)
                assert engine.slots.free_count == freed_before + 1
        assert victim is not None
        assert engine.requests[victim].state == EVICTED
        assert engine.stats.evicted == 1
        assert engine.stats.finished == len(trace) - 1
        # The evicted slot was reallocated to a later request (the eviction
        # fires at first full occupancy, with half the trace still queued).
        victim_slot = [s for s, r in engine.slots.assignments if r == victim][0]
        after = [r for s, r in engine.slots.assignments if s == victim_slot]
        assert after.index(victim) < len(after) - 1
        # Everyone else is untouched by the eviction.
        for rid, (prompt, new) in zip(rids, trace):
            if rid == victim:
                continue
            req = engine.requests[rid]
            assert req.state == FINISHED
            assert req.generated == reference_decode(served, prompt, new)


class TestCompileOnce:
    def test_decode_traced_once_across_everything(self, served):
        """Runs LAST in the class ordering that matters: by now the shared
        step cache has served every engine above — admissions, evictions,
        static and continuous modes — and each step must still have traced
        exactly once."""
        cfg = served[0]
        engine = make_engine(served)
        rids = [engine.submit(p, n) for p, n in synth_requests(cfg, 6, seed=8)]
        engine.window.close()
        ticks = 0
        while not engine.done:
            engine.tick()
            ticks += 1
            if ticks == 3 and engine.slots.active_count > 1:
                running = [
                    r for _, r in engine.slots.active()
                ]
                engine.evict(running[0].rid)
        assert engine.decode_traces == 1, (
            f"decode step traced {engine.decode_traces}x across "
            "admission/eviction cycles (compile-once contract broken)"
        )
        assert all(n == 1 for n in engine.prefill_traces.values()), (
            engine.prefill_traces
        )

    def test_mla_and_ssm_archs_rejected(self, served):
        mla_cfg = get_smoke_config("deepseek_7b")
        if mla_cfg.attn_kind == "mla":
            with pytest.raises(NotImplementedError, match="GQA"):
                ContinuousBatchingEngine(LM(mla_cfg), None, CONFIG)


class TestTtlShedding:
    """Per-request TTL load shedding at the admission boundary
    (DESIGN.md §15.7)."""

    def test_saturating_trace_sheds_and_terminates(self, served):
        from repro import obs

        reg = obs.default_registry()
        reg.reset()
        reg.enable()
        # A fake clock the test drives: one "second" per tick, so queueing
        # delay is deterministic and the test spends no wall time waiting.
        clock = {"now": 0.0}
        # CONFIG unchanged so the engine reuses the module's compiled step
        # cache (compile-once across tests).
        engine = make_engine(served)
        engine.time_fn = lambda: clock["now"]
        # Saturate: 16 requests into 4 slots.  Half carry a TTL shorter than
        # the queueing delay the saturation forces; the rest wait forever.
        rids = []
        for i, (prompt, new) in enumerate(synth_requests(served[0], 16, seed=7)):
            rids.append(
                engine.submit(prompt, new, ttl_s=2.0 if i % 2 else None)
            )
        engine.window.close()
        ticks = 0
        while not engine.done:
            clock["now"] += 1.0
            engine.tick()
            ticks += 1
            assert ticks < 500, "saturated engine failed to terminate"
        from repro.serve import FINISHED, SHED

        states = [engine.requests[rid].state for rid in rids]
        assert engine.stats.shed > 0
        assert all(s in (FINISHED, SHED) for s in states), states
        shed = [r for r in engine.requests.values() if r.state == SHED]
        for r in shed:
            assert r.ttl_s is not None  # only TTL-carrying requests shed
            assert r.finished_s is not None
            assert r.slot is None  # never reached a slot
        finished = sum(1 for s in states if s == FINISHED)
        assert finished + len(shed) == len(rids)
        assert finished >= 4  # running requests always complete
        assert reg.counter("odb_serve_shed_total").value == len(shed)
        assert engine.stats.shed == len(shed)
        reg.reset()

    def test_running_requests_never_shed(self, served):
        """A request that reached a slot completes even if its TTL lapses
        mid-decode: shedding is an admission-boundary decision only."""
        clock = {"now": 0.0}
        engine = make_engine(served)
        engine.time_fn = lambda: clock["now"]
        prompt, new = synth_requests(served[0], 1, seed=9)[0]
        rid = engine.submit(prompt, max(new, 4), ttl_s=0.5)
        engine.window.close()
        clock["now"] += 0.1
        engine.tick()  # admits within TTL
        from repro.serve import FINISHED, RUNNING

        assert engine.requests[rid].state == RUNNING
        clock["now"] += 100.0  # TTL long expired while running
        while not engine.done:
            engine.tick()
        assert engine.requests[rid].state == FINISHED
        assert engine.stats.shed == 0

    def test_shedding_runs_under_full_slot_saturation(self, served):
        """Regression: _admit used to return early on free == 0 *before*
        _shed_expired(), so under exactly the saturation §15.7 exists for,
        expired waiters were never shed until a slot freed."""
        from repro import obs
        from repro.serve import RUNNING, SHED

        reg = obs.default_registry()
        reg.reset()
        reg.enable()
        clock = {"now": 0.0}
        engine = make_engine(served)
        engine.time_fn = lambda: clock["now"]
        # Fill every slot with long-running no-TTL requests...
        runners = [
            engine.submit(prompt, 12)
            for prompt, _ in synth_requests(served[0], CONFIG.num_slots, seed=3)
        ]
        engine.tick()
        assert engine.slots.free_count == 0
        assert all(engine.requests[r].state == RUNNING for r in runners)
        # ...then queue waiters whose TTL lapses while the slots stay busy.
        waiters = [
            engine.submit(prompt, new, ttl_s=1.0)
            for prompt, new in synth_requests(served[0], 4, seed=4)
        ]
        shed_before = reg.counter("odb_serve_shed_total").value
        clock["now"] += 5.0  # TTLs long expired; runners still mid-decode
        engine.tick()
        assert engine.slots.free_count == 0  # saturation held through the tick
        assert all(engine.requests[r].state == RUNNING for r in runners)
        assert all(engine.requests[w].state == SHED for w in waiters)
        assert engine.stats.shed == len(waiters)
        assert reg.counter("odb_serve_shed_total").value - shed_before == len(
            waiters
        )
        engine.window.close()
        while not engine.done:
            engine.tick()
        reg.reset()


class TestTelemetry:
    """One engine tick must emit the documented span + metric set
    (DESIGN.md §13)."""

    def test_one_tick_emits_documented_spans_and_metrics(self, served):
        from repro import obs

        reg, tracer = obs.default_registry(), obs.default_tracer()
        reg.reset()
        tracer.reset()
        tracer.enable()
        try:
            # Constructed AFTER reset/enable: the engine caches its
            # instruments at construction.
            engine = make_engine(served)
            for prompt, new in synth_requests(served[0], 2, seed=5):
                engine.submit(prompt, new)
            engine.tick()
            flat = reg.flat()
            assert flat["serve_ticks_total"] == 1
            assert flat["serve_admitted_total"] >= 1
            assert flat["serve_ttft_seconds_count"] >= 1
            assert 0 < flat["serve_slot_occupancy"] <= 1
            assert "serve_queue_depth" in flat
            events = tracer.events()
            names = {e["name"] for e in events}
            assert {
                "serve/tick", "serve/admit", "serve/prefill", "serve/decode"
            } <= names
            # Phases nest inside the tick span (containment = nesting).
            tick = [e for e in events if e["name"] == "serve/tick"][-1]
            for inner_name in ("serve/admit", "serve/prefill", "serve/decode"):
                inner = [e for e in events if e["name"] == inner_name][-1]
                assert tick["ts"] <= inner["ts"]
                assert (
                    inner["ts"] + inner["dur"]
                    <= tick["ts"] + tick["dur"] + 1e-3
                )
        finally:
            reg.reset()
            reg.enable()
            tracer.reset()
            tracer.disable()
