"""Layer blocks and the scanned stack plan.

One *layer* = mixer (attention or SSD) + FFN (dense MLP or MoE), pre-norm,
residual.  Layers are executed under ``jax.lax.scan`` over *units* to keep
the HLO small at 34B–671B scale:

  * homogeneous stacks (dense / pure-MoE / pure-SSM): unit = 1 layer;
  * DeepSeek-V3: 3 leading dense layers form an unrolled prefix, the 58 MoE
    layers scan;
  * Jamba (hybrid 1:7 + MoE every 2): unit = one 8-layer period (1 attn + 7
    Mamba, alternating MoE), scanned 9 times.

Caches (KV / MLA / SSM) are stacked with the same unit structure and carried
through the scan as xs/ys.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.attention import (
    apply_attention,
    init_kv_cache,
    make_attention_params,
)
from repro.models.layers import apply_mlp, apply_norm, make_mlp_params, make_norm_params
from repro.models.moe import make_moe_params, moe_ffn
from repro.models.ssm import apply_ssm_block, init_ssm_cache, make_ssm_params

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class StackPlan:
    prefix_layers: tuple[int, ...]  # unrolled leading layer indices
    unit_layers: tuple[tuple[int, ...], ...]  # scanned units (layer idx tuples)

    @property
    def n_units(self) -> int:
        return len(self.unit_layers)

    @property
    def period(self) -> int:
        return len(self.unit_layers[0]) if self.unit_layers else 0


def stack_plan(cfg) -> StackPlan:
    prefix = tuple(range(cfg.first_k_dense))
    rest = list(range(cfg.first_k_dense, cfg.n_layers))
    period = cfg.attn_period if cfg.family == "hybrid" else 1
    if cfg.family != "hybrid" and cfg.n_experts and cfg.moe_every > 1:
        period = cfg.moe_every
    assert len(rest) % period == 0, (cfg.name, len(rest), period)
    units = tuple(
        tuple(rest[i : i + period]) for i in range(0, len(rest), period)
    )
    # All units must share a structure signature for scan homogeneity.
    sigs = {
        tuple((cfg.layer_kind(l), cfg.layer_is_moe(l)) for l in u) for u in units
    }
    assert len(sigs) <= 1, f"inhomogeneous scan units for {cfg.name}: {sigs}"
    return StackPlan(prefix_layers=prefix, unit_layers=units)


# -----------------------------------------------------------------------------
# Per-layer params / forward
# -----------------------------------------------------------------------------


def make_layer_params(key, cfg, layer_idx: int, dtype) -> Params:
    keys = jax.random.split(key, 4)
    kind = cfg.layer_kind(layer_idx)
    p: Params = {"norm_mixer": make_norm_params(keys[0], cfg, dtype)}
    if kind == "attn":
        p["mixer"] = make_attention_params(keys[1], cfg, dtype)
    else:
        p["mixer"] = make_ssm_params(keys[1], cfg, dtype)
    if cfg.layer_is_moe(layer_idx):
        p["norm_ffn"] = make_norm_params(keys[2], cfg, dtype)
        p["moe"] = make_moe_params(keys[3], cfg, dtype)
        if cfg.dense_residual:
            p["mlp"] = make_mlp_params(keys[3], cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype)
    elif cfg.d_ff:
        p["norm_ffn"] = make_norm_params(keys[2], cfg, dtype)
        p["mlp"] = make_mlp_params(keys[3], cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype)
    return p


def layer_forward(
    params: Params,
    x: jax.Array,
    cfg,
    layer_idx: int,
    positions: jax.Array,
    segments: jax.Array | None,
    cache,
    cache_index,
    mesh,
    dest_slot=None,
):
    kind = cfg.layer_kind(layer_idx)
    h = apply_norm(params["norm_mixer"], x, cfg)
    if kind == "attn":
        mixed, new_cache = apply_attention(
            params["mixer"], h, cfg, positions, segments, cache, cache_index,
            mesh=mesh, dest_slot=dest_slot,
        )
    else:
        if dest_slot is not None:
            raise NotImplementedError(
                "slot-scatter prefill cannot reconstruct per-segment SSM "
                "states from a packed stream; SSM serving uses the "
                "per-request prefill path (DESIGN.md §12)"
            )
        mixed, new_cache = apply_ssm_block(params["mixer"], h, cfg, cache)
    x = x + mixed
    if "norm_ffn" not in params:  # FFN-free block (mamba2: SSD mixer only)
        return x, new_cache
    h = apply_norm(params["norm_ffn"], x, cfg)
    if cfg.layer_is_moe(layer_idx):
        dense_branch = params.get("mlp") if cfg.dense_residual else None
        ffn = moe_ffn(params["moe"], h, cfg, mesh=mesh, dense_params=dense_branch)
    else:
        ffn = apply_mlp(params["mlp"], h, cfg.act, cfg.gated_mlp)
    return x + ffn, new_cache


# -----------------------------------------------------------------------------
# Unit (scan step): a tuple of layers executed in order
# -----------------------------------------------------------------------------


def make_unit_params(key, cfg, layer_indices, dtype) -> Params:
    keys = jax.random.split(key, len(layer_indices))
    return {
        f"sub{j}": make_layer_params(keys[j], cfg, l, dtype)
        for j, l in enumerate(layer_indices)
    }


def unit_forward(unit_params, x, cfg, layer_indices, positions, segments, unit_cache, cache_index, mesh, dest_slot=None):
    new_caches = {}
    for j, layer_idx in enumerate(layer_indices):
        sub_cache = unit_cache.get(f"sub{j}") if unit_cache else None
        x, nc = layer_forward(
            unit_params[f"sub{j}"], x, cfg, layer_idx,
            positions, segments, sub_cache, cache_index, mesh,
            dest_slot=dest_slot,
        )
        if nc is not None:
            new_caches[f"sub{j}"] = nc
    return x, (new_caches or None)


def init_layer_cache(cfg, layer_idx: int, batch: int, max_len: int, dtype):
    if cfg.layer_kind(layer_idx) == "attn":
        return init_kv_cache(cfg, batch, max_len, dtype)
    return init_ssm_cache(cfg, batch, dtype)


def init_unit_cache(cfg, layer_indices, batch: int, max_len: int, dtype) -> Params:
    return {
        f"sub{j}": init_layer_cache(cfg, l, batch, max_len, dtype)
        for j, l in enumerate(layer_indices)
    }


def stack_params(per_unit: list[Params]) -> Params:
    """Stack identical unit pytrees along a new leading axis (for scan)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *per_unit)
