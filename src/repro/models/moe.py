"""Mixture-of-Experts FFN with expert parallelism (EP) over the model axis.

Design (DESIGN.md §7):

  * Router: softmax top-k with renormalization (optionally over sigmoid
    scores — DeepSeek-V3 aux-free style is approximated by score routing
    without an aux loss; noted in DESIGN.md).
  * Dispatch: TPU-native *scatter into capacity buffers* — no (T, E, C)
    one-hot dispatch einsum (which is O(T·E·C·d) compute) and no dynamic
    ragged shapes.  Position-in-expert comes from a cumsum over a small
    (T·K, E_local+1) one-hot; tokens beyond capacity are dropped (GShard
    semantics, capacity_factor configurable).
  * Expert parallelism: the FFN runs inside ``shard_map`` over the full mesh.
    Token activations are data-sharded and *replicated over the model axis*
    (standard TP activation layout), each model shard owns E/TP experts,
    computes its partial output locally, and a single ``psum`` over the model
    axis combines — the same collective a TP MLP already pays, so EP adds no
    extra collective class.
  * The shared expert (DeepSeek) and the dense residual MLP (Arctic) run
    inside the same shard_map as TP-sharded dense MLPs, folded into the same
    psum.
  * FSDP composes for free: expert weights may be *stored* sharded over the
    data axis; the shard_map in_specs request them model-sharded only, so
    GSPMD inserts the all-gather (fwd) / reduce-scatter (bwd) at the boundary
    — exactly ZeRO-3 semantics.

When ``mesh`` is None (unit tests / single device) the same dispatch code
runs over all experts with no collectives.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import act_fn, dense_init

Params = dict[str, Any]


def make_moe_params(key, cfg, dtype) -> Params:
    e, d = cfg.n_experts, cfg.d_model
    ff = cfg.moe_d_ff or cfg.d_ff
    keys = jax.random.split(key, 6)
    p: Params = {
        "router": dense_init(keys[0], d, e, jnp.float32),
        "w_in": dense_init(keys[1], d, ff, dtype)[None].repeat(e, 0) * 1.0,
        "w_gate": dense_init(keys[2], d, ff, dtype)[None].repeat(e, 0) * 1.0,
        "w_out": dense_init(keys[3], ff, d, dtype)[None].repeat(e, 0) * 1.0,
    }
    if cfg.n_shared_experts:
        p["shared"] = {
            "w_in": dense_init(keys[4], d, ff * cfg.n_shared_experts, dtype),
            "w_gate": dense_init(keys[5], d, ff * cfg.n_shared_experts, dtype),
            "w_out": dense_init(keys[4], ff * cfg.n_shared_experts, d, dtype),
        }
    return p


def router_topk(x_flat: jax.Array, router_w: jax.Array, top_k: int):
    """(T, d) -> (T, k) weights + ids.  fp32 routing, renormalized top-k."""
    logits = x_flat.astype(jnp.float32) @ router_w.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.clip(weights.sum(-1, keepdims=True), 1e-9)
    return weights, ids


def _expert_ffn(buf: jax.Array, w_in, w_gate, w_out, act: str) -> jax.Array:
    """(E_loc, C, d) x (E_loc, d, ff) -> (E_loc, C, d) batched expert MLP."""
    h = jnp.einsum("ecd,edf->ecf", buf, w_in)
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    h = act_fn(act)(g) * h
    return jnp.einsum("ecf,efd->ecd", h, w_out)


def dispatch_compute_combine(
    x_flat: jax.Array,  # (T, d) local tokens
    weights: jax.Array,  # (T, k) fp32
    ids: jax.Array,  # (T, k) global expert ids
    w_in: jax.Array,  # (E_loc, d, ff) local expert slab
    w_gate: jax.Array,
    w_out: jax.Array,
    *,
    e_start: int | jax.Array,
    capacity: int,
    act: str,
) -> jax.Array:
    """Scatter → batched expert GEMMs → gather-combine for local experts.

    Returns the *partial* output (T, d): contributions of experts outside
    [e_start, e_start + E_loc) are zero; the caller psums over the EP axis.
    """
    t, k = ids.shape
    n_local = w_in.shape[0]
    d = x_flat.shape[-1]
    flat_ids = ids.reshape(-1)  # (T*k,)
    local = flat_ids - e_start
    in_range = (local >= 0) & (local < n_local)
    safe_local = jnp.where(in_range, local, n_local)  # n_local = trash bucket
    onehot = jax.nn.one_hot(safe_local, n_local + 1, dtype=jnp.int32)
    slot = (jnp.cumsum(onehot, axis=0) - 1) * onehot
    slot = slot.sum(axis=1)  # (T*k,) position within expert
    keep = in_range & (slot < capacity)
    dest_e = jnp.where(keep, safe_local, n_local)
    dest_c = jnp.where(keep, slot, 0)
    token_of = jnp.arange(t * k) // k

    buf = jnp.zeros((n_local + 1, capacity, d), dtype=x_flat.dtype)
    buf = buf.at[dest_e, dest_c].add(
        x_flat[token_of] * keep[:, None].astype(x_flat.dtype)
    )
    out_buf = _expert_ffn(buf[:n_local], w_in, w_gate, w_out, act)
    out_buf = jnp.concatenate(
        [out_buf, jnp.zeros((1, capacity, d), dtype=out_buf.dtype)], axis=0
    )
    gathered = out_buf[dest_e, dest_c]  # (T*k, d)
    w = (weights.reshape(-1) * keep).astype(gathered.dtype)
    y = jnp.zeros((t, d), dtype=gathered.dtype)
    y = y.at[token_of].add(gathered * w[:, None])
    return y


def _dense_tp_mlp(x_flat, shared: Params, act: str) -> jax.Array:
    """Shared-expert / dense-residual MLP on a local ff slice (TP shard)."""
    h = x_flat @ shared["w_in"]
    g = x_flat @ shared["w_gate"]
    return (act_fn(act)(g) * h) @ shared["w_out"]


def moe_capacity(tokens_local: int, top_k: int, n_experts: int, factor: float) -> int:
    cap = int(tokens_local * top_k / max(n_experts, 1) * factor)
    return max((cap + 7) // 8 * 8, 8)


def moe_ffn(
    params: Params,
    x: jax.Array,  # (B, S, d) — global array under jit
    cfg,
    mesh=None,
    dense_params: Params | None = None,  # Arctic dense-residual branch
    dispatch_chunks: int = 1,
) -> jax.Array:
    """MoE FFN; EP/TP over the `model` mesh axis via shard_map when given."""
    act = cfg.act

    if mesh is None or "model" not in mesh.axis_names or mesh.shape["model"] == 1:
        b, s, d = x.shape
        x_flat = x.reshape(-1, d)
        weights, ids = router_topk(x_flat, params["router"], cfg.top_k)
        cap = moe_capacity(x_flat.shape[0], cfg.top_k, cfg.n_experts, cfg.capacity_factor)
        y = dispatch_compute_combine(
            x_flat, weights, ids,
            params["w_in"], params["w_gate"], params["w_out"],
            e_start=0, capacity=cap, act=act,
        )
        if "shared" in params:
            y = y + _dense_tp_mlp(x_flat, params["shared"], act)
        if dense_params is not None:
            y = y + _dense_tp_mlp(x_flat, dense_params, act)
        return y.reshape(b, s, d).astype(x.dtype)

    # DP axes that evenly divide the batch (batch=1 long-context decode is
    # replicated across data — DESIGN.md §7).
    dp_list: list[str] = []
    size = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names and x.shape[0] % (size * mesh.shape[a]) == 0:
            dp_list.append(a)
            size *= mesh.shape[a]
    dp = tuple(dp_list) if dp_list else None
    ep = mesh.shape["model"]
    n_local = cfg.n_experts // ep

    def body(x_loc, router_w, w_in, w_gate, w_out, shared, dense):
        b, s, d = x_loc.shape
        x_flat = x_loc.reshape(-1, d)
        weights, ids = router_topk(x_flat, router_w, cfg.top_k)
        cap = moe_capacity(x_flat.shape[0], cfg.top_k, cfg.n_experts, cfg.capacity_factor)
        e_start = jax.lax.axis_index("model") * n_local
        if dispatch_chunks > 1 and x_flat.shape[0] % dispatch_chunks == 0:
            xc = x_flat.reshape(dispatch_chunks, -1, d)
            wc = weights.reshape(dispatch_chunks, -1, cfg.top_k)
            ic = ids.reshape(dispatch_chunks, -1, cfg.top_k)
            cap_c = moe_capacity(
                xc.shape[1], cfg.top_k, cfg.n_experts, cfg.capacity_factor
            )
            def chunk(_, args):
                xf, wf, idf = args
                return None, dispatch_compute_combine(
                    xf, wf, idf, w_in, w_gate, w_out,
                    e_start=e_start, capacity=cap_c, act=act,
                )
            _, yc = jax.lax.scan(chunk, None, (xc, wc, ic))
            y = yc.reshape(-1, d)
        else:
            y = dispatch_compute_combine(
                x_flat, weights, ids, w_in, w_gate, w_out,
                e_start=e_start, capacity=cap, act=act,
            )
        if shared is not None:
            y = y + _dense_tp_mlp(x_flat, shared, act)
        if dense is not None:
            y = y + _dense_tp_mlp(x_flat, dense, act)
        y = jax.lax.psum(y, "model")
        return y.reshape(b, s, d).astype(x_loc.dtype)

    shared = params.get("shared")
    shared_specs = (
        {"w_in": P(None, "model"), "w_gate": P(None, "model"), "w_out": P("model", None)}
        if shared is not None
        else None
    )
    dense_specs = (
        {"w_in": P(None, "model"), "w_gate": P(None, "model"), "w_out": P("model", None)}
        if dense_params is not None
        else None
    )
    fn = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(dp, None, None),  # x
            P(None, None),  # router (replicated)
            P("model", None, None),  # expert slabs: EP over model
            P("model", None, None),
            P("model", None, None),
            shared_specs,
            dense_specs,
        ),
        out_specs=P(dp, None, None),
        check_vma=False,
    )
    return fn(
        x, params["router"], params["w_in"], params["w_gate"], params["w_out"],
        shared, dense_params,
    )
