"""Primitive layers: norms, rotary embeddings, MLP, init, cross-entropy."""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]


def truncated_normal_init(key, shape, scale: float, dtype) -> jax.Array:
    stddev = scale / max(math.sqrt(shape[0]), 1.0)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * stddev).astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    return truncated_normal_init(key, (d_in, d_out), 1.0, dtype)


# -- norms ---------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array | None, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    if weight is not None:
        y = y * weight.astype(jnp.float32)
    return y.astype(dt)


def layer_norm(
    x: jax.Array,
    weight: jax.Array | None,
    bias: jax.Array | None,
    eps: float = 1e-5,
) -> jax.Array:
    """Parametric LN, or OLMo's non-parametric LN when weight/bias are None."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    if weight is not None:
        y = y * weight.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dt)


def make_norm_params(key, cfg, dtype) -> Params:
    if cfg.norm == "ln_nonparam":
        return {}
    return {"scale": jnp.ones((cfg.d_model,), dtype=dtype)}


def apply_norm(params: Params, x: jax.Array, cfg) -> jax.Array:
    if cfg.norm == "rms":
        return rms_norm(x, params.get("scale"))
    if cfg.norm == "ln":
        return layer_norm(x, params.get("scale"), None)
    return layer_norm(x, None, None)  # non-parametric (OLMo)


# -- rotary --------------------------------------------------------------------


def rope_frequencies(d_head: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head
    return 1.0 / (theta**exponent)  # (d_head/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, n_heads, d_head); positions: (..., seq)."""
    d_head = x.shape[-1]
    freqs = rope_frequencies(d_head, theta)  # (d/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, d/2)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., seq, 1, d/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- MLP -----------------------------------------------------------------------


def act_fn(name: str):
    return jax.nn.silu if name == "silu" else jax.nn.gelu


def make_mlp_params(key, d_model: int, d_ff: int, gated: bool, dtype) -> Params:
    keys = jax.random.split(key, 3)
    p = {
        "w_in": dense_init(keys[0], d_model, d_ff, dtype),
        "w_out": dense_init(keys[1], d_ff, d_model, dtype),
    }
    if gated:
        p["w_gate"] = dense_init(keys[2], d_model, d_ff, dtype)
    return p


def apply_mlp(params: Params, x: jax.Array, act: str, gated: bool) -> jax.Array:
    h = x @ params["w_in"]
    if gated:
        h = act_fn(act)(x @ params["w_gate"]) * h
    else:
        h = act_fn(act)(h)
    return h @ params["w_out"]


# -- losses --------------------------------------------------------------------


def masked_cross_entropy(
    logits: jax.Array,  # (..., seq, vocab)
    labels: jax.Array,  # (..., seq) int32
    mask: jax.Array,  # (..., seq) float — 1 on valid targets
    *,
    fp32: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Returns (loss_sum, token_count) — the Eq. 2 accumulation primitives.

    Deliberately returns the *sum* (not mean) so the trainer can apply
    sample-/token-level scaling per the selected ODB mode.
    """
    if fp32:
        logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - picked) * mask
    return jnp.sum(nll), jnp.sum(mask)
