"""Attention: GQA/MQA/MHA + MLA (DeepSeek-V3), KV caches, segment masking.

Two train/prefill implementations behind one entry point (DESIGN.md §11):
the pure-jnp (XLA) blockwise path below, and the Pallas segment-aware flash
kernel in ``repro.kernels`` (fused forward + tiled two-pass backward, same
masking contract, validated against ``ref.py``).  ``use_flash_attention``
routes between them from ``ArchConfig.attn_impl`` — "auto" takes the kernel
exactly when the batch is packed and the backend compiles Pallas (TPU); the
decode/cache path and MLA always use XLA.

Memory design: scores are never materialized at (S_q × S_k).  Queries are
processed in blocks via ``lax.scan`` with the mask computed per block from
positions/segments (no (B, S, S) bias tensor), and the block body is
``jax.checkpoint``-ed so the backward pass recomputes per-block probs instead
of saving them — O(S·block) live attention memory instead of O(S²), the
pure-XLA analogue of flash attention's tiling.

Masking contract (shared with the Pallas kernel): attention is allowed iff
``segment_ids`` match (padding carries segment 0) AND (causal ⇒ key position
≤ query position).
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, rms_norm

Params = dict[str, Any]

_NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


class KVCache(NamedTuple):
    k: jax.Array  # (B, S_max, n_kv, d_head)
    v: jax.Array  # (B, S_max, n_kv, d_head)


class MLACache(NamedTuple):
    ckv: jax.Array  # (B, S_max, kv_lora_rank) — compressed latent
    k_rope: jax.Array  # (B, S_max, qk_rope_dim) — shared rope key


# ------------------------------------------------------------------------------
# Parameter construction
# ------------------------------------------------------------------------------


def make_attention_params(key, cfg, dtype) -> Params:
    if cfg.attn_kind == "mla":
        return _make_mla_params(key, cfg, dtype)
    keys = jax.random.split(key, 4)
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    p: Params = {
        "wq": dense_init(keys[0], d, h * dh, dtype),
        "wk": dense_init(keys[1], d, kv * dh, dtype),
        "wv": dense_init(keys[2], d, kv * dh, dtype),
        "wo": dense_init(keys[3], h * dh, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dtype=dtype)
        p["k_norm"] = jnp.ones((dh,), dtype=dtype)
    return p


def _make_mla_params(key, cfg, dtype) -> Params:
    keys = jax.random.split(key, 6)
    d, h = cfg.d_model, cfg.n_heads
    nope, rope, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "w_dq": dense_init(keys[0], d, cfg.q_lora_rank, dtype),
        "q_norm": jnp.ones((cfg.q_lora_rank,), dtype=dtype),
        "w_uq": dense_init(keys[1], cfg.q_lora_rank, h * (nope + rope), dtype),
        "w_dkv": dense_init(keys[2], d, cfg.kv_lora_rank + rope, dtype),
        "kv_norm": jnp.ones((cfg.kv_lora_rank,), dtype=dtype),
        "w_uk": dense_init(keys[3], cfg.kv_lora_rank, h * nope, dtype),
        "w_uv": dense_init(keys[4], cfg.kv_lora_rank, h * vdim, dtype),
        "wo": dense_init(keys[5], h * vdim, d, dtype),
    }


# ------------------------------------------------------------------------------
# Block masking
# ------------------------------------------------------------------------------


def _block_mask(
    q_pos,  # (B, qb)
    k_pos,  # (B, Sk)
    q_seg,  # (B, qb) | None
    k_seg,  # (B, Sk) | None
    k_limit,  # scalar | None — keys at positions >= limit are invalid (cache)
    causal: bool,
):
    """(B, qb, Sk) boolean allow-mask computed per query block."""
    allowed = jnp.ones((q_pos.shape[0], q_pos.shape[1], k_pos.shape[1]), bool)
    if causal:
        allowed &= k_pos[:, None, :] <= q_pos[:, :, None]
    if q_seg is not None and k_seg is not None:
        allowed &= (q_seg[:, :, None] == k_seg[:, None, :]) & (
            k_seg[:, None, :] > 0
        )
    if k_limit is not None:
        allowed &= k_pos[:, None, :] < k_limit
    return allowed


def _pick_block(s: int, preferred: int = 256) -> int:
    for b in (preferred, 128, 64, 32, 16, 8, 4, 2, 1):
        if b <= s and s % b == 0:
            return b
    return 1


# ------------------------------------------------------------------------------
# Kernel routing (DESIGN.md §11): XLA blockwise vs Pallas flash
# ------------------------------------------------------------------------------


def use_flash_attention(cfg, segments, cache) -> bool:
    """Route this call through the Pallas segment-aware flash kernel?

    Structural gates first: only GQA-layout attention without a KV cache
    (train / full-sequence forward) matches the kernel contract.  Then the
    ``attn_impl`` policy: "flash" forces the kernel (interpret mode off-TPU —
    the tests' path), "xla" forces the blockwise-scan path, "auto" picks the
    kernel exactly when the batch is packed (explicit segments, where the
    kernel's segment-range block skipping pays) and the backend compiles
    Pallas (TPU).
    """
    if cache is not None:
        return False
    impl = getattr(cfg, "attn_impl", "xla")
    if impl == "flash":
        return True
    if impl == "auto":
        return segments is not None and jax.default_backend() == "tpu"
    return False


def resolve_flash_grid(cfg, segments) -> str:
    """Concrete grid variant for this call (DESIGN.md §17): the config's
    ``attn_grid`` policy resolved against segment presence and backend —
    shared by the kernel dispatch and the autotune cache key."""
    from repro.kernels.ops import resolve_grid

    return resolve_grid(getattr(cfg, "attn_grid", "auto"), segments)


def _flash_blocks(
    cfg, s: int, b: int, h: int, kv: int, dh: int, dtype, has_segments,
    grid: str = "dense",
):
    """Resolve the (block_q, block_kv) schedule for one shape cell."""
    from repro.kernels.autotune import autotune_blocks, heuristic_blocks
    from repro.kernels.flash_attention import select_block

    if cfg.attn_block_q or cfg.attn_block_kv:
        # Partial pins are honored: the unset side falls back to the
        # heuristic width rather than dropping the explicit one.
        return (
            select_block(s, cfg.attn_block_q or 128),
            select_block(s, cfg.attn_block_kv or 128),
        )
    if cfg.attn_autotune:
        return autotune_blocks(
            b, s, h, kv, dh,
            dtype=dtype, causal=cfg.causal, has_segments=has_segments,
            grid=grid,
        )
    return heuristic_blocks(s)


# ------------------------------------------------------------------------------
# Blockwise SDPA (GQA layout)
# ------------------------------------------------------------------------------


def _block_sdpa(
    q,  # (B, Sq, K, G, dh)
    k,  # (B, Sk, K, dh)
    v,  # (B, Sk, K, dh)
    q_pos,  # (B, Sq)
    k_pos,  # (B, Sk)
    q_seg,  # (B, Sq) | None
    k_seg,  # (B, Sk) | None
    k_limit,  # scalar | None
    causal: bool,
    scale: float,
    q_block: int = 256,
):
    b, sq, kh, g, dh = q.shape
    blk = _pick_block(sq, q_block)

    def block_body(qi, qpi, qsi):
        scores = (
            jnp.einsum("bqkgd,bskd->bkgqs", qi, k).astype(jnp.float32) * scale
        )
        allowed = _block_mask(qpi, k_pos, qsi, k_seg, k_limit, causal)
        scores = jnp.where(allowed[:, None, None, :, :], scores, _NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        return jnp.einsum("bkgqs,bskd->bqkgd", probs, v)

    if blk == sq:
        return block_body(q, q_pos, q_seg)

    nb = sq // blk
    qb = q.reshape(b, nb, blk, kh, g, dh).transpose(1, 0, 2, 3, 4, 5)
    qpb = q_pos.reshape(b, nb, blk).transpose(1, 0, 2)
    qsb = (
        q_seg.reshape(b, nb, blk).transpose(1, 0, 2) if q_seg is not None else None
    )

    def scan_body(_, xs):
        if qsb is None:
            qi, qpi = xs
            qsi = None
        else:
            qi, qpi, qsi = xs
        return None, block_body(qi, qpi, qsi)

    xs = (qb, qpb) if qsb is None else (qb, qpb, qsb)
    _, outs = jax.lax.scan(jax.checkpoint(scan_body), None, xs)
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, kh, g, dh)


# ------------------------------------------------------------------------------
# GQA forward (train / prefill / decode)
# ------------------------------------------------------------------------------


def _head_constraint(t, mesh, head_axis: int):
    """Annotate per-head tensors with (possibly uneven) `model` sharding so
    GSPMD keeps head-parallel layout through the reshape instead of falling
    back to 'involuntary full rematerialization' (replicate-then-reshard) —
    the yi/arctic 56-head fix (§Perf lever).  Uneven constraints are legal on
    intermediates (GSPMD pads)."""
    if mesh is None or "model" not in mesh.axis_names:
        return t
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.launch.sharding import batch_dp_axes

    dp = batch_dp_axes(t.shape[0], mesh)
    spec = [dp] + [None] * (t.ndim - 1)
    spec[head_axis] = "model"
    return jax.lax.with_sharding_constraint(t, NamedSharding(mesh, P(*spec)))


def gqa_attention(
    params: Params,
    x: jax.Array,  # (B, S, d)
    cfg,
    positions: jax.Array,  # (B, S)
    segments: jax.Array | None = None,
    cache: KVCache | None = None,
    cache_index: jax.Array | None = None,  # scalar or (B,): tokens cached
    mesh=None,
    dest_slot: jax.Array | None = None,  # (B, S): packed→slot scatter map
) -> tuple[jax.Array, KVCache | None]:
    b, s, _ = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    g = h // kv
    q = (x @ params["wq"]).reshape(b, s, h, dh)
    k = (x @ params["wk"]).reshape(b, s, kv, dh)
    v = (x @ params["wv"]).reshape(b, s, kv, dh)
    if cfg.attn_head_constraint:
        q = _head_constraint(q, mesh, 2)
        k = _head_constraint(k, mesh, 2)
        v = _head_constraint(v, mesh, 2)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if cache is not None and dest_slot is not None:
        # Slot-scatter prefill (serving, DESIGN.md §12): attention itself is
        # the cache-free packed-segment path — flash-eligible, identical
        # masking contract — while the roped K/V stream is scattered into
        # per-request cache rows at (dest_slot, within-segment position).
        # Padding positions carry an out-of-range dest row, so their writes
        # drop; within-segment rope positions are exactly the per-slot
        # absolute positions the decode path replays against.
        ck = cache.k.at[dest_slot, positions].set(
            k.astype(cache.k.dtype), mode="drop"
        )
        cv = cache.v.at[dest_slot, positions].set(
            v.astype(cache.v.dtype), mode="drop"
        )
        new_cache = KVCache(k=ck, v=cv)
        if use_flash_attention(cfg, segments, None):
            from repro.kernels.ops import flash_attention

            grid = resolve_flash_grid(cfg, segments)
            bq, bkv = _flash_blocks(
                cfg, s, b, h, kv, dh, q.dtype, segments is not None, grid
            )
            out = flash_attention(q, k, v, segments, cfg.causal, bq, bkv, grid)
        else:
            out = _block_sdpa(
                q.reshape(b, s, kv, g, dh), k, v, positions, positions,
                segments, segments, None, cfg.causal, 1.0 / (dh**0.5),
            )
        return out.reshape(b, s, h * dh) @ params["wo"], new_cache

    if use_flash_attention(cfg, segments, cache):
        # Pallas fused path: the kernel's row-absolute causal mask plus the
        # segment-id mask realizes the identical objective as the XLA
        # blockwise path's within-segment positions (cross-segment pairs die
        # on the segment compare either way), so the two routes are
        # numerically interchangeable (tests/test_kernels.py).
        from repro.kernels.ops import flash_attention

        grid = resolve_flash_grid(cfg, segments)
        bq, bkv = _flash_blocks(
            cfg, s, b, h, kv, dh, q.dtype, segments is not None, grid
        )
        out = flash_attention(q, k, v, segments, cfg.causal, bq, bkv, grid)
        return out.reshape(b, s, h * dh) @ params["wo"], None

    q = q.reshape(b, s, kv, g, dh)

    new_cache = None
    if cache is not None:
        assert cache_index is not None
        if jnp.ndim(cache_index) == 1:
            # Per-slot cache frontier (continuous-batching decode): row i
            # writes its new K/V at its own offset ``cache_index[i]`` and
            # reads keys strictly below its frontier — every slot sits at a
            # different depth inside one fixed-shape step.
            rows = jnp.arange(b, dtype=jnp.int32)[:, None]
            cols = (
                cache_index.astype(jnp.int32)[:, None]
                + jnp.arange(s, dtype=jnp.int32)[None, :]
            )
            ck = cache.k.at[rows, cols].set(k.astype(cache.k.dtype), mode="drop")
            cv = cache.v.at[rows, cols].set(v.astype(cache.v.dtype), mode="drop")
            k_limit = (cache_index.astype(positions.dtype)[:, None] + s)[:, :, None]
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache.k, k.astype(cache.k.dtype), cache_index, axis=1
            )
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache.v, v.astype(cache.v.dtype), cache_index, axis=1
            )
            k_limit = cache_index + s
        new_cache = KVCache(k=ck, v=cv)
        s_max = ck.shape[1]
        k_pos = jnp.broadcast_to(
            jnp.arange(s_max, dtype=positions.dtype), (b, s_max)
        )
        out = _block_sdpa(
            q, ck.astype(q.dtype), cv.astype(q.dtype),
            positions, k_pos, None, None, k_limit, cfg.causal,
            1.0 / (dh**0.5),
        )
    else:
        out = _block_sdpa(
            q, k, v, positions, positions, segments, segments, None,
            cfg.causal, 1.0 / (dh**0.5),
        )
    out = out.reshape(b, s, h * dh)
    return out @ params["wo"], new_cache


# ------------------------------------------------------------------------------
# MLA forward
# ------------------------------------------------------------------------------


def _mla_block_sdpa(
    q_nope,  # (B, Sq, H, nope)
    q_rope,  # (B, Sq, H, rope)
    k_nope,  # (B, Sk, H, nope)
    k_rope,  # (B, Sk, rope)
    v,  # (B, Sk, H, vdim)
    q_pos, k_pos, q_seg, k_seg, k_limit, causal, scale, q_block=256,
):
    b, sq, h, _ = q_nope.shape

    def block_body(qn, qr, qpi, qsi):
        scores = jnp.einsum("bqhd,bshd->bhqs", qn, k_nope).astype(jnp.float32)
        scores += jnp.einsum("bqhd,bsd->bhqs", qr, k_rope).astype(jnp.float32)
        scores *= scale
        allowed = _block_mask(qpi, k_pos, qsi, k_seg, k_limit, causal)
        scores = jnp.where(allowed[:, None, :, :], scores, _NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        return jnp.einsum("bhqs,bshd->bqhd", probs, v)

    blk = _pick_block(sq)
    if blk == sq:
        return block_body(q_nope, q_rope, q_pos, q_seg)
    nb = sq // blk
    qn = q_nope.reshape(b, nb, blk, h, -1).transpose(1, 0, 2, 3, 4)
    qr = q_rope.reshape(b, nb, blk, h, -1).transpose(1, 0, 2, 3, 4)
    qpb = q_pos.reshape(b, nb, blk).transpose(1, 0, 2)
    qsb = q_seg.reshape(b, nb, blk).transpose(1, 0, 2) if q_seg is not None else None

    def scan_body(_, xs):
        if qsb is None:
            a, r, p = xs
            sgm = None
        else:
            a, r, p, sgm = xs
        return None, block_body(a, r, p, sgm)

    xs = (qn, qr, qpb) if qsb is None else (qn, qr, qpb, qsb)
    _, outs = jax.lax.scan(jax.checkpoint(scan_body), None, xs)
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, -1)


def mla_attention(
    params: Params,
    x: jax.Array,
    cfg,
    positions: jax.Array,
    segments: jax.Array | None = None,
    cache: MLACache | None = None,
    cache_index: jax.Array | None = None,
) -> tuple[jax.Array, MLACache | None]:
    b, s, _ = x.shape
    h = cfg.n_heads
    nope, rope, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    scale = 1.0 / ((nope + rope) ** 0.5)

    cq = rms_norm(x @ params["w_dq"], params["q_norm"])
    q = (cq @ params["w_uq"]).reshape(b, s, h, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    dkv = x @ params["w_dkv"]
    ckv = rms_norm(dkv[..., : cfg.kv_lora_rank], params["kv_norm"])
    k_rope = apply_rope(
        dkv[..., cfg.kv_lora_rank :][:, :, None, :], positions, cfg.rope_theta
    )[:, :, 0, :]

    if cache is not None and s == 1:
        # Decode — weight-absorbed latent attention: attend in the compressed
        # space so per-step cost is O(S·(kv_lora+rope)) per head and the
        # cache stays (kv_lora + rope) per token (the MLA memory win).
        assert cache_index is not None
        cckv = jax.lax.dynamic_update_slice_in_dim(
            cache.ckv, ckv.astype(cache.ckv.dtype), cache_index, axis=1
        )
        ckr = jax.lax.dynamic_update_slice_in_dim(
            cache.k_rope, k_rope.astype(cache.k_rope.dtype), cache_index, axis=1
        )
        new_cache = MLACache(ckv=cckv, k_rope=ckr)
        s_max = cckv.shape[1]
        w_uk = params["w_uk"].reshape(cfg.kv_lora_rank, h, nope)
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk)
        scores = jnp.einsum("bshr,btr->bhst", q_lat, cckv).astype(jnp.float32)
        scores += jnp.einsum("bshr,btr->bhst", q_rope, ckr).astype(jnp.float32)
        scores *= scale
        k_pos = jnp.broadcast_to(jnp.arange(s_max, dtype=positions.dtype), (b, s_max))
        allowed = (k_pos[:, None, :] <= positions[:, :, None]) & (
            k_pos[:, None, :] < (cache_index + s)
        )
        scores = jnp.where(allowed[:, None, :, :], scores, _NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(cckv.dtype)
        out_lat = jnp.einsum("bhst,btr->bshr", probs, cckv)
        w_uv = params["w_uv"].reshape(cfg.kv_lora_rank, h, vdim)
        out = jnp.einsum("bshr,rhv->bshv", out_lat, w_uv)
        out = out.reshape(b, s, h * vdim)
        return out @ params["wo"], new_cache

    # Train / prefill — direct (non-absorbed) form with blockwise SDPA.
    k_nope = (ckv @ params["w_uk"]).reshape(b, s, h, nope)
    v = (ckv @ params["w_uv"]).reshape(b, s, h, vdim)
    new_cache = None
    if cache is not None:  # prefill fills the latent cache
        cckv = jax.lax.dynamic_update_slice_in_dim(
            cache.ckv, ckv.astype(cache.ckv.dtype), cache_index, axis=1
        )
        ckr = jax.lax.dynamic_update_slice_in_dim(
            cache.k_rope, k_rope.astype(cache.k_rope.dtype), cache_index, axis=1
        )
        new_cache = MLACache(ckv=cckv, k_rope=ckr)
    out = _mla_block_sdpa(
        q_nope, q_rope, k_nope, k_rope, v,
        positions, positions, segments, segments, None, cfg.causal, scale,
    )
    out = out.reshape(b, s, h * vdim)
    return out @ params["wo"], new_cache


def apply_attention(params, x, cfg, positions, segments=None, cache=None, cache_index=None, mesh=None, dest_slot=None):
    if cfg.attn_kind == "mla":
        if dest_slot is not None:
            raise NotImplementedError(
                "slot-scatter prefill needs the GQA cache layout; MLA serving "
                "stays on the per-request prefill path (DESIGN.md §12)"
            )
        return mla_attention(params, x, cfg, positions, segments, cache, cache_index)
    return gqa_attention(
        params, x, cfg, positions, segments, cache, cache_index,
        mesh=mesh, dest_slot=dest_slot,
    )


def init_kv_cache(cfg, batch: int, max_len: int, dtype) -> KVCache | MLACache:
    if cfg.attn_kind == "mla":
        return MLACache(
            ckv=jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype=dtype),
            k_rope=jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype=dtype),
        )
    return KVCache(
        k=jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.d_head), dtype=dtype),
        v=jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.d_head), dtype=dtype),
    )
