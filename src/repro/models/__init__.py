"""Model zoo: composable pure-JAX transformer / MoE / SSD / encoder stacks."""

from repro.models.config import ArchConfig
from repro.models.model import LM, padded_vocab, shift_labels
