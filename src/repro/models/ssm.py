"""Mamba-2 block — SSD (state-space duality) chunked form (arXiv:2405.21060).

Pure-jnp implementation structured as a scan over sequence chunks so the
within-chunk quadratic ``L`` matrix never materializes across the whole
sequence (essential for the 524k-token long-context cells).  The Pallas
kernel in ``repro.kernels.ssd_scan`` fuses the same chunk body; this module
is also its numerical oracle's twin (see kernels/ref.py).

Layout notes (TP over the `model` axis, DESIGN.md §7):
  * z/x projections shard the inner dim; per-head tensors shard heads —
    uneven head counts (mamba2-130m: 24 heads) are left to GSPMD padding;
  * B/C (state projections, ngroups=1) are small and replicated;
  * the inter-chunk recurrence carries (h, p, n) state per sequence — no
    cross-device communication inside the scan.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm

Params = dict[str, Any]


class SSMCache(NamedTuple):
    state: jax.Array  # (B, H, P, N) inter-chunk / decode SSM state
    conv: jax.Array  # (B, d_conv - 1, conv_channels) rolling conv window


def make_ssm_params(key, cfg, dtype) -> Params:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.n_ssm_heads
    keys = jax.random.split(key, 8)
    conv_ch = di + 2 * n
    return {
        "in_z": dense_init(keys[0], d, di, dtype),
        "in_x": dense_init(keys[1], d, di, dtype),
        "in_b": dense_init(keys[2], d, n, dtype),
        "in_c": dense_init(keys[3], d, n, dtype),
        "in_dt": dense_init(keys[4], d, h, dtype),
        "conv_w": (jax.random.normal(keys[5], (cfg.d_conv, conv_ch)) * 0.1).astype(dtype),
        "dt_bias": jnp.zeros((h,), dtype=jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "d_skip": jnp.ones((h,), dtype=jnp.float32),
        "out_norm": jnp.ones((di,), dtype=dtype),
        "out_proj": dense_init(keys[6], di, d, dtype),
    }


def _causal_depthwise_conv(x: jax.Array, w: jax.Array, init: jax.Array | None):
    """x: (B, S, C); w: (K, C). Left-pad with `init` (or zeros) — causal."""
    k = w.shape[0]
    if init is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), dtype=x.dtype)
    else:
        pad = init.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return out, xp[:, -(k - 1) :, :] if k > 1 else pad


def _segsum(a: jax.Array) -> jax.Array:
    """Lower-triangular pairwise cumulative sums: out[..., i, j] = Σ_{j<k<=i} a_k."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # (..., i, j) = Σ_{j<k<=i}
    mask = jnp.tril(jnp.ones((q, q), dtype=bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) — positive (post-softplus)
    a: jax.Array,  # (H,) negative decay rates
    b_proj: jax.Array,  # (B, S, N)
    c_proj: jax.Array,  # (B, S, N)
    chunk: int,
    initial_state: jax.Array | None = None,  # (B, H, P, N)
):
    """Chunked SSD scan; returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    n = b_proj.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_proj = jnp.pad(b_proj, ((0, 0), (0, pad), (0, 0)))
        c_proj = jnp.pad(c_proj, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk

    xc = x.reshape(bsz, nc, chunk, h, p).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(bsz, nc, chunk, h).transpose(1, 0, 2, 3)
    bc = b_proj.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)
    cc = c_proj.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)

    def chunk_body(state, inputs):
        xq, dtq, bq, cq = inputs  # (B, Q, H, P), (B, Q, H), (B, Q, N), (B, Q, N)
        adt = (a[None, None, :] * dtq).astype(jnp.float32)  # (B, Q, H)
        acs = jnp.cumsum(adt, axis=1)  # (B, Q, H)
        # Diagonal (within-chunk) term: decay matrix L.
        l_mat = jnp.exp(_segsum(adt.transpose(0, 2, 1)))  # (B, H, Q, Q)
        scores = jnp.einsum("bqn,bsn->bqs", cq.astype(jnp.float32), bq.astype(jnp.float32))
        y_diag = jnp.einsum(
            "bhqs,bqs,bsh,bshp->bqhp",
            l_mat,
            scores,
            dtq.astype(jnp.float32),
            xq.astype(jnp.float32),
        )
        # Off-diagonal: contribution of the carried state.
        state_decay = jnp.exp(acs)  # (B, Q, H)
        y_off = jnp.einsum(
            "bqn,bqh,bhpn->bqhp", cq.astype(jnp.float32), state_decay, state
        )
        # Update the carried state with this chunk.
        chunk_decay = jnp.exp(acs[:, -1:, :] - acs)  # (B, Q, H)
        new_state = state * jnp.exp(acs[:, -1, :])[:, :, None, None]
        new_state += jnp.einsum(
            "bqn,bqh,bqhp->bhpn",
            bq.astype(jnp.float32),
            (chunk_decay * dtq).astype(jnp.float32),
            xq.astype(jnp.float32),
        )
        return new_state, (y_diag + y_off).astype(x.dtype)

    state0 = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((bsz, h, p, n), dtype=jnp.float32)
    )
    final_state, ys = jax.lax.scan(chunk_body, state0, (xc, dtc, bc, cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, nc * chunk, h, p)
    return y[:, :s], final_state


def apply_ssm_block(
    params: Params,
    u: jax.Array,  # (B, S, d_model)
    cfg,
    cache: SSMCache | None = None,
) -> tuple[jax.Array, SSMCache | None]:
    """Full Mamba-2 mixer: proj → conv → SSD → gate → norm → out."""
    bsz, s, _ = u.shape
    di, n, h, p = cfg.d_inner, cfg.d_state, cfg.n_ssm_heads, cfg.ssm_headdim
    z = u @ params["in_z"]
    xbc = jnp.concatenate(
        [u @ params["in_x"], u @ params["in_b"], u @ params["in_c"]], axis=-1
    )
    conv_init = cache.conv if cache is not None else None
    xbc, conv_tail = _causal_depthwise_conv(xbc, params["conv_w"], conv_init)
    xbc = jax.nn.silu(xbc)
    x_in = xbc[..., :di].reshape(bsz, s, h, p)
    b_proj = xbc[..., di : di + n]
    c_proj = xbc[..., di + n :]
    dt = jax.nn.softplus(
        (u @ params["in_dt"]).astype(jnp.float32) + params["dt_bias"]
    )
    dt = jnp.clip(dt, 1e-4, 10.0)
    a = -jnp.exp(params["a_log"])

    init_state = cache.state if cache is not None else None
    if cache is not None and s == 1:
        # Decode: single-step recurrence (no chunking).
        state = cache.state.astype(jnp.float32)  # (B, H, P, N)
        adt = jnp.exp(a[None, :] * dt[:, 0, :])  # (B, H)
        upd = jnp.einsum(
            "bn,bh,bhp->bhpn",
            b_proj[:, 0].astype(jnp.float32),
            dt[:, 0],
            x_in[:, 0].astype(jnp.float32),
        )
        state = state * adt[:, :, None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", state, c_proj[:, 0].astype(jnp.float32))
        y = y[:, None]  # (B, 1, H, P)
        new_cache = SSMCache(state=state.astype(cache.state.dtype), conv=conv_tail)
    else:
        y, final_state = ssd_chunked(
            x_in, dt, a, b_proj, c_proj, cfg.ssm_chunk, init_state
        )
        new_cache = (
            SSMCache(state=final_state.astype(u.dtype), conv=conv_tail)
            if cache is not None
            else None
        )

    y = y + params["d_skip"][None, None, :, None] * x_in.astype(jnp.float32)
    y = y.reshape(bsz, s, di).astype(u.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["out_norm"])
    return y @ params["out_proj"], new_cache


def init_ssm_cache(cfg, batch: int, dtype) -> SSMCache:
    conv_ch = cfg.d_inner + 2 * cfg.d_state
    return SSMCache(
        state=jnp.zeros(
            (batch, cfg.n_ssm_heads, cfg.ssm_headdim, cfg.d_state), dtype=dtype
        ),
        conv=jnp.zeros((batch, cfg.d_conv - 1, conv_ch), dtype=dtype),
    )
