"""The language model: embeddings + scanned stack + heads + entry points.

Entry points (consumed by launch/ and train/):

  * ``forward(params, batch)``       → logits           (train / encoder)
  * ``loss_sums(params, batch)``     → (loss_sum, token_count)  — the Eq. 2
    accumulation primitives (trainer applies ODB loss scaling);
  * ``prefill(params, tokens, max_len)`` → (logits, caches)
  * ``decode_step(params, caches, tokens, cache_index)`` → (logits, caches)

Batches are dicts: ``tokens`` (B, S) int32 *or* ``embeds`` (B, S, d) for
stubbed-frontend archs (hubert), plus ``labels``, ``loss_mask`` and optional
``positions`` / ``segments`` (packed layout).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.blocks import (
    init_unit_cache,
    make_unit_params,
    stack_params,
    stack_plan,
    unit_forward,
)
from repro.models.config import ArchConfig
from repro.models.layers import (
    apply_norm,
    dense_init,
    make_norm_params,
    masked_cross_entropy,
)

Params = dict[str, Any]

VOCAB_ALIGN = 256  # pad vocab so TP=16 divides and MXU lanes align


def padded_vocab(vocab: int) -> int:
    return (vocab + VOCAB_ALIGN - 1) // VOCAB_ALIGN * VOCAB_ALIGN


def _sp_constraint(x, mesh):
    """Sequence-parallel sharding constraint on the residual stream:
    (B, S, d) → P(dp, "model", None).  GSPMD inserts the all-gather on
    entering attention/FFN and the reduce-scatter on exit (the standard SP
    exchange), shrinking resident activations, norm intermediates and saved
    remat carries by the TP degree (§Perf lever)."""
    if mesh is None or "model" not in mesh.axis_names:
        return x
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.launch.sharding import batch_dp_axes

    dp = batch_dp_axes(x.shape[0], mesh)
    if x.shape[1] % mesh.shape["model"] != 0:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(dp, "model", None))
    )


@dataclasses.dataclass
class LM:
    cfg: ArchConfig
    mesh: Any = None

    def __post_init__(self):
        self.plan = stack_plan(self.cfg)
        self.dtype = jnp.dtype(self.cfg.dtype)
        impl = self.cfg.attn_impl
        if impl not in ("xla", "flash", "auto"):
            raise ValueError(
                f"attn_impl {impl!r} not in ('xla', 'flash', 'auto')"
            )
        if impl == "flash" and self.cfg.attn_kind == "mla":
            raise ValueError(
                "attn_impl='flash' requires GQA-layout attention; MLA's "
                "latent score decomposition trains on the XLA blockwise path"
            )

    # -- init ------------------------------------------------------------------
    def init(self, rng) -> Params:
        cfg = self.cfg
        vp = padded_vocab(cfg.vocab_size)
        k_embed, k_unembed, k_norm, k_prefix, k_stack = jax.random.split(rng, 5)
        params: Params = {"final_norm": make_norm_params(k_norm, cfg, self.dtype)}
        if not cfg.input_embeds:
            params["embed"] = dense_init(k_embed, vp, cfg.d_model, self.dtype)
        params["unembed"] = dense_init(k_unembed, cfg.d_model, vp, self.dtype)
        if self.plan.prefix_layers:
            keys = jax.random.split(k_prefix, len(self.plan.prefix_layers))
            params["prefix"] = [
                make_unit_params(keys[i], cfg, (l,), self.dtype)
                for i, l in enumerate(self.plan.prefix_layers)
            ]
        keys = jax.random.split(k_stack, self.plan.n_units)
        per_unit = [
            make_unit_params(keys[u], cfg, self.plan.unit_layers[u], self.dtype)
            for u in range(self.plan.n_units)
        ]
        params["stack"] = stack_params(per_unit)
        return params

    def abstract_params(self, rng=None) -> Params:
        """Shape/dtype-only params (no allocation) — for the dry-run."""
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    # -- core stack ---------------------------------------------------------------
    def _embed(self, params: Params, batch: dict) -> jax.Array:
        if self.cfg.input_embeds:
            return batch["embeds"].astype(self.dtype)
        return params["embed"][batch["tokens"]]

    def _positions_segments(self, batch: dict, s: int):
        tokens_like = batch.get("tokens", batch.get("embeds"))
        b = tokens_like.shape[0]
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        segments = batch.get("segments")
        return positions, segments

    def _run_stack(
        self, params, x, positions, segments, caches=None, cache_index=None,
        dest_slot=None,
    ):
        cfg, plan, mesh = self.cfg, self.plan, self.mesh

        new_prefix_caches = []
        if plan.prefix_layers:
            for i, l in enumerate(plan.prefix_layers):
                pc = caches["prefix"][i] if caches else None
                x, nc = unit_forward(
                    params["prefix"][i], x, cfg, (l,), positions, segments,
                    pc, cache_index, mesh, dest_slot=dest_slot,
                )
                new_prefix_caches.append(nc)

        unit_layers = plan.unit_layers[0] if plan.unit_layers else ()

        def scan_body(carry, xs):
            h = carry
            unit_params, unit_cache = xs
            if cfg.sequence_sharding:
                h = _sp_constraint(h, mesh)
            h, new_cache = unit_forward(
                unit_params, h, cfg, unit_layers, positions, segments,
                unit_cache, cache_index, mesh, dest_slot=dest_slot,
            )
            if cfg.sequence_sharding:
                h = _sp_constraint(h, mesh)
            return h, new_cache

        body = scan_body
        if cfg.remat == "full":
            body = jax.checkpoint(scan_body)
        elif cfg.remat == "dots":
            body = jax.checkpoint(
                scan_body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )

        stack_caches = caches["stack"] if caches else None
        x, new_stack_caches = jax.lax.scan(
            body, x, (params["stack"], stack_caches)
        )
        new_caches = None
        if caches is not None:
            new_caches = {"prefix": new_prefix_caches, "stack": new_stack_caches}
        return x, new_caches

    # -- public entry points ---------------------------------------------------------
    def forward(self, params: Params, batch: dict) -> jax.Array:
        x = self._embed(params, batch)
        positions, segments = self._positions_segments(batch, x.shape[1])
        x, _ = self._run_stack(params, x, positions, segments)
        x = apply_norm(params["final_norm"], x, self.cfg)
        logits = x @ params["unembed"]
        if self.cfg.logits_fp32:
            logits = logits.astype(jnp.float32)
        vp = padded_vocab(self.cfg.vocab_size)
        if vp != self.cfg.vocab_size:
            pad_bias = jnp.where(
                jnp.arange(vp) < self.cfg.vocab_size, 0.0, -1e9
            ).astype(logits.dtype)
            logits = logits + pad_bias
        return logits

    def loss_sums(self, params: Params, batch: dict):
        """(loss_sum, token_count) over valid targets — Eq. 2 primitives."""
        logits = self.forward(params, batch)
        return masked_cross_entropy(
            logits, batch["labels"], batch["loss_mask"], fp32=self.cfg.logits_fp32
        )

    # -- serving ----------------------------------------------------------------------
    def init_caches(self, batch: int, max_len: int) -> Params:
        plan, cfg = self.plan, self.cfg
        cache_dtype = self.dtype
        prefix = [
            init_unit_cache(cfg, (l,), batch, max_len, cache_dtype)
            for l in plan.prefix_layers
        ]
        per_unit = [
            init_unit_cache(cfg, plan.unit_layers[u], batch, max_len, cache_dtype)
            for u in range(plan.n_units)
        ]
        return {"prefix": prefix, "stack": stack_params(per_unit)}

    def prefill(self, params: Params, tokens: jax.Array, max_len: int):
        """Encode a prompt, filling caches; returns (last-token logits, caches)."""
        b, s = tokens.shape
        caches = self.init_caches(b, max_len)
        batch = {"tokens": tokens}
        x = self._embed(params, batch)
        positions, segments = self._positions_segments(batch, s)
        x, caches = self._run_stack(
            params, x, positions, segments, caches, jnp.array(0, jnp.int32)
        )
        x = apply_norm(params["final_norm"], x[:, -1:], self.cfg)
        logits = (x @ params["unembed"]).astype(jnp.float32)
        return logits, caches

    def prefill_packed(
        self,
        params: Params,
        caches: Params,
        tokens: jax.Array,  # (R, S) packed-segment stream
        positions: jax.Array,  # (R, S) within-segment positions
        segments: jax.Array,  # (R, S) 0 = padding, >=1 per request
        dest_slot: jax.Array,  # (R, S) cache row per stream position
    ):
        """Packed-segment prefill scattering K/V into per-request cache slots.

        The continuous-batching serving path (DESIGN.md §12): several
        admitted prompts share one packed stream — attention is the
        segment-masked train-path route (Pallas flash when routed), so a
        mixed-length admission cohort prefills in one fixed-shape call —
        while each layer's roped K/V lands in the cache row named by
        ``dest_slot`` at its within-segment position.  Padding positions
        point ``dest_slot`` out of range so their writes drop.  Returns the
        full-stream logits (gathering per-segment last positions is the
        caller's concern: the jitted serve step fuses the gather).
        """
        x = self._embed(params, {"tokens": tokens})
        x, caches = self._run_stack(
            params, x, positions, segments, caches, None, dest_slot=dest_slot
        )
        x = apply_norm(params["final_norm"], x, self.cfg)
        logits = (x @ params["unembed"]).astype(jnp.float32)
        return logits, caches

    def decode_step_slots(
        self,
        params: Params,
        caches: Params,
        tokens: jax.Array,  # (B, 1) — one pending token per cache slot
        lengths: jax.Array,  # (B,) int32: per-slot tokens already cached
    ):
        """One decode step against per-slot cache frontiers.

        The continuous-batching analogue of :meth:`decode_step`: every cache
        row (slot) sits at its own depth ``lengths[i]``, so admission and
        eviction never change the step's shape — the jitted decode compiles
        exactly once for ``(B, 1)`` regardless of which requests occupy the
        slots (the compile-once contract, DESIGN.md §12).
        """
        b, s = tokens.shape
        x = self._embed(params, {"tokens": tokens})
        positions = lengths.astype(jnp.int32)[:, None] + jnp.arange(
            s, dtype=jnp.int32
        )
        x, new_caches = self._run_stack(
            params, x, positions, None, caches, lengths
        )
        x = apply_norm(params["final_norm"], x, self.cfg)
        logits = (x @ params["unembed"]).astype(jnp.float32)
        return logits, new_caches

    def decode_step(
        self,
        params: Params,
        caches: Params,
        tokens: jax.Array,  # (B, 1)
        cache_index: jax.Array,  # scalar int32: tokens already cached
    ):
        b, s = tokens.shape
        batch = {"tokens": tokens}
        x = self._embed(params, batch)
        positions = jnp.broadcast_to(
            cache_index.astype(jnp.int32), (b, s)
        ) + jnp.arange(s, dtype=jnp.int32)
        x, new_caches = self._run_stack(
            params, x, positions, None, caches, cache_index
        )
        x = apply_norm(params["final_norm"], x, self.cfg)
        logits = (x @ params["unembed"]).astype(jnp.float32)
        return logits, new_caches


def shift_labels(
    tokens: jax.Array,
    loss_mask: jax.Array,
    pad_id: int = 0,
    segments: jax.Array | None = None,
):
    """Next-token targets: labels[t] = tokens[t+1]; last position masked.

    With ``segments`` (packed layout) a position is additionally masked when
    the next token belongs to a different segment — otherwise the last token
    of each packed sample would be trained to predict its row-neighbour's
    first token (cross-sample label contamination).
    """
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.full_like(tokens[:, :1], pad_id)], axis=1
    )
    mask = loss_mask * jnp.concatenate(
        [loss_mask[:, 1:], jnp.zeros_like(loss_mask[:, :1])], axis=1
    )
    if segments is not None:
        next_seg = jnp.concatenate(
            [segments[:, 1:], jnp.zeros_like(segments[:, :1])], axis=1
        )
        mask = mask * (segments == next_seg).astype(mask.dtype)
    return labels, mask
