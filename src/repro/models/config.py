"""Architecture configuration for the assigned model pool."""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "hybrid", "ssm", "encoder", "vlm"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    vocab_size: int

    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 0
    qk_norm: bool = False
    attn_kind: Literal["gqa", "mla", "none"] = "gqa"
    causal: bool = True
    rope_theta: float = 1e6

    # MLA (DeepSeek-V3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128

    # FFN
    d_ff: int = 0
    act: Literal["silu", "gelu"] = "silu"
    gated_mlp: bool = True

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    first_k_dense: int = 0  # dsv3: leading dense layers
    moe_every: int = 1  # jamba: MoE on every 2nd layer
    dense_residual: bool = False  # arctic: dense MLP in parallel with MoE
    capacity_factor: float = 1.25

    # hybrid / SSM
    attn_period: int = 0  # jamba: one attention layer per `attn_period`
    d_state: int = 0  # SSD state size
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    d_conv: int = 4

    # norm / misc
    norm: Literal["rms", "ln", "ln_nonparam"] = "rms"
    is_encoder: bool = False
    input_embeds: bool = False  # modality frontend stub feeds embeddings
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    logits_fp32: bool = True
    # remat policy for the scanned stack: "none"|"full"|"dots" (perf knob)
    remat: str = "full"

    # ---- kernel routing & autotuning (DESIGN.md §11) ----
    # Training/prefill attention implementation: "xla" = blockwise-scan
    # masking in models/attention.py; "flash" = the Pallas segment-aware
    # flash kernel (fused fwd + tiled two-pass bwd, repro.kernels);
    # "auto" = flash when the batch is packed (segments present) and the
    # backend compiles Pallas (TPU), xla otherwise.  Decode always uses the
    # XLA cache path.
    attn_impl: Literal["xla", "flash", "auto"] = "auto"
    # Flash grid variant (DESIGN.md §17): "dense" walks every kv tile and
    # predicates dead ones out of the MXU; "pruned" routes the kv BlockSpec
    # through a scalar-prefetched liveness index so dead tiles are never
    # DMA'd; "auto" = pruned exactly when the batch is packed (segments
    # present) on TPU.  Without segments there is no liveness table and
    # every variant resolves to dense.
    attn_grid: Literal["dense", "pruned", "auto"] = "auto"
    # Flash kernel block schedule; 0 = pick automatically (measured probe
    # when attn_autotune, else the largest divisor of S ≤ 128).
    attn_block_q: int = 0
    attn_block_kv: int = 0
    # Measured (block_q, block_kv) probe per shape cell, cached under
    # artifacts/autotune/ (repro.kernels.autotune).
    attn_autotune: bool = False

    # ---- §Perf hillclimb levers (default off = paper-faithful baseline) ----
    # cast residual-stream cotangents to bf16 at the head (halves backward
    # activation traffic + makes TP activation all-reduces bf16)
    bf16_grad_barrier: bool = False
    # shard the scanned residual stream's sequence dim over `model` (SP):
    # norms/residual memory and saved remat carries shrink by TP
    sequence_sharding: bool = False
    # annotate attention head tensors with (uneven) model sharding to stop
    # GSPMD's involuntary full-rematerialization reshard (yi/arctic: 56 heads)
    attn_head_constraint: bool = False

    # ------------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def uses_attention(self) -> bool:
        return self.attn_kind != "none"

    @property
    def uses_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        return not self.is_encoder

    def layer_kind(self, layer_idx: int) -> str:
        """'attn' | 'ssm' for the mixing sublayer of layer `layer_idx`."""
        if self.family == "ssm":
            return "ssm"
        if self.family == "hybrid":
            # Jamba: one attention layer per `attn_period` (offset mid-period).
            return "attn" if layer_idx % self.attn_period == self.attn_period // 2 else "ssm"
        return "attn"

    def layer_is_moe(self, layer_idx: int) -> bool:
        if self.n_experts == 0:
            return False
        if layer_idx < self.first_k_dense:
            return False
        return (layer_idx - self.first_k_dense) % self.moe_every == 0

    def param_count(self) -> int:
        """Total parameters (embeddings + stack), exact for our layout."""
        d = self.d_model
        total = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d  # unembed
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            if kind == "attn":
                if self.attn_kind == "mla":
                    q = d * self.q_lora_rank + self.q_lora_rank * self.n_heads * (
                        self.qk_nope_dim + self.qk_rope_dim
                    )
                    kv = d * (self.kv_lora_rank + self.qk_rope_dim)
                    kv += self.kv_lora_rank * self.n_heads * (
                        self.qk_nope_dim + self.v_head_dim
                    )
                    o = self.n_heads * self.v_head_dim * d
                    total += q + kv + o
                else:
                    total += d * self.n_heads * self.d_head  # Q
                    total += 2 * d * self.n_kv_heads * self.d_head  # K,V
                    total += self.n_heads * self.d_head * d  # O
            else:  # ssm
                di = self.d_inner
                in_proj = d * (2 * di + 2 * self.d_state + self.n_ssm_heads)
                total += in_proj + self.d_conv * (di + 2 * self.d_state)
                total += self.n_ssm_heads * 2  # A_log, D
                total += di * d  # out_proj
            # FFN / MoE
            if self.layer_is_moe(i):
                e_ff = self.moe_d_ff or self.d_ff
                per_expert = (3 if self.gated_mlp else 2) * d * e_ff
                total += self.n_experts * per_expert + d * self.n_experts  # router
                total += self.n_shared_experts * per_expert
                if self.dense_residual:
                    total += (3 if self.gated_mlp else 2) * d * self.d_ff
            elif self.d_ff:
                total += (3 if self.gated_mlp else 2) * d * self.d_ff
            # norms
            if self.norm != "ln_nonparam":
                total += 2 * d
        return total

    def active_param_count(self) -> int:
        """Active parameters per token (MoE top-k counting)."""
        if self.n_experts == 0:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        e_ff = self.moe_d_ff or self.d_ff
        per_expert = (3 if self.gated_mlp else 2) * d * e_ff
        moe_layers = sum(self.layer_is_moe(i) for i in range(self.n_layers))
        inactive = moe_layers * (self.n_experts - self.top_k) * per_expert
        return total - inactive
