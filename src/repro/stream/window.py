"""Bounded-lookahead admission window (DESIGN.md §9.1).

The paper's observability constraint: a sample's true cost (its realized
token length) exists only *after* the online pipeline has run.  The offline
loader sidesteps this by calling ``realize_lengths`` over the whole dataset
before scheduling — exactly the length-cache regime ODB rules out.  The
``AdmissionWindow`` restores the online causal order:

  * the *shuffle order* is computed up front from identities alone (the
    DistributedSampler never observes lengths, App. C.1), so the padded view
    order of size ``M = W·ceil(N/W)`` is known without any pipeline work;
  * lengths are realized through ``run_pipeline`` one view at a time, only
    when the view is admitted into the window;
  * at most ``lookahead`` realized-but-undelivered views are resident at any
    instant — the engine pulls via the :class:`repro.core.protocol.ViewSource`
    interface and realization never runs ahead of consumption by more than
    the lookahead budget (backpressure by refusal, not by blocking).

Determinism: given (records, policy, pipeline_epoch, spec, shuffle_epoch),
admission order, view ids and realized lengths are identical to the offline
``realize_lengths`` + ``shard_views`` pair — with ``lookahead >= M`` the
downstream step schedule is bit-for-bit the eager one (tests/test_stream.py).

The cursor/staging/backpressure machinery is independent of *what* is being
realized, so it lives in :class:`BoundedWindow` — the epoch window below
binds it to the sampler order + ``run_pipeline``, and the serving engine
binds the same mechanics to a live request queue
(``repro.serve.requests.RequestWindow``), where "realization" is the
tokenizer stamping a request's true token cost (DESIGN.md §12).
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Callable

from repro import obs
from repro.core.grouping import Sample
from repro.core.protocol import ViewSource
from repro.data.pipeline import PipelinePolicy, RawRecord, run_pipeline
from repro.data.sampler import SamplerSpec, global_view_order


@dataclasses.dataclass
class WindowStats:
    """Observability of the admission window (drives tests + benchmarks)."""

    realized: int = 0  # total views pushed through run_pipeline
    delivered: int = 0  # total views handed to the engine
    peak_resident: int = 0  # max realized-but-undelivered at any instant
    refusals: int = 0  # take() calls throttled by the lookahead budget
    quarantined: int = 0  # realization failures moved to component X (§15)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class BoundedWindow(ViewSource):
    """Lookahead-bounded realization over a (possibly growing) position order.

    Subclasses define the order: :meth:`order_size` (how many positions exist
    right now), :meth:`realize` (pay the realization cost for one position and
    return its :class:`Sample`), and :meth:`order_open` (may more positions
    arrive later? — always ``False`` for an epoch, ``True`` for a live
    request queue until it is closed).  The base class owns the single global
    cursor, the per-rank staging deques (stride-sharding:
    ``rank = position % W``), and the backpressure contract: at most
    ``lookahead`` realized-but-undelivered samples are resident at any
    instant (backpressure by refusal, not by blocking).

    ``lookahead`` must be at least ``world_size`` — below that, a full budget
    can consist entirely of views staged for other ranks and the requesting
    rank could starve for a round with nothing forcing progress.

    Sample quarantine (DESIGN.md §15): a position whose ``realize`` raises
    is moved to the accounted component ``X`` — the cursor advances past it,
    nothing is staged, and the failure is recorded in ``quarantined`` — up
    to ``max_quarantine`` such failures; beyond the budget (or with the
    strict default of 0) the exception propagates.  ``on_quarantine`` lets
    an owner (the stream executor) fold each event into the epoch-level
    Lemma-1 accounting, so a poison sample can neither wedge a round nor
    silently vanish from coverage.
    """

    def __init__(
        self,
        world_size: int,
        lookahead: int,
        *,
        max_quarantine: int = 0,
        quarantine_exempt: frozenset[int] = frozenset(),
    ) -> None:
        if lookahead < world_size:
            raise ValueError(
                f"lookahead {lookahead} < world_size {world_size}: "
                "a full window could hold no view for the requesting rank"
            )
        self.world_size = world_size
        self.lookahead = lookahead
        self.max_quarantine = max_quarantine
        # Identities already quarantined earlier in the epoch (a non-join
        # catch-up iteration or a resumed run re-walks the order and meets
        # the same deterministically-failing sample again): re-quarantining
        # them is free — the budget charges each distinct sample once.
        self.quarantine_exempt = frozenset(quarantine_exempt)
        self._quarantine_charged = 0
        self._charged_ids: set[int] = set()
        # Component X of the extended No-Leak partition (R, Q, B, E, X):
        # positions whose realization failed, with the identity + error kept
        # so audits (and checkpoints) account for every undelivered view.
        self.quarantined: list[dict] = []
        self.on_quarantine: Callable[[int, int, BaseException], None] | None = None
        self.cursor = 0
        self.resident = 0
        self.staged: list[collections.deque[Sample]] = [
            collections.deque() for _ in range(world_size)
        ]
        self.delivered_per_rank = [0] * world_size
        self.stats = WindowStats()
        # Telemetry (DESIGN.md §13): instruments cached at construction so the
        # per-view hot path is one attribute call on a plain-slot object.
        self._m_realized = obs.counter(
            "odb_window_realized_total", help="views pushed through realization"
        )
        self._m_delivered = obs.counter(
            "odb_window_delivered_total", help="views handed to the engine"
        )
        self._m_refusals = obs.counter(
            "odb_window_refusals_total",
            help="take() calls throttled by the lookahead budget",
        )
        self._m_resident = obs.gauge(
            "odb_window_resident", help="realized-but-undelivered views resident now"
        )
        self._m_quarantined = obs.counter(
            "odb_fault_quarantined_total",
            help="views moved to the quarantine component X on realization failure",
        )

    # -- order interface (subclass responsibility) -----------------------------
    def order_size(self) -> int:  # pragma: no cover
        """Number of positions currently in the order (may grow)."""
        raise NotImplementedError

    def realize(self, position: int) -> Sample:  # pragma: no cover
        """Run the realization pipeline for one position."""
        raise NotImplementedError

    def order_open(self) -> bool:
        """May positions beyond ``order_size()`` still arrive?"""
        return False

    def quarantine_identity(self, position: int) -> int:
        """Identity behind ``position`` for quarantine accounting (-1 = n/a)."""
        return -1

    # -- admission -------------------------------------------------------------
    def _admit_one(self) -> None:
        position = self.cursor
        try:
            sample = self.realize(position)
        except Exception as exc:
            identity = self.quarantine_identity(position)
            exempt = identity >= 0 and (
                identity in self.quarantine_exempt
                or identity in self._charged_ids
            )
            if not exempt and self._quarantine_charged >= self.max_quarantine:
                raise
            if not exempt:
                self._quarantine_charged += 1
                if identity >= 0:
                    self._charged_ids.add(identity)
            # The cursor advances past the position WITHOUT staging it: the
            # view leaves the sampler order for component X, so take() keeps
            # making progress and no rank ever waits on the poison sample.
            self.cursor += 1
            self.quarantined.append(
                {
                    "position": position,
                    "identity": identity,
                    "error": f"{type(exc).__name__}: {exc}",
                }
            )
            self.stats.quarantined += 1
            self._m_quarantined.inc()
            if self.on_quarantine is not None:
                self.on_quarantine(position, identity, exc)
            return
        self.staged[position % self.world_size].append(sample)
        self.cursor += 1
        self.resident += 1
        self.stats.realized += 1
        self.stats.peak_resident = max(self.stats.peak_resident, self.resident)
        self._m_realized.inc()

    # -- ViewSource interface --------------------------------------------------
    def take(self, rank: int, k: int) -> list[Sample]:
        dq = self.staged[rank]
        throttled = False
        while len(dq) < k and self.cursor < self.order_size():
            if self.resident >= self.lookahead:
                throttled = True
                break
            self._admit_one()
        if throttled and len(dq) < k:
            self.stats.refusals += 1
            self._m_refusals.inc()
        out: list[Sample] = []
        while dq and len(out) < k:
            out.append(dq.popleft())
        self.resident -= len(out)
        self.delivered_per_rank[rank] += len(out)
        self.stats.delivered += len(out)
        self._m_delivered.inc(len(out))
        self._m_resident.set(self.resident)
        return out

    def exhausted(self, rank: int) -> bool:
        return (
            not self.order_open()
            and self.cursor >= self.order_size()
            and not self.staged[rank]
        )

    def remaining(self, rank: int) -> int:
        """Samples not yet delivered to ``rank`` (staged + beyond the cursor).

        Exact regardless of realized lengths: stride-sharding makes the
        count of future positions owned by ``rank`` a pure function of
        (cursor, order size, W).  For the epoch window this equals
        ``per_rank_quota - delivered`` (the padded order has fixed per-rank
        quota ``ceil(N/W)``).
        """
        size = self.order_size()
        first = self.cursor + ((rank - self.cursor) % self.world_size)
        future = 0 if first >= size else (size - 1 - first) // self.world_size + 1
        return len(self.staged[rank]) + future


class AdmissionWindow(BoundedWindow):
    """Incremental, lookahead-bounded realization of one logical iteration.

    One window corresponds to one logical sampler iteration (one shuffled,
    padded view order, fixed at construction): realization is
    ``run_pipeline`` over the identity at each order position.
    """

    def __init__(
        self,
        records: list[RawRecord],
        policy: PipelinePolicy,
        spec: SamplerSpec,
        *,
        shuffle_epoch: int,
        pipeline_epoch: int = 0,
        lookahead: int | None = None,
        view_id_base: int = 0,
        max_quarantine: int = 0,
        quarantine_exempt: frozenset[int] = frozenset(),
    ) -> None:
        if lookahead is None:
            lookahead = spec.total_views
        super().__init__(
            spec.world_size,
            lookahead,
            max_quarantine=max_quarantine,
            quarantine_exempt=quarantine_exempt,
        )
        self.records = records
        self.policy = policy
        self.spec = spec
        self.shuffle_epoch = shuffle_epoch
        self.pipeline_epoch = pipeline_epoch
        self.view_id_base = view_id_base
        self.order = global_view_order(spec, shuffle_epoch)  # identities only

    # -- order interface -------------------------------------------------------
    def order_size(self) -> int:
        return len(self.order)

    def realize(self, position: int) -> Sample:
        identity = self.order[position]
        length = run_pipeline(self.records[identity], self.policy, self.pipeline_epoch)
        return Sample(
            view_id=self.view_id_base + position,
            identity=identity,
            length=length,
        )

    def quarantine_identity(self, position: int) -> int:
        return self.order[position]

    # -- checkpointing (stream/state.py) ---------------------------------------
    def state_dict(self) -> dict:
        """Serializable mid-iteration window state.

        The shuffle order is NOT serialized — it regenerates deterministically
        from (spec, shuffle_epoch).  Staged views are stored explicitly so a
        resume is exact even though they could in principle be re-realized.
        """
        return {
            "cursor": self.cursor,
            "view_id_base": self.view_id_base,
            "shuffle_epoch": self.shuffle_epoch,
            "pipeline_epoch": self.pipeline_epoch,
            "lookahead": self.lookahead,
            "staged": [
                [[s.view_id, s.identity, s.length] for s in dq]
                for dq in self.staged
            ],
            "delivered_per_rank": list(self.delivered_per_rank),
            "stats": self.stats.as_dict(),
            "max_quarantine": self.max_quarantine,
            "quarantined": [dict(q) for q in self.quarantined],
        }

    def load_state_dict(self, state: dict) -> None:
        self.cursor = state["cursor"]
        self.view_id_base = state["view_id_base"]
        self.lookahead = state["lookahead"]
        self.max_quarantine = state["max_quarantine"]
        self.quarantined = [dict(q) for q in state["quarantined"]]
        self._charged_ids = {
            q["identity"] for q in self.quarantined
            if q["identity"] >= 0 and q["identity"] not in self.quarantine_exempt
        }
        self._quarantine_charged = len(self._charged_ids) + sum(
            1 for q in self.quarantined if q["identity"] < 0
        )
        self.staged = [
            collections.deque(
                Sample(view_id=v, identity=i, length=ln) for v, i, ln in dq
            )
            for dq in state["staged"]
        ]
        self.resident = sum(len(dq) for dq in self.staged)
        self.delivered_per_rank = list(state["delivered_per_rank"])
        self.stats = WindowStats(**state["stats"])
