"""Bounded-lookahead admission window (DESIGN.md §9.1, §16).

The paper's observability constraint: a sample's true cost (its realized
token length) exists only *after* the online pipeline has run.  The offline
loader sidesteps this by calling ``realize_lengths`` over the whole dataset
before scheduling — exactly the length-cache regime ODB rules out.  The
``AdmissionWindow`` restores the online causal order:

  * the *shuffle order* is computed up front from identities alone (the
    DistributedSampler never observes lengths, App. C.1), so the padded view
    order of size ``M = W·ceil(N/W)`` is known without any pipeline work;
  * lengths are realized through ``run_pipeline`` one view at a time, only
    when the view is admitted into the window;
  * at most ``lookahead`` realized-but-undelivered views are resident at any
    instant — the engine pulls via the :class:`repro.core.protocol.ViewSource`
    interface and realization never runs ahead of consumption by more than
    the lookahead budget (backpressure by refusal, not by blocking).

Window state is **per-rank decomposed** (DESIGN.md §16): stride-sharding
assigns rank ``r`` the order positions ``r, r+W, r+2W, …``, and each rank
owns an independent sub-cursor over its own positions plus a lookahead
sub-budget ``L_r`` with ``Σ_r L_r = lookahead``.  Realized length is a pure
function of identity, so the per-rank delivered sequence is invariant to
*when* other ranks' positions are realized — which is exactly what makes the
window distributable: a multi-host deployment runs one :class:`ShardedWindow`
per host over that host's rank block, and the union of per-rank states is
bit-identical to the single-process window's, for any host count.

Determinism: given (records, policy, pipeline_epoch, spec, shuffle_epoch),
admission order, view ids and realized lengths are identical to the offline
``realize_lengths`` + ``shard_views`` pair — with ``lookahead >= M`` no
sub-budget ever binds and the downstream step schedule is bit-for-bit the
eager one (tests/test_stream.py).

The cursor/staging/backpressure machinery is independent of *what* is being
realized, so it lives in :class:`BoundedWindow` — the epoch window below
binds it to the sampler order + ``run_pipeline``, and the serving engine
binds the same mechanics to a live request queue
(``repro.serve.requests.RequestWindow``), where "realization" is the
tokenizer stamping a request's true token cost (DESIGN.md §12).
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Sequence

from repro import obs
from repro.core.grouping import Sample
from repro.core.protocol import ViewSource
from repro.data.pipeline import PipelinePolicy, RawRecord, run_pipeline
from repro.data.sampler import SamplerSpec, global_view_order


def split_lookahead(lookahead: int, world_size: int) -> list[int]:
    """Per-rank lookahead sub-budgets ``L_r`` with ``Σ L_r = lookahead``.

    The remainder spreads over the first ``lookahead % W`` ranks, so with
    ``lookahead >= world_size`` every rank holds at least one slot — the
    per-rank liveness floor that keeps a take() from starving.  Budgets are a
    pure function of the *global* (lookahead, world_size) pair, never of the
    host partition, which is what makes the throttling schedule identical
    across host counts.
    """
    base, extra = divmod(lookahead, world_size)
    return [base + (1 if r < extra else 0) for r in range(world_size)]


def host_rank_blocks(world_size: int, num_hosts: int) -> list[tuple[int, ...]]:
    """Contiguous rank blocks per host, the deployment layout where each
    host's local devices are its rank block.

    ``W % P == 0`` gives the equal partition (host ``h`` owns ranks
    ``[h·W/P, (h+1)·W/P)``).  Uneven world sizes spread the remainder over
    the first ``W % P`` hosts — the same rule as :func:`split_lookahead` —
    so blocks stay contiguous and sizes differ by at most one:
    ``(W=6, P=4) -> (0,1) (2,3) (4,) (5,)`` and
    ``(W=5, P=2) -> (0,1,2) (3,4)``.  Every host must own at least one
    rank, so ``P > W`` (an empty block) stays an error.
    """
    if num_hosts <= 0:
        raise ValueError(f"num_hosts must be positive, got {num_hosts}")
    if num_hosts > world_size:
        raise ValueError(
            f"num_hosts {num_hosts} > world_size {world_size}: "
            "some host would own no rank"
        )
    base, extra = divmod(world_size, num_hosts)
    blocks: list[tuple[int, ...]] = []
    start = 0
    for h in range(num_hosts):
        size = base + (1 if h < extra else 0)
        blocks.append(tuple(range(start, start + size)))
        start += size
    return blocks


@dataclasses.dataclass
class WindowStats:
    """Observability of the admission window (drives tests + benchmarks)."""

    realized: int = 0  # total views pushed through run_pipeline
    delivered: int = 0  # total views handed to the engine
    peak_resident: int = 0  # max realized-but-undelivered at any instant
    refusals: int = 0  # take() calls throttled by the lookahead budget
    quarantined: int = 0  # realization failures moved to component X (§15)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class QuarantineLedger:
    """Shared budget + records of the quarantine component ``X`` (§15, §16).

    One ledger per logical iteration.  In a sharded deployment every host
    window of the iteration shares one ledger (in-process) or merges remote
    charge sets through the gather payload (real multi-host), so the budget
    charges each distinct sample exactly once no matter which host observes
    the failure first — the padded order repeats an identity on several
    ranks, and those ranks may live on different hosts.
    """

    def __init__(self, budget: int, exempt: frozenset[int] = frozenset()) -> None:
        self.budget = budget
        # Identities already quarantined earlier in the epoch (a non-join
        # catch-up iteration or a resumed run re-walks the order and meets
        # the same deterministically-failing sample again): re-quarantining
        # them is free — the budget charges each distinct sample once.
        self.exempt = frozenset(exempt)
        self.charged = 0
        self.charged_ids: set[int] = set()
        self.records: list[dict] = []

    def admit_failure(
        self, position: int, identity: int, exc: BaseException
    ) -> bool:
        """Charge one realization failure; False when the budget is spent."""
        exempt = identity >= 0 and (
            identity in self.exempt or identity in self.charged_ids
        )
        if not exempt and self.charged >= self.budget:
            return False
        if not exempt:
            self.charged += 1
            if identity >= 0:
                self.charged_ids.add(identity)
        self.records.append(
            {
                "position": position,
                "identity": identity,
                "error": f"{type(exc).__name__}: {exc}",
            }
        )
        return True

    def load(self, records: Sequence[dict]) -> None:
        self.records = [dict(q) for q in records]
        self.charged_ids = {
            q["identity"]
            for q in self.records
            if q["identity"] >= 0 and q["identity"] not in self.exempt
        }
        self.charged = len(self.charged_ids) + sum(
            1 for q in self.records if q["identity"] < 0
        )


class BoundedWindow(ViewSource):
    """Lookahead-bounded realization over a (possibly growing) position order.

    Subclasses define the order: :meth:`order_size` (how many positions exist
    right now), :meth:`realize` (pay the realization cost for one position and
    return its :class:`Sample`), and :meth:`order_open` (may more positions
    arrive later? — always ``False`` for an epoch, ``True`` for a live
    request queue until it is closed).  The base class owns the per-rank
    decomposed state (stride-sharding: rank ``r`` owns positions
    ``r, r+W, r+2W, …``): one sub-cursor, one staging deque and one lookahead
    sub-budget per rank, with the backpressure contract that at most
    ``Σ_r L_r = lookahead`` realized-but-undelivered samples are resident at
    any instant (backpressure by refusal, not by blocking).

    ``lookahead`` must be at least ``world_size`` — below that, some rank's
    sub-budget would be zero and a take() for it could never stage a view.

    Sample quarantine (DESIGN.md §15): a position whose ``realize`` raises
    is moved to the accounted component ``X`` — the owning rank's cursor
    advances past it, nothing is staged, and the failure lands in the
    :class:`QuarantineLedger` — up to the ledger's budget; beyond it (or
    with the strict default of 0) the exception propagates.
    ``on_quarantine`` lets an owner (the stream executor) fold each event
    into the epoch-level Lemma-1 accounting; ``on_remote_quarantine`` is the
    §16 merge path — identities another host quarantined arrive through
    :meth:`absorb_gathered` so non-join quota closure shrinks by the
    *global* ``|X|``, never the host-local one.
    """

    def __init__(
        self,
        world_size: int,
        lookahead: int,
        *,
        max_quarantine: int = 0,
        quarantine_exempt: frozenset[int] = frozenset(),
        ledger: QuarantineLedger | None = None,
    ) -> None:
        if lookahead < world_size:
            raise ValueError(
                f"lookahead {lookahead} < world_size {world_size}: "
                "some rank's lookahead sub-budget would be zero"
            )
        self.world_size = world_size
        self.lookahead = lookahead
        self.rank_lookahead = split_lookahead(lookahead, world_size)
        self.ledger = (
            ledger
            if ledger is not None
            else QuarantineLedger(max_quarantine, quarantine_exempt)
        )
        self.on_quarantine: Callable[[int, int, BaseException], None] | None = None
        self.on_remote_quarantine: Callable[[int], None] | None = None
        # Identities learned quarantined from OTHER hosts' gather payloads
        # (§16) — informational here (the owning host charged the ledger),
        # but load-bearing for closure when ledgers are not shared.
        self.remote_quarantined: set[int] = set()
        self.cursors = [0] * world_size  # per-rank owned-position sub-cursors
        self.staged: list[collections.deque[Sample]] = [
            collections.deque() for _ in range(world_size)
        ]
        self.delivered_per_rank = [0] * world_size
        self.stats = WindowStats()
        # Telemetry (DESIGN.md §13): instruments cached at construction so the
        # per-view hot path is one attribute call on a plain-slot object.
        self._m_realized = obs.counter(
            "odb_window_realized_total", help="views pushed through realization"
        )
        self._m_delivered = obs.counter(
            "odb_window_delivered_total", help="views handed to the engine"
        )
        self._m_refusals = obs.counter(
            "odb_window_refusals_total",
            help="take() calls throttled by the lookahead budget",
        )
        self._m_resident = obs.gauge(
            "odb_window_resident", help="realized-but-undelivered views resident now"
        )
        self._m_quarantined = obs.counter(
            "odb_fault_quarantined_total",
            help="views moved to the quarantine component X on realization failure",
        )

    # -- quarantine ledger views ----------------------------------------------
    @property
    def max_quarantine(self) -> int:
        return self.ledger.budget

    @property
    def quarantine_exempt(self) -> frozenset[int]:
        return self.ledger.exempt

    @property
    def quarantined(self) -> list[dict]:
        """Component X of the extended No-Leak partition (R, Q, B, E, X)."""
        return self.ledger.records

    # -- per-rank decomposition -------------------------------------------------
    @property
    def resident(self) -> int:
        """Realized-but-undelivered views resident across all ranks."""
        return sum(len(dq) for dq in self.staged)

    def rank_position(self, rank: int) -> int:
        """Global order position the rank's sub-cursor points at."""
        return rank + self.cursors[rank] * self.world_size

    def rank_order_size(self, rank: int) -> int:
        """Order positions owned by ``rank`` under stride-sharding."""
        size = self.order_size()
        if rank >= size:
            return 0
        return (size - 1 - rank) // self.world_size + 1

    # -- order interface (subclass responsibility) -----------------------------
    def order_size(self) -> int:  # pragma: no cover
        """Number of positions currently in the order (may grow)."""
        raise NotImplementedError

    def realize(self, position: int) -> Sample:  # pragma: no cover
        """Run the realization pipeline for one position."""
        raise NotImplementedError

    def order_open(self) -> bool:
        """May positions beyond ``order_size()`` still arrive?"""
        return False

    def quarantine_identity(self, position: int) -> int:
        """Identity behind ``position`` for quarantine accounting (-1 = n/a)."""
        return -1

    # -- admission -------------------------------------------------------------
    def _admit_one(self, rank: int) -> None:
        position = self.rank_position(rank)
        try:
            sample = self.realize(position)
        except Exception as exc:
            identity = self.quarantine_identity(position)
            if not self.ledger.admit_failure(position, identity, exc):
                raise
            # The rank's cursor advances past the position WITHOUT staging
            # it: the view leaves the sampler order for component X, so
            # take() keeps making progress and no rank ever waits on the
            # poison sample.
            self.cursors[rank] += 1
            self.stats.quarantined += 1
            self._m_quarantined.inc()
            self._m_resident.set(self.resident)
            if self.on_quarantine is not None:
                self.on_quarantine(position, identity, exc)
            return
        self.staged[rank].append(sample)
        self.cursors[rank] += 1
        self.stats.realized += 1
        self.stats.peak_resident = max(self.stats.peak_resident, self.resident)
        self._m_realized.inc()
        self._m_resident.set(self.resident)

    # -- ViewSource interface --------------------------------------------------
    def take(self, rank: int, k: int) -> list[Sample]:
        dq = self.staged[rank]
        throttled = False
        while len(dq) < k and self.cursors[rank] < self.rank_order_size(rank):
            if len(dq) >= self.rank_lookahead[rank]:
                throttled = True
                break
            self._admit_one(rank)
        if throttled and len(dq) < k:
            self.stats.refusals += 1
            self._m_refusals.inc()
        out: list[Sample] = []
        while dq and len(out) < k:
            out.append(dq.popleft())
        self.delivered_per_rank[rank] += len(out)
        self.stats.delivered += len(out)
        self._m_delivered.inc(len(out))
        self._m_resident.set(self.resident)
        return out

    def exhausted(self, rank: int) -> bool:
        return (
            not self.order_open()
            and self.cursors[rank] >= self.rank_order_size(rank)
            and not self.staged[rank]
        )

    def remaining(self, rank: int) -> int:
        """Samples not yet delivered to ``rank`` (staged + beyond the cursor).

        Exact regardless of realized lengths: stride-sharding makes the count
        of positions owned by ``rank`` a pure function of (order size, W), so
        ``remaining = staged + owned - admitted`` — invariant to admission
        order *and* to the host partition (a staged view merely moved from
        the future term to the staged term).  For the epoch window this
        equals ``per_rank_quota - delivered``.
        """
        future = max(0, self.rank_order_size(rank) - self.cursors[rank])
        return len(self.staged[rank]) + future

    # -- §16 payload fold -------------------------------------------------------
    def shard_state(self, rank: int) -> dict:
        """Per-rank window summary folded into the round gather payload.

        Carries the owning host id, the rank's sub-cursor, staged depth and
        delivery count, the host-wide resident total, and the (budget-bounded)
        charged quarantine identities — everything another host needs to
        reconstruct global admission state and the merged ``|X|``.
        """
        return {
            "host": getattr(self, "host", 0),
            "cursor": self.cursors[rank],
            "staged": len(self.staged[rank]),
            "delivered": self.delivered_per_rank[rank],
            "resident": self.resident,
            "quarantined_ids": sorted(self.ledger.charged_ids),
        }

    def absorb_gathered(self, states: Sequence[dict | None]) -> None:
        """Merge other hosts' shard summaries (post-gather, every round).

        Non-join quota closure must shrink by the *global* quarantine
        component: identities charged on another host's ledger join
        ``remote_quarantined`` and fire ``on_remote_quarantine`` exactly
        once, so the epoch runner's ``effective_quota`` sees merged ``|X|``
        rather than the host-local one.  Idempotent when hosts share one
        ledger (the in-process simulated lane).
        """
        for state in states:
            if not state:
                continue
            for identity in state.get("quarantined_ids", ()):
                if (
                    identity in self.ledger.charged_ids
                    or identity in self.remote_quarantined
                ):
                    continue
                self.remote_quarantined.add(identity)
                if self.on_remote_quarantine is not None:
                    self.on_remote_quarantine(identity)


class AdmissionWindow(BoundedWindow):
    """Incremental, lookahead-bounded realization of one logical iteration.

    One window corresponds to one logical sampler iteration (one shuffled,
    padded view order, fixed at construction): realization is
    ``run_pipeline`` over the identity at each order position.
    """

    def __init__(
        self,
        records: list[RawRecord],
        policy: PipelinePolicy,
        spec: SamplerSpec,
        *,
        shuffle_epoch: int,
        pipeline_epoch: int = 0,
        lookahead: int | None = None,
        view_id_base: int = 0,
        max_quarantine: int = 0,
        quarantine_exempt: frozenset[int] = frozenset(),
        ledger: QuarantineLedger | None = None,
    ) -> None:
        if lookahead is None:
            lookahead = spec.total_views
        super().__init__(
            spec.world_size,
            lookahead,
            max_quarantine=max_quarantine,
            quarantine_exempt=quarantine_exempt,
            ledger=ledger,
        )
        self.records = records
        self.policy = policy
        self.spec = spec
        self.shuffle_epoch = shuffle_epoch
        self.pipeline_epoch = pipeline_epoch
        self.view_id_base = view_id_base
        self.order = global_view_order(spec, shuffle_epoch)  # identities only

    # -- order interface -------------------------------------------------------
    def order_size(self) -> int:
        return len(self.order)

    def realize(self, position: int) -> Sample:
        identity = self.order[position]
        length = run_pipeline(self.records[identity], self.policy, self.pipeline_epoch)
        return Sample(
            view_id=self.view_id_base + position,
            identity=identity,
            length=length,
        )

    def quarantine_identity(self, position: int) -> int:
        return self.order[position]

    # -- checkpointing (stream/state.py) ---------------------------------------
    def state_dict(self) -> dict:
        """Serializable mid-iteration window state (v4 schema).

        Keyed per RANK, never per host: the shuffle order regenerates
        deterministically from (spec, shuffle_epoch), staged views are stored
        explicitly so a resume is exact, and because every field is per-rank
        the same payload restores into any host partition of the same world
        size (DESIGN.md §16 resume-across-host-counts).
        """
        return {
            "cursors": list(self.cursors),
            "view_id_base": self.view_id_base,
            "shuffle_epoch": self.shuffle_epoch,
            "pipeline_epoch": self.pipeline_epoch,
            "lookahead": self.lookahead,
            "staged": [
                [[s.view_id, s.identity, s.length] for s in dq]
                for dq in self.staged
            ],
            "delivered_per_rank": list(self.delivered_per_rank),
            "stats": self.stats.as_dict(),
            "max_quarantine": self.ledger.budget,
            "quarantined": [dict(q) for q in self.ledger.records],
            "remote_quarantined": sorted(self.remote_quarantined),
        }

    def load_state_dict(self, state: dict) -> None:
        self.cursors = list(state["cursors"])
        self.view_id_base = state["view_id_base"]
        self.lookahead = state["lookahead"]
        self.rank_lookahead = split_lookahead(self.lookahead, self.world_size)
        self.ledger.budget = state["max_quarantine"]
        self.ledger.load(state["quarantined"])
        self.remote_quarantined = set(state.get("remote_quarantined", []))
        self.staged = [
            collections.deque(
                Sample(view_id=v, identity=i, length=ln) for v, i, ln in dq
            )
            for dq in state["staged"]
        ]
        self.delivered_per_rank = list(state["delivered_per_rank"])
        self.stats = WindowStats(**state["stats"])


class ShardedWindow(AdmissionWindow):
    """Host-local admission window over the host's rank block (§16).

    Each host of a ``num_hosts``-way deployment runs one of these over the
    *same* deterministic sampler order but serves only its own ranks: the
    per-rank decomposition of :class:`BoundedWindow` means the host never
    needs another host's cursor to make progress, and the union of per-rank
    states across hosts is bit-identical to the single-process window's.
    Lookahead sub-budgets are computed from the global (lookahead, W) pair,
    so throttling is also partition-invariant.

    A take() for a rank outside ``host_ranks`` is a deployment bug (the
    engine routed a foreign rank here) and raises instead of silently
    realizing another host's shard.
    """

    def __init__(
        self,
        records: list[RawRecord],
        policy: PipelinePolicy,
        spec: SamplerSpec,
        *,
        host: int,
        num_hosts: int,
        shuffle_epoch: int,
        pipeline_epoch: int = 0,
        lookahead: int | None = None,
        view_id_base: int = 0,
        max_quarantine: int = 0,
        quarantine_exempt: frozenset[int] = frozenset(),
        ledger: QuarantineLedger | None = None,
    ) -> None:
        blocks = host_rank_blocks(spec.world_size, num_hosts)
        if not 0 <= host < num_hosts:
            raise ValueError(f"host {host} outside [0, {num_hosts})")
        super().__init__(
            records,
            policy,
            spec,
            shuffle_epoch=shuffle_epoch,
            pipeline_epoch=pipeline_epoch,
            lookahead=lookahead,
            view_id_base=view_id_base,
            max_quarantine=max_quarantine,
            quarantine_exempt=quarantine_exempt,
            ledger=ledger,
        )
        self.host = host
        self.num_hosts = num_hosts
        self.host_ranks = blocks[host]
        self._host_rank_set = frozenset(self.host_ranks)

    def _check_rank(self, rank: int) -> None:
        if rank not in self._host_rank_set:
            raise ValueError(
                f"rank {rank} is not served by host {self.host} "
                f"(host ranks {self.host_ranks})"
            )

    def take(self, rank: int, k: int) -> list[Sample]:
        self._check_rank(rank)
        return super().take(rank, k)

    def shard_state(self, rank: int) -> dict:
        self._check_rank(rank)
        return super().shard_state(rank)


class WindowRouter(ViewSource):
    """One engine-facing :class:`ViewSource` over P host windows (§16).

    The in-process simulated multi-host lane: the protocol engine still
    simulates all W ranks in one process, and the router dispatches each
    rank's take/exhausted/remaining/shard_state to the :class:`ShardedWindow`
    owning that rank — exactly the call pattern each host process would see
    in a real deployment.  ``absorb_gathered`` fans the post-gather merge to
    every host window, and checkpoint state is re-merged to the per-rank v4
    schema so a resume may repartition onto any host count.
    """

    def __init__(self, windows: Sequence[ShardedWindow]) -> None:
        if not windows:
            raise ValueError("need at least one host window")
        self.windows = list(windows)
        self.world_size = self.windows[0].world_size
        self._owner: dict[int, ShardedWindow] = {}
        for window in self.windows:
            for rank in window.host_ranks:
                if rank in self._owner:
                    raise ValueError(f"rank {rank} owned by two host windows")
                self._owner[rank] = window
        if len(self._owner) != self.world_size:
            raise ValueError(
                f"host windows cover {sorted(self._owner)} of "
                f"{self.world_size} ranks"
            )
        self.ledger = self.windows[0].ledger

    # -- ViewSource ------------------------------------------------------------
    def take(self, rank: int, k: int) -> list[Sample]:
        return self._owner[rank].take(rank, k)

    def exhausted(self, rank: int) -> bool:
        return self._owner[rank].exhausted(rank)

    def remaining(self, rank: int) -> int:
        return self._owner[rank].remaining(rank)

    def shard_state(self, rank: int) -> dict:
        return self._owner[rank].shard_state(rank)

    def absorb_gathered(self, states: Sequence[dict | None]) -> None:
        for window in self.windows:
            window.absorb_gathered(states)

    # -- merged observability ----------------------------------------------------
    @property
    def stats(self) -> WindowStats:
        """Epoch-aggregate stats across host windows.

        ``peak_resident`` sums the per-host peaks — an upper bound on the
        true global peak (hosts peak at different instants), and exactly the
        quantity the ``Σ L_r`` lookahead contract bounds.
        """
        agg = WindowStats()
        for window in self.windows:
            st = window.stats
            agg.realized += st.realized
            agg.delivered += st.delivered
            agg.refusals += st.refusals
            agg.quarantined += st.quarantined
            agg.peak_resident += st.peak_resident
        return agg

    @property
    def resident(self) -> int:
        return sum(window.resident for window in self.windows)

    @property
    def quarantined(self) -> list[dict]:
        return self.ledger.records

    # Hook fan-out: the executor assigns these exactly like on a plain window.
    @property
    def on_quarantine(self):
        return self.windows[0].on_quarantine

    @on_quarantine.setter
    def on_quarantine(self, fn) -> None:
        for window in self.windows:
            window.on_quarantine = fn

    @property
    def on_remote_quarantine(self):
        return self.windows[0].on_remote_quarantine

    @on_remote_quarantine.setter
    def on_remote_quarantine(self, fn) -> None:
        for window in self.windows:
            window.on_remote_quarantine = fn

    # -- checkpointing -----------------------------------------------------------
    def state_dict(self) -> dict:
        """Merged per-rank state, schema-identical to ``AdmissionWindow``'s.

        The checkpoint is host-count-agnostic by construction: every field is
        keyed by rank, so :meth:`load_state_dict` can split it over any other
        partition (including a single plain window).
        """
        w0 = self.windows[0]
        merged = {
            "cursors": [self._owner[r].cursors[r] for r in range(self.world_size)],
            "view_id_base": w0.view_id_base,
            "shuffle_epoch": w0.shuffle_epoch,
            "pipeline_epoch": w0.pipeline_epoch,
            "lookahead": w0.lookahead,
            "staged": [
                [
                    [s.view_id, s.identity, s.length]
                    for s in self._owner[r].staged[r]
                ]
                for r in range(self.world_size)
            ],
            "delivered_per_rank": [
                self._owner[r].delivered_per_rank[r]
                for r in range(self.world_size)
            ],
            "stats": self.stats.as_dict(),
            "max_quarantine": self.ledger.budget,
            "quarantined": [dict(q) for q in self.ledger.records],
            "remote_quarantined": sorted(
                set().union(*(w.remote_quarantined for w in self.windows))
            ),
        }
        return merged

    def load_state_dict(self, state: dict) -> None:
        from repro.core.grouping import Sample as _Sample

        self.ledger.budget = state["max_quarantine"]
        self.ledger.load(state["quarantined"])
        remote = set(state.get("remote_quarantined", []))
        for i, window in enumerate(self.windows):
            window.lookahead = state["lookahead"]
            window.rank_lookahead = split_lookahead(
                window.lookahead, window.world_size
            )
            window.view_id_base = state["view_id_base"]
            window.remote_quarantined = set(remote)
            for rank in window.host_ranks:
                window.cursors[rank] = state["cursors"][rank]
                window.staged[rank] = collections.deque(
                    _Sample(view_id=v, identity=ident, length=ln)
                    for v, ident, ln in state["staged"][rank]
                )
                window.delivered_per_rank[rank] = state["delivered_per_rank"][rank]
            # Aggregate stats cannot be split back per host; attribute the
            # whole epoch-aggregate to host 0 (window_stats() re-aggregates,
            # so executor-level metrics are exact either way).
            window.stats = (
                WindowStats(**state["stats"]) if i == 0 else WindowStats()
            )
