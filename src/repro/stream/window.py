"""Bounded-lookahead admission window (DESIGN.md §9.1).

The paper's observability constraint: a sample's true cost (its realized
token length) exists only *after* the online pipeline has run.  The offline
loader sidesteps this by calling ``realize_lengths`` over the whole dataset
before scheduling — exactly the length-cache regime ODB rules out.  The
``AdmissionWindow`` restores the online causal order:

  * the *shuffle order* is computed up front from identities alone (the
    DistributedSampler never observes lengths, App. C.1), so the padded view
    order of size ``M = W·ceil(N/W)`` is known without any pipeline work;
  * lengths are realized through ``run_pipeline`` one view at a time, only
    when the view is admitted into the window;
  * at most ``lookahead`` realized-but-undelivered views are resident at any
    instant — the engine pulls via the :class:`repro.core.protocol.ViewSource`
    interface and realization never runs ahead of consumption by more than
    the lookahead budget (backpressure by refusal, not by blocking).

Determinism: given (records, policy, pipeline_epoch, spec, shuffle_epoch),
admission order, view ids and realized lengths are identical to the offline
``realize_lengths`` + ``shard_views`` pair — with ``lookahead >= M`` the
downstream step schedule is bit-for-bit the eager one (tests/test_stream.py).
"""

from __future__ import annotations

import collections
import dataclasses

from repro.core.grouping import Sample
from repro.core.protocol import ViewSource
from repro.data.pipeline import PipelinePolicy, RawRecord, run_pipeline
from repro.data.sampler import SamplerSpec, global_view_order


@dataclasses.dataclass
class WindowStats:
    """Observability of the admission window (drives tests + benchmarks)."""

    realized: int = 0  # total views pushed through run_pipeline
    delivered: int = 0  # total views handed to the engine
    peak_resident: int = 0  # max realized-but-undelivered at any instant
    refusals: int = 0  # take() calls throttled by the lookahead budget

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class AdmissionWindow(ViewSource):
    """Incremental, lookahead-bounded realization of one logical iteration.

    One window corresponds to one logical sampler iteration (one shuffled,
    padded view order).  Ranks pull with ``take(rank, k)``; the window
    advances a single global cursor through the order, realizing lengths and
    distributing views to per-rank staging deques (stride-sharding:
    ``rank = position % W``), while never holding more than ``lookahead``
    realized-undelivered views.

    ``lookahead`` must be at least ``world_size`` — below that, a full budget
    can consist entirely of views staged for other ranks and the requesting
    rank could starve for a round with nothing forcing progress.
    """

    def __init__(
        self,
        records: list[RawRecord],
        policy: PipelinePolicy,
        spec: SamplerSpec,
        *,
        shuffle_epoch: int,
        pipeline_epoch: int = 0,
        lookahead: int | None = None,
        view_id_base: int = 0,
    ) -> None:
        if lookahead is None:
            lookahead = spec.total_views
        if lookahead < spec.world_size:
            raise ValueError(
                f"lookahead {lookahead} < world_size {spec.world_size}: "
                "a full window could hold no view for the requesting rank"
            )
        self.records = records
        self.policy = policy
        self.spec = spec
        self.shuffle_epoch = shuffle_epoch
        self.pipeline_epoch = pipeline_epoch
        self.lookahead = lookahead
        self.view_id_base = view_id_base
        self.order = global_view_order(spec, shuffle_epoch)  # identities only
        self.cursor = 0
        self.resident = 0
        self.staged: list[collections.deque[Sample]] = [
            collections.deque() for _ in range(spec.world_size)
        ]
        self.delivered_per_rank = [0] * spec.world_size
        self.stats = WindowStats()

    # -- admission -------------------------------------------------------------
    def _admit_one(self) -> None:
        identity = self.order[self.cursor]
        length = run_pipeline(self.records[identity], self.policy, self.pipeline_epoch)
        sample = Sample(
            view_id=self.view_id_base + self.cursor,
            identity=identity,
            length=length,
        )
        self.staged[self.cursor % self.spec.world_size].append(sample)
        self.cursor += 1
        self.resident += 1
        self.stats.realized += 1
        self.stats.peak_resident = max(self.stats.peak_resident, self.resident)

    # -- ViewSource interface --------------------------------------------------
    def take(self, rank: int, k: int) -> list[Sample]:
        dq = self.staged[rank]
        throttled = False
        while len(dq) < k and self.cursor < len(self.order):
            if self.resident >= self.lookahead:
                throttled = True
                break
            self._admit_one()
        if throttled and len(dq) < k:
            self.stats.refusals += 1
        out: list[Sample] = []
        while dq and len(out) < k:
            out.append(dq.popleft())
        self.resident -= len(out)
        self.delivered_per_rank[rank] += len(out)
        self.stats.delivered += len(out)
        return out

    def exhausted(self, rank: int) -> bool:
        return self.cursor >= len(self.order) and not self.staged[rank]

    def remaining(self, rank: int) -> int:
        """Views not yet delivered to ``rank`` (staged + beyond the cursor).

        Exact because the padded order has fixed per-rank quota
        ``ceil(N/W)`` regardless of realized lengths.
        """
        return self.spec.per_rank_quota - self.delivered_per_rank[rank]

    # -- checkpointing (stream/state.py) ---------------------------------------
    def state_dict(self) -> dict:
        """Serializable mid-iteration window state.

        The shuffle order is NOT serialized — it regenerates deterministically
        from (spec, shuffle_epoch).  Staged views are stored explicitly so a
        resume is exact even though they could in principle be re-realized.
        """
        return {
            "cursor": self.cursor,
            "view_id_base": self.view_id_base,
            "shuffle_epoch": self.shuffle_epoch,
            "pipeline_epoch": self.pipeline_epoch,
            "lookahead": self.lookahead,
            "staged": [
                [[s.view_id, s.identity, s.length] for s in dq]
                for dq in self.staged
            ],
            "delivered_per_rank": list(self.delivered_per_rank),
            "stats": self.stats.as_dict(),
        }

    def load_state_dict(self, state: dict) -> None:
        self.cursor = state["cursor"]
        self.view_id_base = state["view_id_base"]
        self.lookahead = state["lookahead"]
        self.staged = [
            collections.deque(
                Sample(view_id=v, identity=i, length=ln) for v, i, ln in dq
            )
            for dq in state["staged"]
        ]
        self.resident = sum(len(dq) for dq in self.staged)
        self.delivered_per_rank = list(state["delivered_per_rank"])
        self.stats = WindowStats(**state["stats"])
