"""Background-thread prefetcher with bounded-queue backpressure (DESIGN.md §9.3).

Overlaps the data-side work of the streaming path — pipeline realization,
grouping, alignment rounds, bucket padding — with the consumer's jitted train
step.  A producer thread drains the step iterator into a bounded
``queue.Queue``; ``put`` blocks when the consumer falls behind (backpressure:
the producer can never run more than ``depth`` steps ahead, which also caps
host memory for staged batches), and ``get`` blocks when the producer is
behind (a *miss*, i.e. the train step would have stalled on data anyway).

The hit/miss split is the prefetcher's figure of merit: a hit means the next
batch was already staged when the consumer asked — at steady state with
compute-bound steps, the hit rate should approach 1.0 (benchmarks/streaming.py
records it).

Threading notes: producer exceptions are captured and re-raised in the
consumer thread at the position they occurred; ``close()`` signals a
condition the producer waits on, so a producer blocked on a full queue wakes
*immediately* (no put-poll, no timing-dependent spin) and ``close()`` returns
as soon as the producer's current item finishes.  The GIL makes the
protocol/bookkeeping overlap cooperative rather than parallel on pure-Python
stages; ``stream/workers.py`` moves the heavy stages into worker processes
(DESIGN.md §14) and this iterator then carries already-realized steps, with
its ``stage`` hook as the consumer-side ``device_put`` point.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Generic, Iterable, Iterator, TypeVar

from repro import obs

T = TypeVar("T")

_END = object()


class _ClosableQueue:
    """Bounded FIFO whose blocked producers/consumers wake on ``close()``.

    ``queue.Queue`` offers no close signal: a producer blocked in ``put`` on
    a full queue can only poll with a timeout (the old 0.05 s spin).  Here
    both sides wait on one condition; ``close()`` flips the flag under the
    lock and notifies everyone, so shutdown latency is lock-handoff time,
    not a poll interval.
    """

    def __init__(self, maxsize: int) -> None:
        self._maxsize = maxsize
        self._items: list = []
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self.closed = False

    def put(self, item, force: bool = False) -> bool:
        """Block until space or close; False = queue closed, item dropped.

        ``force=True`` appends even when full (never blocks) — reserved for
        the terminal sentinel: a producer that just *failed* must be able to
        deliver ``_END`` past a full queue, or the error it captured would
        sit unreported behind a blocked put until the consumer happened to
        drain (tests/test_stream.py::TestPrefetch).
        """
        with self._cond:
            while not force and len(self._items) >= self._maxsize and not self.closed:
                self._cond.wait()
            if self.closed:
                return False
            self._items.append(item)
            self._cond.notify_all()
            return True

    def get(self, timeout: float | None = None):
        """Pop the head; raises ``queue.Empty`` on timeout (or when closed
        with nothing buffered).  ``timeout=0`` = non-blocking."""
        with self._cond:
            if not self._items and timeout != 0 and not self.closed:
                self._cond.wait_for(lambda: self._items or self.closed, timeout)
            if not self._items:
                raise queue.Empty
            item = self._items.pop(0)
            self._cond.notify_all()
            return item

    def qsize(self) -> int:
        with self._lock:
            return len(self._items)

    def close(self) -> None:
        """Discard buffered items and wake every waiter immediately."""
        with self._cond:
            self.closed = True
            self._items.clear()
            self._cond.notify_all()


@dataclasses.dataclass
class PrefetchStats:
    produced: int = 0  # items the producer finished staging
    consumed: int = 0  # items delivered to the consumer
    hits: int = 0  # get() satisfied without blocking
    misses: int = 0  # consumer had to wait on the producer
    wait_s: float = 0.0  # total consumer stall time
    produce_s: float = 0.0  # total producer-side staging time

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["hit_rate"] = self.hit_rate
        return d


class PrefetchIterator(Generic[T]):
    """Iterate ``source`` through a ``depth``-bounded background queue.

    ``stage`` is an optional producer-side hook applied to every item before
    it is queued (timed into ``produce_s``).  The loader uses it to issue
    ``jax.device_put`` on staged ``DeviceBatch`` arrays so the H2D transfer
    hides under the consumer's jitted step (ROADMAP "device-put overlap"):
    by the time the consumer dequeues, the buffers are already device-resident
    (double-buffered by the queue depth).
    """

    def __init__(
        self, source: Iterable[T], *, depth: int = 2, stage=None
    ) -> None:
        if depth < 1:
            raise ValueError(f"prefetch depth must be >= 1, got {depth}")
        self.depth = depth
        self._stage = stage
        self.stats = PrefetchStats()
        # Telemetry (DESIGN.md §13): hit/miss split + queue depth + stall time.
        self._m_hits = obs.counter(
            "odb_prefetch_hits_total", help="get() satisfied without blocking"
        )
        self._m_misses = obs.counter(
            "odb_prefetch_misses_total", help="consumer waited on the producer"
        )
        self._m_wait = obs.counter(
            "odb_prefetch_wait_seconds_total",
            help="total consumer stall time",
            unit="seconds",
        )
        self._m_depth = obs.gauge(
            "odb_prefetch_queue_depth", help="staged items at last delivery"
        )
        self._queue = _ClosableQueue(depth)
        self._stop = threading.Event()
        self._finished = False  # _END consumed, error raised, or closed
        self._error: BaseException | None = None
        self._thread = threading.Thread(
            target=self._produce, args=(iter(source),), daemon=True
        )
        self._thread.start()

    # -- producer side ---------------------------------------------------------
    def _produce(self, it: Iterator[T]) -> None:
        try:
            tracer = obs.default_tracer()
            while not self._stop.is_set():
                t0 = time.perf_counter()
                try:
                    item = next(it)
                except StopIteration:
                    break
                if self._stage is not None:
                    item = self._stage(item)
                dt = time.perf_counter() - t0
                self.stats.produce_s += dt
                tracer.complete(
                    "prefetch/produce", t0, dt, cat="prefetch",
                    item=self.stats.produced,
                )
                # Blocks on a full queue; a close() wakes it immediately
                # (Event-signaled, not put-polled) and returns False.
                if not self._queue.put(item):
                    return
                self.stats.produced += 1
        except BaseException as exc:  # surfaced on the consumer side
            self._error = exc
        # force: the sentinel must land even on a full queue — on the error
        # path nothing will ever drain ahead of it if the consumer is slow,
        # and the producer thread must exit promptly either way.
        self._queue.put(_END, force=True)

    # -- consumer side ---------------------------------------------------------
    def __iter__(self) -> Iterator[T]:
        return self

    def __next__(self) -> T:
        if self._finished:
            raise StopIteration
        try:
            item = self._queue.get(timeout=0)
            hit = True
        except queue.Empty:
            hit = False
            t0 = time.perf_counter()
            while True:
                try:
                    item = self._queue.get(timeout=0.1)
                    break
                except queue.Empty:
                    # Producer dead with nothing queued (e.g. close() drained
                    # the sentinel): the stream is over, don't block forever —
                    # but never swallow a captured producer error into a bare
                    # StopIteration (the pre-fix masking bug).
                    if self._finished or not self._thread.is_alive():
                        self._finished = True
                        if self._error is not None:
                            error, self._error = self._error, None
                            raise error
                        raise StopIteration from None
            waited = time.perf_counter() - t0
            self.stats.wait_s += waited
            self._m_wait.inc(waited)
            obs.default_tracer().complete(
                "prefetch/wait", t0, waited, cat="prefetch"
            )
        if item is _END:
            # The terminal sentinel is not a data request; don't score it.
            self._finished = True
            self._thread.join(timeout=5.0)
            if self._error is not None:
                raise self._error
            raise StopIteration
        if hit:
            self.stats.hits += 1
            self._m_hits.inc()
        else:
            self.stats.misses += 1
            self._m_misses.inc()
        self.stats.consumed += 1
        self._m_depth.set(self._queue.qsize())
        return item

    def close(self, timeout: float | None = None) -> None:
        """Stop the producer and discard staged items (consumer gave up).

        Blocks until the producer thread exits (its current `next(source)`
        finishes; protocol termination envelopes bound that).  Callers that
        perform post-close rollback of staged work depend on the producer
        being genuinely stopped — pass a ``timeout`` only if a wedged
        producer is preferable to waiting, and check :meth:`producer_alive`
        afterwards.
        """
        self._stop.set()
        self._queue.close()  # wakes a producer blocked on a full queue NOW
        self._thread.join(timeout=timeout)
        self._finished = True

    @property
    def producer_alive(self) -> bool:
        return self._thread.is_alive()

    def __enter__(self) -> "PrefetchIterator[T]":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
