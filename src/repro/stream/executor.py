"""Streaming DGAP executor (DESIGN.md §9.2).

``StreamExecutor`` makes ODB genuinely online: the incremental
:class:`repro.core.protocol.EpochRunner` drives protocol rounds one at a
time, pulling sampler views through the bounded-lookahead
:class:`AdmissionWindow` — realized lengths enter existence only as the
window admits them, and aligned steps leave the executor as soon as a round
produces them.  The full per-epoch length list is never materialized.

Equivalence guarantee (tests/test_stream.py): with ``lookahead >= M`` the
window never throttles a fetch, every protocol round sees exactly the state
the offline engine would, and the delivered step sequence is bit-for-bit the
``odb_schedule`` sequence for the same (seed, epoch, config).  With a tighter
lookahead the schedule legitimately differs — grouping sees a narrower
window — but Theorem 1 coverage is unchanged: every view is still admitted,
fetched, grouped and emitted exactly once.

Checkpoint/resume: ``checkpoint()`` between any two ``step()`` calls
serializes window cursor, residual pools and emit accounting
(stream/state.py); ``StreamExecutor.resume`` reconstructs an executor that
continues the identical step sequence, so mid-epoch preemption preserves
exact-identity coverage.

Fault tolerance (DESIGN.md §15): with ``config.round_deadline_s`` set (or a
chaos injector installed) the engine's collective is wrapped in
:class:`repro.core.comm.ResilientCollective`.  A transient gather fault is
retried transparently; an unrecoverable one surfaces as
:class:`EpochAborted`, which carries a *valid* resumable checkpoint — the
failed gather left no observable protocol change (payloads are memoized in
the wrapper and the round index never advanced), so resuming replays the
identical round and the combined pre-abort + post-resume step stream is the
uninterrupted one.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Iterator

from repro import obs
from repro.core.comm import RankTimeoutError, ResilientCollective
from repro.core.grouping import Group
from repro.core.protocol import (
    EpochAudit,
    EpochRunner,
    OdbConfig,
    OdbProtocolEngine,
)
from repro.data.pipeline import PipelinePolicy, RawRecord
from repro.data.sampler import (
    ITERATION_VIEW_ID_STRIDE,
    SamplerSpec,
    iteration_shuffle_epoch,
)
from repro.stream.state import (
    STATE_VERSION,
    StreamCheckpoint,
    bitmap_to_identities,
    identities_to_bitmap,
    load_rank_state,
    rank_state_dict,
    step_from_json,
    step_to_json,
)
from repro.stream.window import (
    AdmissionWindow,
    QuarantineLedger,
    ShardedWindow,
    WindowRouter,
    WindowStats,
)


class EpochAborted(RuntimeError):
    """Degraded-mode epoch closure (DESIGN.md §15.4).

    Raised by :meth:`StreamExecutor.step` when a round's collective exhausts
    its retry budget (:class:`repro.core.comm.RankTimeoutError`).  The epoch
    is *not* lost: the failed gather left no observable protocol change, so
    :meth:`checkpoint` (lazy — taken on first call, under the executor lock)
    yields a valid stream checkpoint from which ``StreamExecutor.resume``
    replays the aborted round and continues the identical step sequence.

    ``failed_ranks`` forwards the cause's full casualty list (every rank
    that failed the final delivery attempt, not just the first), so abort
    handling — operator logs, ``stream_abort.json`` — keeps the whole
    straggler census.
    """

    def __init__(self, cause: BaseException, executor: "StreamExecutor") -> None:
        super().__init__(f"epoch aborted: {cause}")
        self.cause = cause
        self._executor = executor
        self._checkpoint: StreamCheckpoint | None = None

    @property
    def failed_ranks(self) -> list[int]:
        return list(getattr(self.cause, "failed_ranks", []) or [])

    def checkpoint(self) -> StreamCheckpoint:
        if self._checkpoint is None:
            self._checkpoint = self._executor.checkpoint()
        return self._checkpoint


class StreamExecutor:
    """Step-at-a-time ODB epoch over a bounded admission window."""

    def __init__(
        self,
        records: list[RawRecord],
        policy: PipelinePolicy,
        world_size: int,
        config: OdbConfig,
        *,
        seed: int = 0,
        epoch: int = 0,
        lookahead: int | None = None,
        max_logical_iterations: int = 64,
        dataset_identities: int | None = None,
        fault_injector=None,
        num_hosts: int = 1,
    ) -> None:
        n = len(records) if dataset_identities is None else dataset_identities
        self.records = records
        self.policy = policy
        self.config = config
        self.seed = seed
        self.epoch = epoch
        self.max_logical_iterations = max_logical_iterations
        self.spec = SamplerSpec(dataset_size=n, world_size=world_size, seed=seed)
        if num_hosts < 1 or num_hosts > world_size:
            raise ValueError(
                f"num_hosts {num_hosts} must be in [1, world_size "
                f"{world_size}] (each host owns a contiguous, possibly "
                "uneven rank block)"
            )
        # P > 1 runs one ShardedWindow per host behind a WindowRouter — the
        # in-process simulation of a multi-host deployment (DESIGN.md §16).
        # The delivered step stream is bit-identical for every host count:
        # window state is per-rank decomposed, so partitioning ranks over
        # hosts changes nothing the protocol can observe.
        self.num_hosts = num_hosts
        self.lookahead = (
            self.spec.total_views if lookahead is None else lookahead
        )
        if self.lookahead < world_size:
            # Fail at construction, not at the first window build: a full
            # lookahead budget could otherwise hold no view for the
            # requesting rank (see AdmissionWindow).
            raise ValueError(
                f"lookahead {self.lookahead} < world_size {world_size}"
            )
        if config.output_capacity is not None:
            # Incremental delivery drains out_queue after every round, so the
            # C_r envelope would never bind and the schedule would silently
            # diverge from the eager path's.  Streaming backpressure comes
            # from the admission window + the bounded prefetch queue instead.
            raise ValueError(
                "output_capacity is an eager-path knob; the streaming "
                "executor's backpressure is lookahead + prefetch depth"
            )
        # Chaos injection (repro.chaos): queried per (round, attempt, rank)
        # by the ResilientCollective wrapper.  None in production unless a
        # harness installs one; installing one also turns the wrapper on.
        self.fault_injector = fault_injector
        # Degraded-mode latch: once a round aborts, subsequent step() calls
        # re-raise instead of re-driving rounds into the same dead transport —
        # recovery is checkpoint + resume, not silent retry-forever.
        self.aborted = False
        self._abort_cause: BaseException | None = None
        self.window: AdmissionWindow | WindowRouter | None = None
        self._closed_window_stats: list[WindowStats] = []
        # step()/checkpoint()/audit() are serialized so a checkpoint taken
        # from the trainer thread while a prefetch producer thread is inside
        # a protocol round snapshots a step boundary, never a torn mid-round
        # state (the resume guarantee depends on this).
        self._lock = threading.RLock()
        # Per-epoch DGAP round audit (DESIGN.md §13.3): every protocol round
        # and every iteration closure lands here via the engine/runner hooks;
        # checkpoint() serializes it so a resumed run's audit is continuous.
        self.telemetry = obs.RoundTimeline(world_size)
        self._m_steps = obs.counter(
            "odb_stream_steps_total", help="aligned steps delivered by the executor"
        )
        self.runner = EpochRunner(
            self._make_engine,
            n,
            config,
            world_size=world_size,
            max_logical_iterations=max_logical_iterations,
            incremental=True,
        )
        self.runner.on_closure = self._on_closure

    # -- telemetry hooks -------------------------------------------------------
    def _on_round(self, record) -> None:
        self.telemetry.record_round(
            record, record.duration_s, self.runner.iteration
        )

    def _on_closure(self, event: str, iteration: int, rounds: int) -> None:
        self.telemetry.record_closure(event, iteration, rounds)

    # -- fault hooks -------------------------------------------------------------
    def _on_quarantine(self, position: int, identity: int, exc: BaseException) -> None:
        # Fold a window-level quarantine into the epoch-level Lemma-1
        # accounting: the identity joins component X, which shrinks the
        # effective quota so non-join termination cannot chase a poison
        # identity across logical iterations forever (Theorem 2 caveat, §15).
        self.runner.note_quarantine(identity)

    def _on_remote_quarantine(self, identity: int) -> None:
        # §16 merge path: an identity another host's window quarantined
        # arrives through the gather payload.  Folding it into the runner
        # keeps non-join closure on the MERGED |X| even when host ledgers
        # are not shared (a real deployment); in the in-process lane the
        # shared ledger makes this a no-op by idempotence.
        self.runner.note_quarantine(identity)

    # -- iteration factory -----------------------------------------------------
    def _make_window(self, iteration: int) -> AdmissionWindow | WindowRouter:
        # The quarantine budget is per *epoch* and charges each distinct
        # sample once: a new window gets whatever headroom earlier iterations
        # left unspent, and identities already in X are exempt — a non-join
        # catch-up iteration (or a resumed run) re-walks the order and meets
        # the same deterministically-failing sample again, which must not
        # re-spend the budget.
        budget = max(
            0, self.config.max_quarantine - len(self.runner.quarantined_ids)
        )
        exempt = frozenset(self.runner.quarantined_ids)
        kwargs = dict(
            shuffle_epoch=iteration_shuffle_epoch(self.epoch, iteration),
            pipeline_epoch=self.epoch,
            lookahead=self.lookahead,
            view_id_base=iteration * ITERATION_VIEW_ID_STRIDE,
        )
        window: AdmissionWindow | WindowRouter
        if self.num_hosts == 1:
            window = AdmissionWindow(
                self.records,
                self.policy,
                self.spec,
                max_quarantine=budget,
                quarantine_exempt=exempt,
                **kwargs,
            )
        else:
            # One window per simulated host, all over the same deterministic
            # order, each serving only its rank block.  The ledger is shared
            # so the per-epoch quarantine budget charges each distinct
            # sample once regardless of which host hits the failure first
            # (the padded order repeats identities across rank blocks).
            ledger = QuarantineLedger(budget, exempt)
            window = WindowRouter(
                [
                    ShardedWindow(
                        self.records,
                        self.policy,
                        self.spec,
                        host=host,
                        num_hosts=self.num_hosts,
                        ledger=ledger,
                        **kwargs,
                    )
                    for host in range(self.num_hosts)
                ]
            )
        window.on_quarantine = self._on_quarantine
        window.on_remote_quarantine = self._on_remote_quarantine
        return window

    def _make_engine(self, iteration: int) -> OdbProtocolEngine:
        if self.window is not None:
            self._closed_window_stats.append(self.window.stats)
        self.window = self._make_window(iteration)
        return self._build_engine(self.window)

    def _build_engine(
        self, window: AdmissionWindow | WindowRouter
    ) -> OdbProtocolEngine:
        # A lookahead tighter than the depth envelope throttles fetches to
        # O(lookahead/W) views per rank per round, so the Theorem-4 guard
        # widens from q + O(D) to q + O(D) + O(M) — still a hard finite
        # envelope, just sized for the throttled regime.
        engine = OdbProtocolEngine(
            [[] for _ in range(self.spec.world_size)],
            self.config,
            source=window,
            quota_hint=self.spec.per_rank_quota,
            round_margin=64 + self.spec.total_views,
        )
        engine.on_round = self._on_round
        if self.config.round_deadline_s is not None or self.fault_injector is not None:
            engine.collective = ResilientCollective(
                engine.collective,
                deadline_s=(
                    1.0
                    if self.config.round_deadline_s is None
                    else self.config.round_deadline_s
                ),
                max_retries=self.config.round_retries,
                backoff_base_s=self.config.retry_backoff_s,
                injector=self.fault_injector,
                seed=self.seed,
            )
        return engine

    # -- trainer-facing surface ------------------------------------------------
    def step(self) -> list[Group | None] | None:
        with self._lock:
            if self.aborted:
                raise EpochAborted(self._abort_cause, self)
            try:
                with obs.span("stream/step", cat="stream"):
                    out = self.runner.step()
            except RankTimeoutError as exc:
                # Degraded-mode closure (§15.4): latch, then surface the abort
                # carrying a lazy checkpoint.  We are between steps here (the
                # failed gather never mutated protocol state), so the
                # checkpoint is valid and resume replays the aborted round.
                self.aborted = True
                self._abort_cause = exc
                # Full casualty list into the round audit: the abort record
                # (and the checkpoint it rides in) names EVERY failed rank.
                self.telemetry.record_abort(
                    exc.failed_ranks,
                    round_index=exc.round_index,
                    attempts=exc.attempts,
                    reason=str(exc),
                )
                raise EpochAborted(exc, self) from exc
            if out is not None:
                self._m_steps.inc()
            return out

    def steps(self) -> Iterator[list[Group | None]]:
        while True:
            s = self.step()
            if s is None:
                return
            yield s

    def next_task(self) -> tuple[int, list[Group | None]] | None:
        """One ``(step_index, aligned_step)`` realization task, or None.

        The worker-pool pump (DESIGN.md §14) drives protocol rounds through
        this: task *emission* happens here, under the executor lock, while
        task *execution* (layout planning + padding + token synthesis) runs
        in worker processes — the protocol never waits on realization.  The
        pool itself holds no checkpointable state: tasks submitted but not
        consumed are rolled back via :meth:`requeue`, so a checkpoint is
        worker-count-agnostic and resume with any ``num_workers`` (including
        0) continues the identical step sequence.
        """
        with self._lock:
            step = self.step()
            if step is None:
                return None
            return self.runner.steps_delivered - 1, step

    @property
    def done(self) -> bool:
        return self.runner.done

    def requeue(self, steps) -> None:
        """Roll staged-but-unconsumed steps back (prefetch abandonment)."""
        with self._lock:
            self.runner.requeue(steps)

    def audit(self) -> EpochAudit:
        with self._lock:
            return self.runner.audit()

    def window_stats(self) -> WindowStats:
        """Aggregate admission stats across all iterations so far."""
        agg = WindowStats()
        windows = list(self._closed_window_stats)
        if self.window is not None:
            windows.append(self.window.stats)
        for st in windows:
            agg.realized += st.realized
            agg.delivered += st.delivered
            agg.refusals += st.refusals
            agg.quarantined += st.quarantined
            agg.peak_resident = max(agg.peak_resident, st.peak_resident)
        return agg

    # -- checkpoint / resume ---------------------------------------------------
    def checkpoint(self) -> StreamCheckpoint:
        """Snapshot the executor between two ``step()`` calls.

        Thread-safe: the snapshot is taken under the executor lock, so with a
        prefetch producer running it lands exactly on a step boundary (the
        producer-side frontier)."""
        with self._lock:
            return self._checkpoint_locked()

    def _checkpoint_locked(self) -> StreamCheckpoint:
        runner = self.runner
        engine = runner.engine
        payload = {
            "version": STATE_VERSION,
            "seed": self.seed,
            "epoch": self.epoch,
            # The host partition the checkpoint was TAKEN at — informational:
            # window state is per-rank (v4), so resume may regroup the ranks
            # onto any other divisor host count bit-exactly.
            "num_hosts": self.num_hosts,
            "world_size": self.spec.world_size,
            "dataset_identities": self.spec.dataset_size,
            "lookahead": self.lookahead,
            "max_logical_iterations": self.max_logical_iterations,
            "config": dataclasses.asdict(self.config),
            "policy_key": self.policy.cache_key("stream"),
            "num_records": len(self.records),
            "runner": {
                "iteration": runner.iteration,
                "emitted_total": runner.emitted_total,
                "emitted_bitmap": identities_to_bitmap(runner.emitted_ids),
                "rounds": runner.rounds,
                "rounds_offline_extra": runner.rounds_offline_extra,
                "abandoned": list(runner.abandoned),
                "steps_delivered": runner.steps_delivered,
                "terminated_by": runner.terminated_by,
                "done": runner.done,
                "iteration_open": runner._iteration_open,
                "iter_rounds": runner._iter_rounds,
                "ready": [step_to_json(s) for s in runner._ready],
                # Component X (v3): a small sorted list, not a bitmap — it is
                # bounded by max_quarantine, and the base-window sentinel
                # identity -1 would not fit a dense bitmap anyway.
                "quarantined_ids": sorted(runner.quarantined_ids),
                "quarantined_views": runner.quarantined_views,
            },
            "engine": None
            if engine is None
            else {
                "round_index": engine._round_index,
                "ranks": [rank_state_dict(r) for r in engine.ranks],
            },
            "window": None
            if engine is None or self.window is None
            else self.window.state_dict(),
            # A window whose iteration just finished (engine dropped) isn't
            # serialized above; fold its stats in so resumed-run metrics
            # still aggregate the whole epoch.
            "closed_window_stats": [
                st.as_dict() for st in self._closed_window_stats
            ]
            + (
                [self.window.stats.as_dict()]
                if engine is None and self.window is not None
                else []
            ),
            # Telemetry rides along (optional key, read back with .get() so
            # pre-telemetry checkpoints still resume): the round audit plus
            # the odb_* counter families, so a resumed run *continues* the
            # counters instead of restarting them at zero.
            "telemetry": {
                "rounds": self.telemetry.as_dict(),
                "counters": obs.default_registry().state(prefix="odb_"),
            },
        }
        return StreamCheckpoint(payload)

    @classmethod
    def resume(
        cls,
        checkpoint: StreamCheckpoint,
        records: list[RawRecord],
        policy: PipelinePolicy,
        *,
        fault_injector=None,
        num_hosts: int | None = None,
    ) -> "StreamExecutor":
        """Rebuild an executor that continues the checkpointed step sequence.

        ``records``/``policy`` are re-supplied by the caller (they are data,
        not state); the policy fingerprint is verified so a silently changed
        transform policy — which would drift realized lengths and break
        exact-identity coverage — fails loudly instead.

        ``num_hosts`` may differ from the checkpointing run's: v4 window
        state is per-rank, so an elastic restart regroups the rank states
        onto the new host partition and continues the identical step
        sequence (DESIGN.md §16).  ``None`` keeps the checkpointed count.
        """
        p = checkpoint.payload
        if policy.cache_key("stream") != p["policy_key"]:
            raise ValueError(
                "pipeline policy mismatch: checkpointed lengths were realized "
                "under a different transform policy"
            )
        if len(records) != p["num_records"]:
            raise ValueError(
                f"record count mismatch: {len(records)} != {p['num_records']}"
            )
        ex = cls(
            records,
            policy,
            p["world_size"],
            OdbConfig(**p["config"]),
            seed=p["seed"],
            epoch=p["epoch"],
            lookahead=p["lookahead"],
            max_logical_iterations=p["max_logical_iterations"],
            dataset_identities=p["dataset_identities"],
            fault_injector=fault_injector,
            num_hosts=p.get("num_hosts", 1) if num_hosts is None else num_hosts,
        )
        rs = p["runner"]
        runner = ex.runner
        runner.iteration = rs["iteration"]
        runner.quarantined_ids = set(rs.get("quarantined_ids", []))
        runner.quarantined_views = rs.get("quarantined_views", 0)
        runner.emitted_total = rs["emitted_total"]
        runner.emitted_ids = bitmap_to_identities(rs["emitted_bitmap"])
        runner.rounds = rs["rounds"]
        runner.rounds_offline_extra = rs.get("rounds_offline_extra", 0)
        runner.abandoned = list(rs["abandoned"])
        runner.steps_delivered = rs["steps_delivered"]
        runner.terminated_by = rs["terminated_by"]
        runner._done = rs["done"]
        runner._iteration_open = rs["iteration_open"]
        runner._iter_rounds = rs["iter_rounds"]
        runner._ready = collections.deque(
            step_from_json(s) for s in rs["ready"]
        )
        ex._closed_window_stats = [
            WindowStats(**st) for st in p.get("closed_window_stats", [])
        ]
        telemetry = p.get("telemetry")
        if telemetry is not None:
            ex.telemetry = obs.RoundTimeline.from_dict(telemetry["rounds"])
            obs.default_registry().load_state(telemetry["counters"])
        if p["engine"] is not None:
            window = ex._make_window(rs["iteration"])
            window.load_state_dict(p["window"])
            ex.window = window
            engine = ex._build_engine(window)
            for rank, st in zip(engine.ranks, p["engine"]["ranks"]):
                load_rank_state(rank, st)
            engine._round_index = p["engine"]["round_index"]
            runner._engine = engine
        return ex
