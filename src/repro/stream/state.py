"""Resumable loader/scheduler state (DESIGN.md §9.4).

A mid-epoch checkpoint of the streaming executor captures, layer by layer:

  * epoch-level accounting — iteration index, cumulative emit counts, the
    emitted-identity set (what Theorem 1's coverage audit is computed from)
    as a fixed-size identity *bitmap* (identities are dense in [0, N), so the
    serialized form is N/8 bytes regardless of how many logical iterations
    have emitted — the ledger no longer grows O(quota) per iteration), steps
    delivered so far;
  * the admission window — global cursor, staged-but-undelivered views,
    per-rank delivery counts (the shuffle order itself regenerates
    deterministically from (seed, epoch, iteration));
  * per-rank protocol residuals — the (R, Q, B) pools, the emitted count
    (component E is conservation-counted, never stored per sample), output
    queues, counters and local-finish flags;
  * engine round index, so Round records of a resumed run continue numbering.

Everything is JSON-serializable: samples flatten to ``[view_id, identity,
length]`` triples, groups to lists of triples, IDLE to ``null``.  Restoring
and continuing yields the *identical* step sequence the uninterrupted run
would have produced, so identity coverage (Theorem 1) is preserved across a
checkpoint/resume boundary — proven by tests/test_stream.py.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any

from repro.core.grouping import Group, Sample
from repro.core.protocol import IDLE, OdbConfig, RankCounters, RankRuntime

# v4: distributed window (DESIGN.md §16) — window state is keyed per *rank*
# (cursors/staged/delivered lists) instead of a single global cursor, the
# payload records ``num_hosts``, and the round audit carries the abort
# census; per-rank keying is what makes resume-at-a-different-host-count
# bit-exact, so earlier versions are rejected.
# v3: quarantine component X rode the checkpoint (runner quarantined ids +
# per-window quarantine records, DESIGN.md §15) so a resumed run keeps the
# extended (R, Q, B, E, X) accounting.
# v2: emitted ledgers shrank to count + identity bitmap (ROADMAP "checkpoint
# size"); v1 checkpoints carried per-sample emitted lists and are rejected.
STATE_VERSION = 4


# -- identity bitmap codec ----------------------------------------------------


def identities_to_bitmap(ids) -> str:
    """Hex-encoded bitmap with bit ``i`` set iff identity ``i`` was emitted.

    Identities are dense dataset indices, so the bitmap is ~N/8 bytes — the
    asymptotic fix for checkpoints on 10^7+-sample datasets, where the old
    sorted-id list cost ~8 bytes *per emitted view per logical iteration*.
    """
    if not ids:
        return ""
    buf = bytearray((max(ids) >> 3) + 1)
    for i in ids:
        buf[i >> 3] |= 1 << (i & 7)
    return bytes(buf).hex()


def bitmap_to_identities(bitmap: str) -> set[int]:
    out: set[int] = set()
    for byte_idx, byte in enumerate(bytes.fromhex(bitmap)):
        while byte:
            low = byte & -byte
            out.add((byte_idx << 3) + low.bit_length() - 1)
            byte ^= low
    return out


# -- sample / group / step codecs ---------------------------------------------


def sample_to_json(sample: Sample) -> list:
    return [sample.view_id, sample.identity, sample.length]


def sample_from_json(data: list) -> Sample:
    return Sample(view_id=data[0], identity=data[1], length=data[2])


def group_to_json(group: Group | None) -> list | None:
    if group is IDLE or group is None:
        return None
    return [sample_to_json(s) for s in group.samples]


def group_from_json(data: list | None) -> Group | None:
    if data is None:
        return IDLE
    return Group(samples=tuple(sample_from_json(s) for s in data))


def step_to_json(step: list[Group | None]) -> list:
    return [group_to_json(g) for g in step]


def step_from_json(data: list) -> list[Group | None]:
    return [group_from_json(g) for g in data]


# -- per-rank protocol residuals ----------------------------------------------


def rank_state_dict(rank: RankRuntime) -> dict:
    return {
        "pending": [sample_to_json(s) for s in rank.pending],
        "worker_queue": [sample_to_json(s) for s in rank.worker_queue],
        "buffer": [sample_to_json(s) for s in rank.buffer],
        "emitted_count": rank.emitted_count,
        "out_queue": [group_to_json(g) for g in rank.out_queue],
        "counters": dataclasses.asdict(rank.counters),
        "local_finished": rank.local_finished,
        "admitted": rank.admitted,
        "drain_rate": rank.drain_rate,
    }


def load_rank_state(rank: RankRuntime, state: dict) -> None:
    rank.pending.clear()
    rank.pending.extend(sample_from_json(s) for s in state["pending"])
    rank.worker_queue.clear()
    rank.worker_queue.extend(sample_from_json(s) for s in state["worker_queue"])
    rank.buffer = [sample_from_json(s) for s in state["buffer"]]
    rank.emitted_count = state["emitted_count"]
    rank.out_queue.clear()
    rank.out_queue.extend(group_from_json(g) for g in state["out_queue"])
    rank.counters = RankCounters(**state["counters"])
    rank.local_finished = state["local_finished"]
    rank.admitted = state["admitted"]
    rank.drain_rate = state["drain_rate"]


# -- the checkpoint -----------------------------------------------------------


@dataclasses.dataclass
class StreamCheckpoint:
    """One serializable snapshot of a :class:`StreamExecutor` between steps."""

    payload: dict[str, Any]

    @property
    def step_index(self) -> int:
        return self.payload["runner"]["steps_delivered"]

    @property
    def epoch(self) -> int:
        return self.payload["epoch"]

    def config(self) -> OdbConfig:
        return OdbConfig(**self.payload["config"])

    def to_json(self) -> str:
        return json.dumps(self.payload)

    @classmethod
    def from_json(cls, text: str) -> "StreamCheckpoint":
        payload = json.loads(text)
        version = payload.get("version")
        if version != STATE_VERSION:
            raise ValueError(
                f"unsupported stream checkpoint version {version!r} "
                f"(expected {STATE_VERSION})"
            )
        return cls(payload)

    def save(self, path: str) -> None:
        tmp = f"{path}.tmp"
        with open(tmp, "w") as fh:
            fh.write(self.to_json())
        os.replace(tmp, path)  # atomic publish, same as train/checkpoint.py

    @classmethod
    def load(cls, path: str) -> "StreamCheckpoint":
        with open(path) as fh:
            return cls.from_json(fh.read())
