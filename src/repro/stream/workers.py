"""Multi-process prefetch workers: GIL-free window realization with
shared-memory staging (DESIGN.md §14).

The in-process prefetcher (stream/prefetch.py) overlaps data-side work with
the jitted train step, but the heavy per-step work — layout planning,
first-fit packing, token synthesis, bucket padding — shares the GIL with the
DGAP protocol rounds, so the ``pf·nw`` overlap envelope is cooperative, not
parallel.  This module makes it real: a pool of ``nw`` **spawn**-based worker
processes pulls per-step realization tasks over a task queue and returns the
completed step arrays through preallocated ``multiprocessing.shared_memory``
ring slots.

Protocol (one message kind per line, all via the two mp queues):

    parent -> worker:   ("task", seq, index, slot, step_codec)   | None (stop)
    worker -> parent:   ("claim", wid, seq)
                        ("done",  wid, seq, header, inline|None)
                        ("error", wid, seq, traceback_text)
                        ("obs",   wid, timestamp, registry_state)

Ordering: tasks are sequence-numbered at submission; results may return out
of order (workers race), so the parent holds completed results in a reorder
buffer and releases them strictly by ``seq``.  Delivery order is therefore
identical to the in-process path — which is what keeps Theorem-1 identity
coverage, checkpoint/resume bit-exactness and rank-aligned SPMD shapes
worker-count-agnostic.

Shared-memory ring: ``slots`` fixed-size slots in one segment.  A slot is
acquired at submission (no free slot = natural backpressure: at most
``slots`` steps are ever in flight), written by exactly one worker, read
zero-copy by the consumer (numpy views straight over the slot buffer), and
recycled only when the consumer releases the delivered step — so a view is
never invalidated while the step is still being trained on.  A step too
large for a slot degrades to an inline (pickled-through-the-queue) result and
``odb_worker_shm_overflows_total`` counts it; nothing is ever dropped.

Failure semantics: a dead worker (OOM-killed, segfaulted) is detected by
liveness polling whenever results stall; its claimed-but-unfinished tasks are
re-executed in-process with a warning and ``odb_worker_failures_total``
ticks once per lost worker.  Unclaimed tasks stay on the queue for surviving
workers; when no workers survive, the pool drains its own queue and runs
degraded (every remaining task in-process) — never a hang, never a dropped
sample.

Observability: each worker runs its own (fresh, spawn-isolated) default
registry; its layout counters (``odb_layout_*``) accumulate worker-side and
are shipped to the parent every :data:`OBS_SYNC_EVERY` tasks and at exit,
where :class:`repro.obs.CrossProcessAggregator` merges them (counters sum by
delta, gauges last-write-by-timestamp) into the parent registry — one
``metrics.json`` reports the whole process tree.
"""

from __future__ import annotations

import collections
import dataclasses
import os
import pickle
import queue as queue_mod
import time
import traceback
import warnings
from typing import Callable, Sequence

import numpy as np

from repro import obs
from repro.core.grouping import Group, Sample
from repro.core.layout import BatchLayout, DeviceBatch

__all__ = [
    "DEFAULT_SLOT_BYTES",
    "OBS_SYNC_EVERY",
    "WorkerPool",
    "WorkerPoolStats",
    "WorkerResult",
]

#: Default per-slot byte budget.  Sized for the shipped shape cells (a 4-rank
#: packed 16k-token step is ~4 MiB); steps that exceed it fall back to inline
#: delivery rather than failing.
DEFAULT_SLOT_BYTES = 8 << 20

#: Ship the worker-side registry state to the parent every N completed tasks
#: (and always at clean exit).
OBS_SYNC_EVERY = 16

_ALIGN = 8

# (field, dtype, per-row?) layout of one DeviceBatch inside a slot.
_FIELDS = (
    ("tokens", np.int32),
    ("positions", np.int32),
    ("segments", np.int32),
    ("loss_mask", np.float32),
    ("lengths", np.int32),
)


def _aligned(n: int) -> int:
    return (n + _ALIGN - 1) & ~(_ALIGN - 1)


# -----------------------------------------------------------------------------
# Step codec (queue-side): samples flatten to (view_id, identity, length)
# triples, IDLE/None to None — mirrors stream/state.py but avoids importing
# the protocol layer into the worker interpreter.
# -----------------------------------------------------------------------------


def _encode_step(step: Sequence[Group | None]) -> list:
    return [
        None
        if g is None
        else [(s.view_id, s.identity, s.length) for s in g.samples]
        for g in step
    ]


def _decode_step(data: list) -> list[Group | None]:
    return [
        None
        if g is None
        else Group(
            samples=tuple(
                Sample(view_id=v, identity=i, length=l) for v, i, l in g
            )
        )
        for g in data
    ]


# -----------------------------------------------------------------------------
# Slot serialization: header = per-rank shapes/offsets, payload = raw arrays.
# -----------------------------------------------------------------------------


def _slot_plan(batches: Sequence[DeviceBatch]) -> tuple[list[dict], int]:
    """Per-batch field offsets within a slot, plus the total byte need."""
    cursor = 0
    headers = []
    for b in batches:
        rows, t = b.tokens.shape
        offsets = {}
        for field, dtype in _FIELDS:
            arr = getattr(b, field)
            offsets[field] = cursor
            cursor = _aligned(cursor + arr.nbytes)
        headers.append(
            {
                "shape": (int(rows), int(t)),
                "offsets": offsets,
                "real_samples": b.real_samples,
                "real_tokens": b.real_tokens,
            }
        )
    return headers, cursor


def _write_slot(buf: memoryview, base: int, batches: Sequence[DeviceBatch],
                headers: list[dict]) -> None:
    for b, h in zip(batches, headers):
        for field, dtype in _FIELDS:
            arr = np.ascontiguousarray(getattr(b, field))
            off = base + h["offsets"][field]
            buf[off : off + arr.nbytes] = arr.tobytes()


def _read_slot(buf: memoryview, base: int, headers: list[dict]) -> list[DeviceBatch]:
    """Zero-copy: numpy views straight over the shared-memory slot."""
    out = []
    for h in headers:
        rows, t = h["shape"]
        arrays = {}
        for field, dtype in _FIELDS:
            count = rows if field == "lengths" else rows * t
            view = np.frombuffer(
                buf, dtype=dtype, count=count, offset=base + h["offsets"][field]
            )
            arrays[field] = view if field == "lengths" else view.reshape(rows, t)
        out.append(
            DeviceBatch(
                **arrays,
                real_samples=h["real_samples"],
                real_tokens=h["real_tokens"],
            )
        )
    return out


# -----------------------------------------------------------------------------
# Worker process
# -----------------------------------------------------------------------------


def _attach_shm(name: str):
    """Attach without resource_tracker ownership (the parent owns the ring;
    a child tracker 'cleaning up' the segment would unlink it under the
    parent's feet)."""
    from multiprocessing import shared_memory

    try:
        return shared_memory.SharedMemory(name=name, track=False)
    except TypeError:  # < 3.13: no track kwarg; suppress registration.
        # (unregister-after-attach is wrong here: spawn children share the
        # parent's tracker process, so the extra unregister would race the
        # parent's own unlink-time unregister of the same name.)
        from multiprocessing import resource_tracker

        orig = resource_tracker.register
        resource_tracker.register = lambda *a, **kw: None
        try:
            return shared_memory.SharedMemory(name=name)
        finally:
            resource_tracker.register = orig


def _worker_main(
    worker_id: int,
    task_q,
    result_q,
    shm_name: str,
    slot_bytes: int,
    layout_blob: bytes,
) -> None:
    """Worker loop: decode task -> layout.build_step -> stage into the slot.

    Runs in a fresh spawned interpreter: no jax, no inherited locks, its own
    default registry (merged back via "obs" messages).
    """
    layout: BatchLayout = pickle.loads(layout_blob)
    shm = _attach_shm(shm_name)
    tasks_done = 0

    def ship_obs() -> None:
        state = obs.default_registry().state()
        if state:
            result_q.put(("obs", worker_id, time.time(), state))

    try:
        while True:
            task = task_q.get()
            if task is None:
                break
            _, seq, index, slot, step_codec = task
            result_q.put(("claim", worker_id, seq))
            try:
                step = _decode_step(step_codec)
                batches = layout.build_step(step)
                headers, need = _slot_plan(batches)
                if slot is not None and need <= slot_bytes:
                    _write_slot(shm.buf, slot * slot_bytes, batches, headers)
                    result_q.put(("done", worker_id, seq, headers, None))
                else:
                    # Step too large for the ring slot: inline fallback.
                    result_q.put(("done", worker_id, seq, None, batches))
                tasks_done += 1
                if tasks_done % OBS_SYNC_EVERY == 0:
                    ship_obs()
            except BaseException:
                result_q.put(("error", worker_id, seq, traceback.format_exc()))
    finally:
        try:
            ship_obs()
        except Exception:
            pass
        shm.close()


# -----------------------------------------------------------------------------
# Parent-side pool
# -----------------------------------------------------------------------------


@dataclasses.dataclass
class WorkerPoolStats:
    submitted: int = 0  # tasks handed to the pool
    completed: int = 0  # results delivered in order
    shm_results: int = 0  # staged through the shared-memory ring
    inline_results: int = 0  # slot overflow -> pickled through the queue
    reexecuted: int = 0  # run in-process after a worker loss / degradation
    worker_failures: int = 0  # workers that died with tasks outstanding
    wait_s: float = 0.0  # parent time blocked on worker results

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class WorkerResult:
    """One in-order completed step: arrays + the slot-release handle."""

    index: int
    step: list[Group | None]
    batches: list[DeviceBatch]
    release: Callable[[], None]  # idempotent; recycles the shm slot (if any)


@dataclasses.dataclass
class _Pending:
    index: int
    step: list[Group | None]
    slot: int | None
    claimed_by: int | None = None


class WorkerPool:
    """``nw`` spawned layout workers around a shared-memory slot ring.

    Mechanism only: :meth:`submit` enqueues one aligned step (non-blocking;
    callers gate on :meth:`can_submit`, which is exactly the free-slot
    backpressure), :meth:`take` blocks for the *next in-order* result, and
    :meth:`close` tears everything down.  Pump/ordering policy lives in
    ``OnlineDynamicLoader.streaming_epoch``.
    """

    def __init__(
        self,
        layout: BatchLayout,
        num_workers: int,
        *,
        slots: int | None = None,
        slot_bytes: int = DEFAULT_SLOT_BYTES,
        poll_interval: float = 0.2,
        stall_timeout: float = 30.0,
        fault_hook=None,
    ) -> None:
        if num_workers < 1:
            raise ValueError(f"num_workers must be >= 1, got {num_workers}")
        import multiprocessing as mp
        from multiprocessing import shared_memory

        self.layout = layout
        self.num_workers = num_workers
        self.slots = slots if slots is not None else max(2 * num_workers, 4)
        if self.slots < 1:
            raise ValueError(f"slots must be >= 1, got {self.slots}")
        self.slot_bytes = slot_bytes
        self.stats = WorkerPoolStats()
        self._poll_interval = poll_interval
        self._stall_timeout = stall_timeout
        # Chaos injection (repro.chaos): called as fault_hook(pool, seq) right
        # after each task is enqueued, so a harness can kill a worker process
        # at a deterministic submission index and exercise the reclaim path.
        self._fault_hook = fault_hook
        self._activity = 0  # bumps on every worker message; take()'s stall clock
        self._ctx = mp.get_context("spawn")
        self._shm = shared_memory.SharedMemory(
            create=True, size=self.slots * slot_bytes
        )
        self._free_slots: collections.deque[int] = collections.deque(
            range(self.slots)
        )
        self._task_q = self._ctx.Queue()
        self._result_q = self._ctx.Queue()
        self._agg = obs.CrossProcessAggregator()
        self._pending: dict[int, _Pending] = {}
        self._completed: dict[int, tuple[list[DeviceBatch], int | None]] = {}
        self._next_seq = 0
        self._next_out = 0
        self._closed = False
        self._degraded = False  # all workers lost -> in-process execution
        self._dead_handled: set[int] = set()
        layout_blob = pickle.dumps(layout)
        self._procs = [
            self._ctx.Process(
                target=_worker_main,
                args=(
                    wid, self._task_q, self._result_q,
                    self._shm.name, slot_bytes, layout_blob,
                ),
                daemon=True,
                name=f"odb-worker-{wid}",
            )
            for wid in range(num_workers)
        ]
        for p in self._procs:
            p.start()

    # -- submission ------------------------------------------------------------
    @property
    def inflight(self) -> int:
        """Tasks submitted but not yet taken (pending + reordered)."""
        return len(self._pending) + len(self._completed)

    def can_submit(self) -> bool:
        return not self._closed and bool(self._free_slots)

    def submit(self, index: int, step: list[Group | None]) -> None:
        """Enqueue one aligned step.  Callers must gate on :meth:`can_submit`
        — a free ring slot per task is the backpressure invariant."""
        if self._closed:
            raise RuntimeError("worker pool is closed")
        if not self._free_slots:
            raise RuntimeError(
                "no free shared-memory slot; gate submissions on can_submit()"
            )
        seq = self._next_seq
        self._next_seq += 1
        self.stats.submitted += 1
        obs.counter(
            "odb_worker_tasks_total", help="steps submitted to the worker pool"
        ).inc()
        if self._degraded:
            # No workers left: execute at the submission point (still ordered).
            self._pending[seq] = _Pending(index, step, None)
            self._reexecute(seq)
            return
        slot = self._free_slots.popleft()
        self._pending[seq] = _Pending(index, step, slot)
        self._task_q.put(("task", seq, index, slot, _encode_step(step)))
        obs.gauge(
            "odb_worker_inflight", help="steps in flight in the worker pool"
        ).set(self.inflight)
        if self._fault_hook is not None:
            self._fault_hook(self, seq)

    # -- results ---------------------------------------------------------------
    def take(self) -> WorkerResult | None:
        """Block for the next *in-order* completed step; None when idle.

        Never hangs: whenever the result queue stalls past the poll interval,
        worker liveness is audited and lost workers' claimed tasks are
        re-executed in-process.
        """
        self._drain_results()  # absorb ready results + worker obs dumps
        if self._next_out not in self._pending:
            return None  # nothing submitted at this frontier
        t0 = time.perf_counter()
        last_activity = self._activity
        last_progress = t0
        while self._next_out not in self._completed:
            self._drain_results(timeout=self._poll_interval)
            if self._next_out in self._completed:
                break
            self._audit_liveness()
            now = time.perf_counter()
            if self._activity != last_activity:
                last_activity = self._activity
                last_progress = now
            elif now - last_progress > self._stall_timeout:
                # Total silence past the stall budget: the frontier task's
                # queue message is presumed lost (a worker can die between
                # reading a task and announcing its claim, taking the message
                # with it; a wedged worker looks the same).  Re-execute it
                # here — builds are deterministic, so a late duplicate from a
                # live worker is identical and gets dropped in _fulfill.
                warnings.warn(
                    f"odb step seq={self._next_out} stalled "
                    f">{self._stall_timeout:.1f}s in the worker pool; "
                    "re-executing in-process",
                    RuntimeWarning,
                    stacklevel=2,
                )
                self._reexecute(self._next_out, free_slot=False)
        waited = time.perf_counter() - t0
        self.stats.wait_s += waited
        seq = self._next_out
        self._next_out += 1
        batches, slot = self._completed.pop(seq)
        pend = self._pending.pop(seq)
        self.stats.completed += 1
        release = self._make_release(slot)
        return WorkerResult(
            index=pend.index, step=pend.step, batches=batches, release=release
        )

    def _make_release(self, slot: int | None) -> Callable[[], None]:
        # One-shot across threads: the stage hook (producer side) and the
        # consumer loop may both call release(); list.pop() is atomic, so
        # exactly one caller recycles the slot.
        token = [] if slot is None else [slot]

        def release() -> None:
            try:
                s = token.pop()
            except IndexError:
                return
            if not self._closed:
                self._free_slots.append(s)

        return release

    # -- result-queue pump -----------------------------------------------------
    def _drain_results(self, timeout: float | None = None) -> None:
        block = timeout is not None
        while True:
            try:
                msg = self._result_q.get(block=block, timeout=timeout)
            except queue_mod.Empty:
                return
            block = False  # only the first get blocks; then drain
            self._activity += 1
            kind = msg[0]
            if kind == "claim":
                _, wid, seq = msg
                pend = self._pending.get(seq)
                if pend is not None:
                    pend.claimed_by = wid
            elif kind == "done":
                _, wid, seq, headers, inline = msg
                self._fulfill(seq, headers, inline)
            elif kind == "error":
                _, wid, seq, tb = msg
                # Deterministic task failure: re-execute in-process so the
                # real exception surfaces with a native traceback (and a
                # genuinely transient worker-side failure gets one retry).
                warnings.warn(
                    f"odb worker {wid} failed on step seq={seq}; "
                    f"re-executing in-process:\n{tb}",
                    RuntimeWarning,
                    stacklevel=2,
                )
                self._reexecute(seq)
            elif kind == "obs":
                _, wid, ts, state = msg
                self._agg.merge(f"worker{wid}", state, ts)

    def _fulfill(self, seq: int, headers, inline) -> None:
        pend = self._pending.get(seq)
        if pend is None:
            return  # already taken (late duplicate); quarantined slot stays out
        if seq in self._completed:
            # A fallback re-execution beat this worker to it.  The worker is
            # done touching the slot now, so the quarantine can be lifted.
            if pend.slot is not None:
                self._free_slots.append(pend.slot)
                pend.slot = None
            return
        if inline is not None:
            # Overflow fallback: arrays came through the queue; the slot was
            # never written, recycle it immediately.
            self.stats.inline_results += 1
            obs.counter(
                "odb_worker_shm_overflows_total",
                help="steps too large for a shm slot (inline fallback)",
            ).inc()
            if pend.slot is not None:
                self._free_slots.append(pend.slot)
                pend.slot = None
            self._completed[seq] = (list(inline), None)
        else:
            self.stats.shm_results += 1
            batches = _read_slot(
                self._shm.buf, pend.slot * self.slot_bytes, headers
            )
            self._completed[seq] = (batches, pend.slot)

    def _reexecute(self, seq: int, free_slot: bool = True) -> None:
        """Run one submitted task in the parent process (fallback path).

        ``free_slot=False`` quarantines the task's shm slot instead of
        recycling it: used when a *live* worker might still hold the task
        (lost-message escalation) and could write the slot later — the slot
        is reclaimed if/when that duplicate ``done`` arrives (`_fulfill`).
        """
        pend = self._pending.get(seq)
        if pend is None or seq in self._completed:
            return
        batches = self.layout.build_step(pend.step)
        if free_slot and pend.slot is not None:
            self._free_slots.append(pend.slot)
            pend.slot = None
        self._completed[seq] = (batches, None)
        self.stats.reexecuted += 1
        obs.counter(
            "odb_worker_reexecuted_total",
            help="steps re-executed in-process after a worker failure",
        ).inc()

    # -- failure handling ------------------------------------------------------
    def _audit_liveness(self) -> None:
        dead = [
            p for p in self._procs
            if not p.is_alive() and p.pid not in self._dead_handled
        ]
        if not dead:
            return
        # A final drain first: a worker may have finished results (or shipped
        # obs state) between its last task and its death.
        self._drain_results(timeout=None)
        for p in dead:
            self._dead_handled.add(p.pid)
            wid = int(p.name.rsplit("-", 1)[-1])
            self.stats.worker_failures += 1
            obs.counter(
                "odb_worker_failures_total",
                help="worker processes lost mid-epoch",
            ).inc()
            claimed = [
                seq for seq, pend in sorted(self._pending.items())
                if pend.claimed_by == wid and seq not in self._completed
            ]
            if claimed:
                warnings.warn(
                    f"odb worker {wid} (pid {p.pid}, exitcode {p.exitcode}) "
                    f"died with {len(claimed)} in-flight step(s); "
                    "re-executing in-process",
                    RuntimeWarning,
                    stacklevel=3,
                )
            for seq in claimed:
                self._reexecute(seq)
        if any(p.is_alive() for p in self._procs):
            # A worker can die *between* reading a task message and sending
            # its claim — the message is gone and nobody owns the task.  At
            # most one task per death can be orphaned that way (the oldest
            # unclaimed one, since the queue is FIFO); re-execute one suspect
            # per dead worker, slot quarantined in case a live worker does
            # still deliver it (duplicates are dropped in _fulfill).
            for _ in dead:
                orphan = next(
                    (
                        seq for seq in sorted(self._pending)
                        if self._pending[seq].claimed_by is None
                        and seq not in self._completed
                    ),
                    None,
                )
                if orphan is None:
                    break
                self._reexecute(orphan, free_slot=False)
        if not any(p.is_alive() for p in self._procs):
            # No workers left: reclaim every queued-but-unclaimed task and run
            # the rest of the epoch degraded (in-process, still in order).
            if not self._degraded:
                warnings.warn(
                    "all odb workers lost; continuing in-process (degraded)",
                    RuntimeWarning,
                    stacklevel=3,
                )
            self._degraded = True
            while True:
                try:
                    self._task_q.get_nowait()
                except queue_mod.Empty:
                    break
            for seq in sorted(self._pending):
                self._reexecute(seq)

    @property
    def alive_workers(self) -> int:
        return sum(1 for p in self._procs if p.is_alive())

    # -- teardown --------------------------------------------------------------
    def close(self) -> None:
        """Stop workers, drop undelivered results, unlink the shm ring.

        Submitted-but-undelivered steps are simply discarded here — the
        loader re-queues their protocol-side ``step`` objects into the
        executor (`requeue`), so worker state never needs to survive into a
        checkpoint: resume is worker-count-agnostic by construction.
        """
        if self._closed:
            return
        self._closed = True
        for p in self._procs:
            if p.is_alive():
                try:
                    self._task_q.put_nowait(None)
                except Exception:
                    break
        # Absorb any final obs dumps workers flush on their way out.
        deadline = time.perf_counter() + 2.0
        while (
            any(p.is_alive() for p in self._procs)
            and time.perf_counter() < deadline
        ):
            try:
                self._drain_results(timeout=0.05)
            except Exception:
                break
        try:
            self._drain_results(timeout=None)
        except Exception:
            pass
        for p in self._procs:
            p.join(timeout=1.0)
            if p.is_alive():
                p.terminate()
                p.join(timeout=1.0)
        for q in (self._task_q, self._result_q):
            try:
                q.close()
                q.cancel_join_thread()
            except Exception:
                pass
        self._pending.clear()
        self._completed.clear()
        try:
            self._shm.close()
        except BufferError:
            # Delivered zero-copy views still reference the mapping: drop our
            # handles so the mapping dies with the last view instead of a
            # second (unraisable) close attempt from SharedMemory.__del__.
            # The segment is unlinked below, so nothing outlives the process.
            self._shm._mmap = None
            fd = getattr(self._shm, "_fd", -1)
            if fd >= 0:
                try:
                    os.close(fd)
                except OSError:
                    pass
                self._shm._fd = -1
        try:
            self._shm.unlink()
        except FileNotFoundError:
            pass

    def __enter__(self) -> "WorkerPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self) -> None:  # belt-and-braces; close() is the real path
        try:
            self.close()
        except Exception:
            pass
