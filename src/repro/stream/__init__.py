"""Streaming DGAP execution: bounded-lookahead admission, incremental
scheduling, async prefetch, multi-process realization workers, resumable
loader state, and the sharded multi-host window (DESIGN.md §9, §14, §16)."""

from repro.stream.executor import EpochAborted, StreamExecutor
from repro.stream.prefetch import PrefetchIterator, PrefetchStats
from repro.stream.state import StreamCheckpoint
from repro.stream.window import (
    AdmissionWindow,
    BoundedWindow,
    QuarantineLedger,
    ShardedWindow,
    WindowRouter,
    WindowStats,
    host_rank_blocks,
    split_lookahead,
)
from repro.stream.workers import WorkerPool, WorkerPoolStats, WorkerResult

__all__ = [
    "AdmissionWindow",
    "BoundedWindow",
    "EpochAborted",
    "PrefetchIterator",
    "PrefetchStats",
    "QuarantineLedger",
    "ShardedWindow",
    "StreamCheckpoint",
    "StreamExecutor",
    "WindowRouter",
    "WindowStats",
    "WorkerPool",
    "WorkerPoolStats",
    "WorkerResult",
    "host_rank_blocks",
    "split_lookahead",
]
