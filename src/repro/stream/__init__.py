"""Streaming DGAP execution: bounded-lookahead admission, incremental
scheduling, async prefetch, and resumable loader state (DESIGN.md §9)."""

from repro.stream.executor import StreamExecutor
from repro.stream.prefetch import PrefetchIterator, PrefetchStats
from repro.stream.state import StreamCheckpoint
from repro.stream.window import AdmissionWindow, BoundedWindow, WindowStats

__all__ = [
    "AdmissionWindow",
    "BoundedWindow",
    "PrefetchIterator",
    "PrefetchStats",
    "StreamCheckpoint",
    "StreamExecutor",
    "WindowStats",
]
