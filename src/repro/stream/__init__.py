"""Streaming DGAP execution: bounded-lookahead admission, incremental
scheduling, async prefetch, multi-process realization workers, and resumable
loader state (DESIGN.md §9, §14)."""

from repro.stream.executor import EpochAborted, StreamExecutor
from repro.stream.prefetch import PrefetchIterator, PrefetchStats
from repro.stream.state import StreamCheckpoint
from repro.stream.window import AdmissionWindow, BoundedWindow, WindowStats
from repro.stream.workers import WorkerPool, WorkerPoolStats, WorkerResult

__all__ = [
    "AdmissionWindow",
    "BoundedWindow",
    "EpochAborted",
    "PrefetchIterator",
    "PrefetchStats",
    "StreamCheckpoint",
    "StreamExecutor",
    "WindowStats",
    "WorkerPool",
    "WorkerPoolStats",
    "WorkerResult",
]
