"""ODB-integrated trainer (paper §2.4 metadata contract + Eq. 2 scaling).

Two execution paths:

  * ``Trainer`` — the deployment path: consumes step-aligned per-rank
    ``PaddedBatch``es from :class:`repro.data.loader.OnlineDynamicLoader`,
    unifies them into one global SPMD batch, and drives the jitted
    ``train_step`` (launch/steps.py).  The global masked per-token mean that
    the step computes is exactly the token-level scaled objective: IDLE
    ranks contribute zero tokens and are annihilated (Eq. 2 with t_r = 0).
    Fault tolerance: periodic atomic checkpoints + resume-from-latest.

  * ``dp_shardmap_step`` — the paper-literal path: per-rank mean losses
    prescaled by ``W·w_r`` and mean-reduced over an explicit ``psum``,
    with optional bf16 gradient compression + error feedback.  This is the
    vehicle for the Eq. 2 bit-exactness tests and the loss-scaling-mode
    benchmark (Table 18).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.buckets import PaddedBatch
from repro.core.loss_scaling import prescale_factor
from repro.data.loader import OnlineDynamicLoader
from repro.models.model import LM, shift_labels
from repro.train import checkpoint as ckpt
from repro.train.compression import init_error_state, psum_compressed
from repro.train.optimizer import OptimizerConfig, adamw_update, init_opt_state


def unify_step_shapes(batches: list[PaddedBatch]) -> list[PaddedBatch]:
    """Re-pad all ranks' batches to the step-max bucket shape (SPMD needs one
    global shape; bucket grids are shared so the max is itself a bucket)."""
    n = max(b.tokens.shape[0] for b in batches)
    l = max(b.tokens.shape[1] for b in batches)
    out = []
    for b in batches:
        if b.tokens.shape == (n, l):
            out.append(b)
            continue
        tokens = np.zeros((n, l), dtype=b.tokens.dtype)
        mask = np.zeros((n, l), dtype=b.loss_mask.dtype)
        lengths = np.zeros((n,), dtype=b.lengths.dtype)
        sn, sl = b.tokens.shape
        tokens[:sn, :sl] = b.tokens
        mask[:sn, :sl] = b.loss_mask
        lengths[:sn] = b.lengths
        out.append(
            PaddedBatch(
                tokens=tokens, loss_mask=mask, lengths=lengths,
                real_samples=b.real_samples, real_tokens=b.real_tokens,
            )
        )
    return out


def global_batch_arrays(batches: list[PaddedBatch]) -> dict[str, np.ndarray]:
    """Stack per-rank batches into the global (W·n, len) training batch."""
    batches = unify_step_shapes(batches)
    tokens = np.concatenate([b.tokens for b in batches], axis=0)
    mask = np.concatenate([b.loss_mask for b in batches], axis=0)
    return {"tokens": tokens, "loss_mask": mask}


@dataclasses.dataclass
class TrainerConfig:
    checkpoint_dir: str | None = None
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    log_every: int = 10
    max_steps: int | None = None
    # Data path selection (DESIGN.md §9): the streaming executor admits views
    # through a bounded-lookahead window and overlaps data-side work with the
    # jitted step via a background prefetcher; eager is the offline reference.
    streaming: bool = True
    prefetch: bool = True
    prefetch_depth: int = 2
    lookahead: int | None = None


class Trainer:
    """End-to-end ODB training driver (single-process; mesh-agnostic)."""

    def __init__(
        self,
        model: LM,
        loader: OnlineDynamicLoader,
        opt_cfg: OptimizerConfig | None = None,
        cfg: TrainerConfig | None = None,
        mesh=None,
    ):
        self.model = model
        self.loader = loader
        self.opt_cfg = opt_cfg or OptimizerConfig()
        self.cfg = cfg or TrainerConfig()
        self.mesh = mesh
        self._train_step = None
        self.history: list[dict] = []

    def _build_step(self):
        opt_cfg = self.opt_cfg

        def step(state, batch):
            def loss_fn(params):
                loss_sum, tokens = self.model.loss_sums(params, batch)
                return loss_sum / jnp.maximum(tokens, 1.0), tokens

            (loss, tokens), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state["params"]
            )
            params, opt, om = adamw_update(state["params"], grads, state["opt"], opt_cfg)
            return {"params": params, "opt": opt}, {
                "loss": loss, "tokens": tokens, **om
            }

        self._train_step = jax.jit(step, donate_argnums=(0,))

    def init_state(self, rng) -> dict:
        params = self.model.init(rng)
        return {"params": params, "opt": init_opt_state(params, self.opt_cfg)}

    def restore_or_init(self, rng) -> tuple[dict, int]:
        if self.cfg.checkpoint_dir and ckpt.latest_step(self.cfg.checkpoint_dir) is not None:
            like = jax.eval_shape(self.init_state, rng)
            state, step = ckpt.restore_checkpoint(self.cfg.checkpoint_dir, like)
            return state, step
        return self.init_state(rng), 0

    def _epoch_steps(self, epoch: int):
        """Pick the data path: streaming (default, overlapped) or eager."""
        if self.cfg.streaming:
            return self.loader.streaming_epoch(
                epoch,
                lookahead=self.cfg.lookahead,
                prefetch=self.cfg.prefetch,
                prefetch_depth=self.cfg.prefetch_depth,
            )
        return self.loader.epoch(epoch)

    def train_epoch(self, state: dict, epoch: int = 0, start_step: int = 0):
        if self._train_step is None:
            self._build_step()
        step_idx = start_step
        t0 = time.perf_counter()
        emitted = 0
        for loader_step in self._epoch_steps(epoch):
            batch_np = global_batch_arrays(loader_step.batches)
            tokens = jnp.asarray(batch_np["tokens"])
            labels, mask = shift_labels(tokens, jnp.asarray(batch_np["loss_mask"]))
            batch = {"tokens": tokens, "labels": labels, "loss_mask": mask}
            state, metrics = self._train_step(state, batch)
            step_idx += 1
            emitted += loader_step.metadata.emitted_samples
            if step_idx % self.cfg.log_every == 0:
                dt = time.perf_counter() - t0
                rec = {
                    "step": step_idx,
                    "loss": float(metrics["loss"]),
                    "tokens": float(metrics["tokens"]),
                    "grad_norm": float(metrics["grad_norm"]),
                    "emitted_samples": emitted,
                    "sam_per_s": emitted / dt if dt > 0 else 0.0,
                    "padding": loader_step.metadata.padding_fraction,
                }
                self.history.append(rec)
            if (
                self.cfg.checkpoint_dir
                and step_idx % self.cfg.checkpoint_every == 0
            ):
                ckpt.save_checkpoint(
                    self.cfg.checkpoint_dir, step_idx, state,
                    keep=self.cfg.keep_checkpoints,
                )
            if self.cfg.max_steps and step_idx >= self.cfg.max_steps:
                break
        return state, step_idx


# -----------------------------------------------------------------------------
# Paper-literal shard_map DP step (Eq. 2 prescaling + optional compression)
# -----------------------------------------------------------------------------


def dp_shardmap_step(
    model: LM,
    mesh,
    opt_cfg: OptimizerConfig,
    *,
    loss_mode: str = "exact_token",
    compress_grads: bool = False,
):
    """Per-rank DDP-style step over the ``data`` axis of ``mesh``.

    Each data shard computes its local mean loss L̄_r, prescales it by
    ``W · w_r`` (Eq. 2), and the psum-mean over shards reproduces the global
    objective; gradients reduce via psum (optionally bf16-compressed with
    error feedback).
    """
    world = mesh.shape["data"]

    def local_loss(params, batch):
        loss_sum, tokens = model.loss_sums(params, batch)
        samples = jnp.sum(jnp.max(batch["loss_mask"], axis=1))
        mean_local = loss_sum / jnp.maximum(tokens, 1.0)
        t_tok = jax.lax.psum(tokens, "data")
        n_tot = jax.lax.psum(samples, "data")
        factor = prescale_factor(
            tokens, jnp.maximum(t_tok, 1.0), world, loss_mode,
            local_samples=samples, global_samples=jnp.maximum(n_tot, 1.0),
        )
        scaled = mean_local * factor
        # DDP post-averaging: mean over ranks == psum / W
        return jax.lax.psum(scaled, "data") / world, tokens

    def step(state, batch, err):
        def lf(params):
            return local_loss(params, batch)

        (loss, tokens), grads = jax.value_and_grad(lf, has_aux=True)(state["params"])
        # Local grads hold only this shard's term ∂(scaled_r/W)/∂θ; the DDP
        # AllReduce is the explicit psum below (bf16-compressed if enabled).
        if compress_grads:
            grads, err = psum_compressed(grads, err, "data")
        else:
            grads = jax.lax.psum(grads, "data")
        params, opt, om = adamw_update(state["params"], grads, state["opt"], opt_cfg)
        return {"params": params, "opt": opt}, {"loss": loss, "tokens": tokens, **om}, err

    wrapped = jax.shard_map(
        step,
        mesh=mesh,
        in_specs=(
            P(),  # state replicated across data (DDP semantics)
            {"tokens": P("data", None), "labels": P("data", None), "loss_mask": P("data", None)},
            P(),
        ),
        out_specs=(P(), P(), P()),
        check_vma=False,
    )
    return jax.jit(wrapped, donate_argnums=(0,)), init_error_state
