"""ODB-integrated trainer (paper §2.4 metadata contract + Eq. 2 scaling).

Two execution paths:

  * ``Trainer`` — the deployment path: consumes step-aligned per-rank
    ``DeviceBatch``es from :class:`repro.data.loader.OnlineDynamicLoader`
    (whatever batch layout the loader was built with — DESIGN.md §10),
    unifies them into one global SPMD batch, and drives the jitted
    ``train_step`` shared with launch/steps.py.  The global masked per-token
    mean that the step computes is exactly the token-level scaled objective:
    IDLE ranks contribute zero tokens and are annihilated (Eq. 2 with
    t_r = 0).  Fault tolerance: periodic atomic checkpoints +
    resume-from-latest.

  * ``dp_shardmap_step`` — the paper-literal path: per-rank mean losses
    prescaled by ``W·w_r`` and mean-reduced over an explicit ``psum``,
    with optional bf16 gradient compression + error feedback.  This is the
    vehicle for the Eq. 2 bit-exactness tests and the loss-scaling-mode
    benchmark (Table 18).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import obs
from repro.core.layout import (
    BatchLayout,
    global_batch_arrays,
    unify_step_shapes,
)
from repro.core.loss_scaling import prescale_factor
from repro.data.loader import LoaderStep, OnlineDynamicLoader
from repro.models.model import LM, shift_labels
from repro.train import checkpoint as ckpt
from repro.train.compression import init_error_state, psum_compressed
from repro.train.optimizer import OptimizerConfig, adamw_update, init_opt_state

__all__ = [
    "Trainer",
    "TrainerConfig",
    "assemble_model_batch",
    "dp_shardmap_step",
    "global_batch_arrays",  # re-exported from core.layout (layout-aware)
    "make_train_step",
    "resolve_attn_grid",
    "resolve_attn_impl",
    "unify_step_shapes",
]


def resolve_attn_impl(cfg, *, packed: bool, backend: str | None = None) -> str:
    """Pin ``attn_impl="auto"`` to a concrete route for one training run.

    The routing matrix (DESIGN.md §11): the Pallas flash kernel exactly when
    the layout packs segments into rows (where its segment-range block
    skipping pays), the attention layout is GQA, and the backend compiles
    Pallas (TPU) — the XLA blockwise path otherwise.  CPU runs keep XLA by
    default (interpret-mode Pallas is a test/bench vehicle, not a train
    path); an explicit ``attn_impl="flash"`` is honored unchanged.

    Resolving at trainer-build time (instead of leaving "auto" to trace
    time) makes the compiled route a recorded property of the run.
    """
    if cfg.attn_impl != "auto":
        return cfg.attn_impl
    if cfg.attn_kind != "gqa":
        return "xla"
    backend = backend or jax.default_backend()
    return "flash" if (packed and backend == "tpu") else "xla"


def resolve_attn_grid(cfg, *, packed: bool, backend: str | None = None) -> str:
    """Pin ``attn_grid="auto"`` to a concrete flash grid variant (DESIGN.md
    §17): the scalar-prefetch pruned grid exactly when the layout packs
    segments into rows (the liveness tables are built from segment ids) and
    the backend compiles Pallas; dense otherwise.  An explicit "pruned" is
    honored whenever segments exist — interpret mode included, which is how
    CPU tests and benches exercise the path."""
    grid = getattr(cfg, "attn_grid", "auto")
    if not packed:
        return "dense"  # no segments -> nothing to build liveness from
    if grid != "auto":
        return grid
    backend = backend or jax.default_backend()
    return "pruned" if backend == "tpu" else "dense"


def make_train_step(model: LM, opt_cfg: OptimizerConfig):
    """(state, batch) -> (state, metrics) — THE train step.

    One builder shared by the deployment trainer (jitted shape-polymorphic
    over the bucket grids) and the launch/dry-run compile cells
    (``launch/steps.py`` pins shapes + mesh shardings around this same
    function), so what the dry-run lowers is what training runs.

    Loss normalization: the global masked per-token mean — identical to the
    paper's exact token-level scaled objective (Eq. 2 collapses to the global
    per-token mean in SPMD; bit-exactness of the per-rank weighting form is
    verified separately in tests/test_loss_scaling.py).
    """

    def train_step(state, batch):
        # Executes at trace time only, so the counter is a compile-event
        # census: one tick per (shape, layout) specialization XLA builds.
        obs.counter(
            "train_compile_events_total", help="train_step trace/compile events"
        ).inc()
        obs.instant("train/compile", cat="train")

        def loss_fn(params):
            loss_sum, tokens = model.loss_sums(params, batch)
            return loss_sum / jnp.maximum(tokens, 1.0), tokens

        (loss, tokens), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"]
        )
        new_params, new_opt, opt_metrics = adamw_update(
            state["params"], grads, state["opt"], opt_cfg
        )
        metrics = {"loss": loss, "tokens": tokens, **opt_metrics}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def _timed_phase(span_name: str, metric: str, help: str, fn: Callable):
    """Run one step phase under a trace span + cumulative seconds counter."""
    t0 = time.perf_counter()
    out = fn()
    dt = time.perf_counter() - t0
    obs.counter(metric, help=help, unit="seconds").inc(dt)
    obs.default_tracer().complete(span_name, t0, dt, cat="train")
    return out


def assemble_model_batch(loader_step: LoaderStep, layout: BatchLayout) -> dict:
    """Turn one aligned LoaderStep into the jitted-step batch dict.

    Uses the device-resident arrays staged by the prefetch producer when
    present (device-put overlap), otherwise assembles from host numpy.  The
    packed layout threads positions/segments through to the model (segment-
    aware attention masking + segment-aware label shift); the dense layout
    keeps the lean three-array contract — one sample per row under causal
    masking realizes the identical objective without the segment compare.
    """
    arrays = loader_step.device
    if arrays is None:
        host = _timed_phase(
            "train/pad", "train_pad_seconds_total",
            "host-side batch padding/assembly time",
            lambda: global_batch_arrays(loader_step.batches, layout),
        )
        arrays = _timed_phase(
            "train/device_put", "train_device_put_seconds_total",
            "host-to-device transfer dispatch time",
            lambda: {k: jnp.asarray(v) for k, v in host.items()},
        )
    tokens = arrays["tokens"]
    if layout.needs_segments:
        segments = arrays["segments"]
        labels, mask = shift_labels(tokens, arrays["loss_mask"], segments=segments)
        return {
            "tokens": tokens,
            "positions": arrays["positions"],
            "segments": segments,
            "labels": labels,
            "loss_mask": mask,
        }
    labels, mask = shift_labels(tokens, arrays["loss_mask"])
    return {"tokens": tokens, "labels": labels, "loss_mask": mask}


@dataclasses.dataclass
class TrainerConfig:
    checkpoint_dir: str | None = None
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    log_every: int = 10
    max_steps: int | None = None
    # Data path selection (DESIGN.md §9): the streaming executor admits views
    # through a bounded-lookahead window and overlaps data-side work with the
    # jitted step via a background prefetcher; eager is the offline reference.
    streaming: bool = True
    prefetch: bool = True
    prefetch_depth: int = 2
    lookahead: int | None = None
    # Stage jax.device_put on the prefetch producer so H2D transfer hides
    # under the jitted step (ROADMAP "device-put overlap").
    device_put: bool = False
    # Multi-process realization workers (DESIGN.md §14): 0 keeps layout
    # realization in-process; > 0 spawns that many worker processes staging
    # steps through a shared-memory ring (bit-identical step stream).
    num_workers: int = 0


class Trainer:
    """End-to-end ODB training driver (single-process; mesh-agnostic)."""

    def __init__(
        self,
        model: LM,
        loader: OnlineDynamicLoader,
        opt_cfg: OptimizerConfig | None = None,
        cfg: TrainerConfig | None = None,
        mesh=None,
    ):
        self.model = model
        self.loader = loader
        self.opt_cfg = opt_cfg or OptimizerConfig()
        self.cfg = cfg or TrainerConfig()
        self.mesh = mesh
        self._train_step = None
        self.history: list[dict] = []
        self.attn_impl: str | None = None  # resolved at _build_step
        self.attn_grid: str | None = None  # resolved at _build_step

    def _build_step(self):
        # Pin the "auto" kernel route against the loader's actual layout so
        # what this trainer jits is explicit (and loggable), not an implicit
        # function of the backend probed mid-trace.
        packed = self.loader.layout.needs_segments
        self.attn_impl = resolve_attn_impl(self.model.cfg, packed=packed)
        self.attn_grid = resolve_attn_grid(self.model.cfg, packed=packed)
        pins = {}
        if self.attn_impl != self.model.cfg.attn_impl:
            pins["attn_impl"] = self.attn_impl
        if self.attn_grid != self.model.cfg.attn_grid:
            pins["attn_grid"] = self.attn_grid
        if pins:
            self.model = dataclasses.replace(
                self.model,
                cfg=dataclasses.replace(self.model.cfg, **pins),
            )
        self._train_step = jax.jit(
            make_train_step(self.model, self.opt_cfg), donate_argnums=(0,)
        )

    def init_state(self, rng) -> dict:
        params = self.model.init(rng)
        return {"params": params, "opt": init_opt_state(params, self.opt_cfg)}

    def restore_or_init(self, rng) -> tuple[dict, int]:
        if self.cfg.checkpoint_dir and ckpt.latest_step(self.cfg.checkpoint_dir) is not None:
            like = jax.eval_shape(self.init_state, rng)
            state, step = ckpt.restore_checkpoint(self.cfg.checkpoint_dir, like)
            return state, step
        return self.init_state(rng), 0

    def _epoch_steps(self, epoch: int):
        """Pick the data path: streaming (default, overlapped) or eager."""
        if self.cfg.streaming:
            return self.loader.streaming_epoch(
                epoch,
                lookahead=self.cfg.lookahead,
                prefetch=self.cfg.prefetch,
                prefetch_depth=self.cfg.prefetch_depth,
                device_put=self.cfg.device_put,
                num_workers=self.cfg.num_workers,
            )
        return self.loader.epoch(epoch, device_put=self.cfg.device_put)

    def train_epoch(self, state: dict, epoch: int = 0, start_step: int = 0):
        if self._train_step is None:
            self._build_step()
        step_idx = start_step
        t0 = time.perf_counter()
        emitted = 0
        tokens_seen = 0
        tracer = obs.default_tracer()
        m_steps = obs.counter("train_steps_total", help="optimizer steps run")
        m_tokens = obs.counter("train_tokens_total", help="real tokens trained on")
        m_step_dur = obs.histogram(
            "train_step_duration_seconds",
            help="wall time of one full train step (realize+pad+put+compute)",
            unit="seconds",
        )
        step_iter = iter(self._epoch_steps(epoch))
        while True:
            step_t0 = time.perf_counter()
            # Realize: pull the next aligned step out of the data path
            # (admission + protocol rounds + layout, or a prefetch dequeue).
            loader_step = _timed_phase(
                "train/realize", "train_realize_seconds_total",
                "data-path time to the next aligned step",
                lambda: next(step_iter, None),
            )
            if loader_step is None:
                break
            batch = assemble_model_batch(loader_step, self.loader.layout)

            def _compute():
                new_state, metrics = self._train_step(state, batch)
                if tracer.enabled:
                    # Async dispatch would end the span at enqueue time;
                    # only force completion when someone is looking.
                    jax.block_until_ready(metrics["loss"])
                return new_state, metrics

            state, metrics = _timed_phase(
                "train/compute", "train_compute_seconds_total",
                "jitted train_step time (dispatch; synced when tracing)",
                _compute,
            )
            step_idx += 1
            emitted += loader_step.metadata.emitted_samples
            tokens_seen += loader_step.metadata.total_tokens
            step_dt = time.perf_counter() - step_t0
            m_steps.inc()
            m_tokens.inc(loader_step.metadata.total_tokens)
            m_step_dur.observe(step_dt)
            tracer.complete(
                "train/step", step_t0, step_dt, cat="train", step=step_idx
            )
            if step_idx % self.cfg.log_every == 0:
                dt = time.perf_counter() - t0
                rec = self._publish_log_record(
                    metrics, loader_step, step_idx, emitted, tokens_seen, dt
                )
                self.history.append(rec)
            if (
                self.cfg.checkpoint_dir
                and step_idx % self.cfg.checkpoint_every == 0
            ):
                ckpt.save_checkpoint(
                    self.cfg.checkpoint_dir, step_idx, state,
                    keep=self.cfg.keep_checkpoints,
                )
            if self.cfg.max_steps and step_idx >= self.cfg.max_steps:
                break
        return state, step_idx

    def _publish_log_record(
        self, metrics, loader_step, step_idx: int, emitted: int,
        tokens_seen: int, dt: float,
    ) -> dict:
        """Publish step metrics to the registry and return the log record.

        One value set feeds the registry gauges, ``self.history`` and the
        stdout line (:meth:`format_log_line`) — the record is a *view* of the
        same snapshot ``metrics.json`` serializes, not a second bookkeeping
        path (satellite: no more ad-hoc log dict).
        """
        values = {
            "train_loss": float(metrics["loss"]),
            "train_step_tokens": float(metrics["tokens"]),
            "train_grad_norm": float(metrics["grad_norm"]),
            "train_samples_per_second": emitted / dt if dt > 0 else 0.0,
            "train_tokens_per_second": tokens_seen / dt if dt > 0 else 0.0,
            "train_batch_padding": loader_step.metadata.padding_fraction,
            "train_device_padding": (
                1.0 - loader_step.metadata.total_tokens / loader_step.device_tokens
                if loader_step.device_tokens
                else 0.0
            ),
        }
        reg = obs.default_registry()
        for name, value in values.items():
            reg.gauge(name).set(value)
        return {
            "step": step_idx,
            "loss": values["train_loss"],
            "tokens": values["train_step_tokens"],
            "grad_norm": values["train_grad_norm"],
            "emitted_samples": emitted,
            "sam_per_s": values["train_samples_per_second"],
            "padding": values["train_batch_padding"],
            "device_padding": values["train_device_padding"],
        }

    @staticmethod
    def format_log_line(rec: dict) -> str:
        """Render one history record (the stdout view of the same snapshot)."""
        return (
            f"step {rec['step']:>6}  loss {rec['loss']:.4f}  "
            f"tokens {rec['tokens']:>8.0f}  grad_norm {rec['grad_norm']:.3f}  "
            f"sam/s {rec['sam_per_s']:.1f}  pad {rec['padding']:.3f}  "
            f"dev_pad {rec['device_padding']:.3f}"
        )


# -----------------------------------------------------------------------------
# Paper-literal shard_map DP step (Eq. 2 prescaling + optional compression)
# -----------------------------------------------------------------------------


def dp_shardmap_step(
    model: LM,
    mesh,
    opt_cfg: OptimizerConfig,
    *,
    loss_mode: str = "exact_token",
    compress_grads: bool = False,
):
    """Per-rank DDP-style step over the ``data`` axis of ``mesh``.

    Each data shard computes its local mean loss L̄_r, prescales it by
    ``W · w_r`` (Eq. 2), and the psum-mean over shards reproduces the global
    objective; gradients reduce via psum (optionally bf16-compressed with
    error feedback).
    """
    world = mesh.shape["data"]

    def local_loss(params, batch):
        loss_sum, tokens = model.loss_sums(params, batch)
        samples = jnp.sum(jnp.max(batch["loss_mask"], axis=1))
        mean_local = loss_sum / jnp.maximum(tokens, 1.0)
        t_tok = jax.lax.psum(tokens, "data")
        n_tot = jax.lax.psum(samples, "data")
        factor = prescale_factor(
            tokens, jnp.maximum(t_tok, 1.0), world, loss_mode,
            local_samples=samples, global_samples=jnp.maximum(n_tot, 1.0),
        )
        scaled = mean_local * factor
        # DDP post-averaging: mean over ranks == psum / W
        return jax.lax.psum(scaled, "data") / world, tokens

    def step(state, batch, err):
        def lf(params):
            return local_loss(params, batch)

        (loss, tokens), grads = jax.value_and_grad(lf, has_aux=True)(state["params"])
        # Local grads hold only this shard's term ∂(scaled_r/W)/∂θ; the DDP
        # AllReduce is the explicit psum below (bf16-compressed if enabled).
        if compress_grads:
            grads, err = psum_compressed(grads, err, "data")
        else:
            grads = jax.lax.psum(grads, "data")
        params, opt, om = adamw_update(state["params"], grads, state["opt"], opt_cfg)
        return {"params": params, "opt": opt}, {"loss": loss, "tokens": tokens, **om}, err

    wrapped = jax.shard_map(
        step,
        mesh=mesh,
        in_specs=(
            P(),  # state replicated across data (DDP semantics)
            {"tokens": P("data", None), "labels": P("data", None), "loss_mask": P("data", None)},
            P(),
        ),
        out_specs=(P(), P(), P()),
        check_vma=False,
    )
    return jax.jit(wrapped, donate_argnums=(0,)), init_error_state
