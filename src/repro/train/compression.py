"""Gradient compression with error feedback (distributed-optimization trick).

In the shard_map data-parallel path the gradient all-reduce is explicit, so
we can compress it: cast fp32 grads to bf16 before the ``psum`` and carry the
quantization residual into the next step (error feedback keeps the scheme
unbiased over time — Karimireddy et al., "Error Feedback Fixes SignSGD").

Halves DP gradient-reduction bytes; composes with ODB (which changes batch
geometry, not the reduction).  Exposed as a config flag on the shard_map
trainer; the pure-pjit path keeps XLA's fused reductions.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def init_error_state(grads_like: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def compress_decompress(
    grads: Any, error: Any, *, dtype=jnp.bfloat16
) -> tuple[Any, Any]:
    """Returns (compressed-as-fp32 grads to reduce, new error residuals)."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        gq = g32.astype(dtype)
        new_e = g32 - gq.astype(jnp.float32)
        return gq, new_e

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(error)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        tdef.unflatten([p[0] for p in pairs]),
        tdef.unflatten([p[1] for p in pairs]),
    )


def psum_compressed(grads: Any, error: Any, axis_name: str):
    """Compress → psum(bf16) → decompress; returns (reduced_fp32, new_error)."""
    gq, new_e = compress_decompress(grads, error)
    reduced = jax.lax.psum(gq, axis_name)
    return jax.tree.map(lambda g: g.astype(jnp.float32), reduced), new_e
