"""Fault-tolerant checkpointing: atomic, keep-k, elastic re-shard on resume.

Design for 1000+-node operation (DESIGN.md §3):
  * atomic: write to ``step_XXXX.tmp`` then rename — a crash mid-write never
    corrupts the latest checkpoint;
  * self-describing: the manifest stores the flattened tree structure, so
    restore works into any mesh — arrays are saved unsharded (gathered) and
    re-sharded by the caller's ``device_put`` on resume.  A job restarted
    with a different topology (elastic scaling) resumes cleanly: the new
    mesh's shardings are applied by the train driver, not baked into disk;
  * keep-k rotation + ``latest`` pointer;
  * restart loop: ``launch/train.py`` wraps stepping in try/resume.

Storage is npz-per-checkpoint (CPU container); on a real cluster the same
interface backs onto per-host sharded writes.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import time
import warnings
import zipfile
import zlib
from typing import Any

import jax
import numpy as np

# Failure modes a torn/corrupt npz can present as, depending on where the
# damage landed (zip directory, member header, deflate stream, missing key).
_CORRUPT_ERRORS = (
    OSError,
    EOFError,
    ValueError,
    KeyError,
    zipfile.BadZipFile,
    zlib.error,
)


def _flatten(tree) -> tuple[list[tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out.append((key, leaf))
    return out, treedef


def save_checkpoint(
    directory: str | os.PathLike,
    step: int,
    state: Any,
    *,
    keep: int = 3,
    extra: dict | None = None,
) -> pathlib.Path:
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    flat, _ = _flatten(state)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat}
    tmp = directory / f"step_{step:08d}.tmp.npz"
    final = directory / f"step_{step:08d}.npz"
    np.savez(tmp, **{k.replace("/", "__SEP__"): v for k, v in arrays.items()})
    os.replace(tmp, final)
    manifest = {
        "step": step,
        "keys": [k for k, _ in flat],
        "time": time.time(),
        "extra": extra or {},
    }
    mtmp = directory / "latest.tmp.json"
    mtmp.write_text(json.dumps(manifest))
    os.replace(mtmp, directory / "latest.json")
    # rotate
    ckpts = sorted(directory.glob("step_*.npz"))
    for old in ckpts[:-keep]:
        old.unlink(missing_ok=True)
    return final


def latest_step(directory: str | os.PathLike) -> int | None:
    directory = pathlib.Path(directory)
    mf = directory / "latest.json"
    if not mf.exists():
        return None
    try:
        return int(json.loads(mf.read_text())["step"])
    except (ValueError, KeyError, json.JSONDecodeError):
        return None


def _read_arrays(path: pathlib.Path, keys: list[str]) -> dict[str, np.ndarray]:
    """Fully materialize a checkpoint's arrays, validating every key.

    npz loading is lazy — a truncated deflate stream only explodes when the
    member is decompressed — so restore integrity means reading everything
    up front, inside the caller's corrupt-checkpoint guard."""
    with np.load(path) as data:
        return {k: np.asarray(data[k.replace("/", "__SEP__")]) for k in keys}


def restore_checkpoint(
    directory: str | os.PathLike,
    state_like: Any,
    *,
    step: int | None = None,
    shardings: Any = None,
) -> tuple[Any, int]:
    """Restore into the structure of ``state_like`` (elastic re-shard).

    ``state_like`` provides the pytree structure (shapes may come from a NEW
    mesh/topology); ``shardings`` (optional pytree of NamedSharding) places
    each restored array — this is where elastic re-sharding happens.

    Corrupt-latest fallback (DESIGN.md §15.6): when restoring ``latest``
    (``step=None``) and the newest checkpoint is unreadable — torn zip,
    truncated stream, missing key — restore falls back through the keep-k
    rotation, newest first, with a ``RuntimeWarning`` naming what was
    skipped.  An explicitly requested ``step`` never falls back: the caller
    asked for that artifact, and silently substituting another would be
    worse than failing.  Shape mismatches are a *topology* error, not
    corruption, and stay hard errors on every path.
    """
    directory = pathlib.Path(directory)
    flat, treedef = _flatten(state_like)
    keys = [k for k, _ in flat]
    if step is not None:
        data = _read_arrays(directory / f"step_{step:08d}.npz", keys)
    else:
        candidates: list[pathlib.Path] = []
        pointed = latest_step(directory)
        if pointed is not None:
            candidates.append(directory / f"step_{pointed:08d}.npz")
        for p in sorted(directory.glob("step_*.npz"), reverse=True):
            if p not in candidates:
                candidates.append(p)
        if not candidates:
            raise FileNotFoundError(f"no checkpoint in {directory}")
        data = None
        for path in candidates:
            try:
                data = _read_arrays(path, keys)
            except _CORRUPT_ERRORS as exc:
                warnings.warn(
                    f"checkpoint {path.name} unreadable "
                    f"({type(exc).__name__}: {exc}); falling back to the "
                    "previous keep-k checkpoint",
                    RuntimeWarning,
                    stacklevel=2,
                )
                continue
            step = int(path.stem.split("_")[1])
            break
        if data is None:
            raise FileNotFoundError(
                f"no readable checkpoint in {directory} "
                f"(tried {[p.name for p in candidates]})"
            )
    leaves = []
    flat_shardings = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else None
    )
    for i, (key, like) in enumerate(flat):
        arr = data[key]
        want = np.asarray(like) if not hasattr(like, "shape") else like
        if tuple(arr.shape) != tuple(want.shape):
            raise ValueError(
                f"checkpoint/{key}: shape {arr.shape} != expected {want.shape}"
            )
        if flat_shardings is not None:
            leaves.append(jax.device_put(arr, flat_shardings[i]))
        else:
            leaves.append(jax.numpy.asarray(arr, dtype=like.dtype))
    state = jax.tree_util.tree_unflatten(treedef, leaves)
    return state, step
