"""Fault-tolerant checkpointing: atomic, keep-k, elastic re-shard on resume.

Design for 1000+-node operation (DESIGN.md §3):
  * atomic: write to ``step_XXXX.tmp`` then rename — a crash mid-write never
    corrupts the latest checkpoint;
  * self-describing: the manifest stores the flattened tree structure, so
    restore works into any mesh — arrays are saved unsharded (gathered) and
    re-sharded by the caller's ``device_put`` on resume.  A job restarted
    with a different topology (elastic scaling) resumes cleanly: the new
    mesh's shardings are applied by the train driver, not baked into disk;
  * keep-k rotation + ``latest`` pointer;
  * restart loop: ``launch/train.py`` wraps stepping in try/resume.

Storage is npz-per-checkpoint (CPU container); on a real cluster the same
interface backs onto per-host sharded writes.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import time
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> tuple[list[tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out.append((key, leaf))
    return out, treedef


def save_checkpoint(
    directory: str | os.PathLike,
    step: int,
    state: Any,
    *,
    keep: int = 3,
    extra: dict | None = None,
) -> pathlib.Path:
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    flat, _ = _flatten(state)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat}
    tmp = directory / f"step_{step:08d}.tmp.npz"
    final = directory / f"step_{step:08d}.npz"
    np.savez(tmp, **{k.replace("/", "__SEP__"): v for k, v in arrays.items()})
    os.replace(tmp, final)
    manifest = {
        "step": step,
        "keys": [k for k, _ in flat],
        "time": time.time(),
        "extra": extra or {},
    }
    mtmp = directory / "latest.tmp.json"
    mtmp.write_text(json.dumps(manifest))
    os.replace(mtmp, directory / "latest.json")
    # rotate
    ckpts = sorted(directory.glob("step_*.npz"))
    for old in ckpts[:-keep]:
        old.unlink(missing_ok=True)
    return final


def latest_step(directory: str | os.PathLike) -> int | None:
    directory = pathlib.Path(directory)
    mf = directory / "latest.json"
    if not mf.exists():
        return None
    try:
        return int(json.loads(mf.read_text())["step"])
    except (ValueError, KeyError, json.JSONDecodeError):
        return None


def restore_checkpoint(
    directory: str | os.PathLike,
    state_like: Any,
    *,
    step: int | None = None,
    shardings: Any = None,
) -> tuple[Any, int]:
    """Restore into the structure of ``state_like`` (elastic re-shard).

    ``state_like`` provides the pytree structure (shapes may come from a NEW
    mesh/topology); ``shardings`` (optional pytree of NamedSharding) places
    each restored array — this is where elastic re-sharding happens.
    """
    directory = pathlib.Path(directory)
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    path = directory / f"step_{step:08d}.npz"
    data = np.load(path)
    flat, treedef = _flatten(state_like)
    leaves = []
    flat_shardings = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else None
    )
    for i, (key, like) in enumerate(flat):
        arr = data[key.replace("/", "__SEP__")]
        want = np.asarray(like) if not hasattr(like, "shape") else like
        if tuple(arr.shape) != tuple(want.shape):
            raise ValueError(
                f"checkpoint/{key}: shape {arr.shape} != expected {want.shape}"
            )
        if flat_shardings is not None:
            leaves.append(jax.device_put(arr, flat_shardings[i]))
        else:
            leaves.append(jax.numpy.asarray(arr, dtype=like.dtype))
    state = jax.tree_util.tree_unflatten(treedef, leaves)
    return state, step
