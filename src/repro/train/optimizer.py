"""AdamW + cosine schedule + global-norm clipping, from scratch (no optax).

Matches the paper's training hyperparameters (§3.1): AdamW, cosine decay,
lr 1e-5, warmup_ratio 0.03, grad-clip 4.0, bf16 compute.  Moments may be
stored in bf16 (``moment_dtype``) — the memory lever that makes the 480B/671B
archs fit the HBM budget (EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 1e-5
    warmup_ratio: float = 0.03
    total_steps: int = 10_000
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 4.0
    moment_dtype: str = "float32"  # "bfloat16" for the giants
    min_lr_fraction: float = 0.1


def cosine_lr(step, cfg: OptimizerConfig):
    warmup = jnp.maximum(cfg.warmup_ratio * cfg.total_steps, 1.0)
    warm = step / warmup
    progress = jnp.clip((step - warmup) / jnp.maximum(cfg.total_steps - warmup, 1.0), 0.0, 1.0)
    cos = cfg.min_lr_fraction + (1 - cfg.min_lr_fraction) * 0.5 * (
        1.0 + jnp.cos(jnp.pi * progress)
    )
    return cfg.lr * jnp.where(step < warmup, warm, cos)


def init_opt_state(params: Params, cfg: OptimizerConfig) -> dict:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dtype=mdt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree_util.tree_reduce(jnp.add, sq, jnp.zeros((), jnp.float32)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(params: Params, grads: Params, opt_state: dict, cfg: OptimizerConfig):
    """One AdamW step; returns (new_params, new_opt_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = opt_state["step"] + 1
    lr = cosine_lr(step.astype(jnp.float32), cfg)
    b1, b2 = cfg.betas
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        mh = m32 / bc1
        vh = v32 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt_state["m"])
    flat_v = jax.tree_util.tree_leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return (
        new_p,
        {"m": new_m, "v": new_v, "step": step},
        {"lr": lr, "grad_norm": gnorm},
    )
