"""Exact per-device cost model over optimized (post-SPMD, post-fusion) HLO.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body **once**,
which under scan-over-layers undercounts a 61-layer model by ~60×.  This
parser walks the computation call graph from ENTRY, multiplying through
``known_trip_count`` on while ops, and accumulates:

  * flops        — dot ops: 2 · |result| · |contracting dims| (incl. dots
                   inside fusion bodies); cheap elementwise ignored;
  * hbm_bytes    — per materializing op (fusion / dot / copy / collective /
                   dynamic-*): operand bytes + result bytes.  Fusion-internal
                   ops are free (that is what fusion means);
  * coll_bytes   — operand bytes of all-reduce / all-gather / reduce-scatter /
                   all-to-all / collective-permute (× trip multipliers), plus
                   per-opcode tallies.

All numbers are **per device** (the module is the per-device SPMD program).
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
def _parse_op_line(line: str):
    """Parse '%name = TYPE opcode(REST' with balanced-paren tuple types
    (tuple types may contain '/*index=N*/' comments)."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    eq = s.find(" = ")
    if eq < 0 or not s.startswith("%"):
        return None
    name = s[1:eq].strip()
    rhs = s[eq + 3 :]
    if rhs.startswith("("):  # tuple type: balanced scan
        depth = 0
        end = -1
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        type_str = rhs[: end + 1]
        rest = rhs[end + 1 :].strip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        type_str = rhs[:sp]
        rest = rhs[sp + 1 :].strip()
    m = re.match(r"([a-z0-9\-_]+)\((.*)$", rest, re.DOTALL)
    if not m:
        return None
    return name, type_str, m.group(1), m.group(2)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([^\s(]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_CALLS_RE = re.compile(r"calls=%?([^\s,)]+)")
_BODY_RE = re.compile(r"body=%?([^\s,)]+)")
_COND_RE = re.compile(r"condition=%?([^\s,)]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
)
_FREE_OPS = {
    "get-tuple-element", "tuple", "parameter", "constant", "bitcast",
    "after-all", "add-dependency", "partition-id", "replica-id", "iota",
}


def _type_bytes(type_str: str) -> int:
    return sum(
        _prod_dims(dims) * _DTYPE_BYTES.get(dt, 0)
        for dt, dims in _SHAPE_RE.findall(type_str)
    )


def _prod_dims(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str  # operands + attrs (joined)

    def operand_names(self) -> list[str]:
        depth = 0
        out: list[str] = []
        token = ""
        for ch in self.rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    if token.strip():
                        out.append(token.strip())
                    break
                depth -= 1
            elif ch == "," and depth == 0:
                out.append(token.strip())
                token = ""
                continue
            token += ch
        names = []
        for t in out:
            t = t.strip()
            m = re.search(r"%([^\s,()]+)\s*$", t)
            if m:
                names.append(m.group(1))
        return names


def parse_module(hlo_text: str) -> dict[str, dict[str, Op]]:
    comps: dict[str, dict[str, Op]] = {}
    current: dict[str, Op] | None = None
    entry_name = None
    for line in hlo_text.splitlines():
        mc = _COMP_RE.match(line.strip())
        if mc and "{" in line:
            current = {}
            comps[mc.group(1)] = current
            if line.strip().startswith("ENTRY"):
                entry_name = mc.group(1)
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is None:
            continue
        parsed = _parse_op_line(line)
        if parsed:
            name, type_str, opcode, rest = parsed
            current[name] = Op(name, type_str, opcode, rest)
    comps["__entry__"] = comps.get(entry_name, {})  # type: ignore[arg-type]
    return comps


@dataclasses.dataclass
class CostTotals:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    per_collective: dict | None = None
    transcendentals: float = 0.0

    def add(self, other: "CostTotals", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        for k, v in (other.per_collective or {}).items():
            self.per_collective[k] = self.per_collective.get(k, 0.0) + v * mult


def _dot_flops(op: Op, symbols: dict[str, Op]) -> float:
    result_elems = sum(
        _prod_dims(dims) for _, dims in _SHAPE_RE.findall(op.type_str)
    )
    operands = op.operand_names()
    if not operands:
        return 0.0
    lhs = symbols.get(operands[0])
    if lhs is None:
        return 2.0 * result_elems  # unknown contraction; floor
    lhs_shapes = _SHAPE_RE.findall(lhs.type_str)
    if not lhs_shapes:
        return 2.0 * result_elems
    lhs_dims = [int(d) for d in lhs_shapes[0][1].split(",")] if lhs_shapes[0][1] else []
    mc = _CONTRACT_RE.search(op.rest)
    contract = 1
    if mc and mc.group(1):
        for idx in mc.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    return 2.0 * result_elems * contract


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps = parse_module(hlo_text)
        self._memo: dict[str, CostTotals] = {}

    def _op_cost(self, op: Op, comp_ops: dict[str, Op]) -> CostTotals:
        t = CostTotals(per_collective={})
        oc = op.opcode
        if oc in _FREE_OPS or oc.endswith("-done"):
            return t
        # nested computations
        if oc == "while":
            body = _BODY_RE.search(op.rest)
            cond = _COND_RE.search(op.rest)
            trip = 1
            mt = _TRIP_RE.search(op.rest)
            if mt:
                trip = int(mt.group(1))
            if body:
                t.add(self.computation_cost(body.group(1)), trip)
            if cond:
                t.add(self.computation_cost(cond.group(1)), trip + 1)
            return t
        if oc == "conditional":
            mb = _BRANCHES_RE.search(op.rest)
            if mb:
                branches = [
                    b.strip().lstrip("%") for b in mb.group(1).split(",") if b.strip()
                ]
                if branches:  # average branch cost
                    agg = CostTotals(per_collective={})
                    for b in branches:
                        agg.add(self.computation_cost(b), 1.0 / len(branches))
                    t.add(agg)
            return t
        if oc in ("call", "async-start"):
            mcalls = _CALLS_RE.search(op.rest)
            if mcalls:
                t.add(self.computation_cost(mcalls.group(1)))
            return t

        # materializing op: HBM traffic = operands + result, EXCEPT:
        #  * dynamic-slice reads only the slice (result), not the operand —
        #    critical under scan-over-layers, where the stacked (L, ...)
        #    params are an operand of a per-iteration slice;
        #  * dynamic-update-slice writes only the update (in-place aliasing).
        if oc == "dynamic-slice":
            t.hbm_bytes += 2.0 * _type_bytes(op.type_str)
            return t
        if oc == "dynamic-update-slice":
            opnds = op.operand_names()
            upd = comp_ops.get(opnds[1]) if len(opnds) > 1 else None
            upd_bytes = _type_bytes(upd.type_str) if upd else _type_bytes(op.type_str)
            t.hbm_bytes += 2.0 * upd_bytes
            return t

        if oc == "fusion":
            mcalls = _CALLS_RE.search(op.rest)
            called = mcalls.group(1).lstrip("%") if mcalls else None
            t.hbm_bytes += _type_bytes(op.type_str)  # fusion output
            t.hbm_bytes += self._fusion_input_bytes(op, comp_ops, called)
            if called:
                inner = self.computation_cost(called)
                # fused flops count; fused intermediate bytes do NOT
                t.flops += inner.flops
                t.transcendentals += inner.transcendentals
            return t

        op_bytes = _type_bytes(op.type_str)
        for name in op.operand_names():
            src = comp_ops.get(name)
            if src is not None:
                op_bytes += _type_bytes(src.type_str)
        t.hbm_bytes += op_bytes
        if oc == "dot":
            t.flops += _dot_flops(op, comp_ops)
            return t
        if oc == "convolution":
            result_elems = sum(
                _prod_dims(d) for _, d in _SHAPE_RE.findall(op.type_str)
            )
            t.flops += 2.0 * result_elems  # floor (convs are rare here)
            return t
        for coll in COLLECTIVE_OPS:
            if oc == coll or oc == coll + "-start":
                operand_bytes = 0
                for name in op.operand_names():
                    src = comp_ops.get(name)
                    if src is not None:
                        operand_bytes += _type_bytes(src.type_str)
                if operand_bytes == 0:  # e.g. operand outside comp scope
                    operand_bytes = _type_bytes(op.type_str)
                t.coll_bytes += operand_bytes
                t.per_collective[coll] = t.per_collective.get(coll, 0.0) + operand_bytes
                return t
        if oc in ("exponential", "tanh", "logistic", "rsqrt", "sqrt", "log", "power"):
            t.transcendentals += sum(
                _prod_dims(d) for _, d in _SHAPE_RE.findall(op.type_str)
            )
        return t

    def _fusion_input_bytes(self, op: Op, comp_ops: dict[str, Op], called: str | None) -> float:
        """Input traffic of a fusion: operands consumed *only* through
        dynamic-slice / dynamic-update-slice inside the body are charged at
        slice size (the stacked scan-param case); everything else full."""
        total = 0.0
        operands = op.operand_names()
        called_ops = self.comps.get(called, {}) if called else {}
        params_by_idx: dict[int, str] = {}
        for name, o in called_ops.items():
            if o.opcode == "parameter":
                m = re.match(r"\s*(\d+)\)", o.rest)
                if m:
                    params_by_idx[int(m.group(1))] = name
        for i, opnd in enumerate(operands):
            src = comp_ops.get(opnd)
            full = _type_bytes(src.type_str) if src else 0.0
            pname = params_by_idx.get(i)
            if pname is None:
                total += full
                continue
            consumers = [
                o for o in called_ops.values() if pname in o.operand_names()
            ]
            sliced = 0.0
            ok = bool(consumers)
            for c in consumers:
                if c.opcode == "dynamic-slice" and c.operand_names()[:1] == [pname]:
                    sliced += _type_bytes(c.type_str)
                elif (
                    c.opcode == "dynamic-update-slice"
                    and c.operand_names()[:1] == [pname]
                ):
                    ops2 = c.operand_names()
                    upd = called_ops.get(ops2[1]) if len(ops2) > 1 else None
                    sliced += _type_bytes(upd.type_str) if upd else full
                else:
                    ok = False
                    break
            total += min(sliced, full) if ok else full
        return total

    def computation_cost(self, comp_name: str) -> CostTotals:
        comp_name = comp_name.lstrip("%")
        if comp_name in self._memo:
            return self._memo[comp_name]
        ops = self.comps.get(comp_name, {})
        total = CostTotals(per_collective={})
        self._memo[comp_name] = total  # break cycles defensively
        for op in ops.values():
            total.add(self._op_cost(op, ops))
        return total

    def entry_cost(self) -> CostTotals:
        return self.computation_cost("__entry__")


def analyze(hlo_text: str) -> dict:
    """Per-device totals for the compiled module."""
    model = HloCostModel(hlo_text)
    t = model.entry_cost()
    return {
        "flops": t.flops,
        "hbm_bytes": t.hbm_bytes,
        "coll_bytes": t.coll_bytes,
        "per_collective": dict(t.per_collective or {}),
        "transcendentals": t.transcendentals,
    }
