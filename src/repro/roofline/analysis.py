"""Three-term roofline analysis from compiled dry-run artifacts.

    compute term    = HLO_FLOPs   / (chips × peak_FLOP/s)
    memory term     = HLO_bytes   / (chips × HBM_bw)
    collective term = coll_bytes  / (chips × link_bw)

Sources: ``compiled.cost_analysis()`` for FLOPs/bytes; collective bytes are
parsed out of the optimized HLO text (operand sizes of all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute).

Hardware constants (TPU v5e target): 197 TFLOP/s bf16 per chip, 819 GB/s
HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum operand bytes per collective opcode over the optimized module.

    HLO lines look like ``%x = bf16[16,512]{1,0} all-reduce(bf16[16,512]{1,0}
    %add), replica_groups=...``; we take the shapes appearing *after* the
    opcode's '(' (the operands).  If operand types are not inlined, fall back
    to the result shape(s) on the line.
    """
    totals = {op: 0 for op in _COLLECTIVES}
    counts = {op: 0 for op in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for op in _COLLECTIVES:
            marker = f" {op}("
            idx = stripped.find(marker)
            if idx < 0 or stripped.startswith("//"):
                continue
            if f"{op}-start" in stripped and f"= {op}-start" not in stripped:
                pass
            operand_part = stripped[idx + len(marker):]
            operand_shapes = _SHAPE_RE.findall(operand_part.split(")")[0])
            if not operand_shapes:
                operand_shapes = _SHAPE_RE.findall(stripped[:idx])
            totals[op] += sum(_shape_bytes(d, s) for d, s in operand_shapes)
            counts[op] += 1
            break
    totals["ops"] = sum(counts.values())
    totals["per_op_counts"] = counts  # type: ignore[assignment]
    return totals


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float  # per device (SPMD module), trip-count corrected
    hlo_bytes: float  # per device
    coll_bytes: float  # per device
    model_flops: float  # 6·N_active·D analytic, per device
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)  # type: ignore[arg-type]

    @property
    def bound_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste detector."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU upper bound: useful-compute time / bound time."""
        ideal = self.model_flops / PEAK_FLOPS  # per-device ideal step time
        return ideal / self.bound_time_s if self.bound_time_s else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_for_cell(cfg, cell, n_active: int | None = None) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference fwd), D = tokens."""
    n = n_active if n_active is not None else cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * cell.global_batch


def roofline_from_artifacts(
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    parsed: dict,  # per-device totals from repro.roofline.hlo_cost.analyze
    model_flops_global: float,
) -> RooflineTerms:
    flops = float(parsed.get("flops", 0.0))
    byts = float(parsed.get("hbm_bytes", 0.0))
    cbytes = float(parsed.get("coll_bytes", 0.0))
    return RooflineTerms(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        hlo_flops=flops,
        hlo_bytes=byts,
        coll_bytes=cbytes,
        model_flops=model_flops_global / chips,
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=cbytes / ICI_BW,
    )
