"""DeepSeek-V3-671B [moe] — MLA + 1 shared + 256 routed top-8 [arXiv:2412.19437].

61L d_model=7168 128H, MLA (q_lora 1536, kv_lora 512, rope 64, nope 128,
v 128), MoE d_ff=2048, first 3 layers dense (d_ff 18432), vocab=129280.
MTP (multi-token prediction) is out of scope here — noted in DESIGN.md.
"""

import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    vocab_size=129280,
    n_heads=128,
    n_kv_heads=128,
    d_head=128,
    attn_kind="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    d_ff=18432,  # dense layers (first_k_dense)
    n_experts=256,
    top_k=8,
    moe_d_ff=2048,
    n_shared_experts=1,
    first_k_dense=3,
    norm="rms",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG,
    name="dsv3-smoke",
    n_layers=3,
    d_model=64,
    vocab_size=512,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    q_lora_rank=32,
    kv_lora_rank=16,
    qk_rope_dim=8,
    qk_nope_dim=16,
    v_head_dim=16,
    d_ff=128,
    n_experts=8,
    top_k=2,
    moe_d_ff=32,
    first_k_dense=1,
    dtype="float32",
)
