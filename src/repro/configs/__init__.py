"""Assigned architecture configs (``--arch <id>``) + shape registry."""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ArchConfig

ARCH_IDS = (
    "chameleon_34b",
    "qwen3_0_6b",
    "olmo_1b",
    "deepseek_7b",
    "yi_34b",
    "deepseek_v3_671b",
    "arctic_480b",
    "jamba_1_5_large",
    "mamba2_130m",
    "hubert_xlarge",
)

_ALIASES = {
    "chameleon-34b": "chameleon_34b",
    "qwen3-0.6b": "qwen3_0_6b",
    "olmo-1b": "olmo_1b",
    "deepseek-7b": "deepseek_7b",
    "yi-34b": "yi_34b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "arctic-480b": "arctic_480b",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "mamba2-130m": "mamba2_130m",
    "hubert-xlarge": "hubert_xlarge",
}


def get_config(arch: str) -> ArchConfig:
    arch = _ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; have {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    arch = _ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.SMOKE_CONFIG


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
