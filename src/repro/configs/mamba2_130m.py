"""Mamba2-130M [ssm] — SSD (state-space duality) [arXiv:2405.21060].

24L d_model=768 (attn-free) vocab=50280, ssm_state=128, headdim=64,
expand=2 (d_inner=1536, 24 SSD heads).
"""

import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    vocab_size=50280,
    attn_kind="none",
    d_ff=0,  # attn-free, FFN-free: SSD mixer only (per paper architecture)
    gated_mlp=False,
    d_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_chunk=256,
    norm="rms",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG,
    name="mamba2-smoke",
    n_layers=2,
    d_model=64,
    vocab_size=512,
    d_state=16,
    ssm_headdim=16,
    ssm_chunk=16,
    dtype="float32",
)
