"""OLMo-1B [dense] — non-parametric LN [arXiv:2402.00838].

16L d_model=2048 16H (kv=16, MHA) d_ff=8192 vocab=50304.
"""

import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    vocab_size=50304,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=8192,
    norm="ln_nonparam",
    gated_mlp=True,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG,
    name="olmo-smoke",
    n_layers=2,
    d_model=64,
    vocab_size=512,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=256,
    dtype="float32",
)
