"""Qwen3-0.6B [dense] — qk_norm, GQA [hf:Qwen/Qwen3].

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936; head_dim=128
(Qwen3 decouples head_dim from d_model/n_heads).
"""

import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    vocab_size=151936,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,
    d_ff=3072,
    qk_norm=True,
    norm="rms",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG,
    name="qwen3-smoke",
    n_layers=2,
    d_model=64,
    vocab_size=512,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    dtype="float32",
)
