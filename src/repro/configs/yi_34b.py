"""Yi-34B [dense] — llama-arch GQA [arXiv:2403.04652].

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.  56 heads are not
divisible by the 16-way model axis; GSPMD pads the head dim (overhead
reported in EXPERIMENTS.md §Roofline).
"""

import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    vocab_size=64000,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=20480,
    norm="rms",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG,
    name="yi-smoke",
    n_layers=2,
    d_model=64,
    vocab_size=512,
    n_heads=8,  # keeps GQA ratio 56/8 -> 8/2 shape class
    n_kv_heads=2,
    d_head=8,
    d_ff=160,
    dtype="float32",
)
