"""HuBERT-XLarge [audio] — encoder-only [arXiv:2106.07447].

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (cluster targets).
Encoder-only: bidirectional attention, no decode step (decode/long cells
skipped — DESIGN.md §4).  The conv feature-extractor frontend is a STUB:
``input_specs()`` supplies precomputed frame embeddings (B, T, d_model).
"""

import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="encoder",
    n_layers=48,
    d_model=1280,
    vocab_size=504,
    n_heads=16,
    n_kv_heads=16,
    d_head=80,
    d_ff=5120,
    causal=False,
    is_encoder=True,
    input_embeds=True,
    act="gelu",
    gated_mlp=False,
    norm="ln",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG,
    name="hubert-smoke",
    n_layers=2,
    d_model=64,
    vocab_size=128,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=128,
    dtype="float32",
)
