"""Jamba-1.5-Large-398B [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887].

72L d_model=8192 64H (GQA kv=8) d_ff=24576, MoE every 2nd layer.  SSM layers
use the Mamba-2 SSD block for uniformity with mamba2-130m (DESIGN.md §4);
d_state=128.
"""

import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    vocab_size=65536,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=24576,
    n_experts=16,
    top_k=2,
    moe_d_ff=24576,
    moe_every=2,
    attn_period=8,
    d_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_chunk=256,
    norm="rms",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG,
    name="jamba-smoke",
    n_layers=8,  # one full period: 1 attn + 7 mamba, MoE alternating
    d_model=64,
    vocab_size=512,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    n_experts=4,
    top_k=2,
    moe_d_ff=128,
    d_state=16,
    ssm_headdim=16,
    ssm_chunk=16,
    dtype="float32",
)
