"""Snowflake Arctic-480B [moe] — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base].

35L d_model=7168 56H (GQA kv=8) d_ff=4864 (dense residual MLP in parallel
with the MoE branch on every layer), MoE 128e top-2, vocab=32000.
"""

import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    vocab_size=32000,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=4864,
    n_experts=128,
    top_k=2,
    moe_d_ff=4864,
    dense_residual=True,
    norm="rms",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG,
    name="arctic-smoke",
    n_layers=2,
    d_model=64,
    vocab_size=512,
    n_heads=8,
    n_kv_heads=2,
    d_head=8,
    d_ff=96,
    n_experts=8,
    top_k=2,
    moe_d_ff=96,
    dtype="float32",
)
