"""Chameleon-34B [vlm] — early-fusion, VQ image tokens [arXiv:2405.09818].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.  Early fusion means
image content arrives as VQ token ids *inside the text vocabulary* — the VQ
tokenizer is the (stubbed) modality frontend, so the backbone consumes plain
token ids whose realized count is only known post-pipeline (the paper's
visual-token-expansion regime; DESIGN.md §4).
"""

import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    vocab_size=65536,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=22016,
    qk_norm=True,  # chameleon uses qk-norm for stability
    norm="rms",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG,
    name="chameleon-smoke",
    n_layers=2,
    d_model=64,
    vocab_size=512,
    n_heads=8,
    n_kv_heads=2,
    d_head=8,
    d_ff=160,
    dtype="float32",
)
