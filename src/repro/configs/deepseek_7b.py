"""DeepSeek-7B [dense] — llama-arch [arXiv:2401.02954].

30L d_model=4096 32H (MHA kv=32) d_ff=11008 vocab=102400.
"""

import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    vocab_size=102400,
    n_heads=32,
    n_kv_heads=32,
    d_head=128,
    d_ff=11008,
    norm="rms",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG,
    name="deepseek7b-smoke",
    n_layers=2,
    d_model=64,
    vocab_size=512,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=160,
    dtype="float32",
)
