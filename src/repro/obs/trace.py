"""Span tracer with Chrome trace-event export (DESIGN.md §13.2).

Records nested *spans* (Chrome ``"X"`` complete events: name, start, dur)
and *instant* events into a thread-safe bounded ring buffer, exported as the
``chrome://tracing`` / Perfetto trace-event JSON format — so one telemetry-
enabled epoch renders as a timeline: protocol rounds inside stream steps,
prefetch producer staging against consumer waits, serve admit/prefill/decode
inside engine ticks, realize/pad/device_put/compute inside train steps.

Properties the instrumented hot paths rely on:

  * **disabled is free** — ``span()`` on a disabled tracer returns the one
    shared :data:`NULL_SPAN` context manager (no allocation, no clock read);
  * **bounded memory** — the ring holds ``capacity`` events; overflow drops
    the *oldest* (the tail of a long run is what post-mortems need) and is
    accounted in :attr:`dropped`, never silent;
  * **thread-safe** — producer threads (prefetch) and the trainer thread
    interleave appends under one lock; timestamps share a single monotonic
    origin so cross-thread ordering in the rendered timeline is real.

Nesting needs no explicit parent ids: Chrome's renderer reconstructs the
span tree from ``X``-event containment per (pid, tid) track, which is
exactly what lexically nested ``with tracer.span(...)`` blocks produce.
"""

from __future__ import annotations

import collections
import json
import os
import pathlib
import threading
import time

__all__ = ["NULL_SPAN", "Span", "SpanTracer", "default_tracer"]


class _NullSpan:
    """Shared no-op context manager (disabled-tracer fast path)."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


NULL_SPAN = _NullSpan()


class Span:
    """One live ``with``-scope; emits a single X event at exit."""

    __slots__ = ("_tracer", "name", "cat", "args", "_t0")

    def __init__(self, tracer: "SpanTracer", name: str, cat: str, args: dict):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args

    def __enter__(self) -> "Span":
        self._t0 = self._tracer.clock()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = self._tracer.clock()
        self._tracer.complete(
            self.name, self._t0, t1 - self._t0, cat=self.cat, **self.args
        )
        return False


class SpanTracer:
    """Bounded ring buffer of Chrome trace events."""

    def __init__(
        self,
        capacity: int = 65536,
        enabled: bool = False,
        clock=time.perf_counter,
    ) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.enabled = enabled
        self.clock = clock
        self._events: collections.deque[dict] = collections.deque(maxlen=capacity)
        self._emitted = 0
        self._lock = threading.Lock()
        self._origin = clock()
        self._tids: dict[int, int] = {}

    # -- enablement ------------------------------------------------------------
    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    # -- recording -------------------------------------------------------------
    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            tid = self._tids.setdefault(ident, len(self._tids))
        return tid

    def _us(self, t: float) -> float:
        return round(1e6 * (t - self._origin), 3)

    def _append(self, event: dict) -> None:
        with self._lock:
            self._events.append(event)
            self._emitted += 1

    def span(self, name: str, cat: str = "", **args):
        """Context manager recording one complete (``X``) event on exit."""
        if not self.enabled:
            return NULL_SPAN
        return Span(self, name, cat, args)

    def complete(
        self, name: str, start_s: float, dur_s: float, cat: str = "", **args
    ) -> None:
        """Record an already-timed scope (start/dur on this tracer's clock)."""
        if not self.enabled:
            return
        event = {
            "name": name,
            "ph": "X",
            "ts": self._us(start_s),
            "dur": round(1e6 * dur_s, 3),
            "pid": os.getpid(),
            "tid": self._tid(),
        }
        if cat:
            event["cat"] = cat
        if args:
            event["args"] = args
        self._append(event)

    def instant(self, name: str, cat: str = "", **args) -> None:
        """Record a zero-duration marker (closure events, compile events)."""
        if not self.enabled:
            return
        event = {
            "name": name,
            "ph": "i",
            "s": "t",  # thread-scoped instant
            "ts": self._us(self.clock()),
            "pid": os.getpid(),
            "tid": self._tid(),
        }
        if cat:
            event["cat"] = cat
        if args:
            event["args"] = args
        self._append(event)

    # -- views -----------------------------------------------------------------
    @property
    def dropped(self) -> int:
        """Events evicted by ring overflow (bounded memory, never silent)."""
        with self._lock:
            return self._emitted - len(self._events)

    def events(self) -> list[dict]:
        """Buffered events, oldest first (ts order per thread)."""
        with self._lock:
            return list(self._events)

    def export(self) -> dict:
        """Chrome trace-event JSON object (open in Perfetto / about:tracing)."""
        return {
            "traceEvents": self.events(),
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped},
        }

    def write(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.export(), indent=1))
        return path

    def reset(self) -> None:
        with self._lock:
            self._events.clear()
            self._emitted = 0
            self._origin = self.clock()


_DEFAULT = SpanTracer(enabled=False)


def default_tracer() -> SpanTracer:
    """The process-wide tracer (disabled until ``--telemetry`` / tests)."""
    return _DEFAULT
