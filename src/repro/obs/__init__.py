"""Observability subsystem: metrics registry, span tracer, run reporter.

Dependency-free (stdlib only), so every layer of the repo — core protocol,
stream executor, layout engine, trainer, serving engine, kernels — can import
``repro.obs`` without cycles.  See DESIGN.md §13 for the stable metric-name
catalog and the span hierarchy.

Module-level conveniences operate on the process-wide defaults::

    from repro import obs

    obs.counter("odb_protocol_rounds_total").inc()
    with obs.span("train/step", step=3):
        ...
    obs.instant("dgap/closure", event="join_all_finished")

The default registry is *enabled* (counters are cheap; `metrics.json` and
the trainer log line always have data); the default tracer is *disabled*
until ``--telemetry DIR`` (or a test) switches it on via
:func:`enable_telemetry`.
"""

from __future__ import annotations

from repro.obs.metrics import (
    DEFAULT_BUCKETS,
    DEFAULT_MAX_LABEL_CHILDREN,
    DROPPED_SERIES,
    NULL,
    Counter,
    CrossProcessAggregator,
    Gauge,
    Histogram,
    MetricsRegistry,
    NullMetric,
    default_registry,
)
from repro.obs.report import (
    ROUND_DURATION_BUCKETS,
    RoundTimeline,
    RunReporter,
    enable_telemetry,
)
from repro.obs.scrape import ScrapeServer, start_scrape_server
from repro.obs.trace import NULL_SPAN, Span, SpanTracer, default_tracer

__all__ = [
    "DEFAULT_BUCKETS",
    "DEFAULT_MAX_LABEL_CHILDREN",
    "DROPPED_SERIES",
    "NULL",
    "NULL_SPAN",
    "ROUND_DURATION_BUCKETS",
    "Counter",
    "CrossProcessAggregator",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NullMetric",
    "RoundTimeline",
    "RunReporter",
    "ScrapeServer",
    "Span",
    "SpanTracer",
    "counter",
    "default_registry",
    "default_tracer",
    "enable_telemetry",
    "gauge",
    "histogram",
    "instant",
    "span",
    "start_scrape_server",
]


def counter(name: str, help: str = "", unit: str = "", **labels):
    """Counter from the default registry (NULL sink when disabled)."""
    return default_registry().counter(name, help=help, unit=unit, **labels)


def gauge(name: str, help: str = "", unit: str = "", **labels):
    """Gauge from the default registry (NULL sink when disabled)."""
    return default_registry().gauge(name, help=help, unit=unit, **labels)


def histogram(name: str, buckets=DEFAULT_BUCKETS, help: str = "", unit: str = "", **labels):
    """Histogram from the default registry (NULL sink when disabled)."""
    return default_registry().histogram(
        name, buckets=buckets, help=help, unit=unit, **labels
    )


def span(name: str, cat: str = "", **args):
    """Span context manager on the default tracer (NULL_SPAN when disabled)."""
    return default_tracer().span(name, cat=cat, **args)


def instant(name: str, cat: str = "", **args) -> None:
    """Instant event on the default tracer (no-op when disabled)."""
    default_tracer().instant(name, cat=cat, **args)
