"""Run reporter: metrics.json / trace.json / DGAP round audit (DESIGN.md §13.3).

Two pieces:

  * :class:`RoundTimeline` — the per-epoch DGAP round audit accumulator the
    streaming executor feeds one entry per protocol round: per-round
    durations, alignment targets, per-rank statuses (from which the
    straggler census is computed), join/non-join closure events.  It is
    JSON-round-trippable and rides inside stream checkpoints, so a resumed
    run's audit continues the interrupted one instead of restarting at zero.
  * :class:`RunReporter` — serializes the registry snapshot
    (``metrics.json``), the tracer ring (``trace.json``, Chrome trace-event
    schema) and the round timeline (``rounds.json``) into one telemetry
    directory; ``launch/train.py --telemetry DIR`` and ``launch/serve.py
    --telemetry DIR`` drive it, and CI asserts over the emitted files.

Straggler semantics: a rank *straggles* in a round when it reports
"insufficient data" (status 0) while the round still aligned a non-zero
target from the other ranks — exactly the rounds where DGAP's S_min+/C_min+
rule is what keeps the step from stalling on the slow rank.
"""

from __future__ import annotations

import json
import pathlib

from repro.obs.metrics import MetricsRegistry, default_registry
from repro.obs.trace import SpanTracer, default_tracer

__all__ = [
    "ROUND_DURATION_BUCKETS",
    "RoundTimeline",
    "RunReporter",
    "enable_telemetry",
]

# Protocol rounds are pure-python bookkeeping: microseconds to low
# milliseconds on CPU.  Seconds-scale bins catch pathological stalls.
ROUND_DURATION_BUCKETS = (
    1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4,
    1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2, 0.1, 0.5, 1.0,
)


class RoundTimeline:
    """Bounded per-epoch DGAP round audit (checkpoint-serializable)."""

    def __init__(self, world_size: int, keep_records: int = 4096) -> None:
        self.world_size = world_size
        self.keep_records = keep_records
        self.rounds = 0
        self.emitted_views = 0
        self.duration_sum_s = 0.0
        self.max_duration_s = 0.0
        # Straggler census: rounds each rank sat at status 0 while the
        # alignment target was non-zero (see module docstring).
        self.straggler_rounds = [0] * world_size
        # Cumulative duration histogram on the shared bucket grid.
        self.duration_buckets = [0] * (len(ROUND_DURATION_BUCKETS) + 1)
        self.closures: list[dict] = []
        # Abort census: one entry per epoch abort, carrying the *full*
        # failed-rank list (a multi-rank stall is the common failure mode on
        # real fabrics; reporting only the first rank hides the blast
        # radius from stream_abort.json and the post-mortem).
        self.aborts: list[dict] = []
        # Rolling window of the most recent per-round records (bounded so a
        # long epoch cannot grow the checkpoint without bound).
        self.records: list[dict] = []
        self.records_dropped = 0

    # -- feeding ---------------------------------------------------------------
    def record_round(self, record, duration_s: float, iteration: int) -> None:
        """Absorb one :class:`repro.core.protocol.RoundRecord`."""
        self.rounds += 1
        self.emitted_views += record.emitted_views
        self.duration_sum_s += duration_s
        self.max_duration_s = max(self.max_duration_s, duration_s)
        bin_idx = 0
        for bound in ROUND_DURATION_BUCKETS:
            if duration_s <= bound:
                break
            bin_idx += 1
        self.duration_buckets[bin_idx] += 1
        if record.target > 0:
            for rank, status in enumerate(record.statuses):
                if rank < self.world_size and status == 0:
                    self.straggler_rounds[rank] += 1
        self.records.append(
            {
                "round": record.round_index,
                "iteration": iteration,
                "duration_s": duration_s,
                "target": record.target,
                "emitted_views": record.emitted_views,
                "statuses": list(record.statuses),
                "potential": record.potential,
            }
        )
        if len(self.records) > self.keep_records:
            del self.records[0]
            self.records_dropped += 1

    def record_closure(self, event: str, iteration: int, rounds: int) -> None:
        """One iteration-termination event (join/non-join/quota crossing)."""
        self.closures.append(
            {"event": event, "iteration": iteration, "iteration_rounds": rounds}
        )

    def record_abort(
        self,
        failed_ranks,
        *,
        round_index: int | None = None,
        attempts: int = 0,
        reason: str = "",
    ) -> None:
        """One epoch abort with its complete straggler casualty list."""
        self.aborts.append(
            {
                "failed_ranks": sorted(set(int(r) for r in failed_ranks)),
                "round_index": round_index,
                "attempts": attempts,
                "reason": reason,
            }
        )

    # -- views / serialization -------------------------------------------------
    def as_dict(self) -> dict:
        hist = {}
        running = 0
        for bound, n in zip(ROUND_DURATION_BUCKETS, self.duration_buckets):
            running += n
            hist[repr(bound)] = running
        hist["+Inf"] = self.rounds
        return {
            "world_size": self.world_size,
            "rounds": self.rounds,
            "emitted_views": self.emitted_views,
            "duration_sum_s": self.duration_sum_s,
            "max_duration_s": self.max_duration_s,
            "straggler_rounds_per_rank": list(self.straggler_rounds),
            "duration_histogram_le": hist,
            "closures": list(self.closures),
            "aborts": list(self.aborts),
            "records": list(self.records),
            "records_dropped": self.records_dropped,
        }

    @classmethod
    def from_dict(cls, state: dict) -> "RoundTimeline":
        timeline = cls(state["world_size"])
        timeline.rounds = state["rounds"]
        timeline.emitted_views = state["emitted_views"]
        timeline.duration_sum_s = state["duration_sum_s"]
        timeline.max_duration_s = state["max_duration_s"]
        timeline.straggler_rounds = list(state["straggler_rounds_per_rank"])
        # Invert the cumulative serialized form back to per-bin counts.
        cum = state["duration_histogram_le"]
        previous = 0
        for i, bound in enumerate(ROUND_DURATION_BUCKETS):
            running = int(cum.get(repr(bound), previous))
            timeline.duration_buckets[i] = running - previous
            previous = running
        timeline.duration_buckets[-1] = timeline.rounds - previous
        timeline.closures = list(state["closures"])
        timeline.aborts = list(state.get("aborts", []))
        timeline.records = list(state["records"])
        timeline.records_dropped = state.get("records_dropped", 0)
        return timeline


class RunReporter:
    """Serialize one run's telemetry into ``<dir>/{metrics,trace,rounds}.json``."""

    def __init__(
        self,
        out_dir,
        registry: MetricsRegistry | None = None,
        tracer: SpanTracer | None = None,
    ) -> None:
        self.out_dir = pathlib.Path(out_dir)
        self.registry = registry if registry is not None else default_registry()
        self.tracer = tracer if tracer is not None else default_tracer()

    def _write_json(self, name: str, payload: dict) -> pathlib.Path:
        self.out_dir.mkdir(parents=True, exist_ok=True)
        path = self.out_dir / name
        path.write_text(json.dumps(payload, indent=1, sort_keys=True))
        return path

    def write_metrics(self, extra: dict | None = None) -> pathlib.Path:
        """``metrics.json``: the flat view (CI keys) + the full snapshot."""
        payload = {
            "flat": self.registry.flat(),
            "families": self.registry.snapshot(),
        }
        if extra:
            payload["run"] = extra
        return self._write_json("metrics.json", payload)

    def write_prometheus(self) -> pathlib.Path:
        self.out_dir.mkdir(parents=True, exist_ok=True)
        path = self.out_dir / "metrics.prom"
        path.write_text(self.registry.prometheus_text())
        return path

    def write_trace(self) -> pathlib.Path:
        return self.tracer.write(self.out_dir / "trace.json")

    def write_rounds(self, round_audit: "RoundTimeline | dict") -> pathlib.Path:
        if isinstance(round_audit, RoundTimeline):
            round_audit = round_audit.as_dict()
        return self._write_json("rounds.json", round_audit)

    def write(
        self,
        round_audit: "RoundTimeline | dict | None" = None,
        extra: dict | None = None,
    ) -> dict[str, str]:
        """Emit every artifact; returns name → path written."""
        paths = {
            "metrics": str(self.write_metrics(extra)),
            "prometheus": str(self.write_prometheus()),
            "trace": str(self.write_trace()),
        }
        if round_audit is not None:
            paths["rounds"] = str(self.write_rounds(round_audit))
        return paths


def enable_telemetry(
    out_dir,
    registry: MetricsRegistry | None = None,
    tracer: SpanTracer | None = None,
) -> RunReporter:
    """Switch the (default) registry + tracer on and return a reporter.

    The one call a launcher makes for ``--telemetry DIR`` — before building
    the instrumented objects, so construction-time cached instruments bind
    to live metrics rather than the disabled-mode null sink.
    """
    reporter = RunReporter(out_dir, registry=registry, tracer=tracer)
    reporter.registry.enable()
    reporter.tracer.enable()
    return reporter
