"""Live Prometheus scrape endpoint (DESIGN.md §13; ROADMAP PR-6 follow-on).

``metrics.prom`` is written at exit; long runs want to be scraped *while*
training.  ``ScrapeServer`` is a stdlib ``ThreadingHTTPServer`` on a daemon
thread serving ``GET /metrics`` straight from the process-default registry's
``prometheus_text()`` — no new dependencies, no background work between
requests, and ``stop()`` shuts the listener down and joins the thread so
launchers exit cleanly (tested by tests/test_obs.py).

The registry is resolved *per request*, not at construction: a launcher may
start the server before ``enable_telemetry`` swaps instruments live, and the
scrape must always reflect the current default registry.
"""

from __future__ import annotations

import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer


class ScrapeServer:
    """Serve ``registry.prometheus_text()`` over HTTP until ``stop()``.

    ``port=0`` binds an ephemeral port (tests); read the bound port back
    from ``.port`` after ``start()``.
    """

    def __init__(self, registry=None, host: str = "127.0.0.1", port: int = 0):
        self._registry = registry
        self._host = host
        self._requested_port = port
        self._server: ThreadingHTTPServer | None = None
        self._thread: threading.Thread | None = None

    def _text(self) -> str:
        registry = self._registry
        if registry is None:
            from repro.obs.metrics import default_registry

            registry = default_registry()
        return registry.prometheus_text()

    def start(self) -> "ScrapeServer":
        if self._server is not None:
            return self
        outer = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 - stdlib API
                if self.path.split("?")[0].rstrip("/") in ("", "/metrics"):
                    body = outer._text().encode()
                    self.send_response(200)
                    self.send_header(
                        "Content-Type", "text/plain; version=0.0.4; charset=utf-8"
                    )
                    self.send_header("Content-Length", str(len(body)))
                    self.end_headers()
                    self.wfile.write(body)
                else:
                    self.send_response(404)
                    self.end_headers()

            def log_message(self, *args):  # silence per-request stderr noise
                pass

        self._server = ThreadingHTTPServer(
            (self._host, self._requested_port), Handler
        )
        self._server.daemon_threads = True
        self._thread = threading.Thread(
            target=self._server.serve_forever,
            name="obs-scrape",
            daemon=True,
        )
        self._thread.start()
        return self

    @property
    def port(self) -> int:
        assert self._server is not None, "start() first"
        return self._server.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self._host}:{self.port}/metrics"

    def stop(self, timeout: float = 5.0) -> None:
        """Shut the listener down and join the serving thread. Idempotent."""
        server, thread = self._server, self._thread
        self._server = None
        self._thread = None
        if server is not None:
            server.shutdown()
            server.server_close()
        if thread is not None:
            thread.join(timeout)


def start_scrape_server(port: int, registry=None, host: str = "127.0.0.1") -> ScrapeServer:
    """Launcher-facing one-liner: bind, start, return the running server."""
    return ScrapeServer(registry=registry, host=host, port=port).start()
