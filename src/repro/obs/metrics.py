"""Dependency-free metrics registry (DESIGN.md §13.1).

Three instrument kinds — :class:`Counter` (monotone), :class:`Gauge`
(last-write), :class:`Histogram` (explicit buckets + sum/count) — organized
into named *families* with optional labels, all owned by a
:class:`MetricsRegistry`.  The registry is the single source of truth for
every runtime quantity the repo reports: the admission window, the DGAP
protocol, the batch-layout engine, the trainer step split, the serving
engine and the kernels all write here, and ``metrics.json`` / the stdout log
line / the Prometheus text exposition are *views* of one snapshot.

Design constraints (the reason this is hand-rolled rather than a client
library):

  * **cheap when disabled** — a disabled registry hands every caller the one
    shared :data:`NULL` sink whose methods are no-ops: no allocation, no
    lock, no dict; instrumented hot paths (one counter ``inc`` per admitted
    view, per protocol round, per tick) cost a single attribute call;
  * **cheap when enabled** — instruments are plain-slot objects mutated
    without locking on the hot path (CPython attribute stores are atomic;
    cross-thread visibility is all these need).  Only family *creation* and
    snapshotting take the registry lock;
  * **checkpoint-serializable** — ``state()``/``load_state()`` round-trip
    every instrument through plain JSON types, so stream checkpoints carry
    continuous counters across preemption (stream/state.py);
  * **bounded cardinality** — labeled families cap their child count
    (``max_label_children``); past the cap, new label sets get the NULL sink
    and ``obs_dropped_series_total`` counts the drop, so an accidental
    per-request label in serving cannot grow registry memory without bound;
  * **cross-process mergeable** — :class:`CrossProcessAggregator` folds
    ``state()`` dumps shipped by other processes (prefetch workers,
    multi-host windows) into this registry: counters and histograms merge by
    *delta* against the last dump from the same source (so periodic
    re-shipping never double-counts), gauges are last-write-by-timestamp.

Metric names follow the Prometheus convention (``snake_case``, ``_total``
suffix on counters, base units in the name); the stable catalog lives in
DESIGN.md §13.
"""

from __future__ import annotations

import bisect
import threading

__all__ = [
    "NULL",
    "Counter",
    "CrossProcessAggregator",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NullMetric",
    "default_registry",
]

#: Family name of the cardinality-budget drop counter (itself unlabeled, so
#: it can never be the victim of the cap it enforces).
DROPPED_SERIES = "obs_dropped_series_total"

#: Default per-family labeled-child budget.  Generous for every legitimate
#: label in the catalog (layout names, worker ids, shape cells) while
#: bounding the damage of an accidental per-request label.
DEFAULT_MAX_LABEL_CHILDREN = 256

# Generic latency buckets (seconds) — callers with tighter distributions
# (protocol rounds, TTFT) pass their own explicit grids.
DEFAULT_BUCKETS = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025,
    0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)


class NullMetric:
    """The shared no-op sink a disabled registry returns (zero allocation)."""

    __slots__ = ()

    def inc(self, amount: float = 1) -> None:
        pass

    def dec(self, amount: float = 1) -> None:
        pass

    def set(self, value: float) -> None:
        pass

    def observe(self, value: float) -> None:
        pass

    @property
    def value(self) -> float:
        return 0.0


NULL = NullMetric()


class Counter:
    """Monotonically increasing count (float increments allowed: seconds)."""

    __slots__ = ("value",)
    kind = "counter"

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, amount: float = 1) -> None:
        if amount < 0:
            raise ValueError(f"counter increment must be >= 0, got {amount}")
        self.value += amount

    def sample(self) -> dict:
        return {"value": self.value}

    def load(self, state: dict) -> None:
        self.value = float(state["value"])


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("value",)
    kind = "gauge"

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1) -> None:
        self.value += amount

    def dec(self, amount: float = 1) -> None:
        self.value -= amount

    def sample(self) -> dict:
        return {"value": self.value}

    def load(self, state: dict) -> None:
        self.value = float(state["value"])


class Histogram:
    """Explicit-bucket histogram: per-bin counts plus running sum/count.

    ``counts[i]`` is the number of observations with
    ``bounds[i-1] < v <= bounds[i]`` (``counts[-1]`` is the +Inf overflow
    bin); the snapshot/exposition re-derive the Prometheus *cumulative*
    ``le`` form from these.
    """

    __slots__ = ("bounds", "counts", "sum", "count")
    kind = "histogram"

    def __init__(self, buckets=DEFAULT_BUCKETS) -> None:
        bounds = tuple(float(b) for b in buckets)
        if not bounds or list(bounds) != sorted(set(bounds)):
            raise ValueError(f"buckets must be strictly increasing: {buckets}")
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        self.counts[bisect.bisect_left(self.bounds, value)] += 1
        self.sum += value
        self.count += 1

    def cumulative(self) -> list[tuple[str, int]]:
        """Prometheus-style (le, cumulative count) pairs ending at +Inf."""
        out = []
        running = 0
        for bound, n in zip(self.bounds, self.counts):
            running += n
            out.append((format_float(bound), running))
        out.append(("+Inf", self.count))
        return out

    def sample(self) -> dict:
        return {
            "count": self.count,
            "sum": self.sum,
            "buckets": {le: n for le, n in self.cumulative()},
        }

    def load(self, state: dict) -> None:
        self.count = int(state["count"])
        self.sum = float(state["sum"])
        # Invert the serialized cumulative form back to per-bin counts.
        cum = state["buckets"]
        previous = 0
        for i, bound in enumerate(self.bounds):
            le = format_float(bound)
            running = int(cum.get(le, previous))
            self.counts[i] = running - previous
            previous = running
        self.counts[-1] = self.count - previous


def format_float(v: float) -> str:
    """Canonical bucket-bound / label rendering (no trailing zeros)."""
    return repr(int(v)) if float(v).is_integer() else repr(v)


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricFamily:
    """One named metric plus its labeled children."""

    def __init__(self, name: str, kind: str, help: str, unit: str, buckets) -> None:
        self.name = name
        self.kind = kind
        self.help = help
        self.unit = unit
        self.buckets = buckets
        self.children: dict[tuple[tuple[str, str], ...], object] = {}

    def child(self, labels: tuple[tuple[str, str], ...]):
        metric = self.children.get(labels)
        if metric is None:
            cls = _KINDS[self.kind]
            metric = cls(self.buckets) if self.kind == "histogram" else cls()
            self.children[labels] = metric
        return metric


def _label_key(labels: dict) -> tuple[tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_suffix(key: tuple[tuple[str, str], ...]) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


class MetricsRegistry:
    """Named metric families; snapshot-to-dict + Prometheus exposition."""

    def __init__(
        self,
        enabled: bool = True,
        max_label_children: int | None = DEFAULT_MAX_LABEL_CHILDREN,
    ) -> None:
        self.enabled = enabled
        # Cardinality budget (DESIGN.md §13): per-family cap on *labeled*
        # children; None = unbounded.  The unlabeled child is always allowed.
        self.max_label_children = max_label_children
        self._families: dict[str, MetricFamily] = {}
        self._lock = threading.Lock()

    # -- enablement ------------------------------------------------------------
    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        """Hand out :data:`NULL` from now on; existing instruments keep their
        values (re-enable to resume recording through fresh lookups)."""
        self.enabled = False

    # -- instrument accessors --------------------------------------------------
    def _get(self, name: str, kind: str, help: str, unit: str, buckets, labels):
        if not self.enabled:
            return NULL
        with self._lock:
            family = self._families.get(name)
            if family is None:
                family = MetricFamily(name, kind, help, unit, buckets)
                self._families[name] = family
            elif family.kind != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {family.kind}, "
                    f"requested {kind}"
                )
            key = _label_key(labels)
            if (
                key
                and self.max_label_children is not None
                and key not in family.children
                and sum(1 for k in family.children if k) >= self.max_label_children
            ):
                # Over budget: this label set never materializes.  Count the
                # drop on the (unlabeled, hence uncappable) drop counter.
                dropped = self._families.get(DROPPED_SERIES)
                if dropped is None:
                    dropped = MetricFamily(
                        DROPPED_SERIES, "counter",
                        "label sets refused by the per-family cardinality cap",
                        "", None,
                    )
                    self._families[DROPPED_SERIES] = dropped
                dropped.child(()).inc()
                return NULL
            return family.child(key)

    def counter(self, name: str, help: str = "", unit: str = "", **labels):
        return self._get(name, "counter", help, unit, None, labels)

    def gauge(self, name: str, help: str = "", unit: str = "", **labels):
        return self._get(name, "gauge", help, unit, None, labels)

    def histogram(
        self, name: str, buckets=DEFAULT_BUCKETS, help: str = "",
        unit: str = "", **labels,
    ):
        return self._get(name, "histogram", help, unit, buckets, labels)

    # -- views -----------------------------------------------------------------
    def snapshot(self) -> dict:
        """Structured dict of every family (the ``metrics.json`` payload)."""
        with self._lock:
            out = {}
            for name in sorted(self._families):
                family = self._families[name]
                out[name] = {
                    "type": family.kind,
                    "help": family.help,
                    "unit": family.unit,
                    "samples": [
                        {"labels": dict(key), **family.children[key].sample()}
                        for key in sorted(family.children)
                    ],
                }
            return out

    def flat(self) -> dict[str, float]:
        """Flat ``name{labels} -> value`` view (CI checks, log lines).

        Histograms flatten to ``<name>_count`` and ``<name>_sum``.
        """
        out: dict[str, float] = {}
        with self._lock:
            for name in sorted(self._families):
                family = self._families[name]
                for key in sorted(family.children):
                    metric = family.children[key]
                    suffix = _label_suffix(key)
                    if family.kind == "histogram":
                        out[f"{name}_count{suffix}"] = metric.count
                        out[f"{name}_sum{suffix}"] = metric.sum
                    else:
                        out[f"{name}{suffix}"] = metric.value
        return out

    def prometheus_text(self) -> str:
        """Prometheus text exposition format 0.0.4 (deterministic order)."""
        lines: list[str] = []
        with self._lock:
            for name in sorted(self._families):
                family = self._families[name]
                if family.help:
                    lines.append(f"# HELP {name} {family.help}")
                if family.unit:
                    lines.append(f"# UNIT {name} {family.unit}")
                lines.append(f"# TYPE {name} {family.kind}")
                for key in sorted(family.children):
                    metric = family.children[key]
                    if family.kind == "histogram":
                        for le, n in metric.cumulative():
                            le_key = key + (("le", le),)
                            lines.append(
                                f"{name}_bucket{_label_suffix(le_key)} {n}"
                            )
                        suffix = _label_suffix(key)
                        lines.append(
                            f"{name}_sum{suffix} {format_float(metric.sum)}"
                        )
                        lines.append(f"{name}_count{suffix} {metric.count}")
                    else:
                        lines.append(
                            f"{name}{_label_suffix(key)} "
                            f"{format_float(metric.value)}"
                        )
        return "\n".join(lines) + "\n"

    # -- checkpoint round-trip (stream/state.py) -------------------------------
    def state(self, prefix: str | tuple[str, ...] = "") -> dict:
        """JSON-serializable dump of families whose name matches ``prefix``."""
        with self._lock:
            out = {}
            for name, family in self._families.items():
                if prefix and not name.startswith(prefix):
                    continue
                out[name] = {
                    "type": family.kind,
                    "help": family.help,
                    "unit": family.unit,
                    "buckets": list(family.buckets) if family.buckets else None,
                    "children": [
                        [list(map(list, key)), family.children[key].sample()]
                        for key in sorted(family.children)
                    ],
                }
            return out

    def load_state(self, state: dict) -> None:
        """Restore instruments dumped by :meth:`state` (resume path).

        Existing same-name instruments are overwritten — a resumed run
        *continues* the checkpointed counters rather than double-counting.
        """
        if not self.enabled or not state:
            return
        for name, fam_state in state.items():
            buckets = fam_state.get("buckets") or DEFAULT_BUCKETS
            for key_lists, sample in fam_state["children"]:
                labels = {k: v for k, v in key_lists}
                kind = fam_state["type"]
                if kind == "histogram":
                    metric = self.histogram(
                        name, buckets=tuple(buckets),
                        help=fam_state.get("help", ""),
                        unit=fam_state.get("unit", ""), **labels,
                    )
                else:
                    accessor = self.counter if kind == "counter" else self.gauge
                    metric = accessor(
                        name, help=fam_state.get("help", ""),
                        unit=fam_state.get("unit", ""), **labels,
                    )
                metric.load(sample)

    def reset(self) -> None:
        """Drop every family (test isolation)."""
        with self._lock:
            self._families.clear()


class CrossProcessAggregator:
    """Merge ``MetricsRegistry.state()`` dumps from other processes.

    Each producing process (a prefetch worker, a remote host's window) ships
    its *cumulative* registry state periodically, tagged with a source id and
    a wall-clock timestamp.  Merging is idempotent per dump and safe under
    re-shipping:

      * **counters** — the parent counter is incremented by the delta against
        the previous dump from the same source; a value below the previous
        one means the source restarted, so the full new value is the delta;
      * **gauges** — last-write-by-timestamp across all sources (a stale
        worker dump never overwrites a fresher one);
      * **histograms** — per-bin count deltas (plus sum/count deltas) are
        added onto the parent histogram with matching buckets.

    Families whose kinds collide with an existing parent family are skipped
    rather than raising: a misbehaving worker must not take down the trainer.
    """

    def __init__(self, registry: "MetricsRegistry | None" = None) -> None:
        self.registry = registry
        self._counter_last: dict[tuple, float] = {}
        self._hist_last: dict[tuple, dict] = {}
        self._gauge_ts: dict[tuple, float] = {}

    def _target(self) -> "MetricsRegistry":
        return self.registry or default_registry()

    def merge(self, source: str, state: dict, timestamp: float) -> None:
        registry = self._target()
        if not registry.enabled or not state:
            return
        for name, fam_state in state.items():
            kind = fam_state.get("type")
            if kind not in _KINDS:
                continue
            buckets = fam_state.get("buckets")
            for key_lists, sample in fam_state.get("children", []):
                labels = {k: v for k, v in key_lists}
                try:
                    self._merge_child(
                        registry, source, name, kind, buckets, labels,
                        sample, timestamp,
                        help=fam_state.get("help", ""),
                        unit=fam_state.get("unit", ""),
                    )
                except ValueError:
                    # Kind collision with a parent family: skip, don't raise.
                    continue

    def _merge_child(
        self, registry, source, name, kind, buckets, labels, sample,
        timestamp, *, help, unit,
    ) -> None:
        ident = (name, tuple(sorted(labels.items())))
        if kind == "counter":
            metric = registry.counter(name, help=help, unit=unit, **labels)
            last = self._counter_last.get((source, *ident), 0.0)
            value = float(sample["value"])
            delta = value - last if value >= last else value  # restart
            if delta > 0:
                metric.inc(delta)
            self._counter_last[(source, *ident)] = value
        elif kind == "gauge":
            if timestamp >= self._gauge_ts.get(ident, float("-inf")):
                registry.gauge(name, help=help, unit=unit, **labels).set(
                    sample["value"]
                )
                self._gauge_ts[ident] = timestamp
        else:  # histogram
            metric = registry.histogram(
                name, buckets=tuple(buckets or DEFAULT_BUCKETS),
                help=help, unit=unit, **labels,
            )
            if isinstance(metric, NullMetric):
                return
            last = self._hist_last.get(
                (source, *ident), {"count": 0, "sum": 0.0, "buckets": {}}
            )
            if sample["count"] < last["count"]:  # source restarted
                last = {"count": 0, "sum": 0.0, "buckets": {}}
            # Invert both cumulative forms to per-bin counts, add the deltas.
            previous_new = previous_old = 0
            for i, bound in enumerate(metric.bounds):
                le = format_float(bound)
                running_new = int(sample["buckets"].get(le, previous_new))
                running_old = int(last["buckets"].get(le, previous_old))
                metric.counts[i] += (running_new - previous_new) - (
                    running_old - previous_old
                )
                previous_new, previous_old = running_new, running_old
            metric.counts[-1] += (sample["count"] - previous_new) - (
                last["count"] - previous_old
            )
            metric.sum += sample["sum"] - last["sum"]
            metric.count += sample["count"] - last["count"]
            self._hist_last[(source, *ident)] = sample


_DEFAULT = MetricsRegistry(enabled=True)


def default_registry() -> MetricsRegistry:
    """The process-wide registry every instrumented module writes to."""
    return _DEFAULT
