"""Offline oracle baselines: GMT / BMT / HFG + the scalar length cache.

Paper §3.1 / App. I / App. J.  These are favorable comparators: they use a
one-time scalar cache of post-pipeline ``len(input_ids)`` for *batch
construction only* (training still runs the online pipeline); cache
construction cost is excluded from their throughput, and the cache is
invalidated by any (dataset, transform policy, template, cutoff) change.

  * **GMT-oracle** — fairseq-style *global* max-token batching: ascending
    length sort + greedy packing against a max-token budget, feasibility on
    the padded token area ``max_{i∈b} l_i · |b| ≤ budget`` with singleton
    overflows allowed (zero truncation, full coverage).
  * **BMT-oracle** — *bucketed* max-token: epoch-seeded shuffle,
    sample-count buckets, within-bucket length sort, greedy packing, then
    batch shuffle.
  * **HFG-oracle** — HuggingFace ``group_by_length``-style randomized fixed
    batch: random permutation → megabatches → within-megabatch sort by cached
    length → fixed-bs batches.

All are **rank-replicated**: every rank computes the same global batch list,
the list is padded to a multiple of W by wrap-around repetition of the
leading batches (the offline analogue of ODB's padding), and batches are
assigned to ranks by striding — identical step count on every rank by
construction.
"""

from __future__ import annotations

import dataclasses
import random
import time
from typing import Sequence

from repro.core.grouping import Group, Sample
from repro.data.datasets import DatasetSpec
from repro.data.pipeline import PipelinePolicy, realize_lengths


class StaleCacheError(RuntimeError):
    """The scalar cache was built under a different transform policy."""


@dataclasses.dataclass
class LengthCache:
    """One-time scalar cache of post-pipeline len(input_ids) (App. I)."""

    dataset: str
    key: str
    lengths: list[int]
    build_seconds: float

    @classmethod
    def build(
        cls, spec: DatasetSpec, policy: PipelinePolicy | None = None, seed: int = 0
    ) -> "LengthCache":
        policy = policy or spec.policy
        t0 = time.perf_counter()
        lengths = realize_lengths(spec.records(seed), policy, epoch=0)
        return cls(
            dataset=spec.name,
            key=policy.cache_key(spec.name),
            lengths=lengths,
            build_seconds=time.perf_counter() - t0,
        )

    def validate(self, spec: DatasetSpec, policy: PipelinePolicy) -> None:
        """Raise if the policy changed since the cache was built (churn)."""
        if policy.cache_key(spec.name) != self.key:
            raise StaleCacheError(
                f"length cache for {self.dataset!r} was built under a different "
                f"(transform, template, cutoff) policy — rebuild required"
            )


# ---------------------------------------------------------------------------
# Batch-list construction (global, rank-replicated).
# ---------------------------------------------------------------------------


def _greedy_max_token_batches(
    order: list[int], lengths: Sequence[int], budget: int
) -> list[list[int]]:
    """Greedy packing with padded-area feasibility max_l * |b| <= budget.

    Singleton overflows allowed: a sample longer than the budget still forms
    its own batch (zero truncation, full-epoch coverage).
    """
    batches: list[list[int]] = []
    current: list[int] = []
    cur_max = 0
    for idx in order:
        l = lengths[idx]
        new_max = max(cur_max, l)
        if current and new_max * (len(current) + 1) > budget:
            batches.append(current)
            current, cur_max = [], 0
            new_max = l
        current.append(idx)
        cur_max = new_max
    if current:
        batches.append(current)
    return batches


def _pad_and_stride(
    batches: list[list[int]], world_size: int
) -> list[list[list[int]]]:
    """Pad batch list to a multiple of W by wrap-around; stride-assign.

    Returns ``steps[step][rank] -> list of identity indices``.
    """
    if not batches:
        return []
    pad = (-len(batches)) % world_size
    padded = batches + batches[:pad]
    steps = []
    for start in range(0, len(padded), world_size):
        steps.append(padded[start : start + world_size])
    return steps


def _to_group_steps(
    steps: list[list[list[int]]], lengths: Sequence[int]
) -> list[list[Group | None]]:
    out: list[list[Group | None]] = []
    view = 0
    for step in steps:
        row: list[Group | None] = []
        for batch in step:
            samples = []
            for ident in batch:
                samples.append(
                    Sample(view_id=view, identity=ident, length=lengths[ident])
                )
                view += 1
            row.append(Group(samples=tuple(samples)) if samples else None)
        out.append(row)
    return out


def gmt_schedule(
    cache: LengthCache,
    world_size: int,
    max_tokens_budget: int,
) -> list[list[Group | None]]:
    """Global max-token oracle: ascending sort + greedy packing."""
    lengths = cache.lengths
    order = sorted(range(len(lengths)), key=lambda i: lengths[i])
    batches = _greedy_max_token_batches(order, lengths, max_tokens_budget)
    return _to_group_steps(_pad_and_stride(batches, world_size), lengths)


def bmt_schedule(
    cache: LengthCache,
    world_size: int,
    max_tokens_budget: int,
    *,
    bucket_samples: int = 8192,
    seed: int = 0,
    epoch: int = 0,
) -> list[list[Group | None]]:
    """Bucketed max-token oracle: shuffle → buckets → sort → pack → shuffle."""
    lengths = cache.lengths
    rng = random.Random((seed, epoch).__hash__() & 0x7FFFFFFF)
    order = list(range(len(lengths)))
    rng.shuffle(order)
    batches: list[list[int]] = []
    for start in range(0, len(order), bucket_samples):
        bucket = sorted(
            order[start : start + bucket_samples], key=lambda i: lengths[i]
        )
        batches.extend(_greedy_max_token_batches(bucket, lengths, max_tokens_budget))
    rng.shuffle(batches)
    return _to_group_steps(_pad_and_stride(batches, world_size), lengths)


def hfg_schedule(
    cache: LengthCache,
    world_size: int,
    batch_size: int,
    *,
    megabatch_factor: int = 50,
    seed: int = 0,
    epoch: int = 0,
) -> list[list[Group | None]]:
    """HF group_by_length-style randomized fixed-batch oracle (App. J)."""
    lengths = cache.lengths
    rng = random.Random((seed, epoch, "hfg").__hash__() & 0x7FFFFFFF)
    order = list(range(len(lengths)))
    rng.shuffle(order)
    mega = batch_size * megabatch_factor
    reordered: list[int] = []
    for start in range(0, len(order), mega):
        chunk = sorted(order[start : start + mega], key=lambda i: -lengths[i])
        reordered.extend(chunk)
    batches = [
        reordered[i : i + batch_size] for i in range(0, len(reordered), batch_size)
    ]
    return _to_group_steps(_pad_and_stride(batches, world_size), lengths)
