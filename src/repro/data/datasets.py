"""Synthetic corpora with the paper's length statistics (App. I).

Two families:

  * the six 1000-sample synthetic distributions used for correctness audits
    (App. I): uniform-narrow U[64,512], uniform-wide U[64,2048],
    longtail (90% short / 10% long), bimodal (50/50), all-long U[1800,2048],
    all-short U[32,64];

  * clones of the public datasets' *length distributions* (Table 10):
      UltraChat-200K  N=207,865  mean≈1196  CV=0.48  max 4,471  text
      LLaVA-150K      N=157,712  mean≈508   CV=0.29  max 1,260  multimodal
      ShareGPT4o      N= 57,284  mean≈1494  CV=1.00  max 12,110 multimodal
      MM-Mix          N=272,589  CV≈0.8 bimodal, f_s≈0.37       multimodal
    generated as RawRecords whose realized lengths (through the online
    pipeline) match the target (mean, CV, max).  Dataset sizes are scalable
    (``scale``) so tests run in seconds while benchmarks can use larger N.

We clone length *distributions*, not content: ODB's behaviour is a pure
function of realized lengths, world size and knobs, so distribution clones
reproduce the batching-system operating points exactly.
"""

from __future__ import annotations

import dataclasses
import math
import random
from typing import Callable

from repro.data.pipeline import PipelinePolicy, RawRecord, realize_lengths


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    size: int
    policy: PipelinePolicy
    make_records: Callable[[int, int], list[RawRecord]]  # (size, seed) -> records
    target_cv: float | None = None
    multimodal: bool = False

    def records(self, seed: int = 0) -> list[RawRecord]:
        return self.make_records(self.size, seed)

    def lengths(self, seed: int = 0, epoch: int = 0) -> list[int]:
        return realize_lengths(self.records(seed), self.policy, epoch)


# ---------------------------------------------------------------------------
# Six synthetic audit distributions (App. I).
# ---------------------------------------------------------------------------


def _records_from_lengths(lengths: list[int]) -> list[RawRecord]:
    """Invert the (augmentation-free) pipeline so realized lengths match.

    With strength=0 the pipeline maps chars -> tokens deterministically per
    identity; we solve chars for the desired token count.
    """
    from repro.data.pipeline import _unit_hash

    records = []
    policy = PipelinePolicy()
    for i, target in enumerate(lengths):
        wobble = 0.9 + 0.2 * _unit_hash("tok", i, policy.tokenizer)
        text_target = max(target - policy.template_tokens_per_turn, 1)
        chars = int(round(text_target * policy.chars_per_token * wobble))
        records.append(RawRecord(identity=i, chars=max(chars, 1), turns=1))
    return records


def _synthetic(name: str, gen: Callable[[random.Random], int], size: int = 1000):
    def make(size_: int, seed: int) -> list[RawRecord]:
        rng = random.Random((name, seed).__hash__() & 0x7FFFFFFF)
        return _records_from_lengths([gen(rng) for _ in range(size_)])

    return DatasetSpec(
        name=name, size=size, policy=PipelinePolicy(cutoff_len=4096), make_records=make
    )


SYNTHETIC_DISTRIBUTIONS = {
    "uniform_narrow": _synthetic("uniform_narrow", lambda r: r.randint(64, 512)),
    "uniform_wide": _synthetic("uniform_wide", lambda r: r.randint(64, 2048)),
    "longtail": _synthetic(
        "longtail",
        lambda r: r.randint(32, 256) if r.random() < 0.9 else r.randint(1024, 4000),
    ),
    "bimodal": _synthetic(
        "bimodal",
        lambda r: r.randint(64, 160) if r.random() < 0.5 else r.randint(1200, 2048),
    ),
    "all_long": _synthetic("all_long", lambda r: r.randint(1800, 2048)),
    "all_short": _synthetic("all_short", lambda r: r.randint(32, 64)),
}


# ---------------------------------------------------------------------------
# Public dataset length-distribution clones (Table 10).
# ---------------------------------------------------------------------------


def _lognormal_lengths(
    rng: random.Random, n: int, mean: float, cv: float, lo: int, hi: int
) -> list[int]:
    sigma2 = math.log(1.0 + cv * cv)
    mu = math.log(mean) - sigma2 / 2.0
    sigma = math.sqrt(sigma2)
    out = []
    for _ in range(n):
        l = int(round(math.exp(rng.gauss(mu, sigma))))
        out.append(max(lo, min(l, hi)))
    return out


def _clone(name, size, mean, cv, lo, hi, cutoff, multimodal=False):
    def make(size_: int, seed: int) -> list[RawRecord]:
        rng = random.Random((name, seed).__hash__() & 0x7FFFFFFF)
        lengths = _lognormal_lengths(rng, size_, mean, cv, lo, hi)
        records = _records_from_lengths(lengths)
        if multimodal:
            # Shift ~35% of tokens into image patches for a third of samples
            # (keeps total length; makes lengths depend on visual expansion).
            out = []
            policy = PipelinePolicy(cutoff_len=cutoff)
            for rec, tgt in zip(records, lengths):
                if rng.random() < 0.33 and tgt > 128:
                    img_tokens = int(tgt * 0.35)
                    pixels = int(img_tokens / policy.visual_tokens_per_megapixel * 1e6)
                    txt_tokens = tgt - img_tokens
                    txt = _records_from_lengths([txt_tokens])[0]
                    out.append(
                        RawRecord(
                            identity=rec.identity,
                            chars=txt.chars,
                            turns=1,
                            image_pixels=pixels,
                        )
                    )
                else:
                    out.append(rec)
            records = out
        return records

    return DatasetSpec(
        name=name,
        size=size,
        policy=PipelinePolicy(cutoff_len=cutoff),
        make_records=make,
        target_cv=cv,
        multimodal=multimodal,
    )


DATASET_CLONES = {
    "ultrachat": _clone("ultrachat", 207_865, 1196.0, 0.48, 16, 4471, 8192),
    "llava": _clone("llava", 157_712, 508.0, 0.29, 32, 1260, 2048, multimodal=True),
    "sharegpt4o": _clone(
        "sharegpt4o", 57_284, 1494.0, 1.00, 16, 12_110, 16_384, multimodal=True
    ),
}


def _make_mmmix(size_: int, seed: int) -> list[RawRecord]:
    # Bimodal production mix (App. I): 45% short OCR/VQA labels, 30% mid
    # VQA/caption, 25% long-form captioning; calibrated to CV≈0.85.
    rng = random.Random(("mmmix", seed).__hash__() & 0x7FFFFFFF)
    lengths = []
    for _ in range(size_):
        u = rng.random()
        if u < 0.45:  # short OCR / VQA labels
            lengths.append(rng.randint(32, 480))
        elif u < 0.75:  # mid VQA / short captions
            lengths.append(rng.randint(480, 2200))
        else:  # long-form captioning / dialogue
            lengths.append(int(_lognormal_lengths(rng, 1, 2400, 0.30, 800, 12_110)[0]))
    return _records_from_lengths(lengths)


DATASET_CLONES["mmmix"] = DatasetSpec(
    name="mmmix",
    size=272_589,
    policy=PipelinePolicy(cutoff_len=16_384),
    make_records=_make_mmmix,
    target_cv=0.80,
    multimodal=True,
)


def get_dataset(name: str, scale: float = 1.0) -> DatasetSpec:
    """Fetch a dataset spec, optionally scaled down (same distribution)."""
    table = {**SYNTHETIC_DISTRIBUTIONS, **DATASET_CLONES}
    if name not in table:
        raise KeyError(f"unknown dataset {name!r}; have {sorted(table)}")
    spec = table[name]
    if scale == 1.0:
        return spec
    return dataclasses.replace(spec, size=max(int(spec.size * scale), 8))
