"""DistributedSampler(drop_last=False) semantics (paper App. C.1).

Produces a per-rank sampler-view sequence of size ``ceil(N/W)`` after padding
the global shuffled index list to ``M = W * ceil(N/W)`` views and
stride-sharding it across ranks.  The ``P = M - N`` deterministic tail-padding
views cyclically re-use boundary identities so per-rank counts are equal —
the surplus the App. C.6 identity audit checks against
(``W - N mod W`` when ``N % W != 0``).
"""

from __future__ import annotations

import dataclasses
import math
import random
from typing import Sequence

from repro.core.grouping import Sample


# Logical-iteration addressing shared by the eager scheduler (odb_schedule)
# and the streaming executor.  Bit-exact eager/stream equivalence — and the
# validity of existing stream checkpoints — depends on both paths using
# these, never inline literals.
ITERATION_VIEW_ID_STRIDE = 10**9


def iteration_shuffle_epoch(epoch: int, iteration: int) -> int:
    """Shuffle-epoch for logical iteration ``iteration`` of epoch ``epoch``."""
    return epoch * 1000 + iteration


@dataclasses.dataclass(frozen=True)
class SamplerSpec:
    dataset_size: int  # N identities
    world_size: int  # W
    seed: int = 0
    shuffle: bool = True

    @property
    def per_rank_quota(self) -> int:
        return math.ceil(self.dataset_size / self.world_size)

    @property
    def total_views(self) -> int:  # M
        return self.world_size * self.per_rank_quota

    @property
    def padding_views(self) -> int:  # P = M - N
        return self.total_views - self.dataset_size


def global_view_order(spec: SamplerSpec, epoch: int) -> list[int]:
    """Shuffled identity list padded to M by cyclically re-using boundary
    identities (covers the W > N degenerate case too)."""
    ids = list(range(spec.dataset_size))
    if spec.shuffle:
        random.Random((spec.seed, epoch).__hash__() & 0x7FFFFFFF).shuffle(ids)
    pad = spec.total_views - len(ids)
    cyc = (ids * (pad // len(ids) + 1))[:pad] if pad else []
    return ids + cyc


def shard_views(
    spec: SamplerSpec,
    epoch: int,
    lengths: Sequence[int],
    *,
    view_id_base: int = 0,
) -> list[list[Sample]]:
    """Stride-shard the padded view list into per-rank Sample sequences.

    ``lengths[identity]`` is the realized post-pipeline length (supplied by
    the pipeline; the sampler itself never observes lengths — that is the
    paper's observability point).  ``view_id_base`` disambiguates views across
    chained logical iterations.
    """
    order = global_view_order(spec, epoch)
    out: list[list[Sample]] = [[] for _ in range(spec.world_size)]
    for pos, identity in enumerate(order):
        rank = pos % spec.world_size
        out[rank].append(
            Sample(
                view_id=view_id_base + pos,
                identity=identity,
                length=int(lengths[identity]),
            )
        )
    quotas = {len(v) for v in out}
    assert quotas == {spec.per_rank_quota}, quotas
    return out
