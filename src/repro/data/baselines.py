"""Online baselines: Standard, Sorted, Packing (paper §3.1).

All batchers share one output contract so benchmarks compare like-for-like:
``epoch_schedule(...) -> list[list[Group | None]]`` — a list of aligned
steps, each holding one Group (or IDLE None) per rank.  Padding / update
geometry then comes from ``Group`` itself (padded area = size × max_len).

  * Standard — fixed batch size, random sampling.  The per-step padded cost
    is bs × max-length-in-batch.
  * Sorted — online length-grouped fixed batch: sort within a grouping
    buffer, emit fixed-bs batches of adjacent lengths.  (The paper's Sorted
    is the online analogue of HF group_by_length with a runtime buffer.)
  * Packing — sequence packing into fixed token windows; on TPU this is
    contamination-free via the segment-aware Pallas attention kernel, so it
    is a first-class backend here rather than a text-only caveat.  Packed
    "groups" report zero intra-window padding except the final partial
    window.
"""

from __future__ import annotations

import math
import random
from typing import Sequence

from repro.core.grouping import Group, Sample
from repro.data.sampler import SamplerSpec, shard_views


def _per_rank_views(
    lengths: Sequence[int], world_size: int, seed: int, epoch: int
) -> list[list[Sample]]:
    spec = SamplerSpec(dataset_size=len(lengths), world_size=world_size, seed=seed)
    return shard_views(spec, epoch, lengths)


def _steps_from_rank_batches(
    rank_batches: list[list[Group]],
) -> list[list[Group | None]]:
    """Zip per-rank batch lists into aligned steps, padding tails with IDLE."""
    steps = max(len(b) for b in rank_batches)
    out: list[list[Group | None]] = []
    for i in range(steps):
        out.append([b[i] if i < len(b) else None for b in rank_batches])
    return out


def standard_schedule(
    lengths: Sequence[int],
    world_size: int,
    batch_size: int,
    *,
    seed: int = 0,
    epoch: int = 0,
) -> list[list[Group | None]]:
    """Fixed-bs random batching (DDP default).  drop_last=False semantics."""
    views = _per_rank_views(lengths, world_size, seed, epoch)
    rank_batches = []
    for rank_views in views:
        batches = [
            Group(samples=tuple(rank_views[i : i + batch_size]))
            for i in range(0, len(rank_views), batch_size)
        ]
        rank_batches.append(batches)
    return _steps_from_rank_batches(rank_batches)


def sorted_schedule(
    lengths: Sequence[int],
    world_size: int,
    batch_size: int,
    *,
    buffer_size: int = 1024,
    seed: int = 0,
    epoch: int = 0,
) -> list[list[Group | None]]:
    """Online length-grouped fixed batch: sort per buffer window, emit bs."""
    views = _per_rank_views(lengths, world_size, seed, epoch)
    rank_batches = []
    for rank_views in views:
        batches: list[Group] = []
        for start in range(0, len(rank_views), buffer_size):
            window = sorted(
                rank_views[start : start + buffer_size], key=lambda s: s.length
            )
            for i in range(0, len(window), batch_size):
                chunk = window[i : i + batch_size]
                if chunk:
                    batches.append(Group(samples=tuple(chunk)))
        rank_batches.append(batches)
    return _steps_from_rank_batches(rank_batches)


def packing_schedule(
    lengths: Sequence[int],
    world_size: int,
    window_tokens: int,
    *,
    seed: int = 0,
    epoch: int = 0,
) -> list[list[Group | None]]:
    """Greedy sequential packing into fixed token windows (first-fit order).

    Each emitted Group holds the samples packed into one window; its padded
    area is the window size (``window_tokens``) — i.e. only the final partial
    fill of each window is waste.  Downstream, the segment-aware attention
    kernel keeps windows contamination-free.  Samples longer than the window
    get a singleton window (paper keeps cutoff above max length).
    """
    views = _per_rank_views(lengths, world_size, seed, epoch)
    rank_batches = []
    for rank_views in views:
        batches: list[Group] = []
        current: list[Sample] = []
        used = 0
        for s in rank_views:
            if current and used + s.length > window_tokens:
                batches.append(Group(samples=tuple(current)))
                current, used = [], 0
            current.append(s)
            used += s.length
        if current:
            batches.append(Group(samples=tuple(current)))
        rank_batches.append(batches)
    return _steps_from_rank_batches(rank_batches)


def packed_area(group: Group, window_tokens: int) -> int:
    """Compute cost of a packed window (fixed window area)."""
    return window_tokens * math.ceil(group.real_tokens / window_tokens)


def sweep_batch_sizes(
    candidates: Sequence[int] = (1, 2, 4, 8, 16)
) -> tuple[int, ...]:
    """Paper's Standard/Sorted sweep grid (§3.1)."""
    return tuple(candidates)
