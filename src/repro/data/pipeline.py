"""Online preprocessing pipeline simulator (the paper's observability point).

The paper's premise: the true training cost of a sample is realized only
after preprocessing, augmentation, chat templating, tokenization, and
multimodal visual-token expansion.  We model that causal structure explicitly:

  * a ``RawRecord`` carries only *pre-pipeline* attributes (character count,
    image resolution, turn count) — deliberately insufficient to compute the
    realized token length;
  * ``PipelinePolicy`` holds the transform policy (template id, cutoff,
    augmentation seed/strength, visual patch rate).  Any change to the policy
    changes realized lengths, which is exactly the event that invalidates
    offline oracle caches (App. I: "the cache is per-(dataset, transform
    policy, template, cutoff)") — tested in tests/test_oracles.py;
  * ``run_pipeline(record, policy, epoch)`` returns the realized length.
    Augmentation is epoch-dependent when ``policy.augmentation_strength > 0``
    (e.g. audio speed-perturb / image re-crop), the "augmentation-policy
    churn" regime of §1.

The simulator is deterministic given (record, policy, epoch) so audits and
property tests are reproducible.
"""

from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Callable


@dataclasses.dataclass(frozen=True)
class RawRecord:
    identity: int
    chars: int  # raw text size (pre-template, pre-tokenizer)
    turns: int = 1  # chat turns (template overhead multiplier)
    image_pixels: int = 0  # 0 => text-only
    audio_frames: int = 0  # 0 => not audio


@dataclasses.dataclass(frozen=True)
class PipelinePolicy:
    """Transform policy — the oracle cache key (dataset fixed separately)."""

    template: str = "chatml"
    cutoff_len: int = 16384
    chars_per_token: float = 3.6
    template_tokens_per_turn: int = 11
    visual_tokens_per_megapixel: int = 729  # Qwen-VL-style patch expansion
    augmentation_strength: float = 0.0  # 0 = deterministic lengths per epoch
    tokenizer: str = "qwen3"

    def cache_key(self, dataset: str) -> str:
        body = (
            f"{dataset}|{self.template}|{self.cutoff_len}|{self.chars_per_token}"
            f"|{self.template_tokens_per_turn}|{self.visual_tokens_per_megapixel}"
            f"|{self.augmentation_strength}|{self.tokenizer}"
        )
        return hashlib.sha1(body.encode()).hexdigest()[:16]


def _unit_hash(*parts: object) -> float:
    """Deterministic uniform(0,1) from arbitrary parts."""
    h = hashlib.sha1("|".join(str(p) for p in parts).encode()).digest()
    return int.from_bytes(h[:8], "big") / float(1 << 64)


class SampleCorruptionError(RuntimeError):
    """Online realization failed for one sample (poison input, codec error).

    The admission window converts this into a quarantine entry — component
    ``X`` of the extended No-Leak partition (R, Q, B, E, X) — when a
    quarantine budget is configured (DESIGN.md §15); with the default
    strict budget of 0 it propagates like any realization error.
    """


# Chaos injection point (repro.chaos): called at the top of run_pipeline with
# (record, policy, epoch); raising there simulates a poison sample whose
# corruption only manifests once the online pipeline touches it.  None = off.
_FAULT_HOOK: "Callable[[RawRecord, PipelinePolicy, int], None] | None" = None


def set_pipeline_fault_hook(hook) -> "Callable | None":
    """Install (or clear, with None) the pipeline fault hook; returns the
    previous hook so callers can restore it."""
    global _FAULT_HOOK
    previous = _FAULT_HOOK
    _FAULT_HOOK = hook
    return previous


def run_pipeline(record: RawRecord, policy: PipelinePolicy, epoch: int = 0) -> int:
    """Realize the post-pipeline tokenized length of one sample.

    Stages (all length-affecting, mirroring §1):
      1. augmentation — multiplicative jitter drawn per (identity, epoch)
         when strength > 0 (speed perturb / crop / paraphrase);
      2. chat templating — per-turn fixed token overhead;
      3. tokenization — chars / chars_per_token with a per-sample
         tokenizer-efficiency wobble (content-dependent);
      4. visual-token expansion — image pixels → patch tokens;
      5. cutoff — hard clip at ``cutoff_len`` (experiments use cutoffs above
         the realized max, so this is a guardrail, not truncation).
    """
    if _FAULT_HOOK is not None:
        _FAULT_HOOK(record, policy, epoch)
    aug = 1.0
    if policy.augmentation_strength > 0:
        u = _unit_hash("aug", record.identity, epoch, policy.augmentation_strength)
        aug = 1.0 + policy.augmentation_strength * (2.0 * u - 1.0)
    wobble = 0.9 + 0.2 * _unit_hash("tok", record.identity, policy.tokenizer)
    text_tokens = (record.chars * aug) / (policy.chars_per_token * wobble)
    template_tokens = record.turns * policy.template_tokens_per_turn
    visual_tokens = 0.0
    if record.image_pixels > 0:
        crop = 1.0
        if policy.augmentation_strength > 0:
            u = _unit_hash("crop", record.identity, epoch)
            crop = 1.0 - 0.3 * policy.augmentation_strength * u
        visual_tokens = (
            record.image_pixels * crop / 1.0e6
        ) * policy.visual_tokens_per_megapixel
    audio_tokens = record.audio_frames / 2.0  # conv-stem downsample stub
    total = int(round(text_tokens + template_tokens + visual_tokens + audio_tokens))
    return max(1, min(total, policy.cutoff_len))


def realize_lengths(
    records: list[RawRecord], policy: PipelinePolicy, epoch: int = 0
) -> list[int]:
    """Eager full-dataset realization (the offline regime).

    The streaming path deliberately has no list-returning counterpart:
    ``AdmissionWindow`` (DESIGN.md §9.1) calls :func:`run_pipeline` one view
    at a time so peak realized-lengths in flight stays within its lookahead.
    """
    return [run_pipeline(r, policy, epoch) for r in records]


def length_cv(lengths) -> float:
    """CV = sigma / mu — the paper's heterogeneity metric (§1)."""
    n = len(lengths)
    if n == 0:
        return 0.0
    mu = sum(lengths) / n
    var = sum((l - mu) ** 2 for l in lengths) / n
    return math.sqrt(var) / mu if mu > 0 else 0.0


def short_sample_fraction(lengths, l_max: int) -> float:
    """f_s = Pr[l < L_max / 4] — short-sample mass (§4, App. K)."""
    if not lengths:
        return 0.0
    thresh = l_max / 4.0
    return sum(1 for l in lengths if l < thresh) / len(lengths)
