"""Data substrate: sampler, online pipeline, datasets, loaders, baselines."""

from repro.data.baselines import (
    packing_schedule,
    sorted_schedule,
    standard_schedule,
)
from repro.data.datasets import (
    DATASET_CLONES,
    SYNTHETIC_DISTRIBUTIONS,
    DatasetSpec,
    get_dataset,
)
from repro.data.loader import (
    LoaderStep,
    OnlineDynamicLoader,
    odb_schedule,
)
from repro.data.oracles import (
    LengthCache,
    StaleCacheError,
    bmt_schedule,
    gmt_schedule,
    hfg_schedule,
)
from repro.data.pipeline import (
    PipelinePolicy,
    RawRecord,
    length_cv,
    realize_lengths,
    run_pipeline,
    short_sample_fraction,
)
from repro.data.sampler import SamplerSpec, global_view_order, shard_views
