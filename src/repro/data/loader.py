"""OnlineDynamicLoader — the ODB DataLoader wrapper (paper §2.1, §2.4).

Ties the substrate together:

    sampler (identity views)  →  online pipeline (realized lengths)
      →  DGAP protocol engine (grouping + cross-rank alignment)
        →  step-aligned per-rank Groups  →  bucket padding  →  jitted step

The loader exposes two surfaces:

  * ``odb_schedule(...)`` — the benchmark contract shared with baselines
    (list of aligned steps of per-rank Groups/IDLE);
  * ``OnlineDynamicLoader`` — the trainer-facing iterator yielding
    (per-rank PaddedBatch list, StepMetadata) per aligned step, with
    epoch-level audits (Theorems 1/2) available after iteration.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

from repro.core.buckets import (
    BucketSpec,
    PackedBatch,
    PackedBucketSpec,
    PaddedBatch,
    idle_batch,
    pack_group,
    pad_group,
)
from repro.core.grouping import Group
from repro.core.metadata import EmitAccounting, StepMetadata, step_metadata
from repro.core.protocol import IDLE, EpochAudit, OdbConfig, run_epoch
from repro.data.datasets import DatasetSpec
from repro.data.pipeline import PipelinePolicy, realize_lengths
from repro.data.sampler import SamplerSpec, shard_views


def odb_schedule(
    lengths: Sequence[int],
    world_size: int,
    config: OdbConfig,
    *,
    seed: int = 0,
    epoch: int = 0,
    drain_rates: Sequence[int | None] | None = None,
) -> tuple[list[list[Group | None]], EpochAudit]:
    """Run one epoch of the ODB protocol; return aligned steps + audit."""
    spec = SamplerSpec(dataset_size=len(lengths), world_size=world_size, seed=seed)

    def make_views(iteration: int):
        return shard_views(
            spec, epoch * 1000 + iteration, lengths, view_id_base=iteration * 10**9
        )

    steps: list[list[Group | None]] = []
    audit = run_epoch(
        make_views,
        len(lengths),
        config,
        on_step=steps.append,
        drain_rates=drain_rates,
    )
    return steps, audit


@dataclasses.dataclass
class LoaderStep:
    batches: list[PaddedBatch]  # one per rank (IDLE rows are zero batches)
    metadata: StepMetadata


@dataclasses.dataclass
class PackedLoaderStep:
    """Beyond-paper emission mode (DESIGN.md §8a): each rank's group is
    flattened to one segment-id-tagged token stream for the Pallas
    segment-aware attention kernel — padding decays to the single tail
    bucket, merging the paper's ODB and Packing rows without the GPU varlen
    caveat."""

    batches: list[PackedBatch]
    metadata: StepMetadata


class OnlineDynamicLoader:
    """Drop-in iterator over step-aligned, bucket-padded ODB batches.

    Mirrors the paper's API: wraps the (sampler, pipeline, dataset) triple,
    leaves both untouched, and emits per-step metadata for trainer-side
    accounting + token-level loss scaling.  Lengths are realized through the
    online pipeline at iteration time — there is no length precompute.
    """

    def __init__(
        self,
        dataset: DatasetSpec,
        world_size: int,
        config: OdbConfig,
        *,
        bucket_spec: BucketSpec | None = None,
        policy: PipelinePolicy | None = None,
        seed: int = 0,
        vocab_size: int = 32000,
    ) -> None:
        self.dataset = dataset
        self.world_size = world_size
        self.config = config
        self.policy = policy or dataset.policy
        self.seed = seed
        self.vocab_size = vocab_size
        self.bucket_spec = bucket_spec or BucketSpec(
            max_len=self.policy.cutoff_len, max_count=4096
        )
        self.accounting = EmitAccounting()
        self.last_audit: EpochAudit | None = None
        # grid floor stays below the token budget so near-empty tail
        # groups don't inflate to a full window
        self.packed_spec = PackedBucketSpec(
            min_tokens=max(128, config.l_max // 8),
            max_tokens=max(2 * config.l_max, 2048),
        )

    def epoch(self, epoch: int = 0) -> Iterator[LoaderStep]:
        # Online observability: lengths realized per epoch (augmentation-
        # dependent), never cached across policy changes.
        records = self.dataset.records(self.seed)
        lengths = realize_lengths(records, self.policy, epoch)
        steps, audit = odb_schedule(
            lengths, self.world_size, self.config, seed=self.seed, epoch=epoch
        )
        self.last_audit = audit
        fallback_shape = self.bucket_spec.bucket_shape(1, self.bucket_spec.min_len)
        for i, step in enumerate(steps):
            padded: list[PaddedBatch] = []
            shape = None
            for group in step:
                if group is not IDLE:
                    pb = pad_group(group, self.bucket_spec, vocab_size=self.vocab_size)
                    padded.append(pb)
                    shape = pb.shape
            row: list[PaddedBatch] = []
            j = 0
            for group in step:
                if group is IDLE:
                    row.append(idle_batch(shape or fallback_shape))
                else:
                    row.append(padded[j])
                    j += 1
            md = step_metadata(i, step)
            self.accounting.update(md)
            yield LoaderStep(batches=row, metadata=md)

    def packed_epoch(self, epoch: int = 0):
        """Iterate packed-segment steps (beyond-paper emission; see
        PackedLoaderStep).  IDLE ranks emit an all-padding stream."""
        import numpy as np

        records = self.dataset.records(self.seed)
        lengths = realize_lengths(records, self.policy, epoch)
        steps, audit = odb_schedule(
            lengths, self.world_size, self.config, seed=self.seed, epoch=epoch
        )
        self.last_audit = audit
        token_fn = None
        for i, step in enumerate(steps):
            packed = []
            size = None
            for group in step:
                if group is not IDLE:
                    pk = pack_group(group, self.packed_spec)
                    pk = PackedBatch(
                        tokens=pk.tokens % self.vocab_size,
                        segment_ids=pk.segment_ids,
                        positions=pk.positions,
                        loss_mask=pk.loss_mask,
                        real_samples=pk.real_samples,
                        real_tokens=pk.real_tokens,
                    )
                    packed.append(pk)
                    size = pk.tokens.shape[1]
            row = []
            j = 0
            for group in step:
                if group is IDLE:
                    t = size or self.packed_spec.min_tokens
                    row.append(
                        PackedBatch(
                            tokens=np.zeros((1, t), np.int32),
                            segment_ids=np.zeros((1, t), np.int32),
                            positions=np.zeros((1, t), np.int32),
                            loss_mask=np.zeros((1, t), np.float32),
                            real_samples=0,
                            real_tokens=0,
                        )
                    )
                else:
                    row.append(packed[j])
                    j += 1
            md = step_metadata(i, step)
            self.accounting.update(md)
            yield PackedLoaderStep(batches=row, metadata=md)
