"""OnlineDynamicLoader — the ODB DataLoader wrapper (paper §2.1, §2.4).

Ties the substrate together:

    sampler (identity views)  →  online pipeline (realized lengths)
      →  DGAP protocol engine (grouping + cross-rank alignment)
        →  step-aligned per-rank Groups  →  batch layout  →  jitted step

The padded-vs-packed decision is a pluggable :class:`BatchLayout`
(DESIGN.md §10): the loader builds one :class:`DeviceBatch` per rank per
aligned step through whichever layout it was constructed with, so every
downstream consumer (trainer, prefetcher, benchmarks) is layout-agnostic.

The loader exposes two surfaces:

  * ``odb_schedule(...)`` — the benchmark contract shared with baselines
    (list of aligned steps of per-rank Groups/IDLE);
  * ``OnlineDynamicLoader`` — the trainer-facing iterator yielding
    (per-rank DeviceBatch list, StepMetadata) per aligned step, with
    epoch-level audits (Theorems 1/2) available after iteration.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Iterator, Sequence

from repro.core.buckets import BucketSpec, PackedBucketSpec
from repro.core.grouping import Group
from repro.core.layout import (
    BatchLayout,
    DeviceBatch,
    global_batch_arrays,
    make_layout,
)
from repro.core.metadata import EmitAccounting, StepMetadata, step_metadata
from repro.core.protocol import IDLE, EpochAudit, OdbConfig, run_epoch
from repro.data.datasets import DatasetSpec
from repro.data.pipeline import PipelinePolicy, realize_lengths
from repro.data.sampler import (
    ITERATION_VIEW_ID_STRIDE,
    SamplerSpec,
    iteration_shuffle_epoch,
    shard_views,
)

# NOTE: repro.stream is imported lazily inside streaming_epoch().  A
# module-level import would close an import cycle (stream.executor ->
# repro.data.pipeline -> repro.data.__init__ -> loader -> stream) and make
# `import repro.stream` fail whenever it is the first repro import.


def odb_schedule(
    lengths: Sequence[int],
    world_size: int,
    config: OdbConfig,
    *,
    seed: int = 0,
    epoch: int = 0,
    drain_rates: Sequence[int | None] | None = None,
) -> tuple[list[list[Group | None]], EpochAudit]:
    """Run one epoch of the ODB protocol; return aligned steps + audit."""
    spec = SamplerSpec(dataset_size=len(lengths), world_size=world_size, seed=seed)

    def make_views(iteration: int):
        return shard_views(
            spec,
            iteration_shuffle_epoch(epoch, iteration),
            lengths,
            view_id_base=iteration * ITERATION_VIEW_ID_STRIDE,
        )

    steps: list[list[Group | None]] = []
    audit = run_epoch(
        make_views,
        len(lengths),
        config,
        on_step=steps.append,
        drain_rates=drain_rates,
    )
    return steps, audit


@dataclasses.dataclass
class LoaderStep:
    batches: list[DeviceBatch]  # one per rank (IDLE ranks are zero batches)
    metadata: StepMetadata
    # Optional device-resident global step arrays, populated by the prefetch
    # producer when device-put overlap is enabled (H2D hides under compute).
    device: dict | None = None
    # Worker-path slot handle (DESIGN.md §14): with num_workers > 0 the
    # batch arrays are zero-copy views over a shared-memory ring slot;
    # calling ``release_slot`` recycles the slot.  The loader calls it at
    # the consumer boundary (after the trainer finishes with the step);
    # idempotent, and a no-op on the in-process path.
    release: object = None

    def release_slot(self) -> None:
        if self.release is not None:
            self.release()

    @property
    def device_tokens(self) -> int:
        """Token slots this step occupies on device under its layout."""
        return sum(b.area for b in self.batches)


class OnlineDynamicLoader:
    """Drop-in iterator over step-aligned, bucket-padded ODB batches.

    Mirrors the paper's API: wraps the (sampler, pipeline, dataset) triple,
    leaves both untouched, and emits per-step metadata for trainer-side
    accounting + token-level loss scaling.  Lengths are realized through the
    online pipeline at iteration time — there is no length precompute.
    """

    def __init__(
        self,
        dataset: DatasetSpec,
        world_size: int,
        config: OdbConfig,
        *,
        bucket_spec: BucketSpec | None = None,
        packed_spec: PackedBucketSpec | None = None,
        layout: str | BatchLayout = "dense",
        policy: PipelinePolicy | None = None,
        seed: int = 0,
        vocab_size: int = 32000,
        num_hosts: int = 1,
    ) -> None:
        self.dataset = dataset
        self.world_size = world_size
        self.config = config
        self.policy = policy or dataset.policy
        self.seed = seed
        self.vocab_size = vocab_size
        self.num_hosts = num_hosts
        self.bucket_spec = bucket_spec or BucketSpec(
            max_len=self.policy.cutoff_len, max_count=4096
        )
        self.accounting = EmitAccounting()
        self.last_audit: EpochAudit | None = None
        self.last_executor = None  # StreamExecutor of the last streaming epoch
        self.last_prefetch_stats = None
        self.last_worker_stats = None  # WorkerPoolStats of the last worker epoch
        # Row-capacity grid floor stays well below the token budget so
        # near-empty tail groups don't inflate to a full window; the ceiling
        # must admit the longest realizable sample (one row always fits one
        # sample).  Granularity (floor + alignment) mirrors the dense bucket
        # grid so the padded-vs-packed comparison is apples-to-apples.
        self.packed_spec = packed_spec or PackedBucketSpec(
            min_tokens=max(self.bucket_spec.min_len, config.l_max // 8),
            max_tokens=max(2 * config.l_max, self.policy.cutoff_len, 2048),
            align=self.bucket_spec.align,
        )
        if isinstance(layout, str):
            layout = make_layout(
                layout,
                bucket_spec=self.bucket_spec,
                packed_spec=self.packed_spec,
                vocab_size=vocab_size,
            )
        self.layout = layout

    def _layout_step(self, index: int, step: list[Group | None]) -> LoaderStep:
        """Realize one aligned step through the batch layout (IDLE ranks
        become zero batches of the step shape; all ranks share the planned
        SPMD shape, so ``device_tokens`` is exactly what ships to device).

        Pure: ``accounting`` is updated at the *consumption* point, not here
        — the prefetch producer builds steps the consumer may never take, and
        abandoned staged steps must not count as emitted.
        """
        row = self.layout.build_step(step)
        return LoaderStep(batches=row, metadata=step_metadata(index, step))

    def epoch(
        self, epoch: int = 0, *, device_put: bool = False
    ) -> Iterator[LoaderStep]:
        """Eager path: realize every length, schedule the whole epoch, then
        deliver (the offline regime the streaming path replaces — kept for
        audits and as the equivalence reference).  ``device_put`` stages the
        assembled arrays on device inline (no producer thread to overlap
        with here, but the flag keeps eager/streaming comparisons honest)."""
        records = self.dataset.records(self.seed)
        lengths = realize_lengths(records, self.policy, epoch)
        steps, audit = odb_schedule(
            lengths, self.world_size, self.config, seed=self.seed, epoch=epoch
        )
        self.last_audit = audit
        for i, step in enumerate(steps):
            loader_step = self._layout_step(i, step)
            if device_put:
                loader_step = self._stage_device(loader_step)
            self.accounting.update(
                loader_step.metadata, device_tokens=loader_step.device_tokens
            )
            yield loader_step

    def _stage_device(self, loader_step: LoaderStep) -> LoaderStep:
        """Assemble the global step arrays and issue ``jax.device_put`` —
        runs on the prefetch producer thread so H2D hides under compute."""
        import jax

        arrays = global_batch_arrays(loader_step.batches, self.layout)
        loader_step.device = {k: jax.device_put(v) for k, v in arrays.items()}
        return loader_step

    def streaming_epoch(
        self,
        epoch: int = 0,
        *,
        lookahead: int | None = None,
        prefetch: bool = False,
        prefetch_depth: int = 2,
        device_put: bool = False,
        num_workers: int = 0,
        worker_slots: int | None = None,
        worker_slot_bytes: int | None = None,
        resume_from: "StreamCheckpoint | None" = None,
        finalize_audit: bool = True,
        fault_injector=None,
    ) -> Iterator[LoaderStep]:
        """Online path (DESIGN.md §9): batch formation happens at the point
        where realized lengths become observable.

        Views are admitted through a bounded-lookahead window (at most
        ``lookahead`` realized lengths in flight — defaults to the sampler's
        full view multiset M, which reproduces the eager schedule
        bit-for-bit), protocol rounds interleave with delivery, and with
        ``prefetch=True`` realization + grouping + padding run in a
        background thread, double-buffered against the jitted train step.

        Mid-epoch state is checkpointable: take ``loader.last_executor
        .checkpoint()`` between steps, then pass the checkpoint back as
        ``resume_from`` to continue the identical step sequence.  With
        ``prefetch=True`` the producer runs ahead of the consumer, so to
        checkpoint exactly at the consumer's frontier, close the iterator
        first (with ``finalize_audit=False``) — the staged-but-unconsumed
        tail is rolled back into the executor on close — and checkpoint
        afterwards.  A checkpoint taken while the producer is live is still
        a *consistent* step boundary, but of the producer-side frontier.

        With ``num_workers > 0`` (DESIGN.md §14) the layout realization —
        packing plans, bucket padding, token synthesis — runs in a pool of
        spawn-based worker processes with results returned through
        shared-memory ring slots; protocol rounds stay in-parent (task
        emission via ``executor.next_task()``), so the delivered step stream
        is bit-identical to ``num_workers=0`` and checkpoints are
        worker-count-agnostic (the pool holds no checkpointable state).

        The epoch audit is published to ``last_audit`` when iteration
        completes.
        """
        from repro.stream.executor import StreamExecutor
        from repro.stream.prefetch import PrefetchIterator

        records = self.dataset.records(self.seed)
        if resume_from is not None:
            ck_epoch = resume_from.epoch
            ck_lookahead = resume_from.payload["lookahead"]
            # epoch=0 is the default and means "whatever the checkpoint
            # holds"; any explicit different epoch is a caller error.
            if epoch not in (0, ck_epoch):
                raise ValueError(
                    f"resume_from checkpoint is for epoch {ck_epoch}, "
                    f"but epoch={epoch} was requested"
                )
            if lookahead is not None and lookahead != ck_lookahead:
                raise ValueError(
                    f"resume_from checkpoint was taken with lookahead "
                    f"{ck_lookahead}, but lookahead={lookahead} was requested"
                )
            executor = StreamExecutor.resume(
                resume_from,
                records,
                self.policy,
                fault_injector=fault_injector,
                # Resume at the loader's *current* host count: v4 window
                # state is per-rank, so an elastic host-count change
                # continues the identical step sequence (DESIGN.md §16).
                num_hosts=self.num_hosts,
            )
        else:
            executor = StreamExecutor(
                records,
                self.policy,
                self.world_size,
                self.config,
                seed=self.seed,
                epoch=epoch,
                lookahead=lookahead,
                fault_injector=fault_injector,
                num_hosts=self.num_hosts,
            )
        self.last_executor = executor

        pool = None
        if num_workers and num_workers > 0:
            from repro.stream.workers import DEFAULT_SLOT_BYTES, WorkerPool

            pool = WorkerPool(
                self.layout,
                num_workers,
                slots=worker_slots,
                slot_bytes=worker_slot_bytes or DEFAULT_SLOT_BYTES,
            )
            self.last_worker_stats = pool.stats

        staged: collections.deque[list] = collections.deque()

        def produce(track: bool = False) -> Iterator[LoaderStep]:
            while True:
                step = executor.step()
                if step is None:
                    return
                built = self._layout_step(executor.runner.steps_delivered - 1, step)
                if track:
                    staged.append(step)
                yield built

        def produce_pool(track: bool = False) -> Iterator[LoaderStep]:
            # Pump loop: keep the pool's task queue fed (one free shm slot
            # per submission = the backpressure bound), then deliver the
            # next in-order result.  Steps are staged at *submission* so an
            # abandoned epoch can roll every unconsumed step back into the
            # executor — submission order equals delivery order (seq-ordered
            # reorder buffer), so the staged deque's tail is exactly the
            # undelivered suffix.
            del track  # the pool path always tracks (it always runs ahead)
            done = False
            while True:
                while not done and pool.can_submit():
                    task = executor.next_task()
                    if task is None:
                        done = True
                        break
                    pool.submit(*task)
                    staged.append(task[1])
                if done and not pool.inflight:
                    return
                res = pool.take()
                if res is None:
                    continue
                yield LoaderStep(
                    batches=res.batches,
                    metadata=step_metadata(res.index, res.step),
                    release=res.release,
                )

        def stage_release(built: LoaderStep) -> LoaderStep:
            # Worker path + device_put: once global_batch_arrays has copied
            # the host views into the assembled step arrays, the shm slot
            # can recycle immediately — no need to hold it to the consumer
            # boundary (batches keep only shapes/metadata after this).
            built = self._stage_device(built)
            built.release_slot()
            return built

        source = produce_pool if pool is not None else produce

        try:
            if prefetch:
                stage = None
                if device_put:
                    stage = self._stage_device if pool is None else stage_release
                it = PrefetchIterator(
                    source(track=True),
                    depth=prefetch_depth,
                    stage=stage,
                )
                self.last_prefetch_stats = it.stats
                try:
                    for built in it:
                        staged.popleft()  # consumed: off the rollback ledger
                        self.accounting.update(
                            built.metadata, device_tokens=built.device_tokens
                        )
                        yield built
                        built.release_slot()  # consumer boundary: recycle shm
                finally:
                    # Blocks until the producer's in-flight step finishes
                    # (bounded by the protocol termination envelope) — the
                    # rollback below is only sound with the producer stopped.
                    it.close()
                    if pool is not None:
                        pool.close()
                    # Rewind the executor to the consumer's frontier: the
                    # producer ran ahead, and the staged-but-unconsumed tail
                    # would otherwise be counted delivered yet never trained
                    # on — a silent coverage gap across checkpoint/resume.
                    if staged:
                        executor.requeue(list(staged))
                        staged.clear()
            else:
                track = pool is not None
                try:
                    for built in source(track=track):
                        if track:
                            staged.popleft()
                        if device_put:
                            built = self._stage_device(built)
                        self.accounting.update(
                            built.metadata, device_tokens=built.device_tokens
                        )
                        yield built
                        built.release_slot()
                finally:
                    if pool is not None:
                        pool.close()
                    if staged:
                        executor.requeue(list(staged))
                        staged.clear()
        finally:
            if pool is not None:
                pool.close()
            # Epoch-level audit contract (Theorem 1): even when the consumer
            # stops early (max_steps), finish the remaining *data-side*
            # schedule — grouping/alignment only, no padding, no compute — so
            # ``last_audit`` reflects the full epoch exactly like the eager
            # path.  ``finalize_audit=False`` skips the drain for callers
            # that must exit promptly (preemption after a checkpoint): they
            # hold the executor (``last_executor``) and its checkpoint, and
            # ``last_audit`` then reflects only the delivered prefix.
            # An aborted epoch (EpochAborted, DESIGN.md §15.4) must not be
            # drained — the executor latched after an unrecoverable round
            # fault and every further step() re-raises; the caller recovers
            # via the abort checkpoint, and last_audit reflects the prefix.
            if finalize_audit and not executor.aborted:
                while executor.step() is not None:
                    pass
            self.last_audit = executor.audit()
