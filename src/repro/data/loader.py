"""OnlineDynamicLoader — the ODB DataLoader wrapper (paper §2.1, §2.4).

Ties the substrate together:

    sampler (identity views)  →  online pipeline (realized lengths)
      →  DGAP protocol engine (grouping + cross-rank alignment)
        →  step-aligned per-rank Groups  →  bucket padding  →  jitted step

The loader exposes two surfaces:

  * ``odb_schedule(...)`` — the benchmark contract shared with baselines
    (list of aligned steps of per-rank Groups/IDLE);
  * ``OnlineDynamicLoader`` — the trainer-facing iterator yielding
    (per-rank PaddedBatch list, StepMetadata) per aligned step, with
    epoch-level audits (Theorems 1/2) available after iteration.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Iterator, Sequence

from repro.core.buckets import (
    BucketSpec,
    PackedBatch,
    PackedBucketSpec,
    PaddedBatch,
    idle_batch,
    pack_group,
    pad_group,
)
from repro.core.grouping import Group
from repro.core.metadata import EmitAccounting, StepMetadata, step_metadata
from repro.core.protocol import IDLE, EpochAudit, OdbConfig, run_epoch
from repro.data.datasets import DatasetSpec
from repro.data.pipeline import PipelinePolicy, realize_lengths
from repro.data.sampler import (
    ITERATION_VIEW_ID_STRIDE,
    SamplerSpec,
    iteration_shuffle_epoch,
    shard_views,
)

# NOTE: repro.stream is imported lazily inside streaming_epoch().  A
# module-level import would close an import cycle (stream.executor ->
# repro.data.pipeline -> repro.data.__init__ -> loader -> stream) and make
# `import repro.stream` fail whenever it is the first repro import.


def odb_schedule(
    lengths: Sequence[int],
    world_size: int,
    config: OdbConfig,
    *,
    seed: int = 0,
    epoch: int = 0,
    drain_rates: Sequence[int | None] | None = None,
) -> tuple[list[list[Group | None]], EpochAudit]:
    """Run one epoch of the ODB protocol; return aligned steps + audit."""
    spec = SamplerSpec(dataset_size=len(lengths), world_size=world_size, seed=seed)

    def make_views(iteration: int):
        return shard_views(
            spec,
            iteration_shuffle_epoch(epoch, iteration),
            lengths,
            view_id_base=iteration * ITERATION_VIEW_ID_STRIDE,
        )

    steps: list[list[Group | None]] = []
    audit = run_epoch(
        make_views,
        len(lengths),
        config,
        on_step=steps.append,
        drain_rates=drain_rates,
    )
    return steps, audit


@dataclasses.dataclass
class LoaderStep:
    batches: list[PaddedBatch]  # one per rank (IDLE rows are zero batches)
    metadata: StepMetadata


@dataclasses.dataclass
class PackedLoaderStep:
    """Beyond-paper emission mode (see DESIGN.md §8a "Packed-segment
    emission"): each rank's group is flattened to one segment-id-tagged token
    stream for the Pallas segment-aware attention kernel — padding decays to
    the single tail bucket, merging the paper's ODB and Packing rows without
    the GPU varlen caveat."""

    batches: list[PackedBatch]
    metadata: StepMetadata


class OnlineDynamicLoader:
    """Drop-in iterator over step-aligned, bucket-padded ODB batches.

    Mirrors the paper's API: wraps the (sampler, pipeline, dataset) triple,
    leaves both untouched, and emits per-step metadata for trainer-side
    accounting + token-level loss scaling.  Lengths are realized through the
    online pipeline at iteration time — there is no length precompute.
    """

    def __init__(
        self,
        dataset: DatasetSpec,
        world_size: int,
        config: OdbConfig,
        *,
        bucket_spec: BucketSpec | None = None,
        policy: PipelinePolicy | None = None,
        seed: int = 0,
        vocab_size: int = 32000,
    ) -> None:
        self.dataset = dataset
        self.world_size = world_size
        self.config = config
        self.policy = policy or dataset.policy
        self.seed = seed
        self.vocab_size = vocab_size
        self.bucket_spec = bucket_spec or BucketSpec(
            max_len=self.policy.cutoff_len, max_count=4096
        )
        self.accounting = EmitAccounting()
        self.last_audit: EpochAudit | None = None
        self.last_executor = None  # StreamExecutor of the last streaming epoch
        self.last_prefetch_stats = None
        # grid floor stays below the token budget so near-empty tail
        # groups don't inflate to a full window
        self.packed_spec = PackedBucketSpec(
            min_tokens=max(128, config.l_max // 8),
            max_tokens=max(2 * config.l_max, 2048),
        )

    def _pad_step(self, index: int, step: list[Group | None]) -> LoaderStep:
        """Bucket-pad one aligned step (IDLE ranks become zero batches).

        Pure: ``accounting`` is updated at the *consumption* point, not here
        — the prefetch producer pads steps the consumer may never take, and
        abandoned staged steps must not count as emitted.
        """
        fallback_shape = self.bucket_spec.bucket_shape(1, self.bucket_spec.min_len)
        padded: list[PaddedBatch] = []
        shape = None
        for group in step:
            if group is not IDLE:
                pb = pad_group(group, self.bucket_spec, vocab_size=self.vocab_size)
                padded.append(pb)
                shape = pb.shape
        row: list[PaddedBatch] = []
        j = 0
        for group in step:
            if group is IDLE:
                row.append(idle_batch(shape or fallback_shape))
            else:
                row.append(padded[j])
                j += 1
        return LoaderStep(batches=row, metadata=step_metadata(index, step))

    def epoch(self, epoch: int = 0) -> Iterator[LoaderStep]:
        """Eager path: realize every length, schedule the whole epoch, then
        deliver (the offline regime the streaming path replaces — kept for
        audits and as the equivalence reference)."""
        records = self.dataset.records(self.seed)
        lengths = realize_lengths(records, self.policy, epoch)
        steps, audit = odb_schedule(
            lengths, self.world_size, self.config, seed=self.seed, epoch=epoch
        )
        self.last_audit = audit
        for i, step in enumerate(steps):
            loader_step = self._pad_step(i, step)
            self.accounting.update(loader_step.metadata)
            yield loader_step

    def streaming_epoch(
        self,
        epoch: int = 0,
        *,
        lookahead: int | None = None,
        prefetch: bool = False,
        prefetch_depth: int = 2,
        resume_from: "StreamCheckpoint | None" = None,
        finalize_audit: bool = True,
    ) -> Iterator[LoaderStep]:
        """Online path (DESIGN.md §9): batch formation happens at the point
        where realized lengths become observable.

        Views are admitted through a bounded-lookahead window (at most
        ``lookahead`` realized lengths in flight — defaults to the sampler's
        full view multiset M, which reproduces the eager schedule
        bit-for-bit), protocol rounds interleave with delivery, and with
        ``prefetch=True`` realization + grouping + padding run in a
        background thread, double-buffered against the jitted train step.

        Mid-epoch state is checkpointable: take ``loader.last_executor
        .checkpoint()`` between steps, then pass the checkpoint back as
        ``resume_from`` to continue the identical step sequence.  With
        ``prefetch=True`` the producer runs ahead of the consumer, so to
        checkpoint exactly at the consumer's frontier, close the iterator
        first (with ``finalize_audit=False``) — the staged-but-unconsumed
        tail is rolled back into the executor on close — and checkpoint
        afterwards.  A checkpoint taken while the producer is live is still
        a *consistent* step boundary, but of the producer-side frontier.

        The epoch audit is published to ``last_audit`` when iteration
        completes.
        """
        from repro.stream.executor import StreamExecutor
        from repro.stream.prefetch import PrefetchIterator

        records = self.dataset.records(self.seed)
        if resume_from is not None:
            ck_epoch = resume_from.epoch
            ck_lookahead = resume_from.payload["lookahead"]
            # epoch=0 is the default and means "whatever the checkpoint
            # holds"; any explicit different epoch is a caller error.
            if epoch not in (0, ck_epoch):
                raise ValueError(
                    f"resume_from checkpoint is for epoch {ck_epoch}, "
                    f"but epoch={epoch} was requested"
                )
            if lookahead is not None and lookahead != ck_lookahead:
                raise ValueError(
                    f"resume_from checkpoint was taken with lookahead "
                    f"{ck_lookahead}, but lookahead={lookahead} was requested"
                )
            executor = StreamExecutor.resume(resume_from, records, self.policy)
        else:
            executor = StreamExecutor(
                records,
                self.policy,
                self.world_size,
                self.config,
                seed=self.seed,
                epoch=epoch,
                lookahead=lookahead,
            )
        self.last_executor = executor

        staged: collections.deque[list] = collections.deque()

        def produce(track: bool = False) -> Iterator[LoaderStep]:
            while True:
                step = executor.step()
                if step is None:
                    return
                padded = self._pad_step(executor.runner.steps_delivered - 1, step)
                if track:
                    staged.append(step)
                yield padded

        try:
            if prefetch:
                it = PrefetchIterator(produce(track=True), depth=prefetch_depth)
                self.last_prefetch_stats = it.stats
                try:
                    for padded in it:
                        staged.popleft()  # consumed: off the rollback ledger
                        self.accounting.update(padded.metadata)
                        yield padded
                finally:
                    # Blocks until the producer's in-flight step finishes
                    # (bounded by the protocol termination envelope) — the
                    # rollback below is only sound with the producer stopped.
                    it.close()
                    # Rewind the executor to the consumer's frontier: the
                    # producer ran ahead, and the staged-but-unconsumed tail
                    # would otherwise be counted delivered yet never trained
                    # on — a silent coverage gap across checkpoint/resume.
                    if staged:
                        executor.requeue(list(staged))
                        staged.clear()
            else:
                for padded in produce():
                    self.accounting.update(padded.metadata)
                    yield padded
        finally:
            # Epoch-level audit contract (Theorem 1): even when the consumer
            # stops early (max_steps), finish the remaining *data-side*
            # schedule — grouping/alignment only, no padding, no compute — so
            # ``last_audit`` reflects the full epoch exactly like the eager
            # path.  ``finalize_audit=False`` skips the drain for callers
            # that must exit promptly (preemption after a checkpoint): they
            # hold the executor (``last_executor``) and its checkpoint, and
            # ``last_audit`` then reflects only the delivered prefix.
            if finalize_audit:
                while executor.step() is not None:
                    pass
            self.last_audit = executor.audit()

    def packed_epoch(self, epoch: int = 0):
        """Iterate packed-segment steps (beyond-paper emission; see
        PackedLoaderStep).  IDLE ranks emit an all-padding stream."""
        import numpy as np

        records = self.dataset.records(self.seed)
        lengths = realize_lengths(records, self.policy, epoch)
        steps, audit = odb_schedule(
            lengths, self.world_size, self.config, seed=self.seed, epoch=epoch
        )
        self.last_audit = audit
        token_fn = None
        for i, step in enumerate(steps):
            packed = []
            size = None
            for group in step:
                if group is not IDLE:
                    pk = pack_group(group, self.packed_spec)
                    pk = PackedBatch(
                        tokens=pk.tokens % self.vocab_size,
                        segment_ids=pk.segment_ids,
                        positions=pk.positions,
                        loss_mask=pk.loss_mask,
                        real_samples=pk.real_samples,
                        real_tokens=pk.real_tokens,
                    )
                    packed.append(pk)
                    size = pk.tokens.shape[1]
            row = []
            j = 0
            for group in step:
                if group is IDLE:
                    t = size or self.packed_spec.min_tokens
                    row.append(
                        PackedBatch(
                            tokens=np.zeros((1, t), np.int32),
                            segment_ids=np.zeros((1, t), np.int32),
                            positions=np.zeros((1, t), np.int32),
                            loss_mask=np.zeros((1, t), np.float32),
                            real_samples=0,
                            real_tokens=0,
                        )
                    )
                else:
                    row.append(packed[j])
                    j += 1
            md = step_metadata(i, step)
            self.accounting.update(md)
            yield PackedLoaderStep(batches=row, metadata=md)
