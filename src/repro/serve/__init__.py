"""Continuous-batching serving engine on the ODB admission core (DESIGN.md §12)."""

from repro.serve.engine import ContinuousBatchingEngine, ServeConfig, ServeStats
from repro.serve.requests import (
    EVICTED,
    FINISHED,
    QUEUED,
    RUNNING,
    SHED,
    WAITING,
    Request,
    RequestWindow,
    synth_request_trace,
)
from repro.serve.slots import SlotManager

__all__ = [
    "ContinuousBatchingEngine",
    "EVICTED",
    "FINISHED",
    "QUEUED",
    "RUNNING",
    "Request",
    "RequestWindow",
    "ServeConfig",
    "ServeStats",
    "SHED",
    "SlotManager",
    "WAITING",
    "synth_request_trace",
]
