"""Continuous-batching serving engine on the ODB admission core (DESIGN.md §12).

The ROADMAP observation made real: the incremental admission loop the
trainer runs (bounded-lookahead realization + greedy ``l_max`` token-budget
grouping) *is* a continuous-batching scheduler.  One engine tick is

  1. **admit** — pull realized requests from the :class:`RequestWindow`
     (lookahead-bounded, exactly the training backpressure), form an
     admission cohort with :func:`repro.core.grouping.greedy_group` under the
     budget headroom ``l_max − Σ projected(in-flight)``, and allocate one KV
     slot per admitted request;
  2. **prefill** — pack the cohort's prompts into one segment-masked stream
     (``PackedLayout`` planning, PR 2) and run the slot-scatter prefill (the
     packed flash path, PR 3), which lands every request's K/V in its slot
     and returns each cohort member's first token;
  3. **decode** — one fixed-shape ``(num_slots, 1)`` step over *all* resident
     requests at their individual cache frontiers; completions free slots
     that the next tick's admission refills.

Compile-once contract: the decode step traces exactly once per engine, the
prefill once per occupied ``(rows, capacity)`` bucket — admission, eviction
and slot reuse never change a device shape (tests/test_serve.py guards the
trace counters; benchmarks/serving.py records them).

``continuous=False`` degrades the same machinery to classic static batching
— admit only into an *empty* engine, then drain the whole batch — which is
the baseline the serving benchmark measures against.
"""

from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.buckets import PackedBucketSpec
from repro.core.grouping import Group, Sample, greedy_group
from repro.core.layout import PackedLayout
from repro.launch.shapes import ServeCell
from repro.launch.steps import build_serve_decode_step, build_serve_prefill_step
from repro.models.model import LM
from repro.serve.requests import (
    EVICTED,
    FINISHED,
    RUNNING,
    SHED,
    Request,
    RequestWindow,
)
from repro.serve.slots import SlotManager


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Engine knobs; shape-relevant fields mirror a ``ServeCell``."""

    num_slots: int = 8  # decode rows == KV slots
    max_len: int = 256  # per-slot KV capacity
    l_max: int = 1024  # shared admission token budget (Eq. 1 reused)
    lookahead: int = 32  # realized-but-unscheduled request bound
    continuous: bool = True  # False = static batching baseline
    prefill_min_tokens: int = 64  # packed prefill stream bucket floor
    # Engine-wide queueing TTL (DESIGN.md §15.7): a request still waiting for
    # a slot this many seconds after submission is shed at admission time
    # instead of scheduled into a batch whose caller already gave up.  None
    # disables shedding; per-request Request.ttl_s overrides.
    default_ttl_s: float | None = None

    def cell(self, name: str = "serve") -> ServeCell:
        return ServeCell(name, self.num_slots, self.max_len, self.l_max)

    def prefill_spec(self) -> PackedBucketSpec:
        # max_rows = num_slots: worst case every cohort member needs its own
        # row, so a plan always exists for any cohort the admission rule can
        # form (each prompt fits one row of the widest capacity).
        return PackedBucketSpec(
            min_tokens=self.prefill_min_tokens,
            max_tokens=self.max_len,
            max_rows=self.num_slots,
        )


@dataclasses.dataclass
class ServeStats:
    ticks: int = 0
    decode_steps: int = 0
    prefill_calls: int = 0
    admitted: int = 0
    finished: int = 0
    evicted: int = 0
    shed: int = 0  # TTL-expired while waiting; never occupied a slot
    generated_tokens: int = 0
    # max Σ projected over any tick; ≤ l_max under continuous admission (the
    # static baseline packs slots-only, deliberately ignoring the budget)
    peak_projected_tokens: int = 0
    peak_active_slots: int = 0
    slot_decode_occupancy: float = 0.0  # Σ active / (decode_steps · num_slots)
    _occupied_rows: int = 0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.pop("_occupied_rows")
        return d


class ContinuousBatchingEngine:
    """Slot-cache continuous batching over a live request queue."""

    def __init__(
        self,
        model: LM,
        params,
        config: ServeConfig,
        *,
        mesh=None,
        time_fn=time.perf_counter,
        step_cache: dict | None = None,
    ) -> None:
        cfg = model.cfg
        if not cfg.has_decode:
            raise ValueError(f"{cfg.name} is encoder-only: nothing to serve")
        if cfg.attn_kind == "mla" or any(
            cfg.layer_kind(l) != "attn" for l in range(cfg.n_layers)
        ):
            raise NotImplementedError(
                "the slot-scatter prefill path serves GQA-attention stacks; "
                "MLA/SSM archs stay on the per-request prefill loop "
                "(DESIGN.md §12)"
            )
        self.model = model
        self.params = params
        self.config = config
        self.time_fn = time_fn
        self.cell = config.cell()
        self.window = RequestWindow(lookahead=config.lookahead)
        self.slots = SlotManager(config.num_slots, config.max_len)
        self.waiting: list[Sample] = []
        self.requests: dict[int, Request] = {}
        self.stats = ServeStats()
        self._next_rid = 0
        self._mesh = mesh
        self._layout = PackedLayout(spec=config.prefill_spec())
        self.caches = model.init_caches(config.num_slots, config.max_len)
        # ``step_cache`` lets engines over the same (model, cell) share
        # compiled steps — e.g. a warmup engine pre-compiling for a timed
        # benchmark run, or the static-baseline engine reusing the continuous
        # engine's decode.  The trace counters travel with the cached entry,
        # so the compile-once contract is asserted *across* sharing engines.
        self._step_cache = step_cache if step_cache is not None else {}
        key = ("decode", config.num_slots, config.max_len)
        if key not in self._step_cache:
            fn, _, traces = build_serve_decode_step(model, mesh, self.cell)
            self._step_cache[key] = (fn, traces)
        self._decode_fn, self._decode_traces = self._step_cache[key]
        # Telemetry (DESIGN.md §13): instruments cached once per engine.
        self._m_ticks = obs.counter("serve_ticks_total", help="engine scheduler ticks")
        self._m_admitted = obs.counter(
            "serve_admitted_total", help="requests admitted into KV slots"
        )
        self._m_finished = obs.counter(
            "serve_finished_total", help="requests completed"
        )
        self._m_evicted = obs.counter("serve_evicted_total", help="requests evicted")
        self._m_shed = obs.counter(
            "odb_serve_shed_total",
            help="requests shed at admission because their queueing TTL expired",
        )
        self._m_occupancy = obs.gauge(
            "serve_slot_occupancy", help="active KV slots / num_slots after last tick"
        )
        self._m_queue_depth = obs.gauge(
            "serve_queue_depth",
            help="waiting pool + undelivered submissions after last tick",
        )
        self._m_ttft = obs.histogram(
            "serve_ttft_seconds",
            help="submit-to-first-token latency",
            unit="seconds",
        )

    # -- observability ---------------------------------------------------------
    @property
    def decode_traces(self) -> int:
        """Times XLA traced the decode step (compile-once contract: 1)."""
        return self._decode_traces["count"]

    @property
    def prefill_traces(self) -> dict[tuple[int, int], int]:
        """Per-(rows, cap) bucket trace counts (compile-once: 1 each).

        Scoped to THIS engine's cell: a shared ``step_cache`` may hold
        buckets for other (num_slots, max_len) cells whose identical
        (rows, cap) display keys would otherwise shadow each other.
        """
        own = ("prefill", self.config.num_slots, self.config.max_len)
        return {
            key[-1]: traces["count"]
            for key, (_, traces) in self._step_cache.items()
            if key[:3] == own
        }

    @property
    def done(self) -> bool:
        return (
            self.window.exhausted(0)
            and not self.waiting
            and self.slots.active_count == 0
        )

    # -- request lifecycle -----------------------------------------------------
    def submit(
        self,
        prompt,
        max_new_tokens: int,
        *,
        eos_id: int | None = None,
        ttl_s: float | None = None,
    ) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.shape[0] == 0:
            raise ValueError("empty prompt")
        if max_new_tokens <= 0:
            raise ValueError(f"max_new_tokens must be positive, got {max_new_tokens}")
        cost = int(prompt.shape[0]) + max_new_tokens
        limit = min(self.config.l_max, self.config.max_len)
        if cost > limit:
            raise ValueError(
                f"request projects {cost} tokens > "
                f"min(l_max, max_len) = {limit}: it could never be admitted"
            )
        rid = self._next_rid
        self._next_rid += 1
        request = Request(
            rid=rid,
            prompt=prompt,
            max_new_tokens=max_new_tokens,
            eos_id=eos_id,
            ttl_s=ttl_s,
            submitted_s=self.time_fn(),
        )
        self.requests[rid] = request
        self.window.submit(request)
        return rid

    def evict(self, rid: int) -> Request:
        """Cancel a resident request; its slot frees for the next admission."""
        request = self.requests[rid]
        if request.state != RUNNING or request.slot is None:
            raise ValueError(f"request {rid} is not running ({request.state})")
        self.slots.release(request.slot)
        request.state = EVICTED
        request.finished_s = self.time_fn()
        self.stats.evicted += 1
        self._m_evicted.inc()
        return request

    def _finish(self, request: Request) -> None:
        self.slots.release(request.slot)
        request.state = FINISHED
        request.finished_s = self.time_fn()
        self.stats.finished += 1
        self._m_finished.inc()

    # -- admission (tick phase 1) ----------------------------------------------
    def _shed_expired(self) -> None:
        """Drop waiting-pool requests whose queueing TTL has lapsed (§15.7).

        Load shedding happens at the admission boundary only: a request that
        reached RUNNING keeps its slot (mid-decode cancellation is
        :meth:`evict`, a caller decision).  Under saturation this is what
        keeps the queue from growing without bound — every tick either admits
        work or retires expired work, so the engine always terminates on a
        closed queue even when the offered load exceeds capacity.
        """
        if not self.waiting:
            return
        now = self.time_fn()
        kept: list[Sample] = []
        for sample in self.waiting:
            request = sample.payload
            ttl = (
                request.ttl_s
                if request.ttl_s is not None
                else self.config.default_ttl_s
            )
            if ttl is not None and now - request.submitted_s > ttl:
                request.state = SHED
                request.finished_s = now
                self.stats.shed += 1
                self._m_shed.inc()
            else:
                kept.append(sample)
        self.waiting = kept

    def _admit(self) -> list[Sample]:
        # Hold a grouping pool of up to 2·num_slots realized requests; the
        # window's lookahead bounds realization no matter how greedy this is.
        want = 2 * self.config.num_slots - len(self.waiting)
        if want > 0:
            self.waiting.extend(self.window.take(0, want))
        # Shed before any early return: under full-slot saturation (free==0,
        # the regime §15.7 exists for) expired waiters must still retire this
        # tick, or a saturated engine never drains its queue and the
        # closed-queue termination claim fails.
        self._shed_expired()
        if not self.config.continuous and self.slots.active_count > 0:
            return []  # static batching: drain fully before refilling
        free = self.slots.free_count
        if free == 0:
            return []
        if not self.waiting:
            return []
        if not self.config.continuous:
            cohort = self.waiting[:free]  # arrival order, slots-only rule
            self.waiting = self.waiting[free:]
            return cohort
        budget = self.config.l_max - self.slots.projected_in_flight()
        cohort: list[Sample] = []
        # Greedy token-budget grouping (§2.2) orders the pool longest-first
        # under the same B(l) threshold-carry rule training uses; admission
        # walks that order and stops at the first request the remaining
        # budget cannot hold (head-of-line blocking, so budget-starved long
        # requests are never overtaken forever).
        for group in greedy_group(self.waiting, self.config.l_max):
            for sample in group.samples:
                if len(cohort) >= free or sample.length > budget:
                    taken = {s.view_id for s in cohort}
                    self.waiting = [
                        s for s in self.waiting if s.view_id not in taken
                    ]
                    return cohort
                cohort.append(sample)
                budget -= sample.length
        taken = {s.view_id for s in cohort}
        self.waiting = [s for s in self.waiting if s.view_id not in taken]
        return cohort

    # -- prefill (tick phase 2) ------------------------------------------------
    def _prefill_fn(self, shape: tuple[int, int]):
        key = ("prefill", self.config.num_slots, self.config.max_len, shape)
        if key not in self._step_cache:
            fn, _, traces = build_serve_prefill_step(
                self.model, self._mesh, self.cell, shape[0], shape[1]
            )
            self._step_cache[key] = (fn, traces)
        return self._step_cache[key][0]

    def _prefill(self, cohort: list[Sample]) -> None:
        num_slots = self.config.num_slots
        for sample in cohort:
            self.slots.alloc(sample.payload)
        # Reservation high-water mark: sampled here, before completions can
        # release budget later in the same tick (a 1-token cohort would
        # otherwise read back as zero in-flight).
        self.stats.peak_projected_tokens = max(
            self.stats.peak_projected_tokens, self.slots.projected_in_flight()
        )
        # Plan the packed stream over *prompt* lengths (what prefill ships),
        # not the projected costs admission budgeted (prompt + decode room).
        prompts = tuple(
            dataclasses.replace(s, length=s.payload.prompt_len) for s in cohort
        )
        cap, rows = self._layout.plan_rows(Group(samples=prompts))
        n_rows = self._layout.spec.bucket_rows(len(rows))
        tokens = np.zeros((n_rows, cap), np.int32)
        positions = np.zeros((n_rows, cap), np.int32)
        segments = np.zeros((n_rows, cap), np.int32)
        # Padding stream positions scatter to row ``num_slots`` — one past the
        # cache — and are dropped device-side.
        dest = np.full((n_rows, cap), num_slots, np.int32)
        gather_rows = np.zeros((num_slots,), np.int32)
        gather_cols = np.zeros((num_slots,), np.int32)
        live = np.zeros((num_slots,), bool)
        for r, row in enumerate(rows):
            cursor = 0
            for seg_id, sample in enumerate(row, start=1):
                request = sample.payload
                end = cursor + sample.length
                tokens[r, cursor:end] = request.prompt
                positions[r, cursor:end] = np.arange(sample.length, dtype=np.int32)
                segments[r, cursor:end] = seg_id
                dest[r, cursor:end] = request.slot
                gather_rows[request.slot] = r
                gather_cols[request.slot] = end - 1
                live[request.slot] = True
                cursor = end
        fn = self._prefill_fn((n_rows, cap))
        picked, self.caches = fn(
            self.params, self.caches,
            jnp.asarray(tokens), jnp.asarray(positions), jnp.asarray(segments),
            jnp.asarray(dest), jnp.asarray(gather_rows), jnp.asarray(gather_cols),
        )
        first = np.asarray(jnp.argmax(picked, axis=-1), np.int32)
        now = self.time_fn()
        for sample in cohort:
            request = sample.payload
            request.state = RUNNING
            request.first_token_s = now
            self._m_ttft.observe(now - request.submitted_s)
            token = int(first[request.slot])
            request.generated = [token]
            self.slots.lengths[request.slot] = request.prompt_len
            self.slots.last_token[request.slot] = token
            self.stats.generated_tokens += 1
            if self._is_complete(request, token):
                self._finish(request)
        self.stats.prefill_calls += 1
        self.stats.admitted += len(cohort)

    def _is_complete(self, request: Request, token: int) -> bool:
        if len(request.generated) >= request.max_new_tokens:
            return True
        return request.eos_id is not None and token == request.eos_id

    # -- decode (tick phase 3) -------------------------------------------------
    def _decode(self) -> None:
        active = self.slots.active()
        if not active:
            return
        nxt, self.caches = self._decode_fn(
            self.params, self.caches,
            jnp.asarray(self.slots.last_token[:, None]),
            jnp.asarray(self.slots.lengths),
        )
        nxt = np.asarray(nxt, np.int32)
        for slot, request in active:
            # The fed token's K/V is cached now; the frontier advances.
            self.slots.lengths[slot] += 1
            token = int(nxt[slot, 0])
            request.generated.append(token)
            self.slots.last_token[slot] = token
            self.stats.generated_tokens += 1
            if self._is_complete(request, token):
                self._finish(request)
        self.stats.decode_steps += 1
        self.stats._occupied_rows += len(active)
        total = self.stats.decode_steps * self.config.num_slots
        self.stats.slot_decode_occupancy = self.stats._occupied_rows / total

    # -- scheduler -------------------------------------------------------------
    def tick(self) -> None:
        with obs.span("serve/tick", cat="serve", tick=self.stats.ticks):
            with obs.span("serve/admit", cat="serve"):
                cohort = self._admit()
            if cohort:
                with obs.span("serve/prefill", cat="serve", cohort=len(cohort)):
                    self._prefill(cohort)
                self._m_admitted.inc(len(cohort))
            with obs.span("serve/decode", cat="serve"):
                self._decode()
        self.stats.ticks += 1
        self._m_ticks.inc()
        self._m_occupancy.set(self.slots.active_count / self.config.num_slots)
        self._m_queue_depth.set(len(self.waiting) + self.window.remaining(0))
        self.stats.peak_projected_tokens = max(
            self.stats.peak_projected_tokens, self.slots.projected_in_flight()
        )
        self.stats.peak_active_slots = max(
            self.stats.peak_active_slots, self.slots.active_count
        )

    def run(self, *, close: bool = True) -> dict[int, np.ndarray]:
        """Tick until the (closed) queue drains; returns rid → generated ids."""
        if close and not self.window.closed:
            self.window.close()
        if not self.window.closed:
            raise RuntimeError("run() needs a closed queue; use tick() online")
        while not self.done:
            self.tick()
        return {
            rid: np.asarray(r.generated, np.int32)
            for rid, r in self.requests.items()
            if r.state == FINISHED
        }
