"""Slot-based KV-cache manager (DESIGN.md §12).

The decode step compiles against one fixed-shape cache of ``num_slots`` rows
× ``max_len`` positions; a *slot* is one row.  Admission allocates a slot,
completion/eviction frees it, and the next scheduler tick refills it — the
step shape never changes, so XLA traces the decode exactly once per serve
cell (the compile-once contract, guarded by tests and CI).

Stale rows are safe by masking, not by zeroing: a freed slot's K/V stays in
device memory, but every read is bounded by the per-slot frontier
(``lengths``) that resets on re-allocation, and every re-prefill overwrites
positions ``[0, prompt_len)`` — so reuse needs no cache clears on the hot
path.
"""

from __future__ import annotations

import collections

import numpy as np

from repro.serve.requests import Request


class SlotManager:
    """Free-list of KV-cache rows plus the host-side per-slot frontier."""

    def __init__(self, num_slots: int, max_len: int) -> None:
        if num_slots <= 0:
            raise ValueError(f"num_slots must be positive, got {num_slots}")
        self.num_slots = num_slots
        self.max_len = max_len
        self._free: collections.deque[int] = collections.deque(range(num_slots))
        self._requests: list[Request | None] = [None] * num_slots
        # Device-step inputs, mutated host-side between ticks:
        self.lengths = np.zeros((num_slots,), np.int32)  # cached tokens per slot
        self.last_token = np.zeros((num_slots,), np.int32)  # pending decode input
        # (slot, rid) in allocation order — the reuse audit trail.
        self.assignments: list[tuple[int, int]] = []

    # -- occupancy -------------------------------------------------------------
    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def active_count(self) -> int:
        return self.num_slots - len(self._free)

    def active(self) -> list[tuple[int, Request]]:
        return [(i, r) for i, r in enumerate(self._requests) if r is not None]

    def request_at(self, slot: int) -> Request | None:
        return self._requests[slot]

    def projected_in_flight(self) -> int:
        """Σ projected KV footprints of resident requests (≤ l_max invariant)."""
        return sum(r.projected_tokens for _, r in self.active())

    def cached_in_flight(self) -> int:
        """Σ realized cache frontiers (what the KV memory actually holds)."""
        return int(sum(self.lengths[i] for i, _ in self.active()))

    # -- lifecycle -------------------------------------------------------------
    def alloc(self, request: Request) -> int:
        if not self._free:
            raise RuntimeError("no free slot")
        if request.projected_tokens > self.max_len:
            raise ValueError(
                f"request {request.rid} projects {request.projected_tokens} "
                f"tokens > slot capacity {self.max_len}"
            )
        slot = self._free.popleft()
        self._requests[slot] = request
        request.slot = slot
        self.lengths[slot] = 0
        self.last_token[slot] = 0
        self.assignments.append((slot, request.rid))
        return slot

    def release(self, slot: int) -> Request:
        request = self._requests[slot]
        if request is None:
            raise ValueError(f"slot {slot} is already free")
        self._requests[slot] = None
        self._free.append(slot)
        return request
