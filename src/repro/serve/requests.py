"""Serving requests and the live-queue admission window (DESIGN.md §12).

A serving request is the inference-time analogue of a sampler view: its true
cost (prompt tokens + decode budget = the KV-cache footprint it will pin) is
*realized* only when the request reaches the tokenizer — the same
observability constraint ODB trains under.  :class:`RequestWindow` therefore
reuses the training path's :class:`~repro.stream.window.BoundedWindow`
mechanics verbatim: a single cursor over an (append-only) arrival order,
realization on admission, and a ``lookahead`` bound on
realized-but-unscheduled requests (backpressure by refusal, never by
blocking — an overloaded engine stops *realizing*, it does not drop).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.grouping import Sample
from repro.stream.window import BoundedWindow

def synth_request_trace(
    n: int,
    *,
    vocab: int,
    prompt_min: int,
    prompt_max: int,
    new_min: int,
    new_max: int,
    seed: int,
) -> list[tuple[np.ndarray, int]]:
    """Heterogeneous request profile: uniform prompts, long-tail decode budgets.

    The decode-budget spread is the quantity static batching is blind to — a
    static batch decodes for its *max* budget while paying device steps for
    every slot, so its useful-slot occupancy is roughly mean/max of the
    profile.  One shared generator so the launcher's smoke trace and the
    CI-gated benchmark trace (benchmarks/serving.py) can never drift apart.
    """
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        plen = int(rng.integers(prompt_min, prompt_max + 1))
        new = int(
            np.clip(rng.geometric(2.0 / (new_min + new_max)), new_min, new_max)
        )
        out.append((rng.integers(1, vocab, size=plen).astype(np.int32), new))
    return out


QUEUED = "queued"  # submitted, not yet realized by the window
WAITING = "waiting"  # realized cost, waiting for slot + budget
RUNNING = "running"  # occupies a KV slot
FINISHED = "finished"
EVICTED = "evicted"  # cancelled mid-flight; slot reclaimed
SHED = "shed"  # TTL expired while queued/waiting; never held a slot


@dataclasses.dataclass
class Request:
    """One decode request moving through the continuous-batching engine."""

    rid: int
    prompt: np.ndarray  # (prompt_len,) int32 token ids
    max_new_tokens: int
    eos_id: int | None = None
    # Queueing deadline: shed (never schedule) once now - submitted_s exceeds
    # it.  None defers to the engine-wide ServeConfig.default_ttl_s.
    ttl_s: float | None = None
    state: str = QUEUED
    generated: list[int] = dataclasses.field(default_factory=list)
    slot: int | None = None
    # wall-clock trajectory (drives the latency percentiles in
    # benchmarks/serving.py)
    submitted_s: float = 0.0
    first_token_s: float | None = None
    finished_s: float | None = None

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def projected_tokens(self) -> int:
        """KV-cache footprint bound: prompt plus the full decode budget.

        This is the ``l`` that admission feeds the Eq.-1 token-budget rule —
        conservative by construction, so the in-flight sum can never outgrow
        ``l_max`` mid-decode (a request that stops early only under-uses its
        reservation).
        """
        return self.prompt_len + self.max_new_tokens

    @property
    def latency_s(self) -> float | None:
        if self.finished_s is None:
            return None
        return self.finished_s - self.submitted_s


class RequestWindow(BoundedWindow):
    """Bounded admission over a live request queue (single scheduler rank).

    The order grows as requests are submitted and stays *open* until
    :meth:`close` — ``exhausted`` therefore means "closed and drained", so a
    serving loop can run until the queue is declared final (batch jobs,
    benchmarks) or keep ticking forever (online serving).  Realization stamps
    the request's projected token cost into a :class:`Sample` whose payload
    is the request itself, which is exactly what
    :func:`repro.core.grouping.greedy_group` consumes for admission cohorts.
    """

    def __init__(self, *, lookahead: int) -> None:
        super().__init__(1, lookahead)
        self._arrivals: list[Request] = []
        self._closed = False

    def submit(self, request: Request) -> None:
        if self._closed:
            raise RuntimeError("request queue is closed")
        self._arrivals.append(request)

    def close(self) -> None:
        self._closed = True

    @property
    def closed(self) -> bool:
        return self._closed

    # -- BoundedWindow order interface -----------------------------------------
    def order_size(self) -> int:
        return len(self._arrivals)

    def order_open(self) -> bool:
        return not self._closed

    def realize(self, position: int) -> Sample:
        request = self._arrivals[position]
        request.state = WAITING
        return Sample(
            view_id=position,
            identity=request.rid,
            length=request.projected_tokens,
            payload=request,
        )
