"""Deterministic seeded fault plans (DESIGN.md §15.5).

Every chaos decision — which round's gather is delayed, which rank's payload
drops, which identities are poison, at which submission a worker dies, how
much of a checkpoint file survives — is a pure hash of ``(seed, site)``.
There is no wall-clock RNG anywhere in the subsystem, so a fault run replays
bit-exactly: the same seed produces the same fault schedule, the same retry
trajectory, and the same recovered stream, which is what lets the harness
assert bit-exactness *through* injected failures rather than merely
"it didn't crash".
"""

from __future__ import annotations

import dataclasses
import hashlib

FAULT_KINDS = (
    "gather_delay",  # transient: deadline-missing delivery, recovers on retry
    "gather_drop",  # hard: payload lost on every attempt -> EpochAborted
    "slow_rank",  # persistent sub-deadline straggler (no faults, no retries)
    "poison_sample",  # realization raises -> quarantine component X
    "worker_kill",  # SIGKILL a realization worker mid-claim
    "ckpt_truncate",  # torn latest train checkpoint -> keep-k fallback
)


def unit_hash(*parts: object) -> float:
    """Deterministic uniform(0,1) from arbitrary parts (no wall-clock RNG)."""
    h = hashlib.sha1("|".join(str(p) for p in parts).encode()).digest()
    return int.from_bytes(h[:8], "big") / float(1 << 64)


@dataclasses.dataclass(frozen=True)
class ChaosPlan:
    """One seed's worth of fault-site decisions, queried per injection point."""

    seed: int
    world_size: int

    # -- collective faults -----------------------------------------------------
    def delay(
        self, round_index: int, rank: int, *, rate: float, max_delay_s: float
    ) -> float | None:
        """Simulated delivery latency for (round, rank), or None (clean).

        The draw and the magnitude hash different sites so changing the rate
        never re-rolls the magnitudes of faults that still fire.
        """
        if unit_hash("delay", self.seed, round_index, rank) >= rate:
            return None
        return max_delay_s * unit_hash("delay-mag", self.seed, round_index, rank)

    def drop(self, round_index: int, rank: int, *, rate: float) -> bool:
        """True when (round, rank)'s payload is scheduled to drop."""
        return unit_hash("drop", self.seed, round_index, rank) < rate

    # -- data faults -------------------------------------------------------------
    def poison_identities(self, n: int, *, count: int) -> frozenset[int]:
        """``count`` distinct identities in [0, n) whose realization fails."""
        count = min(count, n)
        ranked = sorted(range(n), key=lambda i: unit_hash("poison", self.seed, i))
        return frozenset(ranked[:count])

    # -- process / file faults -----------------------------------------------------
    def kill_seq(self, total: int) -> int:
        """Submission ordinal at which a realization worker is SIGKILLed."""
        if total <= 0:
            return 0
        return int(unit_hash("kill", self.seed) * total)

    def truncate_fraction(self) -> float:
        """Surviving prefix fraction for a torn checkpoint file, in [0.3, 0.9)."""
        return 0.3 + 0.6 * unit_hash("truncate", self.seed)
