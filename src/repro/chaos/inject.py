"""Fault injectors: the bridge from a :class:`ChaosPlan` to the runtime hooks.

Each injector targets one of the seams the runtime exposes on purpose:

  * :class:`CollectiveInjector` — the ``injector`` hook of
    :class:`repro.core.comm.ResilientCollective` (queried per
    (round, attempt, rank, tag); faults are *simulated* against the
    deadline, so chaos runs spend no wall clock on the faults themselves);
  * :func:`poison_samples` — the module hook of
    :func:`repro.data.pipeline.set_pipeline_fault_hook` (a poison sample's
    corruption manifests only when the online pipeline realizes it);
  * :func:`make_worker_killer` — the ``fault_hook`` of
    :class:`repro.stream.workers.WorkerPool` (SIGKILL at a planned
    submission ordinal);
  * :func:`truncate_file` — torn-write simulation for checkpoint artifacts.

Every injection increments the ``odb_fault_injected_total`` counter family
(labelled by kind), so a chaos run's telemetry states exactly what was done
to it alongside what it recovered from (DESIGN.md §13).
"""

from __future__ import annotations

import contextlib
import os
import pathlib
import signal

from repro import obs
from repro.chaos.plan import ChaosPlan, unit_hash
from repro.data.pipeline import (
    RawRecord,
    SampleCorruptionError,
    set_pipeline_fault_hook,
)


def _count(kind: str) -> None:
    obs.counter(
        "odb_fault_injected_total",
        help="faults injected by the chaos harness",
        kind=kind,
    ).inc()


class CollectiveInjector:
    """Plan-driven ``on_gather`` hook for :class:`ResilientCollective`.

    ``kind`` selects the failure shape:

      * ``"gather_delay"`` — with probability ``rate`` per (round, rank), the
        delivery takes up to ``max_delay_s`` (a fault iff that exceeds the
        wrapper's deadline).  Transient: the fault fires on attempt 0 only,
        so one retry always recovers it.
      * ``"gather_drop"`` — the payload is lost on *every* attempt (hard
        fault: the retry budget exhausts and the gather raises
        ``RankTimeoutError``).  Sites come from the plan with probability
        ``rate`` per (round, rank), or — with ``at_round`` set — exactly one
        plan-chosen rank at that round (the deterministic mid-epoch outage
        the abort/resume scenario needs).
      * ``"slow_rank"`` — rank ``slow_rank`` always delivers late by
        ``max_delay_s`` (meant to sit *below* the deadline: a persistent
        straggler that must not trigger the fault machinery at all).

    Only primary-tag gathers are faulted; the optional secondary gather of a
    round shares the wrapper's round ordinal and faulting both would
    double-count sites against the plan's per-round rate.
    """

    def __init__(
        self,
        plan: ChaosPlan,
        *,
        kind: str,
        rate: float = 0.0,
        max_delay_s: float = 0.0,
        slow_rank: int = 0,
        at_round: int | None = None,
    ) -> None:
        if kind not in ("gather_delay", "gather_drop", "slow_rank"):
            raise ValueError(f"unknown collective fault kind {kind!r}")
        self.plan = plan
        self.kind = kind
        self.rate = rate
        self.max_delay_s = max_delay_s
        self.slow_rank = slow_rank
        self.at_round = at_round
        self.injected = 0

    def on_gather(
        self, round_index: int, attempt: int, rank: int, tag: str
    ) -> str | float | None:
        if tag != "primary":
            return None
        if self.kind == "slow_rank":
            if rank != self.slow_rank:
                return None
            self.injected += 1
            _count(self.kind)
            return self.max_delay_s
        if self.kind == "gather_delay":
            if attempt > 0:  # transient: clean delivery on retry
                return None
            delay = self.plan.delay(
                round_index, rank, rate=self.rate, max_delay_s=self.max_delay_s
            )
            if delay is None:
                return None
            self.injected += 1
            _count(self.kind)
            return delay
        # gather_drop: persists across attempts (hard fault)
        if self.at_round is not None:
            victim = int(
                unit_hash("drop-rank", self.plan.seed) * self.plan.world_size
            )
            if round_index != self.at_round or rank != victim:
                return None
        elif not self.plan.drop(round_index, rank, rate=self.rate):
            return None
        self.injected += 1
        _count(self.kind)
        return "drop"


@contextlib.contextmanager
def poison_samples(identities):
    """Install a pipeline fault hook failing realization for ``identities``.

    Restores the previous hook on exit, so harness scenarios can nest inside
    instrumented runs without leaking global state into later tests.
    """
    poison = frozenset(identities)

    def hook(record: RawRecord, policy, epoch) -> None:
        if record.identity in poison:
            _count("poison_sample")
            raise SampleCorruptionError(
                f"pipeline failed for identity {record.identity} (injected)"
            )

    previous = set_pipeline_fault_hook(hook)
    try:
        yield poison
    finally:
        set_pipeline_fault_hook(previous)


def make_worker_killer(kill_seq: int):
    """``WorkerPool`` fault hook: SIGKILL *every* live worker at submission
    ``kill_seq`` (once) — the DESIGN.md §14 hard-failure drill.  The pool's
    liveness audit must then re-execute all claimed tasks and degrade to
    in-process execution without dropping or reordering steps.

    All workers die together deliberately: a lone SIGKILL can land while the
    victim holds the task queue's reader lock, wedging the *surviving*
    workers on a lock nobody will release — a failure mode of the injection
    mechanism, not of the pool (the pool's stall escalation still terminates,
    just at stall_timeout per step).  Total loss is the deterministic drill.
    """
    state = {"killed": False}

    def hook(pool, seq: int) -> None:
        if state["killed"] or seq != kill_seq:
            return
        state["killed"] = True
        victims = [p for p in pool._procs if p.is_alive()]
        for proc in victims:
            _count("worker_kill")
            os.kill(proc.pid, signal.SIGKILL)
        for proc in victims:
            proc.join(timeout=10)

    return hook


def truncate_file(path: str | os.PathLike, fraction: float) -> int:
    """Tear a file to its first ``fraction`` of bytes (torn-write simulation).

    Returns the new size.  ``fraction`` is clamped to [0, 1); a checkpoint
    torn this way must be detected and skipped by restore, never half-read.
    """
    p = pathlib.Path(path)
    data = p.read_bytes()
    keep = int(len(data) * min(max(fraction, 0.0), 0.999))
    _count("ckpt_truncate")
    p.write_bytes(data[:keep])
    return keep
