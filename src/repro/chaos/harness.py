"""Chaos scenarios: seeded fault plans driven end-to-end through the runtime.

Each scenario builds a small streaming epoch, injects exactly one fault
class from a :class:`ChaosPlan`, and checks the §15 acceptance rails:

  * **bounded termination** — the run finishes (or aborts into a resumable
    checkpoint); protocol rounds stay inside the Theorem-4 envelope, so a
    fault can degrade throughput but never produce an unbounded epoch;
  * **bit-exactness or full accounting** — the recovered step stream is
    identical to the fault-free one (transient faults, worker kills,
    abort/resume), or the divergence is exactly the quarantined component X
    and the epoch audit accounts for every view
    (``EpochAudit.coverage_accounted``).

Scenarios are pure functions of ``seed`` — no wall-clock randomness — so a
failing seed is a complete reproduction recipe (benchmarks/faults.py runs
the matrix and CI gates on it).
"""

from __future__ import annotations

import dataclasses
import hashlib
import tempfile
import time
import warnings

from repro.chaos.inject import (
    CollectiveInjector,
    make_worker_killer,
    poison_samples,
    truncate_file,
)
from repro.chaos.plan import ChaosPlan, unit_hash
from repro.core.buckets import BucketSpec
from repro.core.layout import make_layout
from repro.core.protocol import IDLE, OdbConfig
from repro.data.pipeline import PipelinePolicy, RawRecord
from repro.stream.executor import EpochAborted, StreamExecutor
from repro.stream.state import StreamCheckpoint

WORLD = 4
N_RECORDS = 64
POLICY = PipelinePolicy(cutoff_len=2048)


def make_records(n: int, seed: int) -> list[RawRecord]:
    """Heterogeneous raw records, lengths ~ U[~60, ~900] tokens."""
    return [
        RawRecord(identity=i, chars=int(200 + 3000 * unit_hash("len", seed, i)))
        for i in range(n)
    ]


def base_config(**overrides) -> OdbConfig:
    base = dict(
        l_max=1024,
        # Small buffer + shallow depth so one epoch spans many fetch/drain/
        # emit rounds — chaos sites need a real round structure to land in.
        buffer_size=4,
        prefetch_factor=4,
        num_workers=1,
        # Fast-retry policy for chaos runs: injected faults are simulated, so
        # the only real wall clock spent on a fault is this backoff.
        retry_backoff_s=0.001,
    )
    base.update(overrides)
    return OdbConfig(**base)


def round_bound(executor: StreamExecutor) -> int:
    """Cumulative Theorem-4 envelope over the iterations actually run."""
    per_iteration = (
        executor.spec.per_rank_quota
        + executor.config.depth
        + 64
        + executor.spec.total_views
    )
    return (executor.runner.iteration + 1) * per_iteration


def stream_digest(steps) -> str:
    """Order-sensitive fingerprint of a delivered step stream.

    Hashes the (view_id, identity, length) triple of every sample plus IDLE
    markers, so two streams digest equal iff they deliver the same views in
    the same groups at the same aligned positions.
    """
    h = hashlib.sha256()
    for step in steps:
        for group in step:
            if group is IDLE or group is None:
                h.update(b"|IDLE")
                continue
            for s in group.samples:
                h.update(f"|{s.view_id},{s.identity},{s.length}".encode())
        h.update(b"#")
    return h.hexdigest()


def drain(executor: StreamExecutor) -> list:
    steps = []
    while True:
        step = executor.step()
        if step is None:
            return steps
        steps.append(step)


@dataclasses.dataclass
class ScenarioResult:
    kind: str
    seed: int
    terminated: bool  # finished (or aborted into a checkpoint) — no hang
    within_bound: bool  # protocol rounds inside the Theorem-4 envelope
    rounds: int
    bound: int
    bit_exact: bool  # recovered stream == fault-free stream
    accounted: bool  # divergence fully captured by the (R,Q,B,E,X) audit
    wall_s: float
    details: dict = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return (
            self.terminated
            and self.within_bound
            and (self.bit_exact or self.accounted)
        )

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["ok"] = self.ok
        return d


def _baseline(records, config: OdbConfig, seed: int) -> tuple[str, int]:
    """Fault-free digest + step count for the same (records, config, seed)."""
    ex = StreamExecutor(records, POLICY, WORLD, config, seed=seed)
    steps = drain(ex)
    return stream_digest(steps), len(steps)


# -- scenarios ------------------------------------------------------------------


def scenario_gather_delay(seed: int) -> ScenarioResult:
    """Transient deadline misses on random (round, rank) sites.

    Every fault fires on attempt 0 only, so bounded retry must recover all
    of them and the delivered stream must be bit-exact the fault-free one.
    """
    records = make_records(N_RECORDS, seed)
    config = base_config(round_deadline_s=0.05, round_retries=2)
    ref_digest, _ = _baseline(records, config, seed)
    plan = ChaosPlan(seed, WORLD)
    injector = CollectiveInjector(
        plan, kind="gather_delay", rate=0.3, max_delay_s=0.2
    )
    t0 = time.perf_counter()
    ex = StreamExecutor(
        records, POLICY, WORLD, config, seed=seed, fault_injector=injector
    )
    steps = drain(ex)
    wall = time.perf_counter() - t0
    return ScenarioResult(
        kind="gather_delay",
        seed=seed,
        terminated=True,
        within_bound=ex.runner.rounds <= round_bound(ex),
        rounds=ex.runner.rounds,
        bound=round_bound(ex),
        bit_exact=stream_digest(steps) == ref_digest,
        accounted=ex.audit().coverage_accounted,
        wall_s=wall,
        details={"injected": injector.injected, "steps": len(steps)},
    )


def scenario_gather_drop(seed: int) -> ScenarioResult:
    """Hard payload loss: abort -> checkpoint round-trip -> resume -> bit-exact.

    One rank's payload drops on every attempt at a planned round, so the
    retry budget exhausts and the executor must abort into a *valid* stream
    checkpoint.  Resuming (fault cleared — the rank "came back") replays the
    aborted round; the combined pre-abort + post-resume stream must equal
    the uninterrupted fault-free stream.
    """
    records = make_records(N_RECORDS, seed)
    config = base_config(round_deadline_s=0.05, round_retries=1)
    ref_digest, ref_steps = _baseline(records, config, seed)
    plan = ChaosPlan(seed, WORLD)
    # Rounds 1..3 always exist (depth 4 << per-rank quota 16 forces several
    # fetch rounds), so the planned outage is guaranteed to fire.
    injector = CollectiveInjector(
        plan, kind="gather_drop", at_round=1 + int(unit_hash("drop-at", seed) * 3)
    )
    t0 = time.perf_counter()
    ex = StreamExecutor(
        records, POLICY, WORLD, config, seed=seed, fault_injector=injector
    )
    steps = []  # pre-abort prefix accumulates here, then the resumed suffix
    aborted = False
    try:
        while True:
            step = ex.step()
            if step is None:
                break
            steps.append(step)
    except EpochAborted as exc:
        aborted = True
        # Full degraded-mode path: serialize, reparse, resume clean (the
        # "rank came back" recovery — no injector on the resumed executor).
        ck = StreamCheckpoint.from_json(exc.checkpoint().to_json())
        resumed = StreamExecutor.resume(ck, records, POLICY)
        steps += drain(resumed)
        ex = resumed
    wall = time.perf_counter() - t0
    return ScenarioResult(
        kind="gather_drop",
        seed=seed,
        terminated=True,
        within_bound=ex.runner.rounds <= round_bound(ex),
        rounds=ex.runner.rounds,
        bound=round_bound(ex),
        bit_exact=stream_digest(steps) == ref_digest,
        accounted=ex.audit().coverage_accounted,
        wall_s=wall,
        details={
            "aborted": aborted,
            "injected": injector.injected,
            "steps": len(steps),
            "ref_steps": ref_steps,
        },
    )


def scenario_slow_rank(seed: int) -> ScenarioResult:
    """Persistent sub-deadline straggler: no faults, no retries, bit-exact."""
    records = make_records(N_RECORDS, seed)
    config = base_config(round_deadline_s=0.05, round_retries=2)
    ref_digest, _ = _baseline(records, config, seed)
    plan = ChaosPlan(seed, WORLD)
    injector = CollectiveInjector(
        plan,
        kind="slow_rank",
        max_delay_s=0.01,  # late, but inside the deadline: never a fault
        slow_rank=int(unit_hash("slow", seed) * WORLD),
    )
    t0 = time.perf_counter()
    ex = StreamExecutor(
        records, POLICY, WORLD, config, seed=seed, fault_injector=injector
    )
    steps = drain(ex)
    wall = time.perf_counter() - t0
    return ScenarioResult(
        kind="slow_rank",
        seed=seed,
        terminated=True,
        within_bound=ex.runner.rounds <= round_bound(ex),
        rounds=ex.runner.rounds,
        bound=round_bound(ex),
        bit_exact=stream_digest(steps) == ref_digest,
        accounted=ex.audit().coverage_accounted,
        wall_s=wall,
        details={"injected": injector.injected, "steps": len(steps)},
    )


def scenario_poison_sample(seed: int) -> ScenarioResult:
    """Poison samples -> quarantine component X, surviving checkpoint/resume.

    Three identities fail realization every time they are touched.  With a
    quarantine budget the epoch must complete, the audit must account every
    view as emitted-or-quarantined, and a mid-run checkpoint/resume must
    preserve the quarantine ledger exactly.
    """
    records = make_records(N_RECORDS, seed)
    plan = ChaosPlan(seed, WORLD)
    poison = plan.poison_identities(N_RECORDS, count=3)
    config = base_config(max_quarantine=len(poison))
    t0 = time.perf_counter()
    with poison_samples(poison):
        ex = StreamExecutor(records, POLICY, WORLD, config, seed=seed)
        steps = []
        for _ in range(3):  # deliver a prefix, then checkpoint mid-epoch
            step = ex.step()
            if step is None:
                break
            steps.append(step)
        ck = StreamCheckpoint.from_json(ex.checkpoint().to_json())
        resumed = StreamExecutor.resume(ck, records, POLICY)
        ledger_preserved = (
            resumed.runner.quarantined_ids == ex.runner.quarantined_ids
            and resumed.runner.quarantined_views == ex.runner.quarantined_views
        )
        steps += drain(resumed)
    wall = time.perf_counter() - t0
    audit = resumed.audit()
    quarantine_exact = (
        set(resumed.runner.quarantined_ids) <= set(poison)
        and audit.quarantined_identities == len(poison)
    )
    return ScenarioResult(
        kind="poison_sample",
        seed=seed,
        terminated=True,
        within_bound=resumed.runner.rounds <= round_bound(resumed),
        rounds=resumed.runner.rounds,
        bound=round_bound(resumed),
        bit_exact=False,  # the stream legitimately lacks the poison views
        accounted=(
            audit.coverage_accounted and ledger_preserved and quarantine_exact
        ),
        wall_s=wall,
        details={
            "poison": sorted(poison),
            "quarantined_views": resumed.runner.quarantined_views,
            "steps": len(steps),
        },
    )


def scenario_worker_kill(seed: int) -> ScenarioResult:
    """SIGKILL all realization workers at a planned submission: ordered,
    bit-exact.

    The pool must reclaim every claimed task in-process and finish the epoch
    degraded; the delivered step stream (submission order == delivery order)
    must match the in-process fault-free stream exactly.
    """
    from repro.stream.workers import WorkerPool

    records = make_records(N_RECORDS, seed)
    config = base_config()
    ref = StreamExecutor(records, POLICY, WORLD, config, seed=seed)
    ref_steps = drain(ref)
    plan = ChaosPlan(seed, WORLD)
    layout = make_layout(
        "dense",
        bucket_spec=BucketSpec(min_len=128, max_len=2048, max_count=64),
        vocab_size=128,
    )
    killer = make_worker_killer(plan.kill_seq(len(ref_steps)))
    t0 = time.perf_counter()
    ex = StreamExecutor(records, POLICY, WORLD, config, seed=seed)
    got = []
    with warnings.catch_warnings():
        # Worker loss legitimately warns (RuntimeWarning); the rail here is
        # stream integrity, not silence.
        warnings.simplefilter("ignore", RuntimeWarning)
        pool = WorkerPool(layout, 2, fault_hook=killer)
        try:
            done = False
            while True:
                while not done and pool.can_submit():
                    task = ex.next_task()
                    if task is None:
                        done = True
                        break
                    pool.submit(*task)
                if done and not pool.inflight:
                    break
                res = pool.take()
                if res is None:
                    continue
                got.append(res.step)
                if res.release is not None:
                    res.release()
        finally:
            pool.close()
    wall = time.perf_counter() - t0
    return ScenarioResult(
        kind="worker_kill",
        seed=seed,
        terminated=True,
        within_bound=ex.runner.rounds <= round_bound(ex),
        rounds=ex.runner.rounds,
        bound=round_bound(ex),
        bit_exact=stream_digest(got) == stream_digest(ref_steps),
        accounted=ex.audit().coverage_accounted,
        wall_s=wall,
        details={
            "steps": len(got),
            "worker_failures": pool.stats.worker_failures,
            "reexecuted": pool.stats.reexecuted,
        },
    )


def scenario_ckpt_truncate(seed: int) -> ScenarioResult:
    """Torn latest train checkpoint: restore falls back to the previous step."""
    import numpy as np

    from repro.train import checkpoint as ckpt

    plan = ChaosPlan(seed, WORLD)
    t0 = time.perf_counter()
    with tempfile.TemporaryDirectory() as tmp:
        state_like = {
            "w": np.zeros((8, 4), np.float32),
            "b": np.zeros((4,), np.float32),
        }
        keep = {}
        for step in (1, 2):
            state = {
                "w": np.full((8, 4), float(step), np.float32),
                "b": np.full((4,), float(10 * step), np.float32),
            }
            keep[step] = state
            ckpt.save_checkpoint(tmp, step, state)
        torn = truncate_file(
            f"{tmp}/step_00000002.npz", plan.truncate_fraction()
        )
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            restored, step = ckpt.restore_checkpoint(tmp, state_like)
        exact = step == 1 and all(
            np.array_equal(np.asarray(restored[k]), keep[1][k])
            for k in state_like
        )
    wall = time.perf_counter() - t0
    return ScenarioResult(
        kind="ckpt_truncate",
        seed=seed,
        terminated=True,
        within_bound=True,
        rounds=0,
        bound=1,
        bit_exact=exact,
        accounted=exact,
        wall_s=wall,
        details={"fallback_step": step, "torn_bytes": torn},
    )


SCENARIOS = {
    "gather_delay": scenario_gather_delay,
    "gather_drop": scenario_gather_drop,
    "slow_rank": scenario_slow_rank,
    "poison_sample": scenario_poison_sample,
    "worker_kill": scenario_worker_kill,
    "ckpt_truncate": scenario_ckpt_truncate,
}


def run_all(seed: int = 0, *, kinds=None) -> dict[str, ScenarioResult]:
    out: dict[str, ScenarioResult] = {}
    for kind in kinds or SCENARIOS:
        out[kind] = SCENARIOS[kind](seed)
    return out
