"""Deterministic chaos-injection harness (DESIGN.md §15.5).

Seeded fault plans (:mod:`repro.chaos.plan`), runtime injectors
(:mod:`repro.chaos.inject`) and end-to-end recovery scenarios with
acceptance rails (:mod:`repro.chaos.harness`): every fault class must
terminate within its envelope, and the recovered stream must be bit-exact
or its divergence fully accounted by the (R, Q, B, E, X) audit.
"""

from repro.chaos.harness import (
    SCENARIOS,
    ScenarioResult,
    run_all,
    stream_digest,
)
from repro.chaos.inject import (
    CollectiveInjector,
    make_worker_killer,
    poison_samples,
    truncate_file,
)
from repro.chaos.plan import FAULT_KINDS, ChaosPlan, unit_hash

__all__ = [
    "FAULT_KINDS",
    "SCENARIOS",
    "ChaosPlan",
    "CollectiveInjector",
    "ScenarioResult",
    "make_worker_killer",
    "poison_samples",
    "run_all",
    "stream_digest",
    "truncate_file",
    "unit_hash",
]
