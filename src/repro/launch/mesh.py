"""Production mesh construction (single-pod 16x16, multi-pod 2x16x16).

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and then calls these.
"""

from __future__ import annotations

import jax

try:  # AxisType landed after jax 0.4.x; older CPU containers lack it
    from jax.sharding import AxisType

    def _axis_kwargs(n: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n}

except ImportError:  # pragma: no cover - depends on installed jax

    def _axis_kwargs(n: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh.

    Axes: ``data`` carries DP (+FSDP param storage), ``model`` carries TP/EP,
    ``pod`` is an outer pure-DP axis (gradient reduction crosses pods over
    DCN; params are stored FSDP *within* a pod so weight all-gathers stay on
    ICI — DESIGN.md §7).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (tests / examples on CPU)."""
    n = jax.device_count()
    assert n % model_parallel == 0
    return jax.make_mesh(
        (n // model_parallel, model_parallel),
        ("data", "model"),
        **_axis_kwargs(2),
    )


def make_sim_multihost_mesh(num_hosts: int, model_parallel: int = 1):
    """Mesh with an explicit outer ``host`` DP axis for the simulated
    multi-host lane (``--hosts``, DESIGN.md §16).

    Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` so the
    CPU runtime exposes enough devices; each host owns a contiguous device
    block, matching the contiguous rank-block partition `ShardedWindow`
    uses, so host ``h``'s admitted shard lands on host ``h``'s devices.
    """
    n = jax.device_count()
    if num_hosts < 1 or n % (num_hosts * model_parallel) != 0:
        raise ValueError(
            f"device count {n} not divisible by hosts={num_hosts} "
            f"x model_parallel={model_parallel}"
        )
    return jax.make_mesh(
        (num_hosts, n // (num_hosts * model_parallel), model_parallel),
        ("host", "data", "model"),
        **_axis_kwargs(3),
    )


def dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "host", "data") if a in mesh.axis_names)


def dp_size(mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= mesh.shape[a]
    return out


def tp_size(mesh) -> int:
    return mesh.shape.get("model", 1) if hasattr(mesh.shape, "get") else mesh.shape["model"]
