"""Sharding rules: logical roles → mesh PartitionSpecs (DESIGN.md §7).

Conventions:
  * ``model`` axis: TP (attention heads / FFN hidden / vocab) and EP
    (expert slabs).
  * ``data`` axis: DP for activations; FSDP storage axis for params of
    archs above ``FSDP_THRESHOLD`` (GSPMD inserts the weight all-gather /
    grad reduce-scatter automatically, incl. at shard_map boundaries).
  * ``pod`` axis: pure DP — params replicated across pods so weight
    gathers never cross the DCN; only gradient reduction does.
  * ``host`` axis (simulated multi-host lane, DESIGN.md §16): outer pure-DP
    axis from :func:`make_sim_multihost_mesh`; ``dp_axes`` folds it into
    the batch partition so each host's contiguous device block consumes
    the shard its ``ShardedWindow`` admitted.
  * Input shardings must divide evenly (pjit requirement) — every rule
    checks divisibility and falls back to replication; intermediates may
    be uneven (GSPMD pads).

Cache layout choices (small-kv archs, kv=8 < TP=16): shard the head_dim
(128/16) instead of the kv-head dim; MLA latent caches are replicated over
`model` (they are small — that is MLA's point).
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import dp_axes


def _div(n: int, mesh, axes) -> bool:
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size > 0 and n % size == 0


FSDP_THRESHOLD = 8e9  # params; above this, weights store FSDP over `data`


def use_fsdp(cfg) -> bool:
    return cfg.param_count() > FSDP_THRESHOLD


# -----------------------------------------------------------------------------
# Parameter specs
# -----------------------------------------------------------------------------

_REPLICATED_KEYS = {
    "scale", "q_norm", "k_norm", "kv_norm", "out_norm", "dt_bias", "a_log",
    "d_skip", "conv_w", "router",
}
_COL_PARALLEL = {"wq", "wk", "wv", "w_uq", "in_z", "in_x"}  # (d_in, tp_out)
_ROW_PARALLEL = {"wo", "out_proj"}  # (tp_in, d_out)
_LATENT_DOWN = {"w_dq", "w_dkv", "in_b", "in_c", "in_dt"}  # (d_in, small)
_LATENT_UP = {"w_uk", "w_uv"}  # (latent, tp_out)


def _param_spec(path_keys: list[str], shape: tuple[int, ...], mesh, fsdp: bool):
    name = path_keys[-1]
    in_stack = "stack" in path_keys
    f = "data" if (fsdp and "data" in mesh.axis_names) else None

    def fx(dim: int):
        return f if (f and _div(dim, mesh, f)) else None

    def tp(dim: int):
        return "model" if _div(dim, mesh, "model") else None

    base_shape = shape[1:] if in_stack else shape
    nd = len(base_shape)

    if name in _REPLICATED_KEYS or nd <= 1:
        spec: tuple = (None,) * nd
    elif name == "embed":
        spec = (tp(base_shape[0]), fx(base_shape[1]))
    elif name == "unembed":
        spec = (fx(base_shape[0]), tp(base_shape[1]))
    elif nd == 3 and name in ("w_in", "w_gate"):  # expert slab (E, d, ff)
        spec = (tp(base_shape[0]), fx(base_shape[1]), None)
    elif nd == 3 and name == "w_out":  # expert slab (E, ff, d)
        spec = (tp(base_shape[0]), None, fx(base_shape[2]))
    elif name in ("w_in", "w_gate"):  # dense MLP (d, ff)
        spec = (fx(base_shape[0]), tp(base_shape[1]))
    elif name == "w_out":  # dense MLP (ff, d)
        spec = (tp(base_shape[0]), fx(base_shape[1]))
    elif name in _COL_PARALLEL:
        spec = (fx(base_shape[0]), tp(base_shape[1]))
    elif name in _ROW_PARALLEL:
        spec = (tp(base_shape[0]), fx(base_shape[1]))
    elif name in _LATENT_DOWN:
        spec = (fx(base_shape[0]), None)
    elif name in _LATENT_UP:
        spec = (None, tp(base_shape[1]))
    else:
        spec = (None,) * nd
    if in_stack:
        spec = (None,) + spec
    return P(*spec)


def _path_keys(path) -> list[str]:
    keys = []
    for p in path:
        if hasattr(p, "key"):
            keys.append(str(p.key))
        elif hasattr(p, "idx"):
            keys.append(str(p.idx))
        elif hasattr(p, "name"):
            keys.append(str(p.name))
    return keys


def param_specs(params_tree, cfg, mesh):
    """PartitionSpec pytree matching ``params_tree`` (shapes or arrays)."""
    fsdp = use_fsdp(cfg)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _param_spec(_path_keys(path), leaf.shape, mesh, fsdp),
        params_tree,
    )


def opt_state_specs(opt_shapes, param_spec_tree):
    return {
        "m": param_spec_tree,
        "v": param_spec_tree,
        "step": P(),
    }


# -----------------------------------------------------------------------------
# Batch / cache specs
# -----------------------------------------------------------------------------


def batch_dp_axes(global_batch: int, mesh):
    """Largest prefix of the DP axes that divides the batch evenly."""
    axes = []
    size = 1
    for a in dp_axes(mesh):
        if global_batch % (size * mesh.shape[a]) == 0:
            axes.append(a)
            size *= mesh.shape[a]
    return tuple(axes) if axes else None


def batch_specs(batch_tree, mesh):
    def spec(leaf):
        dp = batch_dp_axes(leaf.shape[0], mesh)
        return P(dp, *([None] * (len(leaf.shape) - 1)))
    return jax.tree.map(spec, batch_tree)


def _cache_leaf_spec(path_keys: list[str], shape, cfg, mesh):
    """Specs for KV / MLA / SSM cache leaves (named tuple fields)."""
    in_stack = "stack" in path_keys
    base = shape[1:] if in_stack else shape
    name = path_keys[-1]
    dp = batch_dp_axes(base[0], mesh)
    if name in ("k", "v"):  # (B, S, kv, dh)
        if _div(base[2], mesh, "model"):
            spec = (dp, None, "model", None)
        elif _div(base[3], mesh, "model"):
            spec = (dp, None, None, "model")  # head-dim sharding (kv < TP)
        else:
            spec = (dp, None, None, None)
    elif name in ("ckv", "k_rope"):  # MLA latents: small, replicate on model
        spec = (dp,) + (None,) * (len(base) - 1)
    elif name == "state":  # SSM (B, H, P, N)
        spec = (dp, "model" if _div(base[1], mesh, "model") else None, None, None)
    elif name == "conv":  # (B, k, channels)
        spec = (dp, None, "model" if _div(base[2], mesh, "model") else None)
    else:
        spec = (dp,) + (None,) * (len(base) - 1)
    if in_stack:
        spec = (None,) + spec
    return P(*spec)


def cache_specs(cache_shapes, cfg, mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _cache_leaf_spec(_path_keys(path), leaf.shape, cfg, mesh),
        cache_shapes,
    )


def named(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs)
