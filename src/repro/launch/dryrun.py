import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init).  For each cell we:

  1. build the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. build the step function with full shardings (launch/steps.py),
  3. ``jit(...).lower(*ShapeDtypeStructs).compile()`` — no allocation,
  4. print ``compiled.memory_analysis()`` (proves the HBM budget) and
     ``compiled.cost_analysis()`` (FLOPs / bytes for §Roofline),
  5. parse collective bytes out of the optimized HLO and persist one JSON
     artifact per cell under ``artifacts/dryrun/`` for the roofline tables.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
      [--mesh single|multi|both] [--list]
"""

import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPE_ORDER, SHAPES, applicability
from repro.launch.steps import build_decode_step, build_prefill_step, build_train_step
from repro.models.model import LM
from repro.roofline.analysis import model_flops_for_cell, roofline_from_artifacts
from repro.roofline.hlo_cost import analyze as hlo_analyze
from repro.train.optimizer import OptimizerConfig

ARTIFACT_DIR = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def opt_config_for(cfg) -> OptimizerConfig:
    # bf16 moments for the giants — the HBM lever (DESIGN.md §7).
    mdt = "bfloat16" if cfg.param_count() > 8e9 else "float32"
    return OptimizerConfig(moment_dtype=mdt)


def builder_for(model: LM, mesh, cell):
    if cell.kind == "train":
        return build_train_step(model, mesh, cell, opt_config_for(model.cfg))
    if cell.kind == "prefill":
        return build_prefill_step(model, mesh, cell)
    return build_decode_step(model, mesh, cell)


def run_cell(
    arch: str,
    shape: str,
    mesh_name: str,
    *,
    verbose: bool = True,
    variant: dict | None = None,
    tag: str = "",
) -> dict:
    cfg = get_config(arch)
    if variant:
        cfg = dataclasses.replace(cfg, **variant)
    cell = SHAPES[shape]
    ok, reason = applicability(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "mesh": mesh_name, "status": "skip", "reason": reason}

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = mesh.devices.size
    model = LM(cfg, mesh=mesh)
    record: dict = {"arch": arch, "shape": shape, "mesh": mesh_name, "chips": chips}
    t0 = time.time()
    try:
        with jax.set_mesh(mesh):
            fn, abstract_args, _ = builder_for(model, mesh, cell)
            lowered = fn.lower(*abstract_args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis() or {}
            hlo = compiled.as_text()
    except Exception as exc:  # a failure here is a bug in the system
        record.update(status="error", error=f"{type(exc).__name__}: {exc}",
                      traceback=traceback.format_exc()[-4000:])
        return record

    import gzip

    ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    hlo_path = ARTIFACT_DIR / f"{arch}__{shape}__{mesh_name}{suffix}.hlo.txt.gz"
    hlo_path.write_bytes(gzip.compress(hlo.encode()))

    parsed = hlo_analyze(hlo)  # per-device, trip-count-corrected
    mflops = model_flops_for_cell(cfg, cell)
    terms = roofline_from_artifacts(arch, shape, mesh_name, chips, parsed, mflops)
    # Memory usefulness: minimal per-device bytes one step must touch
    # (param reads + optimizer traffic for train; params + cache for decode).
    params_bytes = cfg.param_count() * 2.0 / chips  # bf16, fully sharded ideal
    if cell.kind == "train":
        useful_bytes = params_bytes * (3 + 2 + 4)  # read fwd+bwd grads + opt m/v rw
    else:
        useful_bytes = params_bytes
    mem_useful = useful_bytes / parsed["hbm_bytes"] if parsed["hbm_bytes"] else 0.0
    mem_fields = {}
    for field in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        mem_fields[field] = getattr(mem, field, None)
    args_b = mem_fields.get("argument_size_in_bytes") or 0
    temp_b = mem_fields.get("temp_size_in_bytes") or 0
    alias_b = mem_fields.get("alias_size_in_bytes") or 0
    out_b = mem_fields.get("output_size_in_bytes") or 0
    # memory_analysis is per-device already (SPMD module view):
    # live bytes = arguments + temps + (outputs not aliased into arguments).
    per_device = args_b + temp_b + max(out_b - alias_b, 0)

    record.update(
        status="ok",
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        memory=mem_fields,
        bytes_per_device=per_device,
        xla_cost={k: cost.get(k) for k in ("flops", "bytes accessed") if k in cost},
        parsed_cost={k: parsed[k] for k in ("flops", "hbm_bytes", "coll_bytes", "transcendentals")},
        per_collective=parsed["per_collective"],
        roofline=dict(terms.row(), mem_useful_ratio=mem_useful),
    )
    if verbose:
        print(
            f"[{arch} × {shape} × {mesh_name}] compile {t_compile:.0f}s | "
            f"{per_device/1e9:.2f} GB/device | "
            f"flops {terms.hlo_flops:.3e} | coll {terms.coll_bytes:.3e} B | "
            f"dominant={terms.dominant} | roofline_frac={terms.roofline_fraction:.3f}",
            flush=True,
        )
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=("single", "multi", "both"))
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPE_ORDER)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.list:
        for a in archs:
            cfg = get_config(a)
            for s in shapes:
                ok, reason = applicability(cfg, s)
                print(f"{a:18s} {s:12s} {'RUN' if ok else 'SKIP: ' + reason}")
        return

    ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)
    failures = 0
    for a in archs:
        for s in shapes:
            for m in meshes:
                out = ARTIFACT_DIR / f"{a}__{s}__{m}.json"
                if out.exists() and not args.force:
                    cached = json.loads(out.read_text())
                    if cached.get("status") in ("ok", "skip"):
                        print(f"[{a} × {s} × {m}] cached: {cached['status']}", flush=True)
                        continue
                rec = run_cell(a, s, m)
                out.write_text(json.dumps(rec, indent=2, default=str))
                if rec["status"] == "error":
                    failures += 1
                    print(f"[{a} × {s} × {m}] ERROR: {rec['error']}", flush=True)
                elif rec["status"] == "skip":
                    print(f"[{a} × {s} × {m}] SKIP: {rec['reason']}", flush=True)
    print(f"dry-run complete; {failures} failures", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
