import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()

"""§Perf hillclimb driver: re-lower a cell under an optimization variant and
report the roofline-term deltas vs the cached baseline artifact.

    PYTHONPATH=src python -m repro.launch.perf --cell yi_34b:train_4k \
        --variant headshard

Variants (config-level levers; DESIGN.md §8 / EXPERIMENTS.md §Perf):
  headshard   attn_head_constraint=True   (uneven head sharding annotation)
  ce_bf16     logits_fp32=False           (bf16 logits + cross-entropy)
  sp          sequence_sharding=True      (sequence-parallel residual stream)
  sp_ce       sp + ce_bf16
  all         headshard + sp + ce_bf16
  remat_none  remat="none"                (no rematerialization)
  remat_dots  remat="dots"                (save matmul outputs only)
"""

import argparse
import json
import pathlib

VARIANTS = {
    "headshard": {"attn_head_constraint": True},
    "ce_bf16": {"logits_fp32": False},
    "sp": {"sequence_sharding": True},
    "sp_ce": {"sequence_sharding": True, "logits_fp32": False},
    "all": {
        "attn_head_constraint": True,
        "sequence_sharding": True,
        "logits_fp32": False,
    },
    "sp_ce_dots": {
        "sequence_sharding": True,
        "logits_fp32": False,
        "remat": "dots",
    },
    "remat_none": {"remat": "none"},
    "remat_dots": {"remat": "dots"},
}


def main() -> None:
    from repro.launch.dryrun import ARTIFACT_DIR, run_cell

    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--variant", required=True, choices=sorted(VARIANTS))
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()

    arch, shape = args.cell.split(":")
    base_path = ARTIFACT_DIR / f"{arch}__{shape}__{args.mesh}.json"
    base = json.loads(base_path.read_text()) if base_path.exists() else None

    rec = run_cell(
        arch, shape, args.mesh, variant=VARIANTS[args.variant], tag=args.variant
    )
    out = ARTIFACT_DIR / f"{arch}__{shape}__{args.mesh}__{args.variant}.json"
    out.write_text(json.dumps(rec, indent=2, default=str))
    if rec["status"] != "ok":
        print(f"variant FAILED: {rec.get('error')}")
        raise SystemExit(1)

    if base and base.get("status") == "ok":
        b, v = base["roofline"], rec["roofline"]
        print(f"\n{arch} × {shape} × {args.mesh}: baseline → {args.variant}")
        for term in ("compute_s", "memory_s", "collective_s"):
            delta = (v[term] - b[term]) / b[term] * 100 if b[term] else float("nan")
            print(f"  {term:14s} {b[term]:.3e} → {v[term]:.3e}  ({delta:+.1f}%)")
        bt = max(b["compute_s"], b["memory_s"], b["collective_s"])
        vt = max(v["compute_s"], v["memory_s"], v["collective_s"])
        print(f"  bound_time     {bt:.3e} → {vt:.3e}  ({(vt-bt)/bt*100:+.1f}%)")
        print(f"  roofline_frac  {b['roofline_fraction']:.4f} → {v['roofline_fraction']:.4f}")
        print(f"  GB/device      {base['bytes_per_device']/1e9:.1f} → {rec['bytes_per_device']/1e9:.1f}")


if __name__ == "__main__":
    main()
