"""Jitted step functions (train / prefill / decode) with mesh shardings.

``build_*`` returns (jitted_fn, abstract_args, in_shardings) so the same
builders serve the real trainer, the examples, and the dry-run (which calls
``.lower(*abstract_args).compile()`` without allocating anything).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch import sharding as shr
from repro.launch.shapes import (
    ServeCell,
    ShapeCell,
    decode_token_specs,
    prefill_token_specs,
    serve_decode_specs,
    serve_prefill_specs,
    train_batch_specs,
)
from repro.models.model import LM, shift_labels
from repro.train.optimizer import OptimizerConfig, init_opt_state

# The canonical train step lives with the trainer (shared builder: what the
# dry-run lowers here is exactly what the deployment trainer jits).
from repro.train.trainer import make_train_step, resolve_attn_impl  # noqa: F401

Params = Any


def _route_cell_model(model: LM, cell: ShapeCell) -> LM:
    """Pin the cell's preferred attention route (DESIGN.md §11).

    Cells with ``attn_impl="flash"`` (the packed train cells) compile the
    Pallas kernel on TPU; off-TPU the resolution falls back to the XLA
    blockwise path so CPU dry-runs stay on the interpretable route.  An
    explicit route already pinned on the model config wins.
    """
    cfg = model.cfg
    if cell.kind != "train":
        return model
    pins = {}
    if cfg.attn_impl == "auto":
        packed = cell.layout == "packed" or cell.attn_impl == "flash"
        impl = resolve_attn_impl(cfg, packed=packed)
        if impl != cfg.attn_impl:
            pins["attn_impl"] = impl
    # The cell's grid preference (DESIGN.md §17) pins an unset attn_grid;
    # kernels/ops still degrades it to dense when segments are absent.
    if getattr(cfg, "attn_grid", "auto") == "auto" and cell.attn_grid != "auto":
        pins["attn_grid"] = cell.attn_grid
    if not pins:
        return model
    return dataclasses.replace(model, cfg=dataclasses.replace(cfg, **pins))


def abstract_train_state(model: LM, opt_cfg: OptimizerConfig):
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    opt = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), params)
    return {"params": params, "opt": opt}


def train_state_specs(state_shapes, model: LM, mesh):
    pspecs = shr.param_specs(state_shapes["params"], model.cfg, mesh)
    return {
        "params": pspecs,
        "opt": {"m": pspecs, "v": pspecs, "step": P()},
    }


def build_train_step(model: LM, mesh, cell: ShapeCell, opt_cfg=None):
    opt_cfg = opt_cfg or OptimizerConfig()
    model = _route_cell_model(model, cell)
    state_shapes = abstract_train_state(model, opt_cfg)
    batch_shapes = train_batch_specs(model.cfg, cell)
    state_specs = train_state_specs(state_shapes, model, mesh)
    batch_specs_ = shr.batch_specs(batch_shapes, mesh)
    in_shardings = (shr.named(state_specs, mesh), shr.named(batch_specs_, mesh))
    out_shardings = (
        shr.named(state_specs, mesh),
        None,  # metrics: let XLA replicate
    )
    fn = jax.jit(
        make_train_step(model, opt_cfg),
        in_shardings=in_shardings,
        out_shardings=out_shardings,
        donate_argnums=(0,),
    )
    return fn, (state_shapes, batch_shapes), in_shardings


# -----------------------------------------------------------------------------
# Serve: prefill / decode
# -----------------------------------------------------------------------------


def build_prefill_step(model: LM, mesh, cell: ShapeCell, max_len: int | None = None):
    max_len = max_len or cell.seq_len
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    tokens_shape = prefill_token_specs(model.cfg, cell)
    pspecs = shr.param_specs(params_shapes, model.cfg, mesh)
    tspec = shr.batch_specs(tokens_shape, mesh)

    def prefill(params, tokens):
        if model.cfg.input_embeds:
            # encoder "prefill" = full encode; logits for every frame
            logits = model.forward(params, {"embeds": tokens})
            return logits[:, -1:], None
        return model.prefill(params, tokens, max_len)

    cache_shapes = None
    out_shardings = None
    if model.cfg.has_decode and not model.cfg.input_embeds:
        cache_shapes = jax.eval_shape(
            lambda: model.init_caches(cell.global_batch, max_len)
        )
        cspecs = shr.cache_specs(cache_shapes, model.cfg, mesh)
        out_shardings = (None, shr.named(cspecs, mesh))

    fn = jax.jit(
        prefill,
        in_shardings=(shr.named(pspecs, mesh), shr.named(tspec, mesh)),
        out_shardings=out_shardings,
    )
    return fn, (params_shapes, tokens_shape), (pspecs, tspec)


def build_decode_step(model: LM, mesh, cell: ShapeCell, max_len: int | None = None):
    """One-token serve_step against a KV cache of ``cell.seq_len`` tokens."""
    max_len = max_len or cell.seq_len
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    cache_shapes = jax.eval_shape(
        lambda: model.init_caches(cell.global_batch, max_len)
    )
    tokens_shape = decode_token_specs(cell)
    index_shape = jax.ShapeDtypeStruct((), jnp.int32)

    pspecs = shr.param_specs(params_shapes, model.cfg, mesh)
    cspecs = shr.cache_specs(cache_shapes, model.cfg, mesh)
    tspec = shr.batch_specs(tokens_shape, mesh)

    def decode(params, caches, tokens, cache_index):
        return model.decode_step(params, caches, tokens, cache_index)

    fn = jax.jit(
        decode,
        in_shardings=(
            shr.named(pspecs, mesh),
            shr.named(cspecs, mesh),
            shr.named(tspec, mesh),
            NamedSharding(mesh, P()),
        ),
        out_shardings=(None, shr.named(cspecs, mesh)),
        donate_argnums=(1,),
    )
    args = (params_shapes, cache_shapes, tokens_shape, index_shape)
    return fn, args, (pspecs, cspecs, tspec, P())


# -----------------------------------------------------------------------------
# Serve: continuous batching (slot cache, DESIGN.md §12)
# -----------------------------------------------------------------------------


def build_serve_decode_step(model: LM, mesh, cell: ServeCell):
    """Slot decode: ``(num_slots, 1)`` tokens against per-slot frontiers.

    Returns ``(fn, abstract_args, traces)`` where ``traces`` is a mutable
    trace counter incremented every time XLA re-traces the step — the
    compile-once contract says it must read exactly 1 across any sequence of
    admissions and evictions (tests/test_serve.py, benchmarks/serving.py).
    Argmax over the real vocabulary is fused into the step so only
    ``(num_slots, 1)`` token ids travel back to the host per tick.
    """
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    cache_shapes = jax.eval_shape(
        lambda: model.init_caches(cell.num_slots, cell.max_len)
    )
    tokens_shape, lengths_shape = serve_decode_specs(cell)
    traces = {"count": 0}

    def decode(params, caches, tokens, lengths):
        traces["count"] += 1
        logits, caches = model.decode_step_slots(params, caches, tokens, lengths)
        nxt = jnp.argmax(
            logits[:, :, : model.cfg.vocab_size], axis=-1
        ).astype(jnp.int32)
        return nxt, caches

    kwargs = {}
    if mesh is not None:
        pspecs = shr.param_specs(params_shapes, model.cfg, mesh)
        cspecs = shr.cache_specs(cache_shapes, model.cfg, mesh)
        kwargs = dict(
            in_shardings=(
                shr.named(pspecs, mesh),
                shr.named(cspecs, mesh),
                NamedSharding(mesh, P()),
                NamedSharding(mesh, P()),
            ),
            out_shardings=(None, shr.named(cspecs, mesh)),
        )
    fn = jax.jit(decode, donate_argnums=(1,), **kwargs)
    args = (params_shapes, cache_shapes, tokens_shape, lengths_shape)
    return fn, args, traces


def build_serve_prefill_step(
    model: LM, mesh, cell: ServeCell, rows: int, cap: int
):
    """Packed scatter prefill for one ``(rows, cap)`` stream bucket.

    Compiles once per occupied bucket of the engine's ``PackedBucketSpec``
    grid: a mixed-length admission cohort shares one segment-masked stream
    (the PR-2/3 packed flash path), K/V scatters into the cohort's cache
    slots, and the per-segment last-position logits are gathered in-step —
    indexed *by slot*, so the host reads one ``(num_slots, vocab)`` row per
    admitted request no matter how the cohort was packed.
    """
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    cache_shapes = jax.eval_shape(
        lambda: model.init_caches(cell.num_slots, cell.max_len)
    )
    stream_shapes = serve_prefill_specs(rows, cap, cell.num_slots)
    traces = {"count": 0}

    def prefill(params, caches, tokens, positions, segments, dest_slot,
                gather_rows, gather_cols):
        traces["count"] += 1
        logits, caches = model.prefill_packed(
            params, caches, tokens, positions, segments, dest_slot
        )
        picked = logits[gather_rows, gather_cols, : model.cfg.vocab_size]
        return picked, caches

    kwargs = {}
    if mesh is not None:
        pspecs = shr.param_specs(params_shapes, model.cfg, mesh)
        cspecs = shr.cache_specs(cache_shapes, model.cfg, mesh)
        rep = NamedSharding(mesh, P())
        kwargs = dict(
            in_shardings=(shr.named(pspecs, mesh), shr.named(cspecs, mesh))
            + (rep,) * 6,
            out_shardings=(None, shr.named(cspecs, mesh)),
        )
    fn = jax.jit(prefill, donate_argnums=(1,), **kwargs)
    args = (params_shapes, cache_shapes) + stream_shapes
    return fn, args, traces
