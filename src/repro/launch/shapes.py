"""Assigned input-shape cells and their applicability rules (DESIGN.md §4).

LM transformer shapes are seq_len × global_batch; ``decode_*`` / ``long_*``
lower ``serve_step`` (one new token against a filled KV cache), NOT
``train_step``; ``prefill_*`` lowers the prompt-encoding serve path.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
from jax import ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"
    layout: str = "dense"  # batch layout of train cells (DESIGN.md §10)
    # Preferred attention route for this cell (DESIGN.md §11).  "flash" is a
    # preference, not a pin: launch/steps resolves it against the backend,
    # so CPU dry-runs still lower the XLA blockwise path.
    attn_impl: str = "auto"
    # Preferred flash grid variant (DESIGN.md §17): "pruned" routes kv-tile
    # DMA through the scalar-prefetch liveness index on packed cells; only
    # consulted when the cell actually takes the flash route.
    attn_grid: str = "auto"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    # Packed layout: same 4k row capacity, fewer rows (each row carries
    # ~row_capacity real tokens instead of one right-padded sample); routed
    # through the Pallas flash kernel when the backend compiles it.
    "train_4k_packed": ShapeCell(
        "train_4k_packed", 4096, 64, "train", layout="packed",
        attn_impl="flash", attn_grid="pruned",
    ),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

SHAPE_ORDER = ("train_4k", "train_4k_packed", "prefill_32k", "decode_32k", "long_500k")


def applicability(cfg, shape_name: str) -> tuple[bool, str]:
    """(runnable, reason).  Skips are recorded in EXPERIMENTS.md §Dry-run."""
    cell = SHAPES[shape_name]
    if cell.kind == "decode" and not cfg.has_decode:
        return False, "encoder-only arch has no autoregressive decode step"
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "long_500k needs sub-quadratic attention; skipped for pure "
            "full-attention archs (DESIGN.md §4)"
        )
    return True, ""


def train_batch_specs(cfg, cell: ShapeCell) -> dict:
    """ShapeDtypeStruct stand-ins for one global training batch.

    The batch contract is per-layout (DESIGN.md §10): the packed layout
    additionally threads within-segment positions and segment ids through to
    the model — the same dict ``assemble_model_batch`` builds at train time,
    so the dry-run compiles exactly what training runs.
    """
    b, s = cell.global_batch, cell.seq_len
    if cfg.input_embeds:
        return {
            "embeds": ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16),
            "labels": ShapeDtypeStruct((b, s), jnp.int32),
            "loss_mask": ShapeDtypeStruct((b, s), jnp.float32),
        }
    specs = {
        "tokens": ShapeDtypeStruct((b, s), jnp.int32),
        "labels": ShapeDtypeStruct((b, s), jnp.int32),
        "loss_mask": ShapeDtypeStruct((b, s), jnp.float32),
    }
    if cell.layout == "packed":
        specs["positions"] = ShapeDtypeStruct((b, s), jnp.int32)
        specs["segments"] = ShapeDtypeStruct((b, s), jnp.int32)
    return specs


# -----------------------------------------------------------------------------
# Serving cells (continuous batching, DESIGN.md §12)
# -----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServeCell:
    """Shape contract of one continuous-batching serve deployment.

    ``num_slots`` fixes the decode batch rows (= KV-cache slots); ``max_len``
    the per-slot cache capacity; ``l_max`` the shared admission token budget
    (the Eq.-1 knob reused from training).  The packed prefill stream is
    bucketed separately (``PackedBucketSpec`` grid in the engine config), so
    the compiled-program census is: exactly one decode step + one prefill
    step per occupied (rows, capacity) bucket.
    """

    name: str
    num_slots: int
    max_len: int
    l_max: int


SERVE_SHAPES = {
    # Smoke/CI cell: what tests/test_serve.py and benchmarks/serving.py run.
    "serve_smoke": ServeCell("serve_smoke", 8, 256, 1024),
    # Production-shaped cell mirroring decode_32k's batch geometry.
    "serve_32k": ServeCell("serve_32k", 128, 32768, 1 << 22),
}


def serve_decode_specs(cell: ServeCell) -> tuple:
    """(tokens, lengths) stand-ins for the slot decode step."""
    return (
        ShapeDtypeStruct((cell.num_slots, 1), jnp.int32),
        ShapeDtypeStruct((cell.num_slots,), jnp.int32),
    )


def serve_prefill_specs(rows: int, cap: int, num_slots: int) -> tuple:
    """(tokens, positions, segments, dest_slot, gather_rows, gather_cols)
    stand-ins for one packed scatter-prefill bucket."""
    stream = ShapeDtypeStruct((rows, cap), jnp.int32)
    gather = ShapeDtypeStruct((num_slots,), jnp.int32)
    return (stream, stream, stream, stream, gather, gather)


def prefill_token_specs(cfg, cell: ShapeCell):
    if cfg.input_embeds:
        return ShapeDtypeStruct(
            (cell.global_batch, cell.seq_len, cfg.d_model), jnp.bfloat16
        )
    return ShapeDtypeStruct((cell.global_batch, cell.seq_len), jnp.int32)


def decode_token_specs(cell: ShapeCell):
    return ShapeDtypeStruct((cell.global_batch, 1), jnp.int32)
