"""Fault-tolerant training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3_0_6b \
        --dataset ultrachat --steps 50 --smoke

Wraps the ODB trainer in a resume loop: any crash (preemption, node loss)
restarts from the latest atomic checkpoint; the loader is stateless across
restarts (epoch-seeded), and elastic topology changes re-shard on restore
(train/checkpoint.py).  Straggler mitigation is inherent to the DGAP
alignment (slow ranks lower T_grp via S_min+/C_min+ instead of stalling the
step — see tests/test_protocol.py::test_straggler_liveness).
"""

from __future__ import annotations

import argparse
import dataclasses
import pathlib

import jax

from repro.configs import get_config, get_smoke_config
from repro.core import BucketSpec, OdbConfig
from repro.data import OnlineDynamicLoader, get_dataset
from repro.stream import EpochAborted
from repro.models import LM
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig


def _calibrate_layout(
    dataset, world: int, config: OdbConfig, steps: int, bucket_spec: BucketSpec
) -> str:
    """--layout auto: measured dense-vs-packed probe (benchmarks/layout.py)."""
    try:
        from benchmarks.layout import calibrate_layout
    except ImportError:  # benchmarks namespace lives at the repo root
        import pathlib
        import sys

        sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[3]))
        from benchmarks.layout import calibrate_layout

    cal = calibrate_layout(
        dataset, world, config, steps=steps, bucket_spec=bucket_spec
    )
    rows = cal["results"]
    for name, r in rows.items():
        print(
            f"[train] calibrate {name}: {r['steps_per_s']:.2f} steps/s  "
            f"dev-pad {100 * r['device_padding_fraction']:.2f}%"
        )
    print(f"[train] layout auto -> {cal['layout']}")
    return cal["layout"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_0_6b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--dataset", default="ultrachat")
    ap.add_argument("--data-scale", type=float, default=0.002)
    ap.add_argument("--world", type=int, default=4)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--l-max", type=int, default=4096)
    ap.add_argument("--buffer", type=int, default=256)
    ap.add_argument("--prefetch", type=int, default=64)
    ap.add_argument("--non-join", action="store_true")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument(
        "--round-deadline", type=float, default=None, metavar="SECONDS",
        help="per-round collective delivery deadline (DESIGN.md §15); a "
             "round whose gather misses it is retried with exponential "
             "backoff, and an exhausted retry budget aborts the epoch into "
             "a resumable checkpoint instead of hanging. Default: off",
    )
    ap.add_argument(
        "--round-retries", type=int, default=2,
        help="gather retries before a missed --round-deadline aborts",
    )
    ap.add_argument(
        "--max-quarantine", type=int, default=0,
        help="per-epoch budget of samples whose online realization may fail "
             "and be quarantined (accounted component X, DESIGN.md §15) "
             "instead of crashing the epoch. Default 0 = strict",
    )
    ap.add_argument(
        "--eager", action="store_true",
        help="offline data path (full-epoch length realization) instead of "
             "the default streaming executor",
    )
    ap.add_argument(
        "--lookahead", type=int, default=None,
        help="admission-window bound on realized lengths in flight "
             "(default: full view multiset, reproducing the eager schedule)",
    )
    ap.add_argument("--no-prefetch", action="store_true")
    ap.add_argument("--prefetch-depth", type=int, default=2)
    ap.add_argument(
        "--layout", default="dense", choices=("dense", "packed", "auto"),
        help="batch layout: dense bucket padding, packed segment streams "
             "(DESIGN.md §10), or auto — a short measured calibration probe "
             "picks the faster layout for this dataset profile",
    )
    ap.add_argument(
        "--calibration-steps", type=int, default=6,
        help="measured steps per layout for --layout auto",
    )
    ap.add_argument(
        "--attn-impl", default="auto", choices=("auto", "xla", "flash"),
        help="training attention route (DESIGN.md §11): XLA blockwise, the "
             "Pallas flash kernel, or auto (flash when packed on TPU)",
    )
    ap.add_argument(
        "--attn-grid", default="auto", choices=("auto", "dense", "pruned"),
        help="flash grid variant (DESIGN.md §17): dense walks every kv tile, "
             "pruned skips dead-tile DMA through the scalar-prefetch "
             "liveness index; auto = pruned when packed on TPU",
    )
    ap.add_argument(
        "--attn-autotune", action="store_true",
        help="pick the flash kernel's (block_q, block_kv) per shape cell "
             "from a short measured probe (cached under artifacts/autotune/)",
    )
    ap.add_argument(
        "--device-put", action="store_true",
        help="stage jax.device_put on the prefetch producer so H2D hides "
             "under the jitted step",
    )
    ap.add_argument(
        "--num-workers", type=int, default=0,
        help="spawned realization worker processes staging steps through a "
             "shared-memory ring (DESIGN.md §14); 0 = in-process path. The "
             "delivered step stream is bit-identical either way",
    )
    ap.add_argument(
        "--telemetry", default=None, metavar="DIR",
        help="enable the obs subsystem and write metrics.json / trace.json / "
             "rounds.json into DIR at exit (DESIGN.md §13)",
    )
    ap.add_argument(
        "--telemetry-port", type=int, default=None, metavar="PORT",
        help="serve a live Prometheus scrape endpoint (GET /metrics) from a "
             "daemon thread on this port while training (0 = ephemeral); "
             "independent of --telemetry's at-exit files",
    )
    ap.add_argument(
        "--hosts", type=int, default=1,
        help="simulated multi-host lane (DESIGN.md §16): partition the DGAP "
             "ranks over this many sharded admission windows, each running "
             "its own cursor over its rank block. Run under "
             "XLA_FLAGS=--xla_force_host_platform_device_count=N to give "
             "each simulated host its own device block; must divide --world",
    )
    args = ap.parse_args()

    reporter = None
    if args.telemetry:
        from repro import obs

        # Before any instrumented object is built, so construction-time
        # cached instruments bind to live metrics.
        reporter = obs.enable_telemetry(args.telemetry)
    scrape = None
    if args.telemetry_port is not None:
        from repro import obs

        scrape = obs.start_scrape_server(args.telemetry_port)
        print(f"[train] telemetry scrape: {scrape.url}")

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(
        cfg, attn_impl=args.attn_impl, attn_grid=args.attn_grid,
        attn_autotune=args.attn_autotune,
    )
    model = LM(cfg)
    dataset = get_dataset(args.dataset, scale=args.data_scale)
    odb_cfg = OdbConfig(
        l_max=args.l_max, buffer_size=args.buffer,
        prefetch_factor=args.prefetch, num_workers=4,
        join_mode=not args.non_join,
        round_deadline_s=args.round_deadline,
        round_retries=args.round_retries,
        max_quarantine=args.max_quarantine,
    )
    bucket_spec = BucketSpec(min_len=128, max_len=16384, max_count=1024)
    layout = args.layout
    if layout == "auto":
        layout = _calibrate_layout(
            dataset, args.world, odb_cfg, args.calibration_steps, bucket_spec
        )
    loader = OnlineDynamicLoader(
        dataset,
        world_size=args.world,
        config=odb_cfg,
        bucket_spec=bucket_spec,
        layout=layout,
        vocab_size=cfg.vocab_size,
        num_hosts=args.hosts,
    )
    trainer = Trainer(
        model, loader,
        OptimizerConfig(total_steps=max(args.steps, 100)),
        TrainerConfig(
            checkpoint_dir=args.checkpoint_dir, checkpoint_every=20,
            log_every=5, max_steps=args.steps,
            streaming=not args.eager, prefetch=not args.no_prefetch,
            prefetch_depth=args.prefetch_depth, lookahead=args.lookahead,
            device_put=args.device_put, num_workers=args.num_workers,
        ),
    )

    restarts = 0
    while True:
        try:
            state, step = trainer.restore_or_init(jax.random.PRNGKey(0))
            epoch = 0
            while step < args.steps:
                state, step = trainer.train_epoch(state, epoch=epoch, start_step=step)
                epoch += 1
            break
        except KeyboardInterrupt:
            raise
        except EpochAborted as exc:  # degraded-mode closure (DESIGN.md §15.4)
            restarts += 1
            print(
                f"[train] epoch aborted ({exc.cause}); "
                f"restart {restarts}/{args.max_restarts}"
            )
            if exc.failed_ranks:
                # Full casualty list, not just the first straggler — a
                # multi-rank stall usually means a shared link, not a node.
                print(f"[train] failed ranks: {exc.failed_ranks}")
            if args.checkpoint_dir:
                # The abort carries a valid stream checkpoint; persist it
                # beside the model checkpoints so an operator (or the next
                # restart of a stream-resuming driver) can continue the
                # identical step sequence instead of replaying the epoch.
                abort_path = pathlib.Path(args.checkpoint_dir) / "stream_abort.json"
                exc.checkpoint().save(str(abort_path))
                print(f"[train] abort stream checkpoint: {abort_path}")
            if restarts > args.max_restarts or not args.checkpoint_dir:
                raise
        except Exception as exc:  # crash -> resume from latest checkpoint
            restarts += 1
            print(f"[train] crash ({type(exc).__name__}: {exc}); restart {restarts}")
            if restarts > args.max_restarts or not args.checkpoint_dir:
                raise

    print(
        f"[train] layout={layout} attn_impl={trainer.attn_impl} "
        f"attn_grid={trainer.attn_grid}"
    )
    for h in trainer.history[-10:]:
        print(Trainer.format_log_line(h))
    audit = loader.last_audit
    if audit:
        print(f"eta_identity={audit.eta_identity} eta_quota={audit.eta_quota}")
    if loader.last_prefetch_stats is not None:
        st = loader.last_prefetch_stats
        print(f"prefetch hit_rate={st.hit_rate:.2f} waits={st.wait_s:.3f}s")
    if loader.last_worker_stats is not None:
        ws = loader.last_worker_stats
        print(
            f"workers completed={ws.completed} shm={ws.shm_results} "
            f"inline={ws.inline_results} reexec={ws.reexecuted} "
            f"failures={ws.worker_failures} wait={ws.wait_s:.3f}s"
        )
    if reporter is not None:
        executor = loader.last_executor
        paths = reporter.write(
            round_audit=None if executor is None else executor.telemetry,
            extra={
                "arch": cfg.name,
                "layout": layout,
                "attn_impl": trainer.attn_impl,
                "steps": step,
            },
        )
        for kind, path in sorted(paths.items()):
            print(f"[train] telemetry {kind}: {path}")
    if scrape is not None:
        scrape.stop()


if __name__ == "__main__":
    main()
