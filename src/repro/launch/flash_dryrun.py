import os

if __name__ == "__main__":  # device count must be locked before jax init
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ.get("FLASH_DRYRUN_DEVICES", "256")
        + " "
        + os.environ.get("XLA_FLAGS", "")
    ).strip()

"""Flash-under-sharding dry-run (DESIGN.md §17; ROADMAP PR-3 follow-on).

The kernel route was only ever exercised single-device (interpret on CPU,
compiled single-chip on TPU).  This cell validates ``pallas_call`` under the
production mesh: the flash forward + grads wrapped in ``shard_map`` over the
batch (data-parallel) axes, lowered and compiled against abstract inputs —
no allocation — for both grid variants.  The pruned variant builds its
liveness tables INSIDE the sharded region from the local segment shard, so
the scalar-prefetch indices are per-shard local (exactly what a real
multi-host run needs: no global table gather).

As a module (``python -m repro.launch.flash_dryrun``) it forces the
production device count (override with FLASH_DRYRUN_DEVICES) and writes
``artifacts/dryrun/flash_sharded.json``; ``validate_flash_sharded`` is the
in-process entry benchmarks and tests call against any mesh.
"""

import argparse
import json
import pathlib
import time
import traceback

ARTIFACT_DIR = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _sharded_flash_fn(mesh, grid: str, *, causal=True, block_q=128, block_kv=128):
    """shard_map'd loss+grads over the flash route, batch sharded on DP."""
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.kernels.ops import flash_attention
    from repro.launch.mesh import dp_axes

    dp = dp_axes(mesh)
    batch_spec = P(dp)

    def local_loss(q, k, v, seg):
        # Liveness tables (grid="pruned") are built inside this body from
        # the *local* segment shard — per-shard scalar prefetch, no global
        # index exchange.
        out = flash_attention(q, k, v, seg, causal, block_q, block_kv, grid)
        return jnp.sum(out.astype(jnp.float32) ** 2)

    def local_body(q, k, v, seg):
        loss, grads = jax.value_and_grad(local_loss, argnums=(0, 1, 2))(
            q, k, v, seg
        )
        return loss[None], grads  # rank-1 per-shard loss, concat over DP

    def sharded(q, k, v, seg):
        loss, grads = shard_map(
            local_body,
            mesh=mesh,
            in_specs=(batch_spec, batch_spec, batch_spec, batch_spec),
            out_specs=(batch_spec, (batch_spec, batch_spec, batch_spec)),
            check_rep=False,
        )(q, k, v, seg)
        # Per-shard partial losses; summing them is the global objective.
        return jnp.sum(loss), grads

    return jax.jit(sharded)


def validate_flash_sharded(
    mesh,
    grid: str,
    *,
    rows_per_shard: int = 2,
    seq: int = 512,
    heads: int = 4,
    kv_heads: int = 2,
    head_dim: int = 64,
    block_q: int = 128,
    block_kv: int = 128,
    compile_only: bool = True,
) -> dict:
    """Lower + compile (optionally execute) the sharded flash cell.

    ``rows_per_shard`` scales the global batch to ``dp_size(mesh)`` so the
    batch axis always divides the DP extent.
    """
    import jax
    import jax.numpy as jnp

    from repro.launch.mesh import dp_size

    dp = dp_size(mesh)
    b = rows_per_shard * dp
    record = {
        "grid": grid,
        "mesh": dict(mesh.shape),
        "batch": b,
        "seq": seq,
        "heads": heads,
        "kv_heads": kv_heads,
        "head_dim": head_dim,
        "compile_only": compile_only,
    }
    t0 = time.perf_counter()
    try:
        fn = _sharded_flash_fn(
            mesh, grid, block_q=block_q, block_kv=block_kv
        )
        f32 = jnp.float32
        abstract = (
            jax.ShapeDtypeStruct((b, seq, heads, head_dim), f32),
            jax.ShapeDtypeStruct((b, seq, kv_heads, head_dim), f32),
            jax.ShapeDtypeStruct((b, seq, kv_heads, head_dim), f32),
            jax.ShapeDtypeStruct((b, seq), jnp.int32),
        )
        compiled = fn.lower(*abstract).compile()
        record["compile_s"] = round(time.perf_counter() - t0, 3)
        try:
            mem = compiled.memory_analysis()
            if mem is not None:
                record["argument_bytes"] = int(
                    getattr(mem, "argument_size_in_bytes", 0)
                )
                record["temp_bytes"] = int(getattr(mem, "temp_size_in_bytes", 0))
        except Exception:
            pass
        record["status"] = "ok"
    except Exception as exc:  # surfaced in the bench rail / CI assert
        record["status"] = "error"
        record["error"] = f"{type(exc).__name__}: {exc}"
        record["traceback"] = traceback.format_exc(limit=12)
    return record


def main() -> None:
    import jax

    from repro.launch.mesh import make_production_mesh

    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--mesh", default="single", choices=("single", "multi"),
        help="production mesh: single-pod 16x16 or two-pod 2x16x16 "
             "(needs FLASH_DRYRUN_DEVICES=512)",
    )
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--rows-per-shard", type=int, default=2)
    ap.add_argument("--json", action="store_true", help="print the record JSON")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    records = {}
    for grid in ("dense", "pruned"):
        rec = validate_flash_sharded(
            mesh, grid, rows_per_shard=args.rows_per_shard, seq=args.seq
        )
        records[grid] = rec
        if not args.json:
            print(
                f"[flash-dryrun] grid={grid} mesh={args.mesh} "
                f"chips={mesh.devices.size} status={rec['status']} "
                f"compile={rec.get('compile_s', float('nan'))}s"
            )
            if rec["status"] != "ok":
                print(rec.get("traceback", rec.get("error", "")))

    out = {
        "backend": jax.default_backend(),
        "devices": jax.device_count(),
        "cells": records,
    }
    ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)
    path = ARTIFACT_DIR / "flash_sharded.json"
    path.write_text(json.dumps(out, indent=1))
    if args.json:
        print(json.dumps(out))
    else:
        print(f"[flash-dryrun] artifact: {path}")
    if any(r["status"] != "ok" for r in records.values()):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
