"""Serving launcher: continuous batching on the ODB admission core.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_0_6b --smoke \
        --requests 24 --slots 8 --max-len 256 --l-max 1024

Replaces the old static-batch loop: heterogeneous-length requests are
admitted into in-flight decode batches under the shared ``l_max`` budget
(DESIGN.md §12); completed requests free KV slots that the next tick
refills.  ``--mode static`` runs the identical jitted steps in
drain-before-refill mode — the old loop's schedule — for an A/B on the same
request trace (benchmarks/serving.py measures this properly).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import LM
from repro.serve import ContinuousBatchingEngine, ServeConfig, synth_request_trace


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_0_6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--prompt-min", type=int, default=8)
    ap.add_argument("--prompt-max", type=int, default=96)
    ap.add_argument("--new-min", type=int, default=2)
    ap.add_argument("--new-max", type=int, default=48)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--l-max", type=int, default=1024)
    ap.add_argument("--lookahead", type=int, default=32)
    ap.add_argument("--mode", default="continuous", choices=("continuous", "static"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--telemetry", default=None, metavar="DIR",
        help="enable the obs subsystem and write metrics.json / trace.json "
             "into DIR at exit (DESIGN.md §13)",
    )
    ap.add_argument(
        "--telemetry-port", type=int, default=None, metavar="PORT",
        help="serve a live Prometheus scrape endpoint (GET /metrics) from a "
             "daemon thread while serving (0 = ephemeral port)",
    )
    args = ap.parse_args()

    reporter = None
    if args.telemetry:
        from repro import obs

        reporter = obs.enable_telemetry(args.telemetry)
    scrape = None
    if args.telemetry_port is not None:
        from repro import obs

        scrape = obs.start_scrape_server(args.telemetry_port)
        print(f"[serve] telemetry scrape: {scrape.url}")

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.has_decode:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode step")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))

    engine = ContinuousBatchingEngine(
        model, params,
        ServeConfig(
            num_slots=args.slots, max_len=args.max_len, l_max=args.l_max,
            lookahead=args.lookahead, continuous=args.mode == "continuous",
        ),
    )
    trace = synth_request_trace(
        args.requests, vocab=cfg.vocab_size,
        prompt_min=args.prompt_min, prompt_max=args.prompt_max,
        new_min=args.new_min, new_max=args.new_max, seed=args.seed,
    )
    t0 = time.perf_counter()
    rids = [engine.submit(p, n) for p, n in trace]
    outputs = engine.run()
    wall = time.perf_counter() - t0

    lat = np.array([engine.requests[r].latency_s for r in rids])
    ttft = np.array(
        [engine.requests[r].first_token_s - engine.requests[r].submitted_s for r in rids]
    )
    st = engine.stats
    print(
        f"arch={cfg.name} mode={args.mode} requests={args.requests} "
        f"slots={args.slots} l_max={args.l_max}"
    )
    print(
        f"tokens/s: {st.generated_tokens / wall:.1f}  "
        f"({st.generated_tokens} tokens in {wall:.2f}s, "
        f"{st.decode_steps} decode steps, occupancy "
        f"{100 * st.slot_decode_occupancy:.0f}%)"
    )
    print(
        f"latency p50/p99: {1e3 * np.percentile(lat, 50):.0f}/"
        f"{1e3 * np.percentile(lat, 99):.0f} ms; "
        f"ttft p50: {1e3 * np.percentile(ttft, 50):.0f} ms"
    )
    print(
        f"compile-once: decode traced {engine.decode_traces}x, prefill "
        f"buckets {dict(engine.prefill_traces)}"
    )
    sample = outputs[rids[0]]
    print("generated ids[0]:", [int(t) for t in sample])
    if reporter is not None:
        paths = reporter.write(
            extra={
                "arch": cfg.name,
                "mode": args.mode,
                "requests": args.requests,
                "slots": args.slots,
            }
        )
        for kind, path in sorted(paths.items()):
            print(f"[serve] telemetry {kind}: {path}")
    if scrape is not None:
        scrape.stop()


if __name__ == "__main__":
    main()
