"""Serving launcher: batched prefill + autoregressive decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_0_6b --smoke \
        --batch 4 --prompt-len 32 --new-tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.models import LM


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_0_6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.has_decode:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode step")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.new_tokens

    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 1, cfg.vocab_size
    )
    t0 = time.perf_counter()
    logits, caches = model.prefill(params, prompts, max_len=max_len)
    tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t_prefill = time.perf_counter() - t0

    decode = jax.jit(model.decode_step)
    out = [tokens]
    idx = jnp.array(args.prompt_len, jnp.int32)
    t0 = time.perf_counter()
    for _ in range(args.new_tokens - 1):
        logits, caches = decode(params, caches, tokens, idx)
        tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tokens)
        idx = idx + 1
    jax.block_until_ready(tokens)
    t_decode = time.perf_counter() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len}")
    print(f"prefill: {t_prefill*1e3:.1f} ms; decode: "
          f"{1e3 * t_decode / max(args.new_tokens - 1, 1):.2f} ms/token")
    print("generated ids[0]:", [int(t) for t in gen[0]])


if __name__ == "__main__":
    main()
