"""Core ODB library — the paper's contribution as composable modules."""

from repro.core.alignment import (
    AlignmentResult,
    RankAlignmentState,
    align_all,
    align_rank,
    alignment_target,
    overflow_downward,
    split_upward,
)
from repro.core.buckets import (
    BucketSpec,
    PackedBatch,
    PackedBucketSpec,
    PaddedBatch,
    idle_batch,
    pack_group,
    pad_group,
    sample_token_ids,
)
from repro.core.layout import (
    LAYOUTS,
    BatchLayout,
    DenseLayout,
    DeviceBatch,
    PackedLayout,
    device_padding_stats,
    global_batch_arrays,
    make_layout,
    unify_step_shapes,
)
from repro.core.comm import (
    JaxProcessCollective,
    LoopbackCollective,
    ProtocolDesyncError,
    metadata_round_bytes,
)
from repro.core.grouping import (
    Group,
    Sample,
    greedy_group,
    padding_stats,
    target_group_size,
)
from repro.core.loss_scaling import (
    RankLossStats,
    ddp_scaled_loss,
    prescale_factor,
    reference_per_token_loss,
    sample_weights,
    token_weights,
)
from repro.core.metadata import EmitAccounting, StepMetadata, step_metadata
from repro.core.protocol import (
    IDLE,
    BoundedTerminationError,
    EpochAudit,
    EpochRunner,
    IterationResult,
    OdbConfig,
    OdbProtocolEngine,
    RankRuntime,
    RoundRecord,
    ViewSource,
    run_epoch,
)
