"""Dynamic batch sizing and greedy length grouping (paper §2.2, App. D).

ODB keeps the per-batch token count roughly constant via a user-specified
token budget ``L_max``.  For a realized post-pipeline sample length ``l`` the
target local group size is

    B(l) = max(floor(L_max / l), 1)                         (Eq. 1)

so that ``B(l) * l ~= L_max``.

Grouping algorithm (threshold carry-over, §2.2): buffered samples are sorted
ascending by length and iterated *from longest to shortest* with a running
group-size threshold ``t`` (initially 1).  Each sample is appended to the
current group; when the group size reaches ``t`` the group is finalized and
``t <- B(l)`` for the last-added (shortest) sample.  Successive groups
naturally hold more samples since shorter ``l`` yields larger ``B(l)``, so
per-group token counts converge to ``L_max`` (App. D worked example).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Sequence


@dataclasses.dataclass(frozen=True)
class Sample:
    """A sampler view after the online pipeline has realized its length.

    Attributes:
      view_id:  unique id of the *sampler view* (distinct for padding views).
      identity: dataset identity index in ``[0, N)`` — several views may map
                to one identity because ``DistributedSampler(drop_last=False)``
                pads the view multiset to ``W * ceil(N / W)`` (App. C.1).
      length:   realized post-pipeline token length (`len(input_ids)` after
                preprocessing, augmentation, templating, tokenization and
                visual-token expansion).
      payload:  opaque per-sample data carried through to the collate_fn.
    """

    view_id: int
    identity: int
    length: int
    payload: object = None

    def __post_init__(self) -> None:
        if self.length <= 0:
            raise ValueError(f"sample length must be positive, got {self.length}")


@dataclasses.dataclass(frozen=True)
class Group:
    """A finalized variable-size batch of samples (one optimizer micro-group)."""

    samples: tuple[Sample, ...]

    def __post_init__(self) -> None:
        if not self.samples:
            raise ValueError("empty group")

    @property
    def size(self) -> int:
        return len(self.samples)

    @property
    def max_length(self) -> int:
        return max(s.length for s in self.samples)

    @property
    def real_tokens(self) -> int:
        return sum(s.length for s in self.samples)

    @property
    def padded_tokens(self) -> int:
        """Token area after right-padding every sample to the group max."""
        return self.size * self.max_length

    @property
    def padding_fraction(self) -> float:
        padded = self.padded_tokens
        return 0.0 if padded == 0 else 1.0 - self.real_tokens / padded

    def lengths(self) -> tuple[int, ...]:
        return tuple(s.length for s in self.samples)


def target_group_size(length: int, l_max: int) -> int:
    """``B(l) = max(floor(L_max / l), 1)`` — Eq. 1 (clamped memory rule)."""
    if length <= 0:
        raise ValueError(f"length must be positive, got {length}")
    if l_max <= 0:
        raise ValueError(f"L_max must be positive, got {l_max}")
    return max(l_max // length, 1)


def greedy_group(
    samples: Sequence[Sample],
    l_max: int,
    *,
    size_rule: Callable[[int, int], int] = target_group_size,
) -> list[Group]:
    """Threshold-carry greedy grouping (§2.2; worked example App. D).

    Sort ascending, iterate longest → shortest with running threshold ``t``
    (init 1).  Append each sample to the current group; when the group size
    reaches ``t``, finalize and set ``t <- B(l_last_added)``.  A trailing
    partial group (size < t at exhaustion) is finalized as-is so no sample is
    ever dropped (conservation feeds Lemma 1).

    Returns groups in finalization order (longest-sample group first, like the
    paper's App. D trace: G1=[800], G2=[500], G3=[100, 200]).
    """
    if l_max <= 0:
        raise ValueError(f"L_max must be positive, got {l_max}")
    ordered = sorted(samples, key=lambda s: s.length)  # ascending
    groups: list[Group] = []
    current: list[Sample] = []
    threshold = 1
    for sample in reversed(ordered):  # longest -> shortest
        current.append(sample)
        if len(current) >= threshold:
            groups.append(Group(samples=tuple(current)))
            current = []
            threshold = size_rule(sample.length, l_max)
    if current:
        groups.append(Group(samples=tuple(current)))
    return groups


def regroup(samples: Iterable[Sample], l_max: int) -> list[Group]:
    """Re-run grouping over recirculated + fresh samples (overflow reuse)."""
    return greedy_group(list(samples), l_max)


def padding_stats(groups: Sequence[Group]) -> dict[str, float]:
    """Cumulative padding statistics over a set of groups.

    ``pad%`` follows the paper's definition (App. I, Table 13):
    ``1 - sum(L_real) / sum(L_compute)`` where L_compute pads each sample to
    its group max.
    """
    real = sum(g.real_tokens for g in groups)
    padded = sum(g.padded_tokens for g in groups)
    return {
        "groups": float(len(groups)),
        "samples": float(sum(g.size for g in groups)),
        "real_tokens": float(real),
        "padded_tokens": float(padded),
        "padding_fraction": 0.0 if padded == 0 else 1.0 - real / padded,
        "mean_group_tokens": float(padded) / len(groups) if groups else 0.0,
    }
