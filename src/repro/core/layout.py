"""Pluggable batch-layout engine (DESIGN.md §10).

A *layout* decides what an aligned ODB ``Group`` becomes on device.  Both
built-in layouts emit the same :class:`DeviceBatch` contract — tokens,
within-segment positions, segment ids, loss mask, per-row lengths plus
accounting metadata — which is exactly what ``LM.loss_sums`` consumes, so the
loader, trainer, jitted step and benchmarks are all layout-agnostic:

  * :class:`DenseLayout` — the paper-deployed form: one sample per row,
    right-padded to a geometric ``(count, length)`` bucket
    (:class:`~repro.core.buckets.BucketSpec`).  Contamination-free by
    construction (rows are independent batch elements under causal masking).
  * :class:`PackedLayout` — contamination-free packing: samples are first-fit
    packed into ``(rows, row_capacity)`` segment-id-tagged streams.  The row
    capacity is searched over the grid for the minimum-area plan (it must fit
    the longest sample but never the whole stream), so Pallas kernel block
    shapes stay bounded while right-padding decays to the row tails; the row
    count is bucketed on a short grid to bound compiled programs.

Layout invariants shared by both (tests/test_layout.py):

  * every sample lands in exactly one row and never straddles a row border;
  * ``segments`` are non-zero exactly where ``loss_mask`` is non-zero, with a
    distinct id per sample within a row (0 = padding);
  * ``positions`` restart from 0 at every segment start;
  * token ids come from the one shared synthesis point
    (:func:`~repro.core.buckets.sample_token_ids`), so the two layouts carry
    bit-identical streams for the same sample.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro import obs
from repro.core.buckets import BucketSpec, PackedBucketSpec, sample_token_ids
from repro.core.grouping import Group


def _observe_step(name: str, row: Sequence["DeviceBatch"]) -> None:
    """Publish one built step's padding accounting (DESIGN.md §13).

    ``odb_layout_pad_fraction`` is the device-side padding share of the whole
    step (IDLE ranks included — their all-pad area is real device waste);
    ``odb_layout_pack_fill`` is the fill of the rank batches that carry real
    samples, i.e. how well the layout packs where there is anything to pack.
    """
    real = sum(b.real_tokens for b in row)
    area = sum(b.area for b in row)
    occupied_area = sum(b.area for b in row if b.real_samples)
    obs.counter(
        "odb_layout_real_tokens_total", help="real tokens laid out", layout=name
    ).inc(real)
    obs.counter(
        "odb_layout_device_tokens_total",
        help="device token slots shipped",
        layout=name,
    ).inc(area)
    obs.counter(
        "odb_layout_steps_total", help="aligned steps built", layout=name
    ).inc()
    if area:
        obs.gauge(
            "odb_layout_pad_fraction",
            help="device-side padding fraction of the last built step",
        ).set(1.0 - real / area)
    if occupied_area:
        obs.gauge(
            "odb_layout_pack_fill",
            help="real-token fill of non-IDLE rank batches in the last step",
        ).set(real / occupied_area)


@dataclasses.dataclass(frozen=True)
class DeviceBatch:
    """One rank's device-ready batch — the common output of every layout."""

    tokens: np.ndarray  # (rows, T) int32
    positions: np.ndarray  # (rows, T) int32 — within-segment positions
    segments: np.ndarray  # (rows, T) int32 — 0 = padding, >=1 per sample
    loss_mask: np.ndarray  # (rows, T) float32 — 1 on real tokens
    lengths: np.ndarray  # (rows,) int32 — real tokens per row (0 = pad row)
    real_samples: int
    real_tokens: int

    @property
    def shape(self) -> tuple[int, int]:
        return self.tokens.shape  # type: ignore[return-value]

    @property
    def area(self) -> int:
        """Device-side token slots this batch occupies (rows × T)."""
        return int(self.tokens.shape[0] * self.tokens.shape[1])

    @property
    def padding_fraction(self) -> float:
        area = self.area
        return 1.0 - self.real_tokens / area if area else 0.0


def _zero_batch(shape: tuple[int, int]) -> DeviceBatch:
    rows, t = shape
    return DeviceBatch(
        tokens=np.zeros((rows, t), np.int32),
        positions=np.zeros((rows, t), np.int32),
        segments=np.zeros((rows, t), np.int32),
        loss_mask=np.zeros((rows, t), np.float32),
        lengths=np.zeros((rows,), np.int32),
        real_samples=0,
        real_tokens=0,
    )


class BatchLayout:
    """Strategy interface: Group → DeviceBatch, plus SPMD shape plumbing."""

    name: str = "abstract"
    #: whether the jitted step needs explicit positions/segments in the batch
    #: (dense rows are one-sample-per-row, so the model's arange default and
    #: causal masking already realize the identical objective).
    needs_segments: bool = False

    def build(self, group: Group) -> DeviceBatch:  # pragma: no cover
        raise NotImplementedError

    def build_step(self, step: Sequence[Group | None]) -> list[DeviceBatch]:
        """Realize one aligned step (IDLE = None) into same-shape batches.

        The returned batches already share the step-max shape, so what the
        per-batch accounting sums is exactly what the SPMD step ships to
        device.  Layouts may override to *plan* at step scope (the packed
        layout coordinates one row capacity across ranks instead of letting
        per-rank plans diverge and paying for it at unification).
        """
        built = [None if g is None else self.build(g) for g in step]
        real = [b for b in built if b is not None]
        shape = real[-1].shape if real else self.fallback_shape()
        row = self.unify(
            [self.idle_like(shape) if b is None else b for b in built]
        )
        _observe_step(self.name, row)
        return row

    def idle_like(self, shape: tuple[int, int]) -> DeviceBatch:
        """IDLE_DATA sentinel: an all-padding batch annihilated by Eq. 2."""
        return _zero_batch(shape)

    def fallback_shape(self) -> tuple[int, int]:  # pragma: no cover
        """Smallest legal shape — used for all-IDLE steps."""
        raise NotImplementedError

    # -- SPMD shape unification ------------------------------------------------
    def unify(self, batches: Sequence[DeviceBatch]) -> list[DeviceBatch]:
        """Re-pad all ranks' batches to the step-max shape (SPMD needs one
        global shape; grids are shared across ranks so the per-axis max is
        itself a grid point)."""
        rows = max(b.tokens.shape[0] for b in batches)
        t = max(b.tokens.shape[1] for b in batches)
        out = []
        for b in batches:
            if b.tokens.shape == (rows, t):
                out.append(b)
                continue
            sn, sl = b.tokens.shape
            grown = _zero_batch((rows, t))
            grown.tokens[:sn, :sl] = b.tokens
            grown.positions[:sn, :sl] = b.positions
            grown.segments[:sn, :sl] = b.segments
            grown.loss_mask[:sn, :sl] = b.loss_mask
            grown.lengths[:sn] = b.lengths
            out.append(
                dataclasses.replace(
                    grown, real_samples=b.real_samples, real_tokens=b.real_tokens
                )
            )
        return out


@dataclasses.dataclass(frozen=True)
class DenseLayout(BatchLayout):
    """Right-pad each sample to its own row of the ``(count, len)`` bucket."""

    spec: BucketSpec = dataclasses.field(default_factory=BucketSpec)
    vocab_size: int = 32000
    pad_id: int = 0
    token_fn: object = None

    name = "dense"
    needs_segments = False

    def build(self, group: Group) -> DeviceBatch:
        n_b, l_b = self.spec.bucket_shape(group.size, group.max_length)
        batch = _zero_batch((n_b, l_b))
        if self.pad_id:
            batch.tokens.fill(self.pad_id)
        arange = np.arange(l_b, dtype=np.int32)
        batch.positions[:] = arange  # model default; pads are masked anyway
        for i, sample in enumerate(group.samples):
            ids = sample_token_ids(
                sample, vocab_size=self.vocab_size, token_fn=self.token_fn
            )
            batch.tokens[i, : sample.length] = ids
            batch.segments[i, : sample.length] = 1  # one sample per row
            batch.loss_mask[i, : sample.length] = 1.0
            batch.lengths[i] = sample.length
        return dataclasses.replace(
            batch, real_samples=group.size, real_tokens=group.real_tokens
        )

    def fallback_shape(self) -> tuple[int, int]:
        return self.spec.bucket_shape(1, self.spec.min_len)


@dataclasses.dataclass(frozen=True)
class PackedLayout(BatchLayout):
    """First-fit-decreasing packing into bounded ``(rows, row_capacity)``."""

    spec: PackedBucketSpec = dataclasses.field(default_factory=PackedBucketSpec)
    vocab_size: int = 32000
    pad_id: int = 0
    token_fn: object = None

    name = "packed"
    needs_segments = True

    @staticmethod
    def _first_fit(order: Sequence, cap: int) -> list[list]:
        rows: list[list] = []
        used: list[int] = []
        for sample in order:
            for r, u in enumerate(used):
                if u + sample.length <= cap:
                    rows[r].append(sample)
                    used[r] = u + sample.length
                    break
            else:
                rows.append([sample])
                used.append(sample.length)
        return rows

    @staticmethod
    def _order(group: Group) -> list:
        """Deterministic first-fit-decreasing order (ties break on view_id,
        so checkpoint/resume re-plans the identical packing)."""
        return sorted(group.samples, key=lambda s: (-s.length, s.view_id))

    def plan_rows(self, group: Group) -> tuple[int, list[list]]:
        """Pick (row_capacity, first-fit-decreasing row assignment).

        Every grid capacity that fits the longest sample AND keeps the row
        count within ``max_rows`` is a candidate; the one minimizing the
        bucketed device area wins (ties → the narrowest, which also gives
        the smallest kernel block shapes).
        """
        order = self._order(group)
        best: tuple[int, list[list]] | None = None
        best_area = None
        for cap in self.spec.grid():
            if cap < group.max_length:
                continue
            rows = self._first_fit(order, cap)
            if len(rows) > self.spec.max_rows:
                continue  # narrow cap needs too many rows; wider may fit
            area = self.spec.bucket_rows(len(rows)) * cap
            if best_area is None or area < best_area:
                best, best_area = (cap, rows), area
        if best is None:
            raise ValueError(
                f"group (max_length {group.max_length}, {group.size} samples)"
                f" does not fit the packed grid (max_tokens "
                f"{self.spec.max_tokens}, max_rows {self.spec.max_rows})"
            )
        return best

    def plan_step(
        self, groups: Sequence[Group]
    ) -> tuple[int, int, list[list[list]]]:
        """One (row_capacity, row_count) shared by every rank of a step.

        SPMD forces all ranks onto one batch shape anyway; planning it here
        — minimize ``bucket_rows(max rows over ranks) × cap`` over the grid —
        instead of unifying divergent per-rank plans afterwards means the
        shipped device area is exactly what the planner optimized.
        """
        orders = [self._order(g) for g in groups]
        floor = max(g.max_length for g in groups)
        best = None
        best_area = None
        for cap in self.spec.grid():
            if cap < floor:
                continue
            plans = [self._first_fit(o, cap) for o in orders]
            if max(len(p) for p in plans) > self.spec.max_rows:
                continue
            n_rows = self.spec.bucket_rows(max(len(p) for p in plans))
            area = n_rows * cap
            if best_area is None or area < best_area:
                best, best_area = (cap, n_rows, plans), area
        if best is None:
            raise ValueError(
                f"step (max_length {floor}) does not fit the packed grid "
                f"(max_tokens {self.spec.max_tokens}, "
                f"max_rows {self.spec.max_rows})"
            )
        return best

    def _emit(
        self, group: Group, rows: list[list], shape: tuple[int, int]
    ) -> DeviceBatch:
        batch = _zero_batch(shape)
        if self.pad_id:
            batch.tokens.fill(self.pad_id)
        for r, row in enumerate(rows):
            cursor = 0
            for seg_id, sample in enumerate(row, start=1):
                ids = sample_token_ids(
                    sample, vocab_size=self.vocab_size, token_fn=self.token_fn
                )
                end = cursor + sample.length
                batch.tokens[r, cursor:end] = ids
                batch.segments[r, cursor:end] = seg_id
                batch.positions[r, cursor:end] = np.arange(
                    sample.length, dtype=np.int32
                )
                batch.loss_mask[r, cursor:end] = 1.0
                cursor = end
            batch.lengths[r] = cursor
        return dataclasses.replace(
            batch, real_samples=group.size, real_tokens=group.real_tokens
        )

    def build(self, group: Group) -> DeviceBatch:
        cap, rows = self.plan_rows(group)
        return self._emit(group, rows, (self.spec.bucket_rows(len(rows)), cap))

    def build_step(self, step: Sequence[Group | None]) -> list[DeviceBatch]:
        groups = [g for g in step if g is not None]
        if not groups:
            row = [self.idle_like(self.fallback_shape()) for _ in step]
            _observe_step(self.name, row)
            return row
        cap, n_rows, plans = self.plan_step(groups)
        shape = (n_rows, cap)
        emitted = iter(
            self._emit(g, rows, shape) for g, rows in zip(groups, plans)
        )
        row = [
            self.idle_like(shape) if g is None else next(emitted) for g in step
        ]
        _observe_step(self.name, row)
        return row

    def fallback_shape(self) -> tuple[int, int]:
        return (1, self.spec.min_tokens)


LAYOUTS = ("dense", "packed")


def make_layout(
    name: str,
    *,
    bucket_spec: BucketSpec | None = None,
    packed_spec: PackedBucketSpec | None = None,
    vocab_size: int = 32000,
    token_fn=None,
) -> BatchLayout:
    """Factory from a ``--layout`` name; unknown names fail loudly."""
    if name == "dense":
        return DenseLayout(
            spec=bucket_spec or BucketSpec(),
            vocab_size=vocab_size,
            token_fn=token_fn,
        )
    if name == "packed":
        return PackedLayout(
            spec=packed_spec or PackedBucketSpec(),
            vocab_size=vocab_size,
            token_fn=token_fn,
        )
    raise KeyError(f"unknown batch layout {name!r}; have {LAYOUTS}")


# -----------------------------------------------------------------------------
# Step-level assembly (consumed by the trainer and the device-put stage)
# -----------------------------------------------------------------------------


def unify_step_shapes(
    batches: Sequence[DeviceBatch], layout: BatchLayout | None = None
) -> list[DeviceBatch]:
    """Layout-aware SPMD shape unification across one aligned step."""
    layout = layout or BatchLayout()
    return layout.unify(batches)


def global_batch_arrays(
    batches: Sequence[DeviceBatch], layout: BatchLayout | None = None
) -> dict[str, np.ndarray]:
    """Stack per-rank DeviceBatches into the global (W·rows, T) step arrays.

    A layout that does not need explicit positions/segments in the jitted
    step (dense) gets the lean two-array dict — no point assembling and
    shipping (B, T) int32 arrays the model never reads.
    """
    unified = unify_step_shapes(batches, layout)
    keys = ("tokens", "positions", "segments", "loss_mask")
    if layout is not None and not layout.needs_segments:
        keys = ("tokens", "loss_mask")
    return {
        k: np.concatenate([getattr(b, k) for b in unified], axis=0)
        for k in keys
    }


def device_padding_stats(batches: Sequence[DeviceBatch]) -> dict[str, float]:
    """Aggregate *device-side* padding: occupied slots vs real tokens."""
    real = sum(b.real_tokens for b in batches)
    area = sum(b.area for b in batches)
    return {
        "real_tokens": float(real),
        "device_tokens": float(area),
        "device_padding_fraction": 1.0 - real / area if area else 0.0,
    }
