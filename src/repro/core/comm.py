"""Host-side collective channel for the alignment protocol.

The paper runs the alignment metadata exchange on a dedicated Gloo group
inside the collate subprocess, fully isolated from the NCCL group used for
gradient AllReduce (~128 KB per round at W=8, overlapped with GPU compute).

In the JAX adaptation the channel is a *host-side* collective that never
enters the jitted program, so isolation from the ICI collectives is
structural.  Two implementations:

  * ``LoopbackCollective`` — in-process, round-synchronous.  All simulated
    ranks deposit their payload for round ``k``; the gathered list is returned
    to every rank.  Enforces and audits the **uniform all_gather invariant**
    (Lemma 3): every rank must call ``all_gather`` exactly once per round with
    the same round id, otherwise the channel raises — a deadlock in the real
    system surfaces as a hard error in tests.

  * ``JaxProcessCollective`` — thin wrapper over
    ``jax.experimental.multihost_utils`` for real multi-host deployments
    (one Python process per host).  Not exercised in this CPU container but
    kept API-compatible.

``ResilientCollective`` wraps either transport with the fault-tolerance
policy of DESIGN.md §15: a per-round delivery deadline, bounded retry with
exponential backoff + deterministic jitter, and a typed, *recoverable*
failure (:class:`RankTimeoutError`) that is distinct from the
unrecoverable-by-design :class:`ProtocolDesyncError`.  The wrapper memoizes
per-rank payloads so a retried round never re-runs the protocol's
side-effecting payload closures — only the transport attempt repeats.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import threading
import time
from typing import Any, Callable, Sequence

from repro import obs


class ProtocolDesyncError(RuntimeError):
    """A rank broke the uniform-call invariant (would deadlock on hardware)."""


class RankTimeoutError(RuntimeError):
    """A rank missed the per-round delivery deadline after bounded retries.

    Recoverable by construction (unlike :class:`ProtocolDesyncError`, which
    is a protocol *bug*): the failed gather never reached the audited
    transport, so every rank still holds its pre-gather state and an
    executor checkpoint taken afterwards resumes the identical round
    (``StreamExecutor`` converts this into a resumable ``EpochAborted``).

    ``failed_ranks`` carries EVERY rank that failed the final attempt (a
    correlated fault — a downed host — takes out several at once), with
    per-rank reasons in ``failures``; ``rank`` keeps the first for
    backward-compatible callers.
    """

    def __init__(
        self,
        message: str,
        *,
        rank: int | None = None,
        round_index: int | None = None,
        attempts: int = 0,
        failed_ranks: Sequence[int] | None = None,
        failures: Sequence[tuple[int, str]] | None = None,
    ) -> None:
        super().__init__(message)
        self.rank = rank
        self.round_index = round_index
        self.attempts = attempts
        if failed_ranks is None:
            failed_ranks = [] if rank is None else [rank]
        self.failed_ranks = list(failed_ranks)
        self.failures = [tuple(f) for f in (failures or [])]


@dataclasses.dataclass
class ChannelStats:
    rounds: int = 0
    bytes_exchanged: int = 0
    secondary_rounds: int = 0  # optional second gather (exact loss scaling)

    def record(self, payloads: Sequence[Any], secondary: bool) -> None:
        self.rounds += 1
        if secondary:
            self.secondary_rounds += 1
        try:
            self.bytes_exchanged += sum(
                len(json.dumps(p, default=str).encode()) for p in payloads
            )
        except TypeError:
            pass


class Collective:
    """Abstract round-synchronous all_gather over ``world_size`` ranks."""

    def __init__(self, world_size: int) -> None:
        if world_size <= 0:
            raise ValueError("world_size must be positive")
        self.world_size = world_size
        self.stats = ChannelStats()

    def all_gather(self, rank: int, payload: Any, *, tag: str = "primary") -> list[Any]:
        raise NotImplementedError


class LoopbackCollective(Collective):
    """Round-synchronous in-process collective driven by a protocol engine.

    The engine collects one payload per rank per round and then delivers the
    gathered list back; per-rank call counts are audited so a rank that calls
    out of lockstep (the distributed-deadlock failure mode) raises
    ``ProtocolDesyncError`` instead of hanging.
    """

    def __init__(self, world_size: int) -> None:
        super().__init__(world_size)
        self._pending: dict[str, dict[int, Any]] = {}
        self._calls_per_rank = [0] * world_size

    # -- engine-driven API ---------------------------------------------------
    def gather_round(
        self,
        payload_fn: Callable[[int], Any],
        *,
        tag: str = "primary",
    ) -> list[Any]:
        """Run one synchronous round: collect payloads from every rank.

        ``payload_fn(rank)`` plays the role of rank ``r`` reaching its
        ``all_gather`` call site.  Every rank *must* produce a payload — a
        rank that cannot (raises) is a protocol bug, mirrored as an exception.
        """
        payloads = [payload_fn(rank) for rank in range(self.world_size)]
        for rank in range(self.world_size):
            self._calls_per_rank[rank] += 1
        counts = set(self._calls_per_rank)
        if len(counts) != 1:
            raise ProtocolDesyncError(
                f"uniform all_gather invariant violated: per-rank call counts "
                f"{self._calls_per_rank}"
            )
        self.stats.record(payloads, secondary=(tag != "primary"))
        return payloads

    def all_gather(self, rank: int, payload: Any, *, tag: str = "primary") -> list[Any]:
        raise NotImplementedError(
            "LoopbackCollective is engine-driven; use gather_round()"
        )


class JaxProcessCollective(Collective):
    """Multi-host backend over jax.experimental.multihost_utils.

    One payload per host process; uses ``process_allgather`` on a flat int64
    metadata vector (the paper's [idx_budget, n_groups, sizes, tokens] layout
    extended by the §16 window summary — see :func:`encode_round_payload`).
    Functional for real ``world_size == 1`` on any runtime; larger worlds
    need a real multi-process JAX runtime (one Python process per host).

    Audited like :class:`LoopbackCollective`: per-tag call counts are
    tracked (every rank-driven round is exactly one ``all_gather`` per tag,
    Lemma 3), and a gather that returns the wrong number of payloads —
    the rank-driven symptom of a peer calling out of lockstep — raises
    :class:`ProtocolDesyncError` instead of silently mis-slicing.
    """

    def __init__(self, world_size: int) -> None:
        super().__init__(world_size)
        self.calls_per_tag: dict[str, int] = {}

    def all_gather(self, rank: int, payload: Any, *, tag: str = "primary") -> list[Any]:
        import numpy as np
        from jax.experimental import multihost_utils

        arr = np.asarray(payload, dtype=np.int64)
        gathered = np.asarray(multihost_utils.process_allgather(arr))
        if gathered.ndim == arr.ndim:
            # world_size == 1 runtimes return the input shape un-stacked.
            gathered = gathered[None, ...]
        if gathered.shape[0] != self.world_size:
            raise ProtocolDesyncError(
                f"gather returned {gathered.shape[0]} payloads for "
                f"world_size {self.world_size}: a peer called all_gather "
                f"out of lockstep (tag={tag!r})"
            )
        self.calls_per_tag[tag] = self.calls_per_tag.get(tag, 0) + 1
        primary = self.calls_per_tag.get("primary", 0)
        for t, n in self.calls_per_tag.items():
            if t != "primary" and n > primary:
                raise ProtocolDesyncError(
                    f"uniform all_gather invariant violated: tag {t!r} "
                    f"called {n}x against {primary} primary rounds"
                )
        out = [gathered[i] for i in range(gathered.shape[0])]
        self.stats.record([o.tolist() for o in out], secondary=(tag != "primary"))
        return out


# -- int64 wire codec for the round payload (deployment parity) ---------------
#
# ``LoopbackCollective`` moves the payload dict by reference; the rank-driven
# transport moves a flat int64 vector per process.  The layout extends the
# paper's [idx_budget, n_groups, sizes, tokens] schema with the §16 window
# summary so a real multi-host deployment exchanges admission state in the
# same single unconditional gather:
#
#   [ idx_budget, n_groups, n,
#     sizes[0..cap), tokens[0..cap),            # zero-padded to group cap
#     has_window, host, cursor, staged, delivered, resident,
#     qids[0..qcap) ]                           # -1-padded charged |X| ids

_WINDOW_SLOTS = 6  # has_window flag + the five summary fields


def round_payload_length(group_capacity: int, quarantine_capacity: int = 0) -> int:
    return 3 + 2 * group_capacity + _WINDOW_SLOTS + quarantine_capacity


def encode_round_payload(
    payload: dict, *, group_capacity: int, quarantine_capacity: int = 0
):
    """Flatten one rank's round payload dict to the fixed int64 wire layout."""
    import numpy as np

    sizes = list(payload.get("sizes", ()))
    tokens = list(payload.get("tokens", ()))
    if len(sizes) > group_capacity or len(tokens) > group_capacity:
        raise ValueError(
            f"{max(len(sizes), len(tokens))} groups exceed wire capacity "
            f"{group_capacity}"
        )
    vec = np.zeros(
        round_payload_length(group_capacity, quarantine_capacity), np.int64
    )
    vec[0] = payload["idx_budget"]
    vec[1] = payload["n_groups"]
    vec[2] = len(sizes)
    vec[3 : 3 + len(sizes)] = sizes
    base = 3 + group_capacity
    vec[base : base + len(tokens)] = tokens
    wbase = 3 + 2 * group_capacity
    window = payload.get("window")
    qids: list[int] = []
    if window is not None:
        vec[wbase] = 1
        vec[wbase + 1] = window.get("host", 0)
        vec[wbase + 2] = window.get("cursor", 0)
        vec[wbase + 3] = window.get("staged", 0)
        vec[wbase + 4] = window.get("delivered", 0)
        vec[wbase + 5] = window.get("resident", 0)
        qids = list(window.get("quarantined_ids", ()))
        if len(qids) > quarantine_capacity:
            raise ValueError(
                f"{len(qids)} quarantined ids exceed wire capacity "
                f"{quarantine_capacity}"
            )
    qbase = wbase + _WINDOW_SLOTS
    vec[qbase:] = -1
    vec[qbase : qbase + len(qids)] = qids
    return vec


def decode_round_payload(
    vec, *, group_capacity: int, quarantine_capacity: int = 0
) -> dict:
    """Invert :func:`encode_round_payload` back to the payload dict."""
    vec = [int(v) for v in vec]
    expected = round_payload_length(group_capacity, quarantine_capacity)
    if len(vec) != expected:
        raise ValueError(f"wire payload length {len(vec)} != {expected}")
    n = vec[2]
    out: dict[str, Any] = {
        "idx_budget": vec[0],
        "n_groups": vec[1],
        "sizes": vec[3 : 3 + n],
        "tokens": vec[3 + group_capacity : 3 + group_capacity + n],
    }
    wbase = 3 + 2 * group_capacity
    if vec[wbase]:
        qbase = wbase + _WINDOW_SLOTS
        out["window"] = {
            "host": vec[wbase + 1],
            "cursor": vec[wbase + 2],
            "staged": vec[wbase + 3],
            "delivered": vec[wbase + 4],
            "resident": vec[wbase + 5],
            "quarantined_ids": [q for q in vec[qbase:] if q >= 0],
        }
    return out


def _unit_jitter(*parts: object) -> float:
    """Deterministic uniform(0,1) from arbitrary parts (no wall-clock RNG)."""
    h = hashlib.sha1("|".join(str(p) for p in parts).encode()).digest()
    return int.from_bytes(h[:8], "big") / float(1 << 64)


class ResilientCollective(Collective):
    """Deadline + bounded-retry wrapper over another collective (§15).

    Policy per gather: attempt delivery; a rank that misses ``deadline_s``
    (or whose payload a fault injector drops) fails the attempt.  Up to
    ``max_retries`` retries follow, spaced by exponential backoff with
    deterministic jitter (``base · 2^(attempt-1) · U[0.5, 1.5)``, capped at
    ``backoff_cap_s``; the jitter is a pure hash of (seed, round, attempt)
    so fault runs replay bit-exactly).  When retries are exhausted the
    gather raises :class:`RankTimeoutError` — the caller's rank state is
    untouched because nothing reached the inner transport.

    Wrapping ``LoopbackCollective`` (engine-driven ``gather_round``): the
    per-rank payload closures run **once**, on the first attempt; retries
    replay the memoized payloads, so protocol side effects (candidate-group
    collection) never double-run and the inner collective's uniform-call
    audit still sees exactly one call per rank per logical round.  Injected
    faults are *simulated* against the deadline — chaos runs spend no wall
    clock on the faults themselves, only on the (configurable) backoff.

    Wrapping ``JaxProcessCollective`` (rank-driven ``all_gather``): the
    inner gather runs on a watchdog thread and the deadline bounds the
    join, so a wedged remote rank surfaces as ``RankTimeoutError`` instead
    of an indefinite hang (retrying assumes the transport tolerates
    re-entry, which ``process_allgather`` over a fresh round does).

    ``injector`` is the chaos hook (``repro.chaos.inject``): called as
    ``on_gather(round_index, attempt, rank, tag)`` and returns ``None``
    (clean), ``"drop"`` (payload lost), or a float (simulated delivery
    latency in seconds — a fault only if it exceeds the deadline).
    """

    def __init__(
        self,
        inner: Collective,
        *,
        deadline_s: float = 1.0,
        max_retries: int = 2,
        backoff_base_s: float = 0.05,
        backoff_cap_s: float = 2.0,
        injector: Any = None,
        sleep_fn: Callable[[float], None] = time.sleep,
        seed: int = 0,
    ) -> None:
        super().__init__(inner.world_size)
        if deadline_s <= 0:
            raise ValueError(f"deadline_s must be positive, got {deadline_s}")
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        self.inner = inner
        self.deadline_s = deadline_s
        self.max_retries = max_retries
        self.backoff_base_s = backoff_base_s
        self.backoff_cap_s = backoff_cap_s
        self.injector = injector
        self.sleep_fn = sleep_fn
        self.seed = seed
        self.stats = inner.stats  # one ChannelStats: the wrapper adds no rounds
        self.retries = 0  # failed attempts that were retried
        self.recovered = 0  # gathers that succeeded after >= 1 retry
        self._round_counter = 0  # wrapper-local gather ordinal (primary tag)
        self._m_retries = obs.counter(
            "odb_fault_retries_total",
            help="gather attempts retried after a deadline miss or drop",
        )
        self._m_recovered = obs.counter(
            "odb_fault_recovered_total",
            help="gathers that succeeded after at least one retry",
        )

    # -- retry policy ----------------------------------------------------------
    def _backoff_delay(self, round_index: int, attempt: int) -> float:
        base = min(
            self.backoff_cap_s, self.backoff_base_s * (2 ** max(attempt - 1, 0))
        )
        jitter = 0.5 + _unit_jitter("backoff", self.seed, round_index, attempt)
        return base * jitter

    def _failed_ranks(
        self, round_index: int, attempt: int, tag: str
    ) -> list[tuple[int, str]]:
        """Ranks whose delivery fails this attempt (injector-simulated)."""
        if self.injector is None:
            return []
        failed: list[tuple[int, str]] = []
        for rank in range(self.world_size):
            fault = self.injector.on_gather(round_index, attempt, rank, tag)
            if fault is None:
                continue
            if fault == "drop":
                failed.append((rank, "payload dropped"))
            else:
                delay = float(fault)
                if delay > self.deadline_s:
                    failed.append(
                        (rank, f"delivery {delay:.3f}s > deadline {self.deadline_s:.3f}s")
                    )
        return failed

    def _retry_loop(self, round_index: int, tag: str, attempt_fn):
        """Run ``attempt_fn(attempt) -> (ok, failures)`` under the policy."""
        attempt = 0
        failures: list[tuple[int, str]] = []
        while True:
            ok, failures = attempt_fn(attempt)
            if ok:
                if attempt > 0:
                    self.recovered += 1
                    self._m_recovered.inc()
                return
            self.retries += 1
            self._m_retries.inc()
            attempt += 1
            if attempt > self.max_retries:
                # Report EVERY failed rank, not just the first: the straggler
                # census, stream_abort.json and the operator's restart
                # decision all need the full casualty list of the round.
                ranks = [r for r, _ in failures]
                detail = (
                    "; ".join(f"rank {r}: {why}" for r, why in failures)
                    or "timeout"
                )
                raise RankTimeoutError(
                    f"round {round_index} ({tag}): ranks "
                    f"{ranks if ranks else '?'} failed delivery "
                    f"after {attempt} attempts ({detail})",
                    rank=ranks[0] if ranks else None,
                    round_index=round_index,
                    attempts=attempt,
                    failed_ranks=ranks,
                    failures=failures,
                )
            self.sleep_fn(self._backoff_delay(round_index, attempt))

    # -- engine-driven path (Loopback) -------------------------------------------
    def gather_round(
        self, payload_fn: Callable[[int], Any], *, tag: str = "primary"
    ) -> list[Any]:
        round_index = self._round_counter
        payloads: list[Any] | None = None

        def attempt(n: int):
            nonlocal payloads
            if payloads is None:
                # First attempt only: protocol payload closures may have side
                # effects (candidate collection); retries reuse the memo.
                payloads = [payload_fn(rank) for rank in range(self.world_size)]
            return (not (failed := self._failed_ranks(round_index, n, tag)), failed)

        self._retry_loop(round_index, tag, attempt)
        if tag == "primary":
            self._round_counter += 1
        assert payloads is not None
        return self.inner.gather_round(lambda r: payloads[r], tag=tag)

    # -- rank-driven path (JaxProcess) --------------------------------------------
    def all_gather(self, rank: int, payload: Any, *, tag: str = "primary") -> list[Any]:
        round_index = self._round_counter
        box: dict[str, Any] = {}

        def attempt(n: int):
            failed = [
                f for f in self._failed_ranks(round_index, n, tag) if f[0] == rank
            ]
            if failed:
                return False, failed
            worker = threading.Thread(
                target=self._inner_gather, args=(rank, payload, tag, box), daemon=True
            )
            worker.start()
            worker.join(self.deadline_s)
            if worker.is_alive():
                return False, [(rank, f"no delivery within {self.deadline_s:.3f}s")]
            if "err" in box:
                raise box.pop("err")
            return True, []

        self._retry_loop(round_index, tag, attempt)
        if tag == "primary":
            self._round_counter += 1
        return box["out"]

    def _inner_gather(self, rank: int, payload: Any, tag: str, box: dict) -> None:
        try:
            box["out"] = self.inner.all_gather(rank, payload, tag=tag)
        except BaseException as exc:  # surfaced on the calling thread
            box["err"] = exc


def metadata_round_bytes(world_size: int, buffer_size: int) -> int:
    """Paper App. A: one all_gather of ``(2 + 2*buffer) * W * sizeof(int64)``.

    (~128 KB at W=8, buffer=1024.)  Used by benchmarks to report the channel
    footprint without serializing real tensors.
    """
    return (2 + 2 * buffer_size) * world_size * 8
