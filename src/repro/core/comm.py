"""Host-side collective channel for the alignment protocol.

The paper runs the alignment metadata exchange on a dedicated Gloo group
inside the collate subprocess, fully isolated from the NCCL group used for
gradient AllReduce (~128 KB per round at W=8, overlapped with GPU compute).

In the JAX adaptation the channel is a *host-side* collective that never
enters the jitted program, so isolation from the ICI collectives is
structural.  Two implementations:

  * ``LoopbackCollective`` — in-process, round-synchronous.  All simulated
    ranks deposit their payload for round ``k``; the gathered list is returned
    to every rank.  Enforces and audits the **uniform all_gather invariant**
    (Lemma 3): every rank must call ``all_gather`` exactly once per round with
    the same round id, otherwise the channel raises — a deadlock in the real
    system surfaces as a hard error in tests.

  * ``JaxProcessCollective`` — thin wrapper over
    ``jax.experimental.multihost_utils`` for real multi-host deployments
    (one Python process per host).  Not exercised in this CPU container but
    kept API-compatible.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable, Sequence


class ProtocolDesyncError(RuntimeError):
    """A rank broke the uniform-call invariant (would deadlock on hardware)."""


@dataclasses.dataclass
class ChannelStats:
    rounds: int = 0
    bytes_exchanged: int = 0
    secondary_rounds: int = 0  # optional second gather (exact loss scaling)

    def record(self, payloads: Sequence[Any], secondary: bool) -> None:
        self.rounds += 1
        if secondary:
            self.secondary_rounds += 1
        try:
            self.bytes_exchanged += sum(
                len(json.dumps(p, default=str).encode()) for p in payloads
            )
        except TypeError:
            pass


class Collective:
    """Abstract round-synchronous all_gather over ``world_size`` ranks."""

    def __init__(self, world_size: int) -> None:
        if world_size <= 0:
            raise ValueError("world_size must be positive")
        self.world_size = world_size
        self.stats = ChannelStats()

    def all_gather(self, rank: int, payload: Any, *, tag: str = "primary") -> list[Any]:
        raise NotImplementedError


class LoopbackCollective(Collective):
    """Round-synchronous in-process collective driven by a protocol engine.

    The engine collects one payload per rank per round and then delivers the
    gathered list back; per-rank call counts are audited so a rank that calls
    out of lockstep (the distributed-deadlock failure mode) raises
    ``ProtocolDesyncError`` instead of hanging.
    """

    def __init__(self, world_size: int) -> None:
        super().__init__(world_size)
        self._pending: dict[str, dict[int, Any]] = {}
        self._calls_per_rank = [0] * world_size

    # -- engine-driven API ---------------------------------------------------
    def gather_round(
        self,
        payload_fn: Callable[[int], Any],
        *,
        tag: str = "primary",
    ) -> list[Any]:
        """Run one synchronous round: collect payloads from every rank.

        ``payload_fn(rank)`` plays the role of rank ``r`` reaching its
        ``all_gather`` call site.  Every rank *must* produce a payload — a
        rank that cannot (raises) is a protocol bug, mirrored as an exception.
        """
        payloads = [payload_fn(rank) for rank in range(self.world_size)]
        for rank in range(self.world_size):
            self._calls_per_rank[rank] += 1
        counts = set(self._calls_per_rank)
        if len(counts) != 1:
            raise ProtocolDesyncError(
                f"uniform all_gather invariant violated: per-rank call counts "
                f"{self._calls_per_rank}"
            )
        self.stats.record(payloads, secondary=(tag != "primary"))
        return payloads

    def all_gather(self, rank: int, payload: Any, *, tag: str = "primary") -> list[Any]:
        raise NotImplementedError(
            "LoopbackCollective is engine-driven; use gather_round()"
        )


class JaxProcessCollective(Collective):
    """Multi-host backend over jax.experimental.multihost_utils.

    One payload per host process; uses ``process_allgather`` on a flat int64
    metadata vector (the paper's [idx_budget, n_groups, sizes, tokens] layout,
    ~(2 + 2*buffer_size) int64 per rank).  Only functional under a real
    multi-process JAX runtime; provided for deployment parity.
    """

    def all_gather(self, rank: int, payload: Any, *, tag: str = "primary") -> list[Any]:
        import numpy as np
        from jax.experimental import multihost_utils

        arr = np.asarray(payload, dtype=np.int64)
        gathered = multihost_utils.process_allgather(arr)
        out = [gathered[i] for i in range(gathered.shape[0])]
        self.stats.record(out, secondary=(tag != "primary"))
        return out


def metadata_round_bytes(world_size: int, buffer_size: int) -> int:
    """Paper App. A: one all_gather of ``(2 + 2*buffer) * W * sizeof(int64)``.

    (~128 KB at W=8, buffer=1024.)  Used by benchmarks to report the channel
    footprint without serializing real tensors.
    """
    return (2 + 2 * buffer_size) * world_size * 8
