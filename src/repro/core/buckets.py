"""TPU shape-bucketing for dynamic groups (hardware adaptation; DESIGN.md §2).

XLA compiles one program per input shape.  ODB emits variable-size groups
``(n, max_len)``; padding each group up to a small geometric grid of bucket
shapes bounds the number of compiled programs while keeping padding low —
and ODB's token-budget rule concentrates groups near ``L_max`` tokens, which
makes the grid unusually cheap (measured in benchmarks/lmax_ablation).

Two grids:
  * lengths:  powers of two (optionally with a 1.5× midpoint) in
              [min_len, cutoff_len], always hardware-aligned to multiples of
              ``align`` (default 128, the MXU lane width);
  * counts:   {1, 2, 4, 8} then multiples of 8 (sublane-friendly).

``PackedBucketSpec`` buckets packed token streams (segment-id-tagged rows for
the Pallas segment-aware attention kernel): a row-capacity grid over token
counts plus a small row-count grid, so padding decays to the tail bucket while
kernel block shapes stay bounded.  The layout engine (``core/layout.py``)
builds on both specs; ``pad_group``/``pack_group`` remain the low-level
single-group emitters (serving path, kernels tests).
"""

from __future__ import annotations

import bisect
import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core.grouping import Group


def _round_up(x: int, multiple: int) -> int:
    return ((x + multiple - 1) // multiple) * multiple


def sample_token_ids(sample, *, vocab_size: int = 32000, token_fn=None) -> np.ndarray:
    """Token ids for one sample — the single synthesis point for every layout.

    ``token_fn(sample) -> np.ndarray`` extracts ids from the payload; the
    default synthesizes deterministic ids from the view id bounded by
    ``vocab_size`` (benchmarks and tests where only lengths matter).  Both
    dense and packed emitters call this, so the two layouts see bit-identical
    token streams for the same sample.
    """
    if token_fn is not None:
        return np.asarray(token_fn(sample), dtype=np.int32)[: sample.length]
    rng = np.random.default_rng(sample.view_id)
    return rng.integers(1, vocab_size, size=sample.length, dtype=np.int32)


@dataclasses.dataclass(frozen=True)
class BucketSpec:
    """Geometric (count, length) bucket grid."""

    min_len: int = 128
    max_len: int = 32768  # cutoff_len analogue: above the longest realized
    max_count: int = 4096
    align: int = 128
    use_midpoints: bool = True  # add 1.5x length midpoints (denser grid)

    def length_grid(self) -> list[int]:
        grid: list[int] = []
        step = self.min_len
        while step < self.max_len:
            grid.append(step)
            if self.use_midpoints:
                mid = _round_up(step * 3 // 2, self.align)
                if step < mid < min(step * 2, self.max_len):
                    grid.append(mid)
            step *= 2
        grid.append(self.max_len)
        return sorted(set(_round_up(g, self.align) for g in grid))

    def count_grid(self) -> list[int]:
        grid = [1, 2, 4]
        c = 8
        while c <= self.max_count:
            grid.append(c)
            c += 8 if c < 32 else (16 if c < 128 else c // 2)
        if grid[-1] < self.max_count:
            grid.append(self.max_count)
        return grid

    def bucket_length(self, length: int) -> int:
        grid = self.length_grid()
        idx = bisect.bisect_left(grid, length)
        if idx >= len(grid):
            raise ValueError(
                f"length {length} exceeds bucket cutoff {self.max_len}"
            )
        return grid[idx]

    def bucket_count(self, count: int) -> int:
        grid = self.count_grid()
        idx = bisect.bisect_left(grid, count)
        if idx >= len(grid):
            raise ValueError(f"count {count} exceeds max_count {self.max_count}")
        return grid[idx]

    def bucket_shape(self, count: int, length: int) -> tuple[int, int]:
        return self.bucket_count(count), self.bucket_length(length)

    def num_shapes(self) -> int:
        return len(self.count_grid()) * len(self.length_grid())


@dataclasses.dataclass(frozen=True)
class PaddedBatch:
    """A group padded to its bucket shape, ready for the jitted step."""

    tokens: np.ndarray  # (n_bucket, len_bucket) int32
    loss_mask: np.ndarray  # (n_bucket, len_bucket) float32 — 1 on valid targets
    lengths: np.ndarray  # (n_bucket,) int32 — real per-row lengths (0 = pad row)
    real_samples: int
    real_tokens: int

    @property
    def shape(self) -> tuple[int, int]:
        return self.tokens.shape  # type: ignore[return-value]

    @property
    def padding_fraction(self) -> float:
        area = self.tokens.shape[0] * self.tokens.shape[1]
        return 1.0 - self.real_tokens / area if area else 0.0


def pad_group(
    group: Group,
    spec: BucketSpec,
    *,
    pad_id: int = 0,
    token_fn=None,
    vocab_size: int = 32000,
) -> PaddedBatch:
    """Right-pad a group's samples into the bucketed dense batch.

    ``token_fn(sample) -> np.ndarray`` extracts token ids from the payload;
    default synthesizes deterministic ids from the view id bounded by
    ``vocab_size`` (for benchmarks and tests where only lengths matter).
    """
    n_b, l_b = spec.bucket_shape(group.size, group.max_length)
    tokens = np.full((n_b, l_b), pad_id, dtype=np.int32)
    mask = np.zeros((n_b, l_b), dtype=np.float32)
    lengths = np.zeros((n_b,), dtype=np.int32)
    for i, sample in enumerate(group.samples):
        ids = sample_token_ids(sample, vocab_size=vocab_size, token_fn=token_fn)
        tokens[i, : sample.length] = ids
        mask[i, : sample.length] = 1.0
        lengths[i] = sample.length
    return PaddedBatch(
        tokens=tokens,
        loss_mask=mask,
        lengths=lengths,
        real_samples=group.size,
        real_tokens=group.real_tokens,
    )


def idle_batch(shape: tuple[int, int], pad_id: int = 0) -> PaddedBatch:
    """IDLE_DATA sentinel as a zero-token batch — annihilated by Eq. 2."""
    n, l = shape
    return PaddedBatch(
        tokens=np.full((n, l), pad_id, dtype=np.int32),
        loss_mask=np.zeros((n, l), dtype=np.float32),
        lengths=np.zeros((n,), dtype=np.int32),
        real_samples=0,
        real_tokens=0,
    )


# -----------------------------------------------------------------------------
# Beyond-paper: packed-segment emission (merges ODB with contamination-free
# packing; the Pallas segment-aware attention kernel consumes this layout).
# -----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PackedBucketSpec:
    """Packed-stream bucket grids: row capacity (tokens) × row count.

    ``bucket_tokens`` buckets a token count onto the geometric
    ``[min_tokens, max_tokens]`` grid (a whole single-row stream, or — via
    the layout engine — one row's capacity, which is what keeps Pallas kernel
    block shapes bounded).  ``bucket_rows`` buckets the number of packed rows
    onto a small power-of-two grid so the compiled-shape count stays the
    product of two short grids.
    """

    min_tokens: int = 1024
    max_tokens: int = 1 << 20
    align: int = 128
    max_rows: int = 4096

    def grid(self) -> list[int]:
        out = []
        t = self.min_tokens
        while t < self.max_tokens:
            out.append(t)
            mid = _round_up(t * 3 // 2, self.align)
            if t < mid < min(t * 2, self.max_tokens):
                out.append(mid)  # 1.5x midpoints: tail waste <= 1/3 of a step
            t *= 2
        out.append(self.max_tokens)
        return sorted(set(out))

    def bucket_tokens(self, total: int) -> int:
        grid = self.grid()
        idx = bisect.bisect_left(grid, total)
        if idx >= len(grid):
            raise ValueError(f"{total} tokens exceed packed cutoff")
        return grid[idx]

    def row_grid(self) -> list[int]:
        out = []
        r = 1
        while r < self.max_rows:
            out.append(r)
            mid = r * 3 // 2
            if r < mid < min(r * 2, self.max_rows):
                out.append(mid)  # 1.5x midpoints: tail waste <= 1/3 of a step
            r *= 2
        out.append(self.max_rows)
        return sorted(set(out))

    def bucket_rows(self, rows: int) -> int:
        grid = self.row_grid()
        idx = bisect.bisect_left(grid, rows)
        if idx >= len(grid):
            raise ValueError(f"{rows} rows exceed packed max_rows {self.max_rows}")
        return grid[idx]


@dataclasses.dataclass(frozen=True)
class PackedBatch:
    tokens: np.ndarray  # (1, T_bucket) int32
    segment_ids: np.ndarray  # (1, T_bucket) int32 — 0 = padding, 1..n = sample
    positions: np.ndarray  # (1, T_bucket) int32 — within-segment positions
    loss_mask: np.ndarray  # (1, T_bucket) float32
    real_samples: int
    real_tokens: int

    @property
    def padding_fraction(self) -> float:
        area = self.tokens.shape[1]
        return 1.0 - self.real_tokens / area if area else 0.0


def pack_group(
    group: Group,
    spec: PackedBucketSpec,
    *,
    pad_id: int = 0,
    token_fn=None,
    vocab_size: int = 32000,
) -> PackedBatch:
    """Concatenate a group into one packed row with segment ids/positions."""
    total = spec.bucket_tokens(group.real_tokens)
    tokens = np.full((1, total), pad_id, dtype=np.int32)
    seg = np.zeros((1, total), dtype=np.int32)
    pos = np.zeros((1, total), dtype=np.int32)
    mask = np.zeros((1, total), dtype=np.float32)
    cursor = 0
    for i, sample in enumerate(group.samples, start=1):
        ids = sample_token_ids(sample, vocab_size=vocab_size, token_fn=token_fn)
        end = cursor + sample.length
        tokens[0, cursor:end] = ids
        seg[0, cursor:end] = i
        pos[0, cursor:end] = np.arange(sample.length, dtype=np.int32)
        mask[0, cursor:end] = 1.0
        cursor = end
    return PackedBatch(
        tokens=tokens,
        segment_ids=seg,
        positions=pos,
        loss_mask=mask,
        real_samples=group.size,
        real_tokens=group.real_tokens,
    )


def bucket_padding_stats(
    groups: Sequence[Group], spec: BucketSpec
) -> dict[str, float]:
    """Aggregate device-side padding (bucket area vs real tokens)."""
    real = 0
    area = 0
    shapes: set[tuple[int, int]] = set()
    for g in groups:
        n_b, l_b = spec.bucket_shape(g.size, g.max_length)
        shapes.add((n_b, l_b))
        real += g.real_tokens
        area += n_b * l_b
    return {
        "groups": float(len(groups)),
        "real_tokens": float(real),
        "bucket_tokens": float(area),
        "bucket_padding_fraction": 1.0 - real / area if area else 0.0,
        "distinct_shapes": float(len(shapes)),
    }
