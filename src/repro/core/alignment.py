"""Max-Based Bidirectional Group Alignment (paper Alg. 1, Eq. 3, App. A).

Given per-rank candidate group lists with differing counts, compute the
global alignment target over *active* ranks

    T_grp = max( min( max_{r in A} G_r,  C_min+,  S_min+ ),  1 )        (Eq. 3)

where ``C_min+`` / ``S_min+`` are the minimum *positive* output-slot capacity
and buffered-sample count over active ranks (excluding zero values prevents an
empty rank from collapsing the target, App. A), then adjust each active rank:

  * Split (upward, G_r < T_grp): scanning groups in reverse order, find the
    first group with >= 2 samples and extract its *last* sample as a new
    singleton; repeat until G_r == T_grp.
  * Overflow (downward, G_r > T_grp): keep the T_grp largest groups; return
    samples of removed groups to the buffer for recirculation (no discard).

Both operations conserve the sample multiset (Lemma 1 feeds on this).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.grouping import Group, Sample


@dataclasses.dataclass(frozen=True)
class RankAlignmentState:
    """Per-rank inputs to the alignment round (contents of the all_gather)."""

    groups: tuple[Group, ...]
    capacity: int  # output-slot capacity C_r (0 => no free slots this round)
    buffered: int  # buffered-sample count S_r (samples inside groups + spares)

    @property
    def group_count(self) -> int:
        return len(self.groups)


def alignment_target(states: Sequence[RankAlignmentState]) -> int:
    """Compute ``T_grp`` (Eq. 3) over active ranks (G_r > 0).

    Returns 0 when no rank is active (nothing to align this round).
    """
    active = [s for s in states if s.group_count > 0]
    if not active:
        return 0
    g_max = max(s.group_count for s in active)
    pos_caps = [s.capacity for s in active if s.capacity > 0]
    pos_bufs = [s.buffered for s in active if s.buffered > 0]
    c_min = min(pos_caps) if pos_caps else g_max
    s_min = min(pos_bufs) if pos_bufs else g_max
    return max(min(g_max, c_min, s_min), 1)


@dataclasses.dataclass(frozen=True)
class AlignmentResult:
    """Aligned groups plus recirculated overflow samples for one rank."""

    groups: tuple[Group, ...]
    recirculated: tuple[Sample, ...]
    splits: int
    overflowed_groups: int


def split_upward(groups: list[Group], target: int) -> tuple[list[Group], int]:
    """Split until ``len(groups) == target`` (Alg. 1 lines 3-6).

    Scans from the last group backward for the first group with >= 2 samples
    and extracts its last sample as a new singleton group.  If no splittable
    group remains the rank stays below target (the protocol layer then pads
    with IDLE outputs; the theorems only require G_r <= target emission with
    step alignment via idle sentinels).
    """
    groups = list(groups)
    splits = 0
    while len(groups) < target:
        donor_idx = -1
        for i in range(len(groups) - 1, -1, -1):
            if groups[i].size >= 2:
                donor_idx = i
                break
        if donor_idx < 0:
            break  # nothing splittable: protocol pads with IDLE sentinels
        donor = groups[donor_idx]
        remaining, extracted = donor.samples[:-1], donor.samples[-1]
        groups[donor_idx] = Group(samples=remaining)
        groups.append(Group(samples=(extracted,)))
        splits += 1
    return groups, splits


def overflow_downward(
    groups: list[Group], target: int
) -> tuple[list[Group], list[Sample]]:
    """Keep the ``target`` largest groups; recirculate the rest (Alg. 1 line 8).

    "Largest" is by sample count (ties broken by token count then original
    order, deterministically).  Returned extras go back to the rank's buffer —
    overflow recirculation ensures no samples are permanently discarded.
    """
    if len(groups) <= target:
        return list(groups), []
    order = sorted(
        range(len(groups)),
        key=lambda i: (-groups[i].size, -groups[i].real_tokens, i),
    )
    keep = sorted(order[:target])  # preserve original emission order
    drop = sorted(order[target:])
    kept = [groups[i] for i in keep]
    extras: list[Sample] = []
    for i in drop:
        extras.extend(groups[i].samples)
    return kept, extras


def align_rank(state: RankAlignmentState, target: int) -> AlignmentResult:
    """Apply bidirectional adjustment for one active rank (Alg. 1 body)."""
    if state.group_count == 0 or target <= 0:
        return AlignmentResult(
            groups=state.groups, recirculated=(), splits=0, overflowed_groups=0
        )
    groups = list(state.groups)
    splits = 0
    recirculated: list[Sample] = []
    overflowed = 0
    if len(groups) < target:
        groups, splits = split_upward(groups, target)
    elif len(groups) > target:
        before = len(groups)
        groups, recirculated = overflow_downward(groups, target)
        overflowed = before - len(groups)
    return AlignmentResult(
        groups=tuple(groups),
        recirculated=tuple(recirculated),
        splits=splits,
        overflowed_groups=overflowed,
    )


def align_all(
    states: Sequence[RankAlignmentState],
) -> tuple[int, list[AlignmentResult]]:
    """One full alignment round over all ranks: target + per-rank adjustment."""
    target = alignment_target(states)
    return target, [align_rank(s, target) for s in states]
