"""Unified loop protocol for the Distributed Group Alignment Problem (DGAP).

Implements the paper's §2.3 / App. A / App. C / App. E machinery:

  * per-rank state machine over the four disjoint components
    ``(R, Q, B, E)`` = (sampler-pending, worker queue, collate buffer,
    emitted) with the three transition primitives Fetch/Drain/Emit
    (App. C.1) — every transition moves sampler views between components,
    never creating or destroying them (Lemma 1, No-Leak);
  * one unconditional primary ``all_gather`` per outer round exchanging
    ``[idx_budget_r, n_groups_r, sizes_r (, tokens_r)]`` with
    ``n_groups_r ∈ {n>0, 0, -1}`` = produced / insufficient-data / finished;
  * Max-Based Bidirectional Group Alignment to the target ``T_grp`` (Eq. 3)
    with split / overflow-recirculate adjustment (Alg. 1);
  * **join mode** (default): ranks drain outstanding sampler views before
    advertising local finish; global completion only when *all* ranks
    advertise ``-1`` (Theorem 1 — strict identity coverage, η_logical = 0);
  * **non-join mode** (opt-in): the logical iteration ends when *any* rank
    advertises ``-1``; at most ``W·D`` fetched views are abandoned per
    logical iteration (Lemma 4) and the trainer chains logical iterations
    until the cumulative emit count reaches the quota
    ``N ≤ S_emit ≤ N + S_max`` (Theorem 2);
  * IDLE sentinels: a rank that emits fewer than ``T_grp`` real groups in a
    round pads its output queue with IDLE entries so per-step positions stay
    aligned across ranks.  In the JAX/SPMD adaptation an IDLE entry becomes a
    zero-token batch whose contribution is exactly annihilated by token-level
    loss scaling (Eq. 2 with ``t_r = 0``) — see DESIGN.md §2.

The engine simulates ``W`` ranks in-process, round-synchronously, through
``LoopbackCollective`` — the same per-rank methods can be driven by one
process per host over ``JaxProcessCollective`` on a real cluster.
"""

from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Callable, Iterator, Sequence

from repro import obs
from repro.core.alignment import (
    AlignmentResult,
    RankAlignmentState,
    align_rank,
    alignment_target,
)
from repro.core.comm import LoopbackCollective, ProtocolDesyncError
from repro.core.grouping import Group, Sample, greedy_group

IDLE = None  # IDLE_DATA sentinel in the output queue


@dataclasses.dataclass(frozen=True)
class OdbConfig:
    """ODB knobs (paper §3.1 'Method-specific parameters')."""

    l_max: int  # per-step token budget L_max
    buffer_size: int = 1024  # grouping buffer (collate-side)
    prefetch_factor: int = 256  # pf
    num_workers: int = 4  # nw
    join_mode: bool = True  # default join (paper default; App. Q)
    output_capacity: int | None = None  # C_r envelope; None = unbounded
    exact_token_scaling: bool = True  # triggers the optional second gather
    # -- fault-tolerance knobs (DESIGN.md §15) ---------------------------------
    # Per-round collective delivery deadline; None disables the resilient
    # wrapper (no deadline, no retries — the pre-§15 behaviour).
    round_deadline_s: float | None = None
    round_retries: int = 2  # bounded retries before RankTimeoutError
    retry_backoff_s: float = 0.05  # backoff base (exponential, jittered)
    # Epoch budget of realization failures moved to quarantine component X;
    # 0 = strict (a poison sample raises, exactly the historical semantics).
    max_quarantine: int = 0

    @property
    def depth(self) -> int:
        """Outstanding-depth envelope ``D = max(pf*nw, buffer_size)`` (§2.3).

        When ``pf*nw < buffer_size`` the reset logic injects extra indices so
        the collate stage can assemble a full group — the clamp validated in
        App. P.
        """
        return max(self.prefetch_factor * self.num_workers, self.buffer_size)


@dataclasses.dataclass
class RankCounters:
    fetched: int = 0
    drained: int = 0
    emitted_views: int = 0
    emitted_groups: int = 0
    idle_slots: int = 0
    splits: int = 0
    overflow_groups: int = 0
    recirculated_views: int = 0


class ViewSource:
    """Lazy per-rank sampler-view source (streaming admission; DESIGN.md §9).

    The offline engine materializes the whole shard into ``R`` up front; a
    ``ViewSource`` instead hands views out incrementally so realized lengths
    stay bounded by the admission window.  The protocol only needs three
    observables per rank:

      * ``take(rank, k)``   — up to ``k`` more realized views (may under-fill
        when the admission window's lookahead budget is exhausted);
      * ``exhausted(rank)`` — no further views will ever arrive for ``rank``;
      * ``remaining(rank)`` — count of not-yet-delivered views (known exactly:
        the sampler's padded order has fixed size ``M = W·ceil(N/W)`` even
        though *lengths* are unknown until realization).
    """

    def take(self, rank: int, k: int) -> list[Sample]:  # pragma: no cover
        raise NotImplementedError

    def exhausted(self, rank: int) -> bool:  # pragma: no cover
        raise NotImplementedError

    def remaining(self, rank: int) -> int:  # pragma: no cover
        raise NotImplementedError

    # -- distributed-window fold (DESIGN.md §16; optional) ---------------------
    def shard_state(self, rank: int) -> dict | None:
        """Per-rank admission summary to fold into the round gather payload.

        ``None`` (the default) keeps the payload schema unchanged; a sharded
        window returns its host-local cursor/resident/quarantine summary so
        every host observes global admission state once per round.
        """
        return None

    def absorb_gathered(self, states: Sequence[dict | None]) -> None:
        """Merge the gathered per-rank window summaries (post-gather)."""


class RankRuntime:
    """Per-rank protocol state: the (R, Q, B, E) machine of App. C.1."""

    def __init__(
        self,
        rank: int,
        views: Sequence[Sample],
        config: OdbConfig,
        *,
        source: ViewSource | None = None,
    ):
        self.rank = rank
        self.config = config
        self.source = source  # lazy feeder of R (None = offline/materialized)
        self.pending: collections.deque[Sample] = collections.deque(views)  # R
        self.worker_queue: collections.deque[Sample] = collections.deque()  # Q
        self.buffer: list[Sample] = []  # B
        # E is conservation-counted, not stored: emitted views never re-enter
        # the machine, and identity coverage lives in EpochRunner.emitted_ids
        # — so the ledger (and its serialized form) is O(1), not O(quota).
        self.emitted_count: int = 0  # |E|
        self.out_queue: collections.deque[Group | None] = collections.deque()
        self.counters = RankCounters()
        self.local_finished = False
        self.admitted = len(self.pending)  # views ever entered into R
        # Straggler simulation: max views moved Q->B per round (None = all).
        self.drain_rate: int | None = None

    # -- invariants ----------------------------------------------------------
    def component_sizes(self) -> tuple[int, int, int, int]:
        return (
            len(self.pending),
            len(self.worker_queue),
            len(self.buffer),
            self.emitted_count,
        )

    @property
    def outstanding(self) -> int:
        """|U_r| = |Q_r ⊎ B_r| — fetched-but-not-emitted (Lemma 4)."""
        return len(self.worker_queue) + len(self.buffer)

    @property
    def total_views(self) -> int:
        return sum(self.component_sizes())

    @property
    def source_drained(self) -> bool:
        """True when no further views can ever enter ``R`` for this rank."""
        return self.source is None or self.source.exhausted(self.rank)

    @property
    def no_more_input(self) -> bool:
        """R and Q empty and the source (if any) can never refill them."""
        return not self.pending and not self.worker_queue and self.source_drained

    @property
    def idx_budget(self) -> int:
        """|R| plus the source's undelivered tail — equal to the offline
        engine's ``len(pending)`` for the same (seed, epoch, config)."""
        extra = 0 if self.source is None else self.source.remaining(self.rank)
        return len(self.pending) + extra

    # -- transition primitives (App. C.1) -------------------------------------
    def fetch_and_drain(self) -> None:
        """Fetch R->Q up to the depth envelope, then drain Q->B.

        The iterator schedules fetch/drain so the fetched-but-not-emitted set
        ``Q ⊎ B`` stays within ``D``; the collate buffer ``B`` itself is a
        bounded grouping window of at most ``buffer_size`` samples (paper
        §2.1: workers drain "into a configured grouping buffer") — larger
        buffers group over wider windows (Table 17's mechanism).
        """
        budget = self.config.depth - self.outstanding
        if self.source is not None and budget > len(self.pending):
            fresh = self.source.take(self.rank, budget - len(self.pending))
            self.pending.extend(fresh)
            self.admitted += len(fresh)
        while budget > 0 and self.pending:
            self.worker_queue.append(self.pending.popleft())
            self.counters.fetched += 1
            budget -= 1
        allowance = (
            len(self.worker_queue) if self.drain_rate is None else self.drain_rate
        )
        while (
            allowance > 0
            and self.worker_queue
            and len(self.buffer) < self.config.buffer_size
        ):
            self.buffer.append(self.worker_queue.popleft())
            self.counters.drained += 1
            allowance -= 1

    # -- round payload ---------------------------------------------------------
    def candidate_groups(self) -> list[Group]:
        """Form candidate groups when the buffer is ready (collate stage).

        Grouping triggers when the buffer has filled to ``buffer_size`` or the
        sampler + worker queue are exhausted (tail drain).  Otherwise the rank
        reports "insufficient data" (n_groups = 0) and the round only
        fetches/drains for it (skip behaviour, Lemma 2 case (b)).
        """
        ready = len(self.buffer) >= self.config.buffer_size or (
            self.no_more_input and self.buffer
        )
        if not ready:
            return []
        return greedy_group(self.buffer, self.config.l_max)

    def status_code(self, groups: Sequence[Group]) -> int:
        """n_groups ∈ {n>0, 0, -1}: produced / insufficient / finished."""
        if groups:
            return len(groups)
        if self.no_more_input and not self.buffer:
            return -1
        return 0

    @property
    def free_slots(self) -> int:
        if self.config.output_capacity is None:
            return 1 << 30  # effectively unbounded
        return max(self.config.output_capacity - len(self.out_queue), 0)

    # -- emission ----------------------------------------------------------------
    def emit_aligned(self, result: AlignmentResult, target: int) -> int:
        """Emit aligned groups, recirculate overflow, pad with IDLE to target."""
        emitted_now = 0
        emitted_view_ids = set()
        for group in result.groups:
            self.out_queue.append(group)
            self.emitted_count += group.size
            emitted_view_ids.update(s.view_id for s in group.samples)
            emitted_now += 1
            self.counters.emitted_groups += 1
            self.counters.emitted_views += group.size
        # Buffer keeps only recirculated + previously-unbuffered leftovers.
        recirc_ids = {s.view_id for s in result.recirculated}
        self.buffer = [
            s
            for s in self.buffer
            if s.view_id not in emitted_view_ids or s.view_id in recirc_ids
        ]
        self.counters.splits += result.splits
        self.counters.overflow_groups += result.overflowed_groups
        self.counters.recirculated_views += len(result.recirculated)
        while emitted_now < target:
            self.out_queue.append(IDLE)
            self.counters.idle_slots += 1
            emitted_now += 1
        return emitted_now


@dataclasses.dataclass
class RoundRecord:
    """Audit record of one outer protocol round (drives tests/benchmarks)."""

    round_index: int
    statuses: tuple[int, ...]
    idx_budgets: tuple[int, ...]
    target: int
    emitted_views: int
    skip_output: bool
    second_gather: bool
    potential: int  # Lyapunov Φ = Σ_r (|R|+|Q|+|B|)  (App. C.2)
    duration_s: float = 0.0  # wall time of the round (telemetry; DESIGN.md §13)


@dataclasses.dataclass
class IterationResult:
    """Outcome of one logical sampler iteration."""

    rounds: int
    emitted_views: int
    abandoned_views: int  # Σ|U_r| at a non-join stop (Lemma 4 envelope)
    records: list[RoundRecord]
    terminated_by: str  # "join_all_finished" | "nonjoin_any_finished"


class BoundedTerminationError(RuntimeError):
    """Round count exceeded the Theorem-4 envelope — a protocol bug."""


class OdbProtocolEngine:
    """Round-synchronous driver of the unified loop over W simulated ranks."""

    def __init__(
        self,
        per_rank_views: Sequence[Sequence[Sample]],
        config: OdbConfig,
        *,
        collective: LoopbackCollective | None = None,
        round_margin: int = 64,
        source: ViewSource | None = None,
        quota_hint: int | None = None,
    ) -> None:
        world = len(per_rank_views)
        if world == 0:
            raise ValueError("need at least one rank")
        quotas = {len(v) for v in per_rank_views}
        self.equal_quota = len(quotas) == 1
        self.config = config
        self.collective = collective or LoopbackCollective(world)
        self.source = source
        self.ranks = [
            RankRuntime(r, views, config, source=source)
            for r, views in enumerate(per_rank_views)
        ]
        self.records: list[RoundRecord] = []
        self._round_index = 0
        # Theorem 4 envelope: q + O(D) rounds. The constant in O(D) covers
        # drain-rate-1 stragglers (one view per round) plus slack.  A lazy
        # source with a lookahead tighter than the depth envelope can throttle
        # fetches to O(lookahead/W) views per rank per round, so the streaming
        # executor widens round_margin accordingly (stream/executor.py).
        q = quota_hint
        if q is None:
            q = max(len(v) for v in per_rank_views) if per_rank_views else 0
        self.max_rounds = q + config.depth + round_margin
        # -- telemetry (DESIGN.md §13) ------------------------------------
        # record_telemetry is cleared for audit-only replays (the offline
        # reference continuation in EpochRunner) so rounds that never deliver
        # steps don't pollute the live counters.  on_round lets an owner (the
        # streaming executor's RoundTimeline) absorb each RoundRecord.
        self.record_telemetry = True
        self.on_round: Callable[[RoundRecord], None] | None = None
        self._m_rounds = obs.counter(
            "odb_protocol_rounds_total", help="DGAP outer protocol rounds run"
        )
        self._m_emitted = obs.counter(
            "odb_protocol_emitted_views_total",
            help="sampler views emitted by protocol rounds",
        )
        self._m_round_dur = obs.histogram(
            "odb_protocol_round_duration_seconds",
            buckets=obs.ROUND_DURATION_BUCKETS,
            help="wall time of one protocol round",
            unit="seconds",
        )

    @property
    def world_size(self) -> int:
        return len(self.ranks)

    def potential(self) -> int:
        """Lyapunov Φ = M - Σ|E_r| (App. C.2)."""
        return sum(len(r.pending) + len(r.worker_queue) + len(r.buffer) for r in self.ranks)

    def check_no_leak(self, expected_total: int | None = None) -> None:
        """Lemma 1: R ⊎ Q ⊎ B ⊎ E == admitted views at every round, per rank.

        Offline, ``admitted`` is frozen at construction so this is the classic
        conservation check against the shard size; with a lazy source it grows
        as views are admitted, and conservation must hold against the running
        total (views in flight inside the admission window are not yet the
        engine's responsibility).
        """
        if expected_total is None:
            expected_total = sum(r.admitted for r in self.ranks)
        total = sum(r.total_views for r in self.ranks)
        if total != expected_total:
            raise AssertionError(
                f"No-Leak invariant violated: {total} != {expected_total}"
            )

    # -- one outer round -----------------------------------------------------------
    def run_round(self) -> RoundRecord:
        round_t0 = time.perf_counter()
        cfg = self.config
        # Phase 1: fetch/drain on every unfinished rank.
        for rank in self.ranks:
            if not rank.local_finished:
                rank.fetch_and_drain()

        # Phase 2: candidate groups + primary all_gather payloads (Lemma 3:
        # one unconditional gather per round, on every rank).  With a sharded
        # admission window (DESIGN.md §16) each rank's payload also carries
        # its host window's per-rank summary, so group formation and quota
        # closure downstream observe GLOBAL admission state — the distributed
        # deployment's only cross-host window channel.
        candidates: list[list[Group]] = []

        def payload(r: int):
            groups = [] if self.ranks[r].local_finished else self.ranks[r].candidate_groups()
            candidates.append(groups)
            status = -1 if self.ranks[r].local_finished else self.ranks[r].status_code(groups)
            sizes = [g.size for g in groups]
            tokens = [g.real_tokens for g in groups]
            p = {
                "idx_budget": self.ranks[r].idx_budget,
                "n_groups": status,
                "sizes": sizes,
                "tokens": tokens,
            }
            if self.source is not None:
                shard = self.source.shard_state(r)
                if shard is not None:
                    p["window"] = shard
            return p

        gathered = self.collective.gather_round(payload)
        statuses = tuple(p["n_groups"] for p in gathered)
        idx_budgets = tuple(p["idx_budget"] for p in gathered)
        if self.source is not None:
            window_states = [p.get("window") for p in gathered]
            if any(ws is not None for ws in window_states):
                self.source.absorb_gathered(window_states)

        # Phase 3: alignment target over active ranks (identical on all ranks:
        # pure function of the gathered tensor).
        states = [
            RankAlignmentState(
                groups=tuple(candidates[r]),
                capacity=self.ranks[r].free_slots,
                buffered=len(self.ranks[r].buffer),
            )
            for r in range(self.world_size)
        ]
        active_states = [s for s in states if s.group_count > 0]
        target = alignment_target(active_states) if active_states else 0
        skip_output = target == 0

        emitted_views = 0
        alignment_noop = True
        if not skip_output:
            for r, state in enumerate(states):
                if state.group_count > 0 and state.capacity > 0:
                    result = align_rank(state, target)
                    if result.splits or result.overflowed_groups:
                        alignment_noop = False
                    before = self.ranks[r].counters.emitted_views
                    self.ranks[r].emit_aligned(result, target)
                    emitted_views += self.ranks[r].counters.emitted_views - before
                else:
                    # Inactive (or zero-capacity) rank: pad with IDLE to keep
                    # per-step positions aligned.
                    alignment_noop = False
                    empty = AlignmentResult(
                        groups=(), recirculated=(), splits=0, overflowed_groups=0
                    )
                    self.ranks[r].emit_aligned(empty, target)

        # Phase 4 (optional, deterministic predicate φ over the shared
        # tensors): second gather re-broadcasting post-alignment token counts
        # for exact token-level loss scaling (App. B).  All-or-none (Lemma 3).
        second = bool(
            cfg.exact_token_scaling and not skip_output and not alignment_noop
        )
        if second:
            self.collective.gather_round(
                lambda r: {
                    "post_tokens": [
                        (0 if g is IDLE else g.real_tokens)
                        for g in list(self.ranks[r].out_queue)[-target:]
                    ]
                },
                tag="secondary",
            )

        # Phase 5: join-mode local-finish advertisement for the *next* round.
        for rank in self.ranks:
            if rank.no_more_input and not rank.buffer:
                rank.local_finished = True

        duration_s = time.perf_counter() - round_t0
        record = RoundRecord(
            round_index=self._round_index,
            statuses=statuses,
            idx_budgets=idx_budgets,
            target=target,
            emitted_views=emitted_views,
            skip_output=skip_output,
            second_gather=second,
            potential=self.potential(),
            duration_s=duration_s,
        )
        self.records.append(record)
        self._round_index += 1
        if self.record_telemetry:
            self._m_rounds.inc()
            self._m_emitted.inc(emitted_views)
            self._m_round_dur.observe(duration_s)
            obs.default_tracer().complete(
                "dgap/round",
                round_t0,
                duration_s,
                cat="protocol",
                round=record.round_index,
                target=target,
                emitted_views=emitted_views,
            )
            if self.on_round is not None:
                self.on_round(record)
        return record

    # -- full logical iteration ---------------------------------------------------
    def run_iteration(self) -> IterationResult:
        """Run rounds until the mode-specific termination predicate fires."""
        start_round = self._round_index
        emitted_start = sum(r.emitted_count for r in self.ranks)
        terminated_by = ""
        while True:
            if self._round_index - start_round > self.max_rounds:
                raise BoundedTerminationError(
                    f"exceeded Theorem-4 envelope of {self.max_rounds} rounds "
                    f"(Φ={self.potential()})"
                )
            record = self.run_round()
            self.check_no_leak()
            if self.config.join_mode:
                if all(s == -1 for s in record.statuses):
                    terminated_by = "join_all_finished"
                    break
            else:
                if any(s == -1 for s in record.statuses):
                    terminated_by = "nonjoin_any_finished"
                    break
        abandoned = sum(r.outstanding for r in self.ranks)
        emitted = sum(r.emitted_count for r in self.ranks) - emitted_start
        return IterationResult(
            rounds=self._round_index - start_round,
            emitted_views=emitted,
            abandoned_views=abandoned,
            records=self.records[start_round:],
            terminated_by=terminated_by,
        )

    # -- trainer-facing step stream ------------------------------------------------
    def aligned_steps(self) -> Iterator[list[Group | None]]:
        """Yield step-aligned per-rank batches (Group or IDLE) in order.

        Queue lengths are equal across ranks after every round by
        construction (every round appends exactly ``target`` entries to every
        rank's queue), so the zip below is the SPMD step schedule.
        """
        lengths = {len(r.out_queue) for r in self.ranks}
        if len(lengths) != 1:
            raise ProtocolDesyncError(f"unaligned output queues: {lengths}")
        steps = lengths.pop()
        for _ in range(steps):
            yield [r.out_queue.popleft() for r in self.ranks]

    def pop_aligned_steps(self) -> list[list[Group | None]]:
        """Drain every currently-queued aligned step (used by EpochRunner to
        hand steps out as soon as a round produces them)."""
        return list(self.aligned_steps())


# ---------------------------------------------------------------------------------
# Epoch-level runners (trainer-side control logic).
# ---------------------------------------------------------------------------------


@dataclasses.dataclass
class EpochAudit:
    """Terminal audit quantities of §C.5/C.6 and Theorems 1/2."""

    dataset_identities: int  # N
    world_size: int  # W
    sampler_views: int  # M = W * ceil(N/W)
    emitted_views: int  # S_emit (trainer-side cumulative)
    emitted_identities: int  # |∪_r IDs_r|
    surplus_emits: int  # Σ|emits_r| - N  (vs deterministic padding P)
    logical_iterations: int
    rounds: int  # protocol rounds actually run
    rounds_offline: int  # rounds the offline reference engine would have run
    abandoned_views_per_iteration: list[int]
    eta_quota: float  # max(0, 1 - S_emit / N)          (Thm 2)
    eta_identity: float  # 1 - |∪ IDs| / N              (App. C.6)
    terminal_epoch: float  # S_emit / N
    # Quarantine component X (DESIGN.md §15): realization failures moved out
    # of the sampler order instead of wedging a round.  Views counts every
    # event (an identity can re-fail across non-join iterations); identities
    # is the coverage-relevant set size.
    quarantined_views: int = 0
    quarantined_identities: int = 0

    @property
    def padding_views(self) -> int:
        return self.sampler_views - self.dataset_identities  # P = M - N

    @property
    def coverage_accounted(self) -> bool:
        """Theorem-1 rail under faults: every identity either emitted or
        explicitly quarantined — no silent coverage gap."""
        return (
            self.emitted_identities + self.quarantined_identities
            >= self.dataset_identities
        )


class EpochRunner:
    """Resumable ``step()``-at-a-time epoch engine (Theorems 1/2 control).

    Owns the trainer-side chaining logic that used to live inside the
    monolithic ``run_epoch`` loop: logical-iteration construction, join /
    non-join termination, quota crossing, and the identity/emit accounting
    that becomes the :class:`EpochAudit`.  Each ``step()`` call returns the
    next aligned per-rank step (or ``None`` once the epoch is complete), so a
    trainer — or the streaming executor — can interleave protocol progress
    with compute and checkpoint between any two steps.

    Two scheduling modes:

      * ``incremental=False`` — exact ``run_epoch`` semantics: each logical
        iteration's rounds run to termination before its steps are delivered
        (the offline regime; audits are bit-identical to the historical
        implementation);
      * ``incremental=True`` — rounds interleave with delivery: after every
        protocol round, newly aligned steps are handed out immediately, so
        the first train step starts after O(D) admitted views instead of
        after the whole epoch's rounds.  In non-join mode the quota crossing
        also stops round execution eagerly (the remaining fetched-but-unused
        views are counted as abandoned, Lemma 4).  The delivered *step
        sequence* is identical in both modes whenever ``output_capacity`` is
        unbounded, because rounds are a pure function of engine state that
        popping the output queues cannot influence.

    ``make_engine(iteration)`` builds the protocol engine for one logical
    iteration; with a lazy :class:`ViewSource` attached, views (and their
    realized lengths) are admitted on demand — see ``repro/stream``.
    """

    def __init__(
        self,
        make_engine: Callable[[int], "OdbProtocolEngine"],
        dataset_identities: int,
        config: OdbConfig,
        *,
        world_size: int,
        max_logical_iterations: int = 64,
        incremental: bool = False,
    ) -> None:
        self.make_engine = make_engine
        self.n = dataset_identities
        self.config = config
        self.world = world_size
        self.quota = world_size * math.ceil(dataset_identities / world_size)
        self.max_logical_iterations = max_logical_iterations
        self.incremental = incremental
        # -- resumable accounting state (serialized by stream/state.py) -----
        self.iteration = 0
        self.emitted_total = 0
        self.emitted_ids: set[int] = set()
        # Quarantine component X (§15): identities whose realization failed
        # (fed by the admission window's on_quarantine hook) plus the event
        # count.  In non-join mode the Theorem-2 quota shrinks by |X| — a
        # deterministically poisoned identity can never be emitted, so the
        # raw quota would chain iterations forever.
        self.quarantined_ids: set[int] = set()
        self.quarantined_views = 0
        self.rounds = 0
        # Incremental non-join stops rounds at the quota crossing (the eager
        # win); the offline engine would have kept going until a rank
        # advertised -1.  The continuation rounds are counted here so the
        # audit can report both (ROADMAP "round trimming" item).
        self.rounds_offline_extra = 0
        self.abandoned: list[int] = []
        self.steps_delivered = 0
        self.terminated_by: str | None = None
        self._ready: collections.deque[list[Group | None]] = collections.deque()
        self._engine: OdbProtocolEngine | None = None
        self._iteration_open = False
        self._iter_rounds = 0
        self._done = False
        # Telemetry hook: called as on_closure(terminated_by, iteration,
        # iteration_rounds) whenever a logical iteration's rounds terminate
        # (the streaming executor wires its RoundTimeline here).
        self.on_closure: Callable[[str, int, int], None] | None = None

    @property
    def done(self) -> bool:
        return self._done

    @property
    def engine(self) -> "OdbProtocolEngine | None":
        return self._engine

    # -- quarantine accounting (§15) -------------------------------------------
    def note_quarantine(self, identity: int) -> None:
        """Record one realization failure moved to component X."""
        self.quarantined_ids.add(identity)
        self.quarantined_views += 1

    @property
    def effective_quota(self) -> int:
        """Theorem-2 quota minus quarantined identities (they cannot emit)."""
        return max(0, self.n - len(self.quarantined_ids))

    # -- iteration lifecycle --------------------------------------------------
    def _open_iteration(self) -> None:
        self._engine = self.make_engine(self.iteration)
        self._iteration_open = True
        self._iter_rounds = 0

    def _close_iteration(self) -> None:
        """Bookkeeping after an iteration's steps are fully delivered."""
        self.iteration += 1
        self._iteration_open = False
        self._engine = None
        if self.config.join_mode:
            self.terminated_by = self.terminated_by or "join_all_finished"
            self._done = True
        elif self.emitted_total >= self.effective_quota:
            self._done = True
        elif self.iteration >= self.max_logical_iterations:
            raise BoundedTerminationError(
                f"quota not closed after {self.iteration} logical iterations "
                f"({self.emitted_total}/{self.n})"
            )

    def _finish_iteration_rounds(self, terminated_by: str) -> None:
        """The termination predicate fired: absorb round/abandon accounting."""
        assert self._engine is not None
        self.rounds += self._iter_rounds
        self.abandoned.append(sum(r.outstanding for r in self._engine.ranks))
        obs.instant(
            "dgap/closure",
            cat="protocol",
            event=terminated_by,
            iteration=self.iteration,
            iteration_rounds=self._iter_rounds,
        )
        if self.on_closure is not None:
            self.on_closure(terminated_by, self.iteration, self._iter_rounds)
        if terminated_by == "nonjoin_quota_crossed":
            # The eager stop trimmed the iteration's tail rounds.  Replay the
            # remainder on the (about-to-be-dropped) engine — rounds are a
            # pure function of engine state, and with output_capacity
            # unbounded the undrained queues cannot change them — so the
            # audit also reports what the offline engine would have run.
            # Grouping/alignment only: no padding, no compute, no delivery.
            engine = self._engine
            # Audit-only rounds: keep them out of the live round counters.
            engine.record_telemetry = False
            engine.on_round = None
            extra = 0
            while True:
                if self._iter_rounds + extra > engine.max_rounds:
                    raise BoundedTerminationError(
                        f"offline-reference replay exceeded Theorem-4 "
                        f"envelope of {engine.max_rounds} rounds"
                    )
                record = engine.run_round()
                extra += 1
                if any(s == -1 for s in record.statuses):
                    break
            self.rounds_offline_extra += extra
        self.terminated_by = terminated_by
        self._engine = None  # rounds done; steps may still sit in _ready

    # -- batch mode: run a whole iteration's rounds, then deliver -------------
    def _advance_batch(self) -> None:
        if self._iteration_open:
            self._close_iteration()
            if self._done:
                return
        self._open_iteration()
        assert self._engine is not None
        result = self._engine.run_iteration()
        self._iter_rounds = result.rounds
        ready = self._engine.pop_aligned_steps()
        self._finish_iteration_rounds(result.terminated_by)
        self._ready.extend(ready)

    # -- incremental mode: one protocol round per pass ------------------------
    def _advance_incremental(self) -> None:
        while not self._ready and not self._done:
            if self._engine is None:
                if self._iteration_open:
                    self._close_iteration()
                    continue
                self._open_iteration()
            engine = self._engine
            assert engine is not None
            if self._iter_rounds > engine.max_rounds:
                raise BoundedTerminationError(
                    f"exceeded Theorem-4 envelope of {engine.max_rounds} "
                    f"rounds (Φ={engine.potential()})"
                )
            record = engine.run_round()
            engine.check_no_leak()
            self._iter_rounds += 1
            self._ready.extend(engine.pop_aligned_steps())
            if self.config.join_mode:
                if all(s == -1 for s in record.statuses):
                    self._finish_iteration_rounds("join_all_finished")
            elif any(s == -1 for s in record.statuses):
                self._finish_iteration_rounds("nonjoin_any_finished")

    # -- delivery -------------------------------------------------------------
    def _account(self, step: list[Group | None]) -> None:
        real = [g for g in step if g is not IDLE]
        self.emitted_total += sum(g.size for g in real)
        for g in real:
            self.emitted_ids.update(s.identity for s in g.samples)
        self.steps_delivered += 1
        if not self.config.join_mode and self.emitted_total >= self.effective_quota:
            # Theorem 2: the final quota crossing happens inside one aligned
            # step, so S_emit - N <= S_max.  Stop delivering; abandon the
            # rest of the iteration (rounds + queued steps).
            if self._engine is not None:
                self._finish_iteration_rounds("nonjoin_quota_crossed")
            self._ready.clear()
            if self._iteration_open:
                # Guarded so a requeued crossing step re-delivered after a
                # prefetch rollback doesn't close the iteration twice.
                self.iteration += 1
                self._iteration_open = False
            self._done = True

    def requeue(self, steps: Sequence[list[Group | None]]) -> None:
        """Roll delivered-but-unconsumed steps back into the ready queue.

        The prefetch path delivers steps into a staging queue ahead of the
        consumer; when the consumer abandons the epoch, the staged tail is
        pushed back (in order) so a checkpoint taken afterwards reflects the
        consumer's frontier exactly.  Emit counts are reversed; emitted
        identities are not — the identical steps re-deliver the identical
        identities, so the coverage union is unchanged.
        """
        for step in reversed(list(steps)):
            real = [g for g in step if g is not IDLE]
            self.emitted_total -= sum(g.size for g in real)
            self.steps_delivered -= 1
            self._ready.appendleft(step)

    def step(self) -> list[Group | None] | None:
        """Return the next aligned per-rank step, or None when complete."""
        while not self._ready:
            if self._done:
                return None
            if self.incremental:
                self._advance_incremental()
            else:
                self._advance_batch()
        out = self._ready.popleft()
        self._account(out)
        return out

    def steps(self) -> Iterator[list[Group | None]]:
        while True:
            s = self.step()
            if s is None:
                return
            yield s

    def audit(self) -> EpochAudit:
        n = self.n
        return EpochAudit(
            dataset_identities=n,
            world_size=self.world,
            sampler_views=self.quota,
            emitted_views=self.emitted_total,
            emitted_identities=len(self.emitted_ids),
            surplus_emits=self.emitted_total - n,
            logical_iterations=self.iteration,
            rounds=self.rounds,
            rounds_offline=self.rounds + self.rounds_offline_extra,
            abandoned_views_per_iteration=self.abandoned,
            eta_quota=max(0.0, 1.0 - self.emitted_total / n) if n else 0.0,
            eta_identity=1.0 - len(self.emitted_ids) / n if n else 0.0,
            terminal_epoch=self.emitted_total / n if n else 0.0,
            quarantined_views=self.quarantined_views,
            quarantined_identities=len(self.quarantined_ids),
        )


def run_epoch(
    make_views: Callable[[int], Sequence[Sequence[Sample]]],
    dataset_identities: int,
    config: OdbConfig,
    *,
    max_logical_iterations: int = 64,
    on_step: Callable[[list[Group | None]], None] | None = None,
    drain_rates: Sequence[int | None] | None = None,
) -> EpochAudit:
    """Run one training epoch's worth of sampler quota through the protocol.

    Thin wrapper over :class:`EpochRunner` (batch mode) preserving the
    historical contract: ``make_views(iteration)`` returns the per-rank
    sampler-view lists for logical iteration ``iteration`` (re-shuffled per
    iteration, mirroring the re-seeded DistributedSampler).  In join mode a
    single logical iteration emits the full multiset M (Theorem 1).  In
    non-join mode iterations are chained until ``S_emit >= N`` (Theorem 2).
    """
    world = len(make_views(0))

    def make_engine(iteration: int) -> OdbProtocolEngine:
        engine = OdbProtocolEngine(make_views(iteration), config)
        if drain_rates is not None:
            for rank, rate in zip(engine.ranks, drain_rates):
                rank.drain_rate = rate
        return engine

    runner = EpochRunner(
        make_engine,
        dataset_identities,
        config,
        world_size=world,
        max_logical_iterations=max_logical_iterations,
    )
    for step in runner.steps():
        if on_step is not None:
            on_step(step)
    return runner.audit()
