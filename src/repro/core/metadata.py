"""Trainer-facing per-step metadata (paper §2.4 API contract).

The reference LLaMA-Factory integration consumes ODB step metadata for
emitted-sample accounting, token-level loss scaling, and optional
sample-quota stopping.  This is the framework-agnostic version of that
interface: one ``StepMetadata`` per aligned trainer step.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.grouping import Group


@dataclasses.dataclass(frozen=True)
class StepMetadata:
    """Metadata of one step-aligned emission across W ranks."""

    step: int
    samples_per_rank: tuple[int, ...]
    tokens_per_rank: tuple[int, ...]  # real (unpadded) token counts t_r
    padded_tokens_per_rank: tuple[int, ...]
    idle_ranks: tuple[int, ...]

    @property
    def world_size(self) -> int:
        return len(self.samples_per_rank)

    @property
    def emitted_samples(self) -> int:
        return sum(self.samples_per_rank)

    @property
    def total_tokens(self) -> int:
        return sum(self.tokens_per_rank)

    @property
    def total_padded_tokens(self) -> int:
        return sum(self.padded_tokens_per_rank)

    @property
    def padding_fraction(self) -> float:
        padded = self.total_padded_tokens
        return 0.0 if padded == 0 else 1.0 - self.total_tokens / padded


def step_metadata(step: int, batches: Sequence[Group | None]) -> StepMetadata:
    """Build metadata from one aligned step's per-rank batches (IDLE = None)."""
    samples, tokens, padded, idle = [], [], [], []
    for rank, group in enumerate(batches):
        if group is None:
            samples.append(0)
            tokens.append(0)
            padded.append(0)
            idle.append(rank)
        else:
            samples.append(group.size)
            tokens.append(group.real_tokens)
            padded.append(group.padded_tokens)
    return StepMetadata(
        step=step,
        samples_per_rank=tuple(samples),
        tokens_per_rank=tuple(tokens),
        padded_tokens_per_rank=tuple(padded),
        idle_ranks=tuple(idle),
    )


@dataclasses.dataclass
class EmitAccounting:
    """Cumulative trainer-side accounting (drives quota stop + throughput)."""

    emitted_samples: int = 0
    emitted_tokens: int = 0
    padded_tokens: int = 0
    device_tokens: int = 0  # token slots actually occupied on device (layout)
    steps: int = 0
    max_step_samples: int = 0  # S_max (Theorem 2 overshoot bound)

    def update(self, md: StepMetadata, device_tokens: int = 0) -> None:
        self.steps += 1
        self.emitted_samples += md.emitted_samples
        self.emitted_tokens += md.total_tokens
        self.padded_tokens += md.total_padded_tokens
        self.device_tokens += device_tokens
        self.max_step_samples = max(self.max_step_samples, md.emitted_samples)

    @property
    def padding_fraction(self) -> float:
        if self.padded_tokens == 0:
            return 0.0
        return 1.0 - self.emitted_tokens / self.padded_tokens

    @property
    def device_padding_fraction(self) -> float:
        """1 - real/occupied over what the chosen batch layout shipped to
        device — the measured quantity the padded-vs-packed choice moves."""
        if self.device_tokens == 0:
            return 0.0
        return 1.0 - self.emitted_tokens / self.device_tokens
