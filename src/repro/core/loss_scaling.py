"""Loss scaling under heterogeneous per-rank batches (paper §2.3, App. B, N).

ODB's per-rank batches differ in token counts ``t_r``, so naive DDP averaging
``(1/W) Σ_r L̄_r`` is a biased estimate of the per-token reference loss

    L* = (1/T_tok) Σ_{r,i,k} ℓ_{r,i,k},      T_tok = Σ_r t_r.           (Eq. 4)

Prescaling each rank's loss by ``W · w_r`` makes DDP's post-averaging output
equal ``Σ_r w_r L̄_r``; the unique weight that recovers ``L*`` exactly is the
token-level weight ``w_r = t_r / T_tok`` (Eq. 2).  Sample-level weighting
``w_r = n_r / N`` matches only when ``t_r / n_r`` is constant across ranks.

Three modes (App. N):
  1. ``sample``        — w_r = n_r / n_total.
  2. ``approx_token``  — token-level with post-alignment tokens *estimated*
                         from the pre-alignment mean: t_adj ≈ n_adj · t̄_r.
  3. ``exact_token``   — token-level with true post-alignment counts
                         (re-broadcast by the deterministic second gather).

``exact_token`` also annihilates IDLE batches exactly (t_r = 0 ⇒ w_r = 0),
which is what lets the JAX/SPMD step schedule include IDLE slots without
biasing the loss (DESIGN.md §2).

Numerics note: the prescale is applied in the algebraically-stable form
``W · ℓ_sum_r / T_tok`` (identical to ``W · w_r · L̄_r`` in exact arithmetic,
but avoiding the ``t_r`` divide-then-multiply round trip), so the
post-averaging output is *bitwise* equal to computing ``Σ_r ℓ_sum_r / T_tok``
with the same summation order — the Eq. 2 bit-exactness contract we test.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

MODES = ("sample", "approx_token", "exact_token")


@dataclasses.dataclass(frozen=True)
class RankLossStats:
    """Per-rank loss statistics for one aligned trainer step."""

    loss_sum: float  # Σ_{i,k} ℓ_{r,i,k} over valid tokens
    tokens: int  # t_r (post-alignment true count)
    samples: int  # n_r
    tokens_pre_alignment: int | None = None  # for approx mode
    samples_pre_alignment: int | None = None

    @property
    def mean_loss(self) -> float:
        return 0.0 if self.tokens == 0 else self.loss_sum / self.tokens


def token_weights(tokens: Sequence[int]) -> np.ndarray:
    """w_r = t_r / T_tok (Eq. 2); all-zero step maps to zero weights."""
    t = np.asarray(tokens, dtype=np.float64)
    total = t.sum()
    if total == 0:
        return np.zeros_like(t)
    return t / total


def sample_weights(samples: Sequence[int]) -> np.ndarray:
    n = np.asarray(samples, dtype=np.float64)
    total = n.sum()
    if total == 0:
        return np.zeros_like(n)
    return n / total


def approx_token_counts(stats: Sequence[RankLossStats]) -> list[float]:
    """App. B approximate mode: t_adj ≈ n_adj · t̄_r with t̄_r from the
    *pre-alignment* piggybacked counts (no second gather)."""
    out = []
    for s in stats:
        n_pre = s.samples_pre_alignment
        t_pre = s.tokens_pre_alignment
        if not n_pre or t_pre is None:
            out.append(float(s.tokens))
        else:
            out.append(s.samples * (t_pre / n_pre))
    return out


def ddp_scaled_loss(stats: Sequence[RankLossStats], mode: str) -> float:
    """Simulate DDP post-averaging output of the prescaled per-rank losses.

    Returns ``mean_r( W · w_r · L̄_r )`` computed in the stable form.  With
    ``mode='exact_token'`` this equals the per-token reference bit-precisely.
    """
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    w_count = len(stats)
    if w_count == 0:
        return 0.0
    if mode == "sample":
        weights = sample_weights([s.samples for s in stats])
        scaled = [
            w_count * weights[r] * stats[r].mean_loss for r in range(w_count)
        ]
        return float(np.sum(scaled) / w_count)
    if mode == "approx_token":
        t_est = approx_token_counts(stats)
        total = float(np.sum(t_est))
        if total == 0:
            return 0.0
        scaled = [
            w_count * (t_est[r] / total) * stats[r].mean_loss
            for r in range(w_count)
        ]
        return float(np.sum(scaled) / w_count)
    # exact_token — stable form: W * ℓ_sum_r / T_tok, then mean over ranks.
    total_tokens = float(np.sum([s.tokens for s in stats], dtype=np.float64))
    if total_tokens == 0:
        return 0.0
    scaled = [w_count * s.loss_sum / total_tokens for s in stats]
    return float(np.sum(scaled) / w_count)


def reference_per_token_loss(stats: Sequence[RankLossStats]) -> float:
    """L* = Σ ℓ_sum_r / Σ t_r — the single-pass per-token mean (Eq. 4)."""
    total_tokens = float(np.sum([s.tokens for s in stats], dtype=np.float64))
    if total_tokens == 0:
        return 0.0
    return float(np.sum([s.loss_sum for s in stats]) / total_tokens)


def prescale_factor(
    local_tokens,  # jax or numpy scalar: t_r
    global_tokens,  # T_tok (from psum or the second gather)
    world_size: int,
    mode: str = "exact_token",
    local_samples=None,
    global_samples=None,
):
    """Factor applied to the local *mean* loss before the DP mean-reduce.

    jax-traceable (pure arithmetic).  ``mean_r(factor_r · L̄_r)`` then equals
    the mode's target.  For exact_token: factor = W · t_r / T_tok.
    """
    if mode == "exact_token" or mode == "approx_token":
        return world_size * local_tokens / global_tokens
    if mode == "sample":
        if local_samples is None or global_samples is None:
            raise ValueError("sample mode needs sample counts")
        return world_size * local_samples / global_samples
    raise ValueError(f"unknown mode {mode!r}")
