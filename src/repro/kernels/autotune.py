"""Measured (block_q, block_kv) schedules for the flash kernels (DESIGN.md §11).

The flash kernel's tile shape is a real throughput knob: the MXU wants
128-lane tiles, but the best (block_q, block_kv) pair per *shape cell*
(B, S, H, KV, D, dtype, causal, packed?) depends on VMEM pressure and the
live-tile census, so it is picked from a short measured probe rather than a
table.  Results are cached per process and persisted next to the other
bench/plan artifacts (``artifacts/autotune/attn_blocks.json``) so repeated
launches — and the dry-run's compile cells — reuse one schedule.

The probe runs at trace time (block sizes are static arguments to the
kernel), on synthetic inputs of the real shape, timing forward + backward
through the ``flash_attention`` custom-vjp.  When autotuning is off
(``ArchConfig.attn_autotune = False``, the default) the heuristic schedule
is used: the largest block ≤ 128 dividing S, the same rule
``select_block`` applies to ragged shapes.
"""

from __future__ import annotations

import json
import os
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.kernels.flash_attention import select_block

DEFAULT_CACHE_PATH = pathlib.Path("artifacts") / "autotune" / "attn_blocks.json"

# One in-process schedule per cache file, so an explicit cache_path (tests,
# side experiments) never bleeds into — or is served from — the default pool.
_CACHES: dict[str, dict[str, tuple[int, int]]] = {}


def heuristic_blocks(s: int) -> tuple[int, int]:
    """Probe-free default: square blocks at the largest divisor ≤ 128."""
    b = select_block(s, 128)
    return b, b


def candidate_blocks(s: int) -> list[tuple[int, int]]:
    """Candidate (block_q, block_kv) pairs — exact divisors of S only,
    capped at 128 (the kernel's ``select_block`` cap: larger requests would
    silently alias the 128 schedule and pollute the persisted cache)."""
    divs = [d for d in (128, 64, 32) if d <= s and s % d == 0]
    if not divs:
        divs = [select_block(s, 128)]
    return sorted({(bq, bk) for bq in divs for bk in divs})


def shape_key(
    b: int, s: int, h: int, kv: int, d: int,
    *, dtype=jnp.float32, causal: bool = True, has_segments: bool = False,
    grid: str = "dense",
) -> str:
    # Keyed by grid variant (DESIGN.md §17): the pruned scalar-prefetch grid
    # has a different DMA/compute balance per tile shape, so a schedule
    # measured on one grid must never be served to the other.
    return (
        f"{jax.default_backend()}/b{b}s{s}h{h}kv{kv}d{d}"
        f"/{jnp.dtype(dtype).name}/causal{int(causal)}/seg{int(has_segments)}"
        f"/grid.{grid}"
    )


def _load_cache(path: pathlib.Path) -> dict[str, tuple[int, int]]:
    cache = _CACHES.get(str(path))
    if cache is not None:
        return cache
    cache = _CACHES.setdefault(str(path), {})
    try:
        stored = json.loads(path.read_text())
    except (OSError, ValueError):
        return cache
    for key, pair in stored.items():
        cache.setdefault(key, (int(pair[0]), int(pair[1])))
    return cache


def _persist_cache(path: pathlib.Path, cache: dict[str, tuple[int, int]]) -> None:
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_suffix(".tmp")
        tmp.write_text(
            json.dumps({k: list(v) for k, v in sorted(cache.items())}, indent=1)
        )
        os.replace(tmp, path)
    except OSError:  # read-only checkout: keep the in-process cache only
        pass


def cached_schedule(
    cache_path: str | os.PathLike | None = None,
) -> dict[str, tuple[int, int]]:
    """Snapshot of one cache file's measured schedule (benchmarks artifact)."""
    path = pathlib.Path(cache_path) if cache_path is not None else DEFAULT_CACHE_PATH
    return dict(_load_cache(path))


def _probe_segments(b: int, s: int) -> jax.Array:
    """Synthetic packed rows: a few segments plus a padding tail, so the
    probe exercises the segment-masked (block-skipping) kernel variant."""
    seg = np.zeros((b, s), np.int32)
    cuts = [0, s // 3, (2 * s) // 3, s - s // 8]
    for i in range(b):
        for j in range(len(cuts) - 1):
            seg[i, cuts[j] : cuts[j + 1]] = j + 1
    return jnp.asarray(seg)


def autotune_blocks(
    b: int, s: int, h: int, kv: int, d: int,
    *,
    dtype=jnp.float32,
    causal: bool = True,
    has_segments: bool = False,
    include_bwd: bool = True,
    repeats: int = 2,
    probe_batch: int = 2,
    cache_path: str | os.PathLike | None = None,
    grid: str = "dense",
) -> tuple[int, int]:
    """Measured (block_q, block_kv) for one shape cell, cached on disk.

    The probe batch is capped (default 2 rows) — tile timing is row-
    independent, so the full train batch need not be materialized.
    """
    path = pathlib.Path(cache_path) if cache_path is not None else DEFAULT_CACHE_PATH
    cache = _load_cache(path)
    key = shape_key(
        b, s, h, kv, d, dtype=dtype, causal=causal,
        has_segments=has_segments, grid=grid,
    )
    if key in cache:
        obs.counter(
            "kernel_autotune_cache_hits_total",
            help="autotune shape cells served from cache",
        ).inc()
        return cache[key]
    obs.counter(
        "kernel_autotune_cache_misses_total",
        help="autotune shape cells that ran the measured probe",
    ).inc()
    probe_t0 = time.perf_counter()

    from repro.kernels.ops import flash_attention  # late: avoid import cycle

    pb = max(1, min(b, probe_batch))
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (pb, s, h, d)).astype(dtype)
    k = jax.random.normal(ks[1], (pb, s, kv, d)).astype(dtype)
    v = jax.random.normal(ks[2], (pb, s, kv, d)).astype(dtype)
    seg = _probe_segments(pb, s) if has_segments else None

    best: tuple[int, int] | None = None
    best_t = None
    for bq, bk in candidate_blocks(s):
        def fwd(q_, k_, v_):
            return flash_attention(q_, k_, v_, seg, causal, bq, bk, grid)

        if include_bwd:
            def run(q_, k_, v_):
                loss = lambda *a: jnp.sum(fwd(*a).astype(jnp.float32) ** 2)
                return jax.grad(loss, argnums=(0, 1, 2))(q_, k_, v_)
        else:
            run = fwd
        timed = jax.jit(run)
        try:
            jax.block_until_ready(timed(q, k, v))  # compile outside the clock
            t0 = time.perf_counter()
            for _ in range(repeats):
                jax.block_until_ready(timed(q, k, v))
            t = (time.perf_counter() - t0) / repeats
        except Exception:
            continue  # candidate does not fit (VMEM, ragged tail): skip
        if best_t is None or t < best_t:
            best, best_t = (bq, bk), t
    if best is None:
        best = heuristic_blocks(s)
    cache[key] = best
    _persist_cache(path, cache)
    obs.default_tracer().complete(
        "kernels/autotune", probe_t0, time.perf_counter() - probe_t0,
        cat="kernels", key=key, block_q=best[0], block_kv=best[1],
    )
    return best
