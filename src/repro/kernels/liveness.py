"""Block-liveness tables for the scalar-prefetch flash grid (DESIGN.md §17).

PR 3's dense grid predicates dead (q, kv) tiles out of the MXU with
``pl.when`` but the Pallas pipeline still DMAs every kv tile — on the
longtail-packed census only ~0.20 of tiles are live, so ~80% of kv HBM
bandwidth is fetched and discarded.  The scalar-prefetch grid fixes the
fetch: a cheap XLA-side pass over per-block segment-id ranges builds, per
(batch, q-block) row, a *compacted* index of live kv blocks plus a per-row
live count.  ``PrefetchScalarGridSpec`` hands that index to the kv
``BlockSpec`` index_map; live blocks are visited in ascending order (so the
online-softmax accumulation sequence is bit-identical to the dense grid's),
and for grid steps past the live count the index map repeats the last live
block — Pallas skips the re-DMA when consecutive index_map results agree, so
dead kv tiles are never fetched.  The causal predicate folds into the
liveness table so causally-dead tiles prune too.

The same tables drive both backward passes: the q-stationary dQ pass reuses
the row index verbatim, and the kv-stationary dK/dV pass uses the transposed
*column* index (per (batch, kv-block): which q blocks attend into this kv
tile).

Everything here is plain jnp (jit- and shard_map-friendly — tables for a
sharded batch are built inside the sharded region from the local segment
shard) plus one numpy census mirror for benchmarks/CI.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import _SEG_BIG, select_block


class LivenessTables(NamedTuple):
    """Compacted live-block indices for one (segment_ids, block_q, block_kv).

    ``kv_idx[b, qb, t]`` is the t-th live kv block of q-block ``qb`` (row
    index, ascending), clamped to the last live block for ``t >=
    kv_count[b, qb]``; ``q_idx[b, kb, t]`` / ``q_count[b, kb]`` are the
    transposed column tables for the kv-stationary backward.  Rows with no
    live blocks (all-padding packed rows) carry count 0 and index 0.
    """

    kv_idx: jax.Array  # (B, nq, nk) int32
    kv_count: jax.Array  # (B, nq) int32
    q_idx: jax.Array  # (B, nk, nq) int32
    q_count: jax.Array  # (B, nk) int32


def _range_bounds(segment_ids: jax.Array, block: int) -> tuple[jax.Array, jax.Array]:
    """Per-block (lo, hi) over positive segment ids; lo = _SEG_BIG when the
    block is all padding.  Valid because ids are nondecreasing over the real
    prefix of a packed row (layout contract, DESIGN.md §10)."""
    b, s = segment_ids.shape
    n = s // block
    blocks = segment_ids.reshape(b, n, block)
    lo = jnp.min(jnp.where(blocks > 0, blocks, _SEG_BIG), axis=-1)
    hi = jnp.max(blocks, axis=-1)
    return lo, hi


def block_liveness(
    segment_ids: jax.Array, block_q: int, block_kv: int, *, causal: bool = True
) -> jax.Array:
    """(B, nq, nk) bool — the kernel's ``_block_live`` rule, vectorized:
    segment ranges overlap (ids 0 excluded) AND (causal ⇒ the q block can
    reach the kv block)."""
    _, s = segment_ids.shape
    nq, nk = s // block_q, s // block_kv
    q_lo, q_hi = _range_bounds(segment_ids, block_q)
    k_lo, k_hi = _range_bounds(segment_ids, block_kv)
    live = (
        (q_hi[:, :, None] > 0)
        & (k_hi[:, None, :] > 0)
        & (q_hi[:, :, None] >= k_lo[:, None, :])
        & (k_hi[:, None, :] >= q_lo[:, :, None])
    )
    if causal:
        qb = jnp.arange(nq, dtype=jnp.int32)
        kb = jnp.arange(nk, dtype=jnp.int32)
        reach = (qb[:, None] * block_q + block_q - 1) >= kb[None, :] * block_kv
        live &= reach[None]
    return live


def compact_index(live: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Compact a (..., n) liveness mask into (idx, count).

    ``idx[..., t]`` lists the live positions in ascending order for
    ``t < count[...]`` and repeats the *last* live position beyond it (the
    clamp that makes the Pallas pipeline skip dead-tail DMAs).  Stable: keys
    live positions below dead ones, argsorts, then gathers through the
    clamped step index."""
    n = live.shape[-1]
    ar = jnp.arange(n, dtype=jnp.int32)
    key = jnp.where(live, ar, n + ar)
    order = jnp.argsort(key, axis=-1).astype(jnp.int32)
    count = jnp.sum(live, axis=-1).astype(jnp.int32)
    step = jnp.broadcast_to(ar, live.shape)
    clamped = jnp.minimum(step, jnp.maximum(count[..., None] - 1, 0))
    idx = jnp.take_along_axis(order, clamped, axis=-1)
    return idx, count


def build_liveness_tables(
    segment_ids: jax.Array,
    *,
    block_q: int,
    block_kv: int,
    causal: bool = True,
) -> LivenessTables:
    """Row + column tables for one packed batch.  ``block_q`` / ``block_kv``
    must already be resolved (``select_block`` applied) — asserted so the
    tables can never disagree with the kernel grid."""
    _, s = segment_ids.shape
    assert s % block_q == 0 and s % block_kv == 0, (s, block_q, block_kv)
    live = block_liveness(segment_ids, block_q, block_kv, causal=causal)
    kv_idx, kv_count = compact_index(live)
    q_idx, q_count = compact_index(jnp.swapaxes(live, 1, 2))
    return LivenessTables(kv_idx, kv_count, q_idx, q_count)


# -----------------------------------------------------------------------------
# Host-side fetch census (benchmarks / CI rails)
# -----------------------------------------------------------------------------


def fetched_tile_counts(
    segment_ids,
    s: int,
    block_q: int,
    block_kv: int,
    *,
    causal: bool = True,
    heads: int = 1,
    kv_heads: int = 1,
    head_dim: int = 64,
    itemsize: int = 4,
) -> dict:
    """Exact kv-tile DMA census for the forward grid, dense vs pruned.

    Mirrors the Pallas pipeline rule precisely: walking the (b, h, nq, nk)
    grid in row-major order, a kv tile is (re)fetched whenever the kv
    index_map result differs from the previous grid step's.  The dense grid
    maps step ik → kv block ik (every step fetches a new tile); the pruned
    grid maps through the clamped row index, so the dead tail of each row
    repeats the last live block and fetches nothing.  Bytes count both the k
    and v tiles (``2 · block_kv · head_dim · itemsize`` per fetch).
    """
    import numpy as np

    seg = np.asarray(segment_ids)
    bsz = seg.shape[0]
    block_q = select_block(s, block_q)
    block_kv = select_block(s, block_kv)
    nq, nk = s // block_q, s // block_kv
    g = max(heads // kv_heads, 1)

    live = np.asarray(
        block_liveness(jnp.asarray(seg), block_q, block_kv, causal=causal)
    )
    counts = live.sum(axis=-1)  # (B, nq)

    dense_fetches = 0
    pruned_fetches = 0
    prev_dense = None
    prev_pruned = None
    for ib in range(bsz):
        for ih in range(heads):
            kvh = ih // g
            for iq in range(nq):
                row_live = np.flatnonzero(live[ib, iq])
                cnt = int(counts[ib, iq])
                last = int(row_live[-1]) if cnt else 0
                for ik in range(nk):
                    tile_d = (ib, kvh, ik)
                    if tile_d != prev_dense:
                        dense_fetches += 1
                    prev_dense = tile_d
                    kb = int(row_live[ik]) if ik < cnt else last
                    tile_p = (ib, kvh, kb)
                    if tile_p != prev_pruned:
                        pruned_fetches += 1
                    prev_pruned = tile_p

    steps = bsz * heads * nq * nk
    tile_bytes = 2 * block_kv * head_dim * itemsize  # k + v
    out = {
        "grid": [bsz, heads, nq, nk],
        "block_q": block_q,
        "block_kv": block_kv,
        "grid_steps": steps,
        "live_tiles": int(counts.sum()),
        "dense_fetches": dense_fetches,
        "pruned_fetches": pruned_fetches,
        "dense_fetched_fraction": dense_fetches / steps if steps else 0.0,
        "pruned_fetched_fraction": pruned_fetches / steps if steps else 0.0,
        "kv_tile_bytes": tile_bytes,
        "dense_fetched_bytes": dense_fetches * tile_bytes,
        "pruned_fetched_bytes": pruned_fetches * tile_bytes,
    }
    from repro import obs  # deferred: keep kernel import time lean

    obs.gauge(
        "kernel_fetched_tile_fraction",
        help="fraction of forward-grid steps that DMA a fresh kv tile",
        grid="dense",
    ).set(out["dense_fetched_fraction"])
    obs.gauge("kernel_fetched_tile_fraction", grid="pruned").set(
        out["pruned_fetched_fraction"]
    )
    obs.gauge(
        "kernel_fetched_kv_bytes",
        help="kv bytes DMA'd by the forward grid per batch",
        grid="dense",
    ).set(float(out["dense_fetched_bytes"]))
    obs.gauge("kernel_fetched_kv_bytes", grid="pruned").set(
        float(out["pruned_fetched_bytes"])
    )
    return out
