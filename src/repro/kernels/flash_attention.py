"""Segment-aware causal flash attention — Pallas TPU kernels (fwd + bwd).

TPU-native adaptation of the paper's packing story (DESIGN.md §2, §11): ODB's
packed groups need contamination-free attention; on GPU that is a varlen
CUDA kernel (flash_attn_varlen), on TPU the natural form is *segment-id
masking fused into a tiled attention kernel*.

Forward tiling: grid = (batch, q_heads, num_q_blocks, num_kv_blocks), the
last axis sequential (TPU "arbitrary" dimension semantics) carrying the
online-softmax accumulators (m, l, acc) in VMEM scratch.  BlockSpecs pull one
(block_q × d) query tile and one (block_kv × d) key/value tile into VMEM per
step; GQA is expressed in the k/v index_map (kv head = q head // group).

Block skipping: causally dead (q, kv) block pairs are skipped via
``pl.when``, and — with packed rows — so are *segment-disjoint* pairs.
Segment ids within a packed row are nondecreasing over the real prefix (the
padding tail carries 0), so each block covers a contiguous id range
``[lo, hi]``; a (q, kv) pair is live only when the ranges overlap:
``q_hi >= k_lo and k_hi >= q_lo`` (ids 0 excluded).  Packing therefore turns
directly into proportionally fewer live tiles (measured by
benchmarks/kernels.py as the live-tile fraction).

Backward: the standard recompute-free two-pass formulation.  The forward
saves per-row ``lse = m + log(l)``; the backward recomputes probabilities as
``p = exp(s - lse)`` tile by tile (never materializing O(S²)), with

    delta = rowsum(dO ⊙ O)            (precomputed outside the kernels)
    dV   += Pᵀ · dO                   (kv-stationary pass)
    dS    = P ⊙ (dO·Vᵀ − delta)
    dK   += scale · dSᵀ · Q           (kv-stationary pass)
    dQ   += scale · dS · K            (q-stationary pass)

Two kernels: a q-stationary pass (grid (b, h, nq, nk), kv sequential)
accumulating dQ, and a kv-stationary pass (grid (b, kv, nk, g·nq), the
sequential axis walking every (group member, q block) pair of one kv tile)
whose VMEM scratch accumulates the GQA group-sum in place — dK/dV leave the
kernel at kv-head resolution, with no per-q-head HBM intermediates.  Both
share the
masking contract — allowed iff segments match (0 = padding) and (causal ⇒
k_pos ≤ q_pos) — and the same block skipping, and rows whose softmax mass is
empty (l == 0, all-padding rows) contribute exactly zero gradient because
``p`` is built under the mask.

Block sizes need not divide S: ``select_block`` drops to the largest
divisor ≤ 128 (ragged sequence cells degrade gracefully instead of
asserting).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU compiler params are optional off-TPU / in interpret mode
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
    def _compiler_params():
        try:
            return pltpu.CompilerParams(
                dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
            )
        except Exception:
            return None
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None
    def _compiler_params():
        return None

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)
_SEG_BIG = 1 << 30  # "no positive segment in this block" sentinel


def select_block(s: int, requested: int, cap: int = 128) -> int:
    """Largest block ≤ min(requested, cap) that divides ``s``.

    Keeps the kernel grid exact for ragged sequence cells instead of
    asserting ``s % block == 0``.  Divisors that are multiples of 8 (the
    fp32 sublane) are preferred so the compiled TPU path keeps
    Mosaic-legal tile shapes: 384 → 128, 200 → 40 (not 100), 96 → 96.
    Shapes with no aligned divisor (e.g. prime S) fall back to the largest
    divisor of any width — interpret-mode territory.
    """
    b = min(requested, cap, s)
    unaligned = 1
    for c in range(b, 0, -1):
        if s % c:
            continue
        if c % 8 == 0:
            return c
        if unaligned == 1:
            unaligned = c
    return unaligned


def resolve_blocks(s: int, block_q: int, block_kv: int) -> tuple[int, int]:
    """Resolve one ``(block_q, block_kv)`` pair for sequence length ``s``.

    ``select_block`` is a projection onto the divisors of ``s`` but is *not*
    idempotent on arbitrary requests (``select_block(120, 15) == 8``, not
    15), so independently re-resolving in the forward and backward could in
    principle drift if the two passes ever saw different raw requests.  The
    routing layer (kernels/ops.py) calls this once per shape and threads the
    resolved pair through the ``custom_vjp`` nondiff args; both passes then
    assert the pair is a fixed point (``expect_resolved=True``) instead of
    silently re-resolving.
    """
    return select_block(s, block_q), select_block(s, block_kv)


def _check_resolved(s: int, block_q: int, block_kv: int) -> None:
    assert (block_q, block_kv) == resolve_blocks(s, block_q, block_kv), (
        f"block pair ({block_q}, {block_kv}) is not resolved for S={s}: "
        f"routing must pin resolve_blocks() once and pass the fixed point"
    )


def _block_live(causal, qb, kb, block_q, block_kv, qseg_ref, kseg_ref):
    """Scalar liveness of one (q, kv) block pair: causal reach AND (for
    packed rows) overlapping per-block segment-id ranges."""
    live = qb * block_q + block_q - 1 >= kb * block_kv if causal else True
    if qseg_ref is not None:
        qseg = qseg_ref[...]
        kseg = kseg_ref[...]
        q_lo = jnp.min(jnp.where(qseg > 0, qseg, _SEG_BIG))
        k_lo = jnp.min(jnp.where(kseg > 0, kseg, _SEG_BIG))
        q_hi = jnp.max(qseg)
        k_hi = jnp.max(kseg)
        seg_live = (q_hi > 0) & (k_hi > 0) & (q_hi >= k_lo) & (k_hi >= q_lo)
        live = seg_live if live is True else live & seg_live
    return live


def _tile_mask(qb, kb, block_q, block_kv, causal, qseg_ref, kseg_ref):
    """(block_q, block_kv) boolean allow-mask — the shared contract."""
    allowed = jnp.ones((block_q, block_kv), dtype=jnp.bool_)
    if causal:
        q_pos = qb * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0
        )
        k_pos = kb * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1
        )
        allowed &= k_pos <= q_pos
    if qseg_ref is not None:
        qseg = qseg_ref[...]
        kseg = kseg_ref[...]
        allowed &= (qseg[:, None] == kseg[None, :]) & (kseg[None, :] > 0)
    return allowed


# -----------------------------------------------------------------------------
# Forward
# -----------------------------------------------------------------------------


def _flash_body(
    q_ref, k_ref, v_ref, qseg_ref, kseg_ref, o_ref, lse_ref,
    m_scratch, l_scratch, acc_scratch,
    *, scale, causal, block_q, block_kv, num_kv_blocks,
):
    qb = pl.program_id(2)
    kb = pl.program_id(3)

    @pl.when(kb == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch[...], NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch[...])
        acc_scratch[...] = jnp.zeros_like(acc_scratch[...])

    live = _block_live(causal, qb, kb, block_q, block_kv, qseg_ref, kseg_ref)

    @pl.when(live)
    def _compute():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        v = v_ref[...].astype(jnp.float32)
        scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        allowed = _tile_mask(qb, kb, block_q, block_kv, causal, qseg_ref, kseg_ref)
        scores = jnp.where(allowed, scores, NEG_INF)

        m_prev = m_scratch[:, 0]
        l_prev = l_scratch[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1))
        safe_m = jnp.where(m_new <= NEG_INF, 0.0, m_new)
        p = jnp.where(allowed, jnp.exp(scores - safe_m[:, None]), 0.0)
        alpha = jnp.where(m_prev <= NEG_INF, 0.0, jnp.exp(m_prev - safe_m))
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc = acc_scratch[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ()))
        )
        m_scratch[...] = jnp.broadcast_to(m_new[:, None], m_scratch.shape)
        l_scratch[...] = jnp.broadcast_to(l_new[:, None], l_scratch.shape)
        acc_scratch[...] = acc

    @pl.when(kb == num_kv_blocks - 1)
    def _finalize():
        l = l_scratch[:, 0]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_scratch[...] / denom[:, None]).astype(o_ref.dtype)
        if lse_ref is not None:
            m = m_scratch[:, 0]
            lse = jnp.where(l > 0.0, m + jnp.log(denom), NEG_INF)
            lse_ref[...] = lse.astype(lse_ref.dtype)


def segment_flash_attention(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, KV, D)
    v: jax.Array,  # (B, S, KV, D)
    segment_ids: jax.Array | None = None,  # (B, S) int32; 0 = padding
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
    return_residuals: bool = False,
    expect_resolved: bool = False,
) -> jax.Array | tuple[jax.Array, jax.Array]:
    """Forward kernel; with ``return_residuals`` also emits per-row
    ``lse = m + log(l)`` of shape (B, S, H) for the backward pass."""
    b, s, h, d = q.shape
    kv = k.shape[2]
    assert h % kv == 0, (h, kv)
    g = h // kv
    scale = scale if scale is not None else 1.0 / (d**0.5)
    if expect_resolved:
        _check_resolved(s, block_q, block_kv)
    block_q = select_block(s, block_q)
    block_kv = select_block(s, block_kv)
    nq, nk = s // block_q, s // block_kv
    grid = (b, h, nq, nk)

    q_spec = pl.BlockSpec(
        (None, block_q, None, d), lambda ib, ih, iq, ik: (ib, iq, ih, 0)
    )
    kv_spec = pl.BlockSpec(
        (None, block_kv, None, d), lambda ib, ih, iq, ik: (ib, ik, ih // g, 0)
    )
    o_spec = pl.BlockSpec(
        (None, block_q, None, d), lambda ib, ih, iq, ik: (ib, iq, ih, 0)
    )

    in_specs = [q_spec, kv_spec, kv_spec]
    args = [q, k, v]
    has_seg = segment_ids is not None
    if has_seg:
        in_specs.append(pl.BlockSpec((None, block_q), lambda ib, ih, iq, ik: (ib, iq)))
        in_specs.append(pl.BlockSpec((None, block_kv), lambda ib, ih, iq, ik: (ib, ik)))
        args.extend([segment_ids, segment_ids])

    body = functools.partial(
        _flash_body,
        scale=scale, causal=causal, block_q=block_q,
        block_kv=block_kv, num_kv_blocks=nk,
    )

    out_shape: object = jax.ShapeDtypeStruct(q.shape, q.dtype)
    out_specs: object = o_spec
    if return_residuals:
        out_shape = (
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((b, s, h), jnp.float32),
        )
        out_specs = (
            o_spec,
            pl.BlockSpec((None, block_q, None), lambda ib, ih, iq, ik: (ib, iq, ih)),
        )

    if has_seg and return_residuals:
        def kernel(q_ref, k_ref, v_ref, qs, ks, o_ref, lse_ref, m, l, acc):
            body(q_ref, k_ref, v_ref, qs, ks, o_ref, lse_ref, m, l, acc)
    elif has_seg:
        def kernel(q_ref, k_ref, v_ref, qs, ks, o_ref, m, l, acc):
            body(q_ref, k_ref, v_ref, qs, ks, o_ref, None, m, l, acc)
    elif return_residuals:
        def kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m, l, acc):
            body(q_ref, k_ref, v_ref, None, None, o_ref, lse_ref, m, l, acc)
    else:
        def kernel(q_ref, k_ref, v_ref, o_ref, m, l, acc):
            body(q_ref, k_ref, v_ref, None, None, o_ref, None, m, l, acc)

    scratch = [
        _VMEM((block_q, 128), jnp.float32),
        _VMEM((block_q, 128), jnp.float32),
        _VMEM((block_q, d), jnp.float32),
    ]
    kwargs = {}
    cp = _compiler_params()
    if cp is not None and not interpret:
        kwargs["compiler_params"] = cp
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
        **kwargs,
    )(*args)


# -----------------------------------------------------------------------------
# Backward
# -----------------------------------------------------------------------------


def _recompute_p_ds(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, qseg_ref, kseg_ref,
    *, scale, causal, block_q, block_kv, qb, kb,
):
    """Shared tile recompute: (p, ds) from the saved (lse, delta) residuals.

    ``p`` is assembled under the allow-mask, so fully-masked rows (the
    packed layout's l == 0 padding rows, whose saved lse is the NEG_INF
    sentinel) produce an all-zero tile rather than NaN/Inf.
    """
    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    do = do_ref[...].astype(jnp.float32)
    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    allowed = _tile_mask(qb, kb, block_q, block_kv, causal, qseg_ref, kseg_ref)
    lse = lse_ref[...].astype(jnp.float32)
    p = jnp.where(allowed, jnp.exp(scores - lse[:, None]), 0.0)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
    delta = delta_ref[...].astype(jnp.float32)
    ds = p * (dp - delta[:, None])
    return q, k, do, p, ds


def _bwd_dq_body(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, qseg_ref, kseg_ref,
    dq_ref, dq_scratch,
    *, scale, causal, block_q, block_kv, num_kv_blocks,
):
    """q-stationary pass: dQ = scale · Σ_kv dS · K."""
    qb = pl.program_id(2)
    kb = pl.program_id(3)

    @pl.when(kb == 0)
    def _init():
        dq_scratch[...] = jnp.zeros_like(dq_scratch[...])

    live = _block_live(causal, qb, kb, block_q, block_kv, qseg_ref, kseg_ref)

    @pl.when(live)
    def _compute():
        _, k, _, _, ds = _recompute_p_ds(
            q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, qseg_ref, kseg_ref,
            scale=scale, causal=causal, block_q=block_q, block_kv=block_kv,
            qb=qb, kb=kb,
        )
        dq_scratch[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ()))
        ) * scale

    @pl.when(kb == num_kv_blocks - 1)
    def _finalize():
        dq_ref[...] = dq_scratch[...].astype(dq_ref.dtype)


def _bwd_dkv_body(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, qseg_ref, kseg_ref,
    dk_ref, dv_ref, dk_scratch, dv_scratch,
    *, scale, causal, block_q, block_kv, num_q_blocks, group,
):
    """kv-stationary pass: dK = scale · Σ dSᵀ · Q, dV = Σ Pᵀ · dO.

    The sequential grid axis runs over (group member, q block) pairs —
    ``group · num_q_blocks`` steps per kv tile — so the GQA group-sum
    accumulates in the same VMEM scratch and the outputs land at kv-head
    resolution directly (no (B, S, H, D) per-q-head intermediates in HBM).
    """
    kb = pl.program_id(2)
    t = pl.program_id(3)
    qb = t % num_q_blocks

    @pl.when(t == 0)
    def _init():
        dk_scratch[...] = jnp.zeros_like(dk_scratch[...])
        dv_scratch[...] = jnp.zeros_like(dv_scratch[...])

    live = _block_live(causal, qb, kb, block_q, block_kv, qseg_ref, kseg_ref)

    @pl.when(live)
    def _compute():
        q, _, do, p, ds = _recompute_p_ds(
            q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, qseg_ref, kseg_ref,
            scale=scale, causal=causal, block_q=block_q, block_kv=block_kv,
            qb=qb, kb=kb,
        )
        dv_scratch[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())))
        dk_scratch[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ()))
        ) * scale

    @pl.when(t == group * num_q_blocks - 1)
    def _finalize():
        dk_ref[...] = dk_scratch[...].astype(dk_ref.dtype)
        dv_ref[...] = dv_scratch[...].astype(dv_ref.dtype)


def segment_flash_attention_bwd(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, KV, D)
    v: jax.Array,  # (B, S, KV, D)
    segment_ids: jax.Array | None,
    out: jax.Array,  # (B, S, H, D) — forward output
    lse: jax.Array,  # (B, S, H) fp32 — forward log-sum-exp residual
    do: jax.Array,  # (B, S, H, D) — cotangent of out
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
    expect_resolved: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Tiled two-pass backward: returns (dq, dk, dv) without ever
    materializing the (S × S) probability matrix."""
    b, s, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    scale = scale if scale is not None else 1.0 / (d**0.5)
    if expect_resolved:
        _check_resolved(s, block_q, block_kv)
    block_q = select_block(s, block_q)
    block_kv = select_block(s, block_kv)
    nq, nk = s // block_q, s // block_kv

    # delta_i = Σ_d dO ⊙ O — one cheap rowwise pass outside the kernels.
    delta = jnp.sum(
        do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1
    )  # (B, S, H)

    has_seg = segment_ids is not None

    def specs(at):
        """The six shared tensor specs under one grid→(ib, ih, iq, ik) map."""
        q_spec = pl.BlockSpec(
            (None, block_q, None, d), at(lambda ib, ih, iq, ik: (ib, iq, ih, 0))
        )
        kv_spec = pl.BlockSpec(
            (None, block_kv, None, d), at(lambda ib, ih, iq, ik: (ib, ik, ih // g, 0))
        )
        row_spec = pl.BlockSpec(
            (None, block_q, None), at(lambda ib, ih, iq, ik: (ib, iq, ih))
        )
        seg_specs = []
        if has_seg:
            seg_specs = [
                pl.BlockSpec((None, block_q), at(lambda ib, ih, iq, ik: (ib, iq))),
                pl.BlockSpec((None, block_kv), at(lambda ib, ih, iq, ik: (ib, ik))),
            ]
        return [q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec] + seg_specs

    args = [q, k, v, do, lse, delta]
    if has_seg:
        args.extend([segment_ids, segment_ids])

    kwargs = {}
    cp = _compiler_params()
    if cp is not None and not interpret:
        kwargs["compiler_params"] = cp

    # -- pass 1: q-stationary dQ ---------------------------------------------
    dq_body = functools.partial(
        _bwd_dq_body,
        scale=scale, causal=causal, block_q=block_q, block_kv=block_kv,
        num_kv_blocks=nk,
    )
    if has_seg:
        def dq_kernel(qr, kr, vr, dor, lser, dr, qs, ks, dqr, acc):
            dq_body(qr, kr, vr, dor, lser, dr, qs, ks, dqr, acc)
    else:
        def dq_kernel(qr, kr, vr, dor, lser, dr, dqr, acc):
            dq_body(qr, kr, vr, dor, lser, dr, None, None, dqr, acc)
    dq = pl.pallas_call(
        dq_kernel,
        grid=(b, h, nq, nk),
        in_specs=specs(lambda fn: fn),
        out_specs=pl.BlockSpec(
            (None, block_q, None, d), lambda ib, ih, iq, ik: (ib, iq, ih, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[_VMEM((block_q, d), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(*args)

    # -- pass 2: kv-stationary dK/dV -----------------------------------------
    # Grid (b, kv_heads, nk, g·nq): the sequential axis walks every
    # (group member, q block) pair of one kv tile, so the GQA group-sum
    # accumulates in scratch and the outputs are kv-head resolution —
    # no (B, S, H, D) per-q-head intermediates in HBM.
    def dkv_at(fn):
        return lambda ib, ikv, ik, t: fn(
            ib, ikv * g + t // nq, t % nq, ik
        )

    dkv_body = functools.partial(
        _bwd_dkv_body,
        scale=scale, causal=causal, block_q=block_q, block_kv=block_kv,
        num_q_blocks=nq, group=g,
    )
    if has_seg:
        def dkv_kernel(qr, kr, vr, dor, lser, dr, qs, ks, dkr, dvr, ka, va):
            dkv_body(qr, kr, vr, dor, lser, dr, qs, ks, dkr, dvr, ka, va)
    else:
        def dkv_kernel(qr, kr, vr, dor, lser, dr, dkr, dvr, ka, va):
            dkv_body(qr, kr, vr, dor, lser, dr, None, None, dkr, dvr, ka, va)
    kv_out_spec = pl.BlockSpec(
        (None, block_kv, None, d), lambda ib, ikv, ik, t: (ib, ik, ikv, 0)
    )
    dk, dv = pl.pallas_call(
        dkv_kernel,
        grid=(b, kv, nk, g * nq),
        in_specs=specs(dkv_at),
        out_specs=(kv_out_spec, kv_out_spec),
        out_shape=(
            jax.ShapeDtypeStruct(k.shape, k.dtype),
            jax.ShapeDtypeStruct(v.shape, v.dtype),
        ),
        scratch_shapes=[
            _VMEM((block_kv, d), jnp.float32),
            _VMEM((block_kv, d), jnp.float32),
        ],
        interpret=interpret,
        **kwargs,
    )(*args)
    return dq, dk, dv


# -----------------------------------------------------------------------------
# Scalar-prefetch pruned grid (DESIGN.md §17)
# -----------------------------------------------------------------------------
#
# The dense grid above predicates dead tiles out of the MXU but still DMAs
# every kv tile.  The pruned variants keep the *static* grid shape (data-
# dependent grid sizes are impossible at trace time) and instead route the kv
# BlockSpec index_map through a compacted live-block index fed in via
# ``PrefetchScalarGridSpec``: step t of a row visits its t-th live kv block
# (ascending), and steps past the row's live count repeat the last live block
# — the Pallas pipeline skips the re-DMA when consecutive index_map results
# agree, so dead tiles are never fetched.  Compute is predicated on
# ``t < count``; init fires at t == 0 and finalize at the last grid step, so
# every output block is written even for rows with zero live tiles.
#
# Because live blocks are visited in the same ascending order the dense grid
# uses (which never touches the accumulators on dead tiles), the fp32
# accumulation sequence is identical and the pruned outputs/grads are
# bit-exact against the dense grid — asserted by tests and the bench parity
# rail, with the dense grid kept as the differential-testing oracle.


def _require_prefetch():
    if pltpu is None:  # pragma: no cover - exercised only on broken installs
        raise RuntimeError(
            "scalar-prefetch grid needs jax.experimental.pallas.tpu "
            "(PrefetchScalarGridSpec); route attn_grid=dense instead"
        )


def _flash_prefetch_body(
    kv_idx_ref, kv_cnt_ref,
    q_ref, k_ref, v_ref, qseg_ref, kseg_ref, o_ref, lse_ref,
    m_scratch, l_scratch, acc_scratch,
    *, scale, causal, block_q, block_kv, num_kv_blocks,
):
    ib = pl.program_id(0)
    qb = pl.program_id(2)
    t = pl.program_id(3)
    kb = kv_idx_ref[ib, qb, t]

    @pl.when(t == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch[...], NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch[...])
        acc_scratch[...] = jnp.zeros_like(acc_scratch[...])

    @pl.when(t < kv_cnt_ref[ib, qb])
    def _compute():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        v = v_ref[...].astype(jnp.float32)
        scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        allowed = _tile_mask(qb, kb, block_q, block_kv, causal, qseg_ref, kseg_ref)
        scores = jnp.where(allowed, scores, NEG_INF)

        m_prev = m_scratch[:, 0]
        l_prev = l_scratch[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1))
        safe_m = jnp.where(m_new <= NEG_INF, 0.0, m_new)
        p = jnp.where(allowed, jnp.exp(scores - safe_m[:, None]), 0.0)
        alpha = jnp.where(m_prev <= NEG_INF, 0.0, jnp.exp(m_prev - safe_m))
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc = acc_scratch[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ()))
        )
        m_scratch[...] = jnp.broadcast_to(m_new[:, None], m_scratch.shape)
        l_scratch[...] = jnp.broadcast_to(l_new[:, None], l_scratch.shape)
        acc_scratch[...] = acc

    @pl.when(t == num_kv_blocks - 1)
    def _finalize():
        l = l_scratch[:, 0]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_scratch[...] / denom[:, None]).astype(o_ref.dtype)
        if lse_ref is not None:
            m = m_scratch[:, 0]
            lse = jnp.where(l > 0.0, m + jnp.log(denom), NEG_INF)
            lse_ref[...] = lse.astype(lse_ref.dtype)


def segment_flash_attention_pruned(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, KV, D)
    v: jax.Array,  # (B, S, KV, D)
    segment_ids: jax.Array,  # (B, S) int32; 0 = padding — required
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
    return_residuals: bool = False,
    expect_resolved: bool = False,
    tables=None,
) -> jax.Array | tuple[jax.Array, jax.Array]:
    """Scalar-prefetch forward: dense-grid math, DMA-pruned kv fetch."""
    _require_prefetch()
    assert segment_ids is not None, "pruned grid requires segment ids"
    b, s, h, d = q.shape
    kv = k.shape[2]
    assert h % kv == 0, (h, kv)
    g = h // kv
    scale = scale if scale is not None else 1.0 / (d**0.5)
    if expect_resolved:
        _check_resolved(s, block_q, block_kv)
    block_q = select_block(s, block_q)
    block_kv = select_block(s, block_kv)
    nq, nk = s // block_q, s // block_kv

    if tables is None:
        from repro.kernels.liveness import build_liveness_tables

        tables = build_liveness_tables(
            segment_ids, block_q=block_q, block_kv=block_kv, causal=causal
        )
    kv_idx, kv_cnt = tables.kv_idx, tables.kv_count

    q_spec = pl.BlockSpec(
        (None, block_q, None, d), lambda ib, ih, iq, ik, I, C: (ib, iq, ih, 0)
    )
    kv_spec = pl.BlockSpec(
        (None, block_kv, None, d),
        lambda ib, ih, iq, ik, I, C: (ib, I[ib, iq, ik], ih // g, 0),
    )
    qseg_spec = pl.BlockSpec((None, block_q), lambda ib, ih, iq, ik, I, C: (ib, iq))
    kseg_spec = pl.BlockSpec(
        (None, block_kv), lambda ib, ih, iq, ik, I, C: (ib, I[ib, iq, ik])
    )
    o_spec = pl.BlockSpec(
        (None, block_q, None, d), lambda ib, ih, iq, ik, I, C: (ib, iq, ih, 0)
    )

    body = functools.partial(
        _flash_prefetch_body,
        scale=scale, causal=causal, block_q=block_q,
        block_kv=block_kv, num_kv_blocks=nk,
    )
    out_shape: object = jax.ShapeDtypeStruct(q.shape, q.dtype)
    out_specs: object = o_spec
    if return_residuals:
        out_shape = (
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((b, s, h), jnp.float32),
        )
        out_specs = (
            o_spec,
            pl.BlockSpec(
                (None, block_q, None), lambda ib, ih, iq, ik, I, C: (ib, iq, ih)
            ),
        )

        def kernel(I, C, qr, kr, vr, qs, ks, o_ref, lse_ref, m, l, acc):
            body(I, C, qr, kr, vr, qs, ks, o_ref, lse_ref, m, l, acc)
    else:
        def kernel(I, C, qr, kr, vr, qs, ks, o_ref, m, l, acc):
            body(I, C, qr, kr, vr, qs, ks, o_ref, None, m, l, acc)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, h, nq, nk),
        in_specs=[q_spec, kv_spec, kv_spec, qseg_spec, kseg_spec],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
    )
    kwargs = {}
    cp = _compiler_params()
    if cp is not None and not interpret:
        kwargs["compiler_params"] = cp
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
        **kwargs,
    )(kv_idx, kv_cnt, q, k, v, segment_ids, segment_ids)


def _bwd_dq_prefetch_body(
    kv_idx_ref, kv_cnt_ref,
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, qseg_ref, kseg_ref,
    dq_ref, dq_scratch,
    *, scale, causal, block_q, block_kv, num_kv_blocks,
):
    """q-stationary dQ over the pruned row index — mirrors _bwd_dq_body."""
    ib = pl.program_id(0)
    qb = pl.program_id(2)
    t = pl.program_id(3)
    kb = kv_idx_ref[ib, qb, t]

    @pl.when(t == 0)
    def _init():
        dq_scratch[...] = jnp.zeros_like(dq_scratch[...])

    @pl.when(t < kv_cnt_ref[ib, qb])
    def _compute():
        _, k, _, _, ds = _recompute_p_ds(
            q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, qseg_ref, kseg_ref,
            scale=scale, causal=causal, block_q=block_q, block_kv=block_kv,
            qb=qb, kb=kb,
        )
        dq_scratch[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ()))
        ) * scale

    @pl.when(t == num_kv_blocks - 1)
    def _finalize():
        dq_ref[...] = dq_scratch[...].astype(dq_ref.dtype)


def _bwd_dkv_prefetch_body(
    q_idx_ref, q_cnt_ref,
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, qseg_ref, kseg_ref,
    dk_ref, dv_ref, dk_scratch, dv_scratch,
    *, scale, causal, block_q, block_kv, num_q_blocks, group,
):
    """kv-stationary dK/dV over the transposed column index: the sequential
    axis still walks (group member, q step) pairs, but the q step now maps
    through ``q_idx[b, kb]`` so each member only fetches the q tiles that
    attend into this kv tile."""
    ib = pl.program_id(0)
    kb = pl.program_id(2)
    t = pl.program_id(3)
    qt = t % num_q_blocks
    qb = q_idx_ref[ib, kb, qt]

    @pl.when(t == 0)
    def _init():
        dk_scratch[...] = jnp.zeros_like(dk_scratch[...])
        dv_scratch[...] = jnp.zeros_like(dv_scratch[...])

    @pl.when(qt < q_cnt_ref[ib, kb])
    def _compute():
        q, _, do, p, ds = _recompute_p_ds(
            q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, qseg_ref, kseg_ref,
            scale=scale, causal=causal, block_q=block_q, block_kv=block_kv,
            qb=qb, kb=kb,
        )
        dv_scratch[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())))
        dk_scratch[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ()))
        ) * scale

    @pl.when(t == group * num_q_blocks - 1)
    def _finalize():
        dk_ref[...] = dk_scratch[...].astype(dk_ref.dtype)
        dv_ref[...] = dv_scratch[...].astype(dv_ref.dtype)


def segment_flash_attention_bwd_pruned(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    segment_ids: jax.Array,  # required
    out: jax.Array,
    lse: jax.Array,
    do: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
    expect_resolved: bool = False,
    tables=None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Pruned two-pass backward: the dQ pass reuses the forward row index,
    the dK/dV pass the transposed column index."""
    _require_prefetch()
    assert segment_ids is not None, "pruned grid requires segment ids"
    b, s, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    scale = scale if scale is not None else 1.0 / (d**0.5)
    if expect_resolved:
        _check_resolved(s, block_q, block_kv)
    block_q = select_block(s, block_q)
    block_kv = select_block(s, block_kv)
    nq, nk = s // block_q, s // block_kv

    if tables is None:
        from repro.kernels.liveness import build_liveness_tables

        tables = build_liveness_tables(
            segment_ids, block_q=block_q, block_kv=block_kv, causal=causal
        )

    delta = jnp.sum(
        do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1
    )  # (B, S, H)
    args = [q, k, v, do, lse, delta, segment_ids, segment_ids]

    kwargs = {}
    cp = _compiler_params()
    if cp is not None and not interpret:
        kwargs["compiler_params"] = cp

    # -- pass 1: q-stationary dQ over the row index --------------------------
    def dq_specs():
        def at(fn):
            return lambda ib, ih, iq, ik, I, C: fn(ib, ih, iq, I[ib, iq, ik])

        q_spec = pl.BlockSpec(
            (None, block_q, None, d), at(lambda ib, ih, iq, ik: (ib, iq, ih, 0))
        )
        kv_spec = pl.BlockSpec(
            (None, block_kv, None, d), at(lambda ib, ih, iq, ik: (ib, ik, ih // g, 0))
        )
        row_spec = pl.BlockSpec(
            (None, block_q, None), at(lambda ib, ih, iq, ik: (ib, iq, ih))
        )
        seg_specs = [
            pl.BlockSpec((None, block_q), at(lambda ib, ih, iq, ik: (ib, iq))),
            pl.BlockSpec((None, block_kv), at(lambda ib, ih, iq, ik: (ib, ik))),
        ]
        return [q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec] + seg_specs

    dq_body = functools.partial(
        _bwd_dq_prefetch_body,
        scale=scale, causal=causal, block_q=block_q, block_kv=block_kv,
        num_kv_blocks=nk,
    )

    def dq_kernel(I, C, qr, kr, vr, dor, lser, dr, qs, ks, dqr, acc):
        dq_body(I, C, qr, kr, vr, dor, lser, dr, qs, ks, dqr, acc)

    dq_grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, h, nq, nk),
        in_specs=dq_specs(),
        out_specs=pl.BlockSpec(
            (None, block_q, None, d),
            lambda ib, ih, iq, ik, I, C: (ib, iq, ih, 0),
        ),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
    )
    dq = pl.pallas_call(
        dq_kernel,
        grid_spec=dq_grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
        **kwargs,
    )(tables.kv_idx, tables.kv_count, *args)

    # -- pass 2: kv-stationary dK/dV over the column index -------------------
    def dkv_specs():
        def at(fn):
            return lambda ib, ikv, ik, t, I, C: fn(
                ib, ikv * g + t // nq, I[ib, ik, t % nq], ik
            )

        q_spec = pl.BlockSpec(
            (None, block_q, None, d), at(lambda ib, ih, iq, ik: (ib, iq, ih, 0))
        )
        kv_spec = pl.BlockSpec(
            (None, block_kv, None, d), at(lambda ib, ih, iq, ik: (ib, ik, ih // g, 0))
        )
        row_spec = pl.BlockSpec(
            (None, block_q, None), at(lambda ib, ih, iq, ik: (ib, iq, ih))
        )
        seg_specs = [
            pl.BlockSpec((None, block_q), at(lambda ib, ih, iq, ik: (ib, iq))),
            pl.BlockSpec((None, block_kv), at(lambda ib, ih, iq, ik: (ib, ik))),
        ]
        return [q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec] + seg_specs

    dkv_body = functools.partial(
        _bwd_dkv_prefetch_body,
        scale=scale, causal=causal, block_q=block_q, block_kv=block_kv,
        num_q_blocks=nq, group=g,
    )

    def dkv_kernel(I, C, qr, kr, vr, dor, lser, dr, qs, ks, dkr, dvr, ka, va):
        dkv_body(I, C, qr, kr, vr, dor, lser, dr, qs, ks, dkr, dvr, ka, va)

    kv_out_spec = pl.BlockSpec(
        (None, block_kv, None, d), lambda ib, ikv, ik, t, I, C: (ib, ik, ikv, 0)
    )
    dkv_grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kv, nk, g * nq),
        in_specs=dkv_specs(),
        out_specs=(kv_out_spec, kv_out_spec),
        scratch_shapes=[
            pltpu.VMEM((block_kv, d), jnp.float32),
            pltpu.VMEM((block_kv, d), jnp.float32),
        ],
    )
    dk, dv = pl.pallas_call(
        dkv_kernel,
        grid_spec=dkv_grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct(k.shape, k.dtype),
            jax.ShapeDtypeStruct(v.shape, v.dtype),
        ),
        interpret=interpret,
        **kwargs,
    )(tables.q_idx, tables.q_count, *args)
    return dq, dk, dv


def live_tile_counts(
    segment_ids, s: int, block_q: int, block_kv: int, causal: bool = True
) -> dict:
    """Host-side mirror of the kernel's block-skip rule (benchmarks/tests).

    Counts (row, q-block, kv-block) tiles that survive (a) the causal skip
    alone and (b) causal + segment-range skipping, for a (B, S) segment-id
    array.  Pure numpy; mirrors ``_block_live`` exactly.
    """
    import numpy as np

    seg = np.asarray(segment_ids)
    bsz = seg.shape[0]
    block_q = select_block(s, block_q)
    block_kv = select_block(s, block_kv)
    nq, nk = s // block_q, s // block_kv
    total = bsz * nq * nk
    causal_live = 0
    seg_live = 0
    for i in range(bsz):
        for qb in range(nq):
            qs = seg[i, qb * block_q : (qb + 1) * block_q]
            q_pos = qs[qs > 0]
            for kb in range(nk):
                if causal and qb * block_q + block_q - 1 < kb * block_kv:
                    continue
                causal_live += 1
                ks = seg[i, kb * block_kv : (kb + 1) * block_kv]
                k_pos = ks[ks > 0]
                if (
                    q_pos.size
                    and k_pos.size
                    and q_pos.max() >= k_pos.min()
                    and k_pos.max() >= q_pos.min()
                ):
                    seg_live += 1
    out = {
        "tiles": total,
        "block_q": block_q,
        "block_kv": block_kv,
        "causal_live": causal_live,
        "segment_live": seg_live,
        "causal_live_fraction": causal_live / total if total else 0.0,
        "segment_live_fraction": seg_live / total if total else 0.0,
    }
    from repro import obs  # deferred: keep kernel import time lean

    obs.gauge(
        "kernel_live_tile_fraction",
        help="fraction of attention tiles surviving the block-skip rule",
        mode="causal",
    ).set(out["causal_live_fraction"])
    obs.gauge(
        "kernel_live_tile_fraction", mode="segment"
    ).set(out["segment_live_fraction"])
    return out
