"""Segment-aware causal flash attention — Pallas TPU kernel.

TPU-native adaptation of the paper's packing story (DESIGN.md §2): ODB's
packed groups need contamination-free attention; on GPU that is a varlen
CUDA kernel (flash_attn_varlen), on TPU the natural form is *segment-id
masking fused into a tiled attention kernel*.

Tiling: grid = (batch, q_heads, num_q_blocks, num_kv_blocks), the last axis
sequential (TPU "arbitrary" dimension semantics) carrying the online-softmax
accumulators (m, l, acc) in VMEM scratch.  BlockSpecs pull one (block_q × d)
query tile and one (block_kv × d) key/value tile into VMEM per step; GQA is
expressed in the k/v index_map (kv head = q head // group).  Causally dead
(q, kv) block pairs are skipped via ``pl.when``.

Backward: exposed through ``jax.custom_vjp`` in ops.py with the pure-jnp
reference as the recompute path — the forward kernel is the perf-critical
piece (prefill / packed-batch forward).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU compiler params are optional off-TPU / in interpret mode
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
    def _compiler_params():
        try:
            return pltpu.CompilerParams(
                dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
            )
        except Exception:
            return None
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None
    def _compiler_params():
        return None

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _flash_body(
    q_ref, k_ref, v_ref, qseg_ref, kseg_ref, o_ref,
    m_scratch, l_scratch, acc_scratch,
    *, scale, causal, block_q, block_kv, num_kv_blocks,
):
    qb = pl.program_id(2)
    kb = pl.program_id(3)

    @pl.when(kb == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch[...], NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch[...])
        acc_scratch[...] = jnp.zeros_like(acc_scratch[...])

    if causal:
        live = qb * block_q + block_q - 1 >= kb * block_kv
    else:
        live = True

    @pl.when(live)
    def _compute():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        v = v_ref[...].astype(jnp.float32)
        scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale

        q_pos = qb * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0
        )
        k_pos = kb * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1
        )
        allowed = jnp.ones((block_q, block_kv), dtype=jnp.bool_)
        if causal:
            allowed &= k_pos <= q_pos
        if qseg_ref is not None:
            qseg = qseg_ref[...]
            kseg = kseg_ref[...]
            allowed &= (qseg[:, None] == kseg[None, :]) & (kseg[None, :] > 0)
        scores = jnp.where(allowed, scores, NEG_INF)

        m_prev = m_scratch[:, 0]
        l_prev = l_scratch[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1))
        safe_m = jnp.where(m_new <= NEG_INF, 0.0, m_new)
        p = jnp.where(allowed, jnp.exp(scores - safe_m[:, None]), 0.0)
        alpha = jnp.where(m_prev <= NEG_INF, 0.0, jnp.exp(m_prev - safe_m))
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc = acc_scratch[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ()))
        )
        m_scratch[...] = jnp.broadcast_to(m_new[:, None], m_scratch.shape)
        l_scratch[...] = jnp.broadcast_to(l_new[:, None], l_scratch.shape)
        acc_scratch[...] = acc

    @pl.when(kb == num_kv_blocks - 1)
    def _finalize():
        l = l_scratch[:, 0]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_scratch[...] / denom[:, None]).astype(o_ref.dtype)


def segment_flash_attention(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, KV, D)
    v: jax.Array,  # (B, S, KV, D)
    segment_ids: jax.Array | None = None,  # (B, S) int32; 0 = padding
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, s, h, d = q.shape
    kv = k.shape[2]
    assert h % kv == 0, (h, kv)
    g = h // kv
    scale = scale if scale is not None else 1.0 / (d**0.5)
    block_q = min(block_q, s)
    block_kv = min(block_kv, s)
    assert s % block_q == 0 and s % block_kv == 0, (s, block_q, block_kv)
    nq, nk = s // block_q, s // block_kv
    grid = (b, h, nq, nk)

    q_spec = pl.BlockSpec(
        (None, block_q, None, d), lambda ib, ih, iq, ik: (ib, iq, ih, 0)
    )
    kv_spec = pl.BlockSpec(
        (None, block_kv, None, d), lambda ib, ih, iq, ik: (ib, ik, ih // g, 0)
    )
    o_spec = pl.BlockSpec(
        (None, block_q, None, d), lambda ib, ih, iq, ik: (ib, iq, ih, 0)
    )

    in_specs = [q_spec, kv_spec, kv_spec]
    args = [q, k, v]
    has_seg = segment_ids is not None
    if has_seg:
        in_specs.append(pl.BlockSpec((None, block_q), lambda ib, ih, iq, ik: (ib, iq)))
        in_specs.append(pl.BlockSpec((None, block_kv), lambda ib, ih, iq, ik: (ib, ik)))
        args.extend([segment_ids, segment_ids])

    body = functools.partial(
        _flash_body,
        scale=scale, causal=causal, block_q=block_q,
        block_kv=block_kv, num_kv_blocks=nk,
    )

    if has_seg:
        def kernel(q_ref, k_ref, v_ref, qs, ks, o_ref, m, l, acc):
            body(q_ref, k_ref, v_ref, qs, ks, o_ref, m, l, acc)
    else:
        def kernel(q_ref, k_ref, v_ref, o_ref, m, l, acc):
            body(q_ref, k_ref, v_ref, None, None, o_ref, m, l, acc)

    scratch = [
        _VMEM((block_q, 128), jnp.float32),
        _VMEM((block_q, 128), jnp.float32),
        _VMEM((block_q, d), jnp.float32),
    ]
    kwargs = {}
    cp = _compiler_params()
    if cp is not None and not interpret:
        kwargs["compiler_params"] = cp
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **kwargs,
    )(*args)
