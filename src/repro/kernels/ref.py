"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth).

``segment_flash_attention_ref`` — materializing softmax attention with the
shared masking contract: allowed iff segments match (0 = padding) and
(causal ⇒ k_pos ≤ q_pos).  GQA via head grouping.

``ssd_scan_ref`` — sequential (token-by-token) state-space recurrence, the
mathematical definition the chunked SSD kernel must reproduce.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def segment_flash_attention_ref(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, KV, D)
    v: jax.Array,  # (B, S, KV, D)
    segment_ids: jax.Array | None = None,  # (B, S) int32; 0 = padding
    causal: bool = True,
    scale: float | None = None,
) -> jax.Array:
    b, s, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    scale = scale if scale is not None else 1.0 / (d**0.5)
    qg = q.reshape(b, s, kv, g, d)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * scale
    allowed = jnp.ones((b, s, s), dtype=bool)
    if causal:
        pos = jnp.arange(s)
        allowed &= pos[None, None, :] <= pos[None, :, None]
    if segment_ids is not None:
        allowed &= (segment_ids[:, :, None] == segment_ids[:, None, :]) & (
            segment_ids[:, None, :] > 0
        )
    scores = jnp.where(allowed[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, s, h, d)


def ssd_scan_ref(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) positive
    a: jax.Array,  # (H,) negative decay rates
    b_proj: jax.Array,  # (B, S, N)
    c_proj: jax.Array,  # (B, S, N)
    initial_state: jax.Array | None = None,  # (B, H, P, N)
):
    """Token-level recurrence: h_t = exp(a·dt_t)·h_{t-1} + dt_t·B_t⊗x_t;
    y_t = C_t · h_t.  Returns (y (B,S,H,P), final_state)."""
    bsz, s, h, p = x.shape
    n = b_proj.shape[-1]
    state0 = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((bsz, h, p, n), jnp.float32)
    )

    def step(state, inputs):
        xt, dtt, bt, ct = inputs
        decay = jnp.exp(a[None, :] * dtt)  # (B, H)
        upd = jnp.einsum(
            "bn,bh,bhp->bhpn",
            bt.astype(jnp.float32),
            dtt.astype(jnp.float32),
            xt.astype(jnp.float32),
        )
        state = state * decay[:, :, None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", state, ct.astype(jnp.float32))
        return state, y

    xs = (
        x.transpose(1, 0, 2, 3),
        dt.transpose(1, 0, 2).astype(jnp.float32),
        b_proj.transpose(1, 0, 2),
        c_proj.transpose(1, 0, 2),
    )
    final, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final
