"""Mamba-2 chunked SSD scan — Pallas TPU kernel.

Fuses one SSD chunk step (within-chunk quadratic term + carried-state term +
state update) per grid step.  grid = (batch, heads, num_chunks); the chunk
axis is sequential ("arbitrary") and carries the (P × N) SSM state in VMEM
scratch, so the state never round-trips HBM between chunks — the TPU
analogue of the fused CUDA chunk scan in the Mamba-2 reference.

Inputs are pre-projected (the surrounding block computes x/B/C/dt):
  x   (B, S, H, P)   — per-head inputs
  adt (B, S, H)      — a·dt (negative; pre-multiplied decay exponents)
  dt  (B, S, H)      — positive step sizes
  b_p (B, S, N)      — state input projection (ngroups=1)
  c_p (B, S, N)      — state output projection
Output: y (B, S, H, P).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None


def _ssd_kernel(
    x_ref,  # (Q, P)
    adt_ref,  # (Q, 1)
    dt_ref,  # (Q, 1)
    b_ref,  # (Q, N)
    c_ref,  # (Q, N)
    y_ref,  # (Q, P)
    state,  # scratch (P, N) f32
    *,
    chunk: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state[...] = jnp.zeros_like(state[...])

    x = x_ref[...].astype(jnp.float32)  # (Q, P)
    adt = adt_ref[...][:, 0].astype(jnp.float32)  # (Q,)
    dt = dt_ref[...][:, 0].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)  # (Q, N)
    c = c_ref[...].astype(jnp.float32)

    acs = jnp.cumsum(adt)  # (Q,)
    # within-chunk decay matrix L[i, j] = exp(sum_{j<k<=i} adt_k), lower-tri
    diff = acs[:, None] - acs[None, :] + adt[None, :]  # = Σ_{j<=k<=i}? see below
    # acs_i - acs_j = Σ_{j<k<=i} adt_k  (for i >= j)
    diff = acs[:, None] - acs[None, :]
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = row >= col
    l_mat = jnp.where(tri, jnp.exp(diff), 0.0)  # (Q, Q)

    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())))  # (Q, Q)
    w = l_mat * scores * dt[None, :]
    y_diag = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())))  # (Q, P)

    s = state[...]  # (P, N)
    y_off = jnp.exp(acs)[:, None] * jax.lax.dot_general(
        c, s, (((1,), (1,)), ((), ()))
    )  # (Q, P)

    # state update: s' = exp(acs_last)·s + Σ_q (chunk_decay_q·dt_q)·x_q ⊗ B_q
    chunk_decay = jnp.exp(acs[-1] - acs) * dt  # (Q,)
    xw = x * chunk_decay[:, None]  # (Q, P)
    s_new = jnp.exp(acs[-1]) * s + jax.lax.dot_general(
        xw, b, (((0,), (0,)), ((), ()))
    )  # (P, N)
    state[...] = s_new
    y_ref[...] = (y_diag + y_off).astype(y_ref.dtype)


def ssd_scan(
    x: jax.Array,  # (B, S, H, P)
    adt: jax.Array,  # (B, S, H)
    dt: jax.Array,  # (B, S, H)
    b_p: jax.Array,  # (B, S, N)
    c_p: jax.Array,  # (B, S, N)
    *,
    chunk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    bsz, s, h, p = x.shape
    n = b_p.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    grid = (bsz, h, nc)

    x_spec = pl.BlockSpec((None, chunk, None, p), lambda ib, ih, ic: (ib, ic, ih, 0))
    sc_spec = pl.BlockSpec((None, chunk, None, 1), lambda ib, ih, ic: (ib, ic, ih, 0))
    bn_spec = pl.BlockSpec((None, chunk, n), lambda ib, ih, ic: (ib, ic, 0))

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[x_spec, sc_spec, sc_spec, bn_spec, bn_spec],
        out_specs=x_spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[_VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, adt[..., None], dt[..., None], b_p, c_p)
