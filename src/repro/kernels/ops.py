"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute under ``interpret=True``; on TPU
they compile through Mosaic.  ``flash_attention`` carries a ``custom_vjp``
whose backward runs the dedicated Pallas dq/dkv kernels
(:func:`~repro.kernels.flash_attention.segment_flash_attention_bwd`) from the
saved ``(q, k, v, out, lse)`` residuals — the recompute-free two-pass
formulation, so the training backward never round-trips through the O(S²)
jnp reference (``kernels/ref.py`` remains the allclose oracle for tests
only).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import (
    segment_flash_attention,
    segment_flash_attention_bwd,
)
from repro.kernels.ssd_scan import ssd_scan


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def flash_attention(q, k, v, segment_ids=None, causal=True, block_q=128, block_kv=128):
    return segment_flash_attention(
        q, k, v, segment_ids,
        causal=causal, block_q=block_q, block_kv=block_kv, interpret=_on_cpu(),
    )


def _flash_fwd(q, k, v, segment_ids, causal, block_q, block_kv):
    out, lse = segment_flash_attention(
        q, k, v, segment_ids,
        causal=causal, block_q=block_q, block_kv=block_kv,
        interpret=_on_cpu(), return_residuals=True,
    )
    return out, (q, k, v, segment_ids, out, lse)


def _flash_bwd(causal, block_q, block_kv, res, g):
    q, k, v, segment_ids, out, lse = res
    dq, dk, dv = segment_flash_attention_bwd(
        q, k, v, segment_ids, out, lse, g,
        causal=causal, block_q=block_q, block_kv=block_kv, interpret=_on_cpu(),
    )
    return dq, dk, dv, None


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def ssd_chunked_scan(x, dt, a, b_proj, c_proj, *, chunk: int = 256):
    """Kernel-backed SSD: y = SSD(x, dt, a, B, C) with zero initial state."""
    adt = a[None, None, :] * dt
    return ssd_scan(
        x, adt.astype(jnp.float32), dt.astype(jnp.float32), b_proj, c_proj,
        chunk=chunk, interpret=_on_cpu(),
    )
