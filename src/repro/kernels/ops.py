"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute under ``interpret=True``; on TPU
they compile through Mosaic.  ``flash_attention`` carries a ``custom_vjp``
whose backward runs the dedicated Pallas dq/dkv kernels
(:func:`~repro.kernels.flash_attention.segment_flash_attention_bwd`) from the
saved ``(q, k, v, out, lse)`` residuals — the recompute-free two-pass
formulation, so the training backward never round-trips through the O(S²)
jnp reference (``kernels/ref.py`` remains the allclose oracle for tests
only).

Grid routing (DESIGN.md §17): ``grid ∈ {dense, pruned, auto}`` picks between
the dense ``(b, h, nq, nk)`` grid and the scalar-prefetch pruned grid that
skips dead kv-tile DMAs through a compacted liveness index.  ``auto``
resolves to pruned exactly when segment ids are present and the backend is
TPU; an explicit ``pruned`` is honored anywhere segments exist (interpret
mode included — that is how CPU tests and benches exercise the path) and
degrades to dense without them, since there is nothing to build liveness
from.  Block sizes are resolved once here (``resolve_blocks``) and threaded
through the ``custom_vjp`` nondiff args, so the forward and both backward
passes provably consume the same ``(block_q, block_kv)`` pair —
``select_block`` is not idempotent on raw requests, and letting each pass
re-resolve independently is how fwd/bwd grids could silently drift for
ragged S.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import (
    resolve_blocks,
    segment_flash_attention,
    segment_flash_attention_bwd,
    segment_flash_attention_bwd_pruned,
    segment_flash_attention_pruned,
)
from repro.kernels.ssd_scan import ssd_scan

GRID_MODES = ("dense", "pruned", "auto")


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def resolve_grid(grid: str | None, segment_ids) -> str:
    """Resolve an ``attn_grid`` request to a concrete grid variant."""
    if grid is None:
        grid = "auto"
    if grid not in GRID_MODES:
        raise ValueError(f"grid must be one of {GRID_MODES}, got {grid!r}")
    if segment_ids is None:
        return "dense"  # no segments -> no liveness table to prune from
    if grid == "auto":
        return "pruned" if jax.default_backend() == "tpu" else "dense"
    return grid


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _flash(q, k, v, segment_ids, causal, block_q, block_kv, grid):
    if grid == "pruned":
        return segment_flash_attention_pruned(
            q, k, v, segment_ids,
            causal=causal, block_q=block_q, block_kv=block_kv,
            interpret=_on_cpu(), expect_resolved=True,
        )
    return segment_flash_attention(
        q, k, v, segment_ids,
        causal=causal, block_q=block_q, block_kv=block_kv,
        interpret=_on_cpu(), expect_resolved=True,
    )


def _flash_fwd(q, k, v, segment_ids, causal, block_q, block_kv, grid):
    fwd = (
        segment_flash_attention_pruned
        if grid == "pruned"
        else segment_flash_attention
    )
    out, lse = fwd(
        q, k, v, segment_ids,
        causal=causal, block_q=block_q, block_kv=block_kv,
        interpret=_on_cpu(), return_residuals=True, expect_resolved=True,
    )
    return out, (q, k, v, segment_ids, out, lse)


def _flash_bwd(causal, block_q, block_kv, grid, res, g):
    q, k, v, segment_ids, out, lse = res
    bwd = (
        segment_flash_attention_bwd_pruned
        if grid == "pruned"
        else segment_flash_attention_bwd
    )
    dq, dk, dv = bwd(
        q, k, v, segment_ids, out, lse, g,
        causal=causal, block_q=block_q, block_kv=block_kv,
        interpret=_on_cpu(), expect_resolved=True,
    )
    return dq, dk, dv, None


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(
    q, k, v, segment_ids=None, causal=True, block_q=128, block_kv=128,
    grid="auto",
):
    """Public flash-attention entry: resolves the block pair and grid variant
    once, then dispatches through the custom_vjp with both pinned as nondiff
    args (one resolution per shape for fwd *and* bwd)."""
    s = q.shape[1]
    block_q, block_kv = resolve_blocks(s, block_q, block_kv)
    mode = resolve_grid(grid, segment_ids)
    return _flash(q, k, v, segment_ids, causal, block_q, block_kv, mode)


def ssd_chunked_scan(x, dt, a, b_proj, c_proj, *, chunk: int = 256):
    """Kernel-backed SSD: y = SSD(x, dt, a, B, C) with zero initial state."""
    adt = a[None, None, :] * dt
    return ssd_scan(
        x, adt.astype(jnp.float32), dt.astype(jnp.float32), b_proj, c_proj,
        chunk=chunk, interpret=_on_cpu(),
    )
