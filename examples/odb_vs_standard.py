"""Compare all seven batching systems on one dataset (mini Table 1).

Every method builds its *real* schedule (real grouping/alignment/padding);
the H20 cost model converts schedules into indicative wall time.

    PYTHONPATH=src python examples/odb_vs_standard.py --dataset sharegpt4o
"""

import argparse

from benchmarks.common import MODEL_8B, PREP_RATE, evaluate_schedule
from repro.core import OdbConfig
from repro.data import (
    LengthCache,
    bmt_schedule,
    get_dataset,
    gmt_schedule,
    hfg_schedule,
    odb_schedule,
    sorted_schedule,
    standard_schedule,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="sharegpt4o")
    ap.add_argument("--scale", type=float, default=0.03)
    ap.add_argument("--world", type=int, default=8)
    ap.add_argument("--l-max", type=int, default=12288)
    args = ap.parse_args()

    ds = get_dataset(args.dataset, scale=args.scale)
    lengths = ds.lengths()
    cache = LengthCache.build(ds)
    prep = PREP_RATE.get(args.dataset, PREP_RATE["default"])
    w = args.world

    reports = []
    reports.append(
        evaluate_schedule("standard(bs=1)", standard_schedule(lengths, w, 1), MODEL_8B, prep_rate=prep)
    )
    reports.append(
        evaluate_schedule("sorted(bs=2)", sorted_schedule(lengths, w, 2), MODEL_8B, prep_rate=prep)
    )
    reports.append(
        evaluate_schedule("gmt-oracle*", gmt_schedule(cache, w, args.l_max), MODEL_8B, prep_rate=prep)
    )
    reports.append(
        evaluate_schedule("bmt-oracle*", bmt_schedule(cache, w, args.l_max), MODEL_8B, prep_rate=prep)
    )
    reports.append(
        evaluate_schedule("hfg-oracle*", hfg_schedule(cache, w, 2), MODEL_8B, prep_rate=prep)
    )
    cfg = OdbConfig(l_max=args.l_max, buffer_size=1024, prefetch_factor=256, num_workers=4)
    steps, audit = odb_schedule(lengths, w, cfg)
    reports.append(evaluate_schedule("ODB (ours)", steps, MODEL_8B, prep_rate=prep, depth=cfg.depth))

    std = reports[0].sam_per_s
    print(f"\n{args.dataset} (N={len(lengths)}), W={w}, L_max={args.l_max}")
    print(f"{'method':16s} {'sam/s':>8} {'spd':>6} {'pad%':>6} {'sam/upd':>8} {'upd/ep':>7}")
    for r in reports:
        print(
            f"{r.method:16s} {r.sam_per_s:>8.2f} {r.sam_per_s/std:>5.2f}x "
            f"{r.padding_pct:>6.2f} {r.sam_per_upd:>8.1f} {r.upd_per_epoch:>7}"
        )
    print("* offline oracle rows use a scalar length cache (construction excluded)")
    print(
        f"ODB cache build avoided; length-cache build took {cache.build_seconds:.2f}s host time "
        f"for {len(lengths)} samples (invalidated on any policy change)"
    )
    print(f"ODB audit: eta_identity={audit.eta_identity} eta_quota={audit.eta_quota}")


if __name__ == "__main__":
    main()
