"""Quickstart: ODB end-to-end in ~60 seconds on CPU.

Builds a tiny decoder LM, wraps a synthetic high-CV dataset with the
OnlineDynamicLoader (ODB: online length observation + DGAP alignment), and
trains a few aligned steps — printing per-step metadata (emitted samples,
token counts, padding) and the terminal protocol audit (Theorems 1/2).

    PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

import jax

from repro.configs import get_smoke_config
from repro.core import BucketSpec, OdbConfig
from repro.data import OnlineDynamicLoader, get_dataset
from repro.models import LM
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    cfg = dataclasses.replace(get_smoke_config("qwen3_0_6b"), vocab_size=512)
    model = LM(cfg)

    loader = OnlineDynamicLoader(
        get_dataset("longtail", scale=0.5),  # synthetic 90/10 long-tail (App. I)
        world_size=4,
        config=OdbConfig(l_max=2048, buffer_size=64, prefetch_factor=32, num_workers=4),
        # coarse bucket grid: few distinct shapes => few XLA compiles on CPU
        bucket_spec=BucketSpec(
            min_len=512, max_len=4096, align=512, max_count=64, use_midpoints=False
        ),
        vocab_size=cfg.vocab_size,
    )

    trainer = Trainer(
        model,
        loader,
        OptimizerConfig(lr=1e-3, total_steps=40),
        TrainerConfig(log_every=1, max_steps=8),
    )
    state = trainer.init_state(jax.random.PRNGKey(0))
    state, steps = trainer.train_epoch(state)

    print(f"\n{'step':>4} {'loss':>8} {'tokens':>8} {'sam/s':>8} {'pad%':>6}")
    for h in trainer.history:
        print(
            f"{h['step']:>4} {h['loss']:>8.4f} {h['tokens']:>8.0f} "
            f"{h['sam_per_s']:>8.2f} {100 * h['padding']:>5.1f}%"
        )
    audit = loader.last_audit
    print(
        f"\nprotocol audit: eta_identity={audit.eta_identity:.4f} "
        f"eta_quota={audit.eta_quota:.4f} rounds={audit.rounds} "
        f"(join mode, Theorem 1: both must be 0)"
    )
    acc = loader.accounting
    print(
        f"accounting: {acc.emitted_samples} samples, {acc.emitted_tokens} real tokens, "
        f"padding {100 * acc.padding_fraction:.2f}%"
    )


if __name__ == "__main__":
    main()
