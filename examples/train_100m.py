"""End-to-end training driver: ~100M-param LM with the full ODB stack.

The production configuration (``--preset 100m``) trains a 100M decoder for a
few hundred aligned steps on the UltraChat length-distribution clone with
checkpointing and fault-tolerant resume — sized for a real accelerator.
``--preset smoke`` (default here, CPU container) runs the identical pipeline
at reduced width for a fast demonstration.

    PYTHONPATH=src python examples/train_100m.py --preset smoke
    PYTHONPATH=src python examples/train_100m.py --preset 100m --steps 300
"""

import argparse
import dataclasses

import jax

from repro.core import BucketSpec, OdbConfig
from repro.data import OnlineDynamicLoader, get_dataset
from repro.models import LM
from repro.models.config import ArchConfig
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig

PRESETS = {
    # ~104M params: 12L, d=640, untied 32k vocab — the "train ~100M for a few
    # hundred steps" end-to-end deliverable configuration.
    "100m": ArchConfig(
        name="lm-100m", family="dense", n_layers=12, d_model=640,
        vocab_size=32_000, n_heads=10, n_kv_heads=5, d_head=64, d_ff=2560,
        norm="rms", dtype="float32",
    ),
    "smoke": ArchConfig(
        name="lm-smoke", family="dense", n_layers=4, d_model=128,
        vocab_size=1024, n_heads=4, n_kv_heads=2, d_head=32, d_ff=512,
        norm="rms", dtype="float32",
    ),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="smoke")
    ap.add_argument("--dataset", default="ultrachat")
    ap.add_argument("--data-scale", type=float, default=0.002)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--world", type=int, default=4)
    ap.add_argument("--l-max", type=int, default=4096)
    ap.add_argument("--checkpoint-dir", default="artifacts/train_100m")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    model = LM(cfg)
    print(f"model {cfg.name}: {cfg.param_count()/1e6:.1f}M params")

    loader = OnlineDynamicLoader(
        get_dataset(args.dataset, scale=args.data_scale),
        world_size=args.world,
        config=OdbConfig(
            l_max=args.l_max, buffer_size=256, prefetch_factor=64, num_workers=4
        ),
        bucket_spec=BucketSpec(min_len=128, max_len=8192, max_count=512),
        vocab_size=cfg.vocab_size,
    )
    trainer = Trainer(
        model,
        loader,
        OptimizerConfig(lr=3e-4, total_steps=max(args.steps, 100)),
        TrainerConfig(
            checkpoint_dir=args.checkpoint_dir,
            checkpoint_every=20,
            log_every=5,
            max_steps=args.steps,
        ),
    )
    state, start = (
        trainer.restore_or_init(jax.random.PRNGKey(0))
        if args.resume
        else (trainer.init_state(jax.random.PRNGKey(0)), 0)
    )
    if start:
        print(f"resumed from step {start}")
    epoch = 0
    step = start
    while step < args.steps:
        state, step = trainer.train_epoch(state, epoch=epoch, start_step=step)
        epoch += 1
    for h in trainer.history:
        print(
            f"step {h['step']:>5}  loss {h['loss']:.4f}  "
            f"sam/s {h['sam_per_s']:.2f}  pad {100*h['padding']:.2f}%"
        )
    audit = loader.last_audit
    print(f"eta_identity={audit.eta_identity} eta_quota={audit.eta_quota}")


if __name__ == "__main__":
    main()
