"""Serve a small LM with continuous batching over the packed-segment path.

Heterogeneous-length requests are admitted under the ODB ``l_max`` token
budget into a slot-based KV cache (DESIGN.md §12): each admission cohort
prefills in ONE packed segment-masked forward (the PR-2/3 packed-flash
layout) whose K/V scatters straight into per-request cache slots, and every
generated token costs one fixed-shape ``(num_slots, 1)`` decode step against
the slot cache — O(S) per token, replacing this example's previous
re-prefill-per-token loop (O(S²)).

    PYTHONPATH=src python examples/serve_packed.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.kernels.ops import flash_attention
from repro.models import LM
from repro.serve import ContinuousBatchingEngine, ServeConfig


def main():
    cfg = dataclasses.replace(get_smoke_config("qwen3_0_6b"), vocab_size=512)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # Incoming request queue: heterogeneous prompt AND decode lengths.
    rng = np.random.default_rng(0)
    engine = ContinuousBatchingEngine(
        model, params,
        ServeConfig(num_slots=4, max_len=160, l_max=512, lookahead=8),
    )
    rids = []
    for _ in range(12):
        prompt = rng.integers(1, cfg.vocab_size, size=int(rng.integers(8, 96)))
        rids.append(engine.submit(prompt, int(rng.integers(4, 24))))
    outputs = engine.run()

    st = engine.stats
    print(
        f"{len(rids)} requests -> {st.prefill_calls} packed prefill cohorts, "
        f"{st.decode_steps} decode steps "
        f"({100 * st.slot_decode_occupancy:.0f}% slot occupancy)"
    )
    print(
        f"slot reuse: {len(engine.slots.assignments)} allocations over "
        f"{engine.config.num_slots} slots; peak budget "
        f"{st.peak_projected_tokens}/{engine.config.l_max} tokens"
    )
    print(
        f"compile-once: decode traced {engine.decode_traces}x, prefill "
        f"buckets {dict(engine.prefill_traces)}"
    )
    for rid in rids[:3]:
        req = engine.requests[rid]
        print(
            f"  req {rid}: prompt {req.prompt_len} -> "
            f"{len(outputs[rid])} new tokens {[int(t) for t in outputs[rid][:6]]}..."
        )

    # Kernel sanity on the packed layout (interpret mode = CPU execution).
    b, s, h, kv, d = 1, 128, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))
    seg = jnp.asarray(np.repeat([[1] * 50 + [2] * 60 + [0] * 18], b, axis=0), jnp.int32)
    out = flash_attention(q, k, v, seg)
    print(f"\nPallas segment flash attention output: {out.shape}, finite={bool(jnp.isfinite(out).all())}")


if __name__ == "__main__":
    main()
