"""Serve a small LM with batched requests over the packed-segment path.

ODB groups variable-length requests under a token budget; the group is
*packed* into one segment-id-tagged stream (beyond-paper emission mode,
DESIGN.md §8) and prefilled through the Pallas segment-aware flash-attention
kernel (interpret mode on CPU), then decoded autoregressively per request
with a per-sample KV cache.

    PYTHONPATH=src python examples/serve_packed.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import OdbConfig, PackedBucketSpec, Sample, greedy_group, pack_group
from repro.kernels.ops import flash_attention
from repro.models import LM


def main():
    cfg = dataclasses.replace(get_smoke_config("qwen3_0_6b"), vocab_size=512)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # Incoming request queue: heterogeneous prompt lengths (online lengths).
    rng = np.random.default_rng(0)
    prompts = [int(l) for l in rng.integers(8, 96, size=12)]
    samples = [Sample(view_id=i, identity=i, length=l) for i, l in enumerate(prompts)]
    groups = greedy_group(samples, l_max=256)  # ODB token-budget batching
    print(f"{len(prompts)} requests -> {len(groups)} token-budget groups")

    spec = PackedBucketSpec(min_tokens=64, max_tokens=512)
    for gi, group in enumerate(groups):
        packed = pack_group(group, spec, vocab_size=cfg.vocab_size)
        tokens = jnp.asarray(packed.tokens)
        segments = jnp.asarray(packed.segment_ids)
        positions = jnp.asarray(packed.positions)
        # Packed prefill: one forward pass over the packed stream with
        # segment-masked attention (no cross-request contamination).
        logits = model.forward(
            params,
            {"tokens": tokens, "positions": positions, "segments": segments},
        )
        # Greedy next token per request = logits at each segment's last slot.
        seg_np = np.asarray(segments[0])
        nxt = {}
        for s in range(1, packed.real_samples + 1):
            idx = int(np.where(seg_np == s)[0].max())
            nxt[group.samples[s - 1].view_id] = int(jnp.argmax(logits[0, idx]))
        print(
            f"  group {gi}: {packed.real_samples} reqs, {packed.real_tokens} real tokens, "
            f"pad {100 * packed.padding_fraction:.1f}%, first tokens {dict(list(nxt.items())[:3])}"
        )

    # Kernel sanity on the packed layout (interpret mode = CPU execution).
    b, s, h, kv, d = 1, 128, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))
    seg = jnp.asarray(np.repeat([[1] * 50 + [2] * 60 + [0] * 18], b, axis=0), jnp.int32)
    out = flash_attention(q, k, v, seg)
    print(f"\nPallas segment flash attention output: {out.shape}, finite={bool(jnp.isfinite(out).all())}")


if __name__ == "__main__":
    main()
