"""Benchmark harness entry point — one module per paper table.

Prints ``name,us_per_call,derived`` CSV lines and persists JSON artifacts
under ``artifacts/bench/``.

  throughput         — Table 1 / 13 / 14 (all methods × datasets × 2B/8B)
  ablations          — Tables 2 / 3 / 17 + App. P clamp
  protocol_audit     — Tables 4 / 5 + Corollary 1
  join_and_scaling   — Tables 18 / 21 + Fig. 2b / App. K
  roofline_bench     — §Roofline (reads dry-run artifacts)
  streaming          — eager vs streaming vs prefetch data paths
                       (emits BENCH_streaming.json; also `run.py --streaming`)
  layout             — measured dense vs packed batch layouts on real jitted
                       steps (emits BENCH_layout.json; also `run.py --layout`)
  kernels            — XLA blockwise vs Pallas flash fwd/bwd on packed rows +
                       live-tile census under segment-aware block skipping
                       (emits BENCH_kernels.json; also `run.py --kernels`)
  serving            — continuous vs static batching on the slot-cache serve
                       engine: tokens/s, p50/p99 latency, compile-once census
                       (emits BENCH_serving.json; also `run.py --serving`)
  faults             — deterministic chaos scenarios with bounded-termination
                       and bit-exact/accounted recovery rails
                       (emits BENCH_faults.json; also `run.py --faults`)
  multihost          — sharded-window host-count sweep with the §16
                       digest-equality + elastic-resume rails
                       (emits BENCH_multihost.json; also `run.py --multihost`)

Select one module by name (``run.py streaming``) or flag (``run.py
--streaming``); no argument runs everything.
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (
        ablations,
        faults,
        join_and_scaling,
        kernels,
        layout,
        multihost,
        protocol_audit,
        roofline_bench,
        serving,
        streaming,
        throughput,
    )

    modules = [
        ("throughput", throughput),
        ("ablations", ablations),
        ("protocol_audit", protocol_audit),
        ("join_and_scaling", join_and_scaling),
        ("roofline", roofline_bench),
        ("streaming", streaming),
        ("layout", layout),
        ("kernels", kernels),
        ("serving", serving),
        ("faults", faults),
        ("multihost", multihost),
    ]
    only = sys.argv[1].lstrip("-") if len(sys.argv) > 1 else None
    names = [name for name, _ in modules]
    if only is not None and only not in names:
        raise SystemExit(f"unknown benchmark module {only!r}; choose from {names}")
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        if only and only != name:
            continue
        t0 = time.perf_counter()
        try:
            for line in mod.main([]):
                print(line, flush=True)
            print(f"{name}/__wall__,{1e6*(time.perf_counter()-t0):.0f},ok=1", flush=True)
        except Exception as exc:  # pragma: no cover
            failures += 1
            print(f"{name}/__error__,0.0,error={type(exc).__name__}:{exc}", flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
