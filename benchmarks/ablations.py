"""Tables 2 / 3 / 17 / App. P — L_max, outstanding depth D, buffer ablations."""

from __future__ import annotations

import json
import pathlib

from benchmarks.common import MODEL_2B, MODEL_8B, PREP_RATE, evaluate_schedule
from repro.core import OdbConfig
from repro.data import get_dataset, odb_schedule

WORLD = 8
DATASETS = ("ultrachat", "llava", "sharegpt4o")


def lmax_ablation(scale=0.03):
    """Table 2: throughput vs per-batch token budget at fixed D=1024."""
    rows = []
    for dataset in DATASETS:
        ds = get_dataset(dataset, scale=scale)
        lengths = ds.lengths()
        prep = PREP_RATE.get(dataset, PREP_RATE["default"])
        for lmax in (2048, 4096, 8192, 12288, 14336, 16384):
            cfg = OdbConfig(l_max=lmax, buffer_size=1024, prefetch_factor=256, num_workers=4)
            steps, _ = odb_schedule(lengths, WORLD, cfg)
            rep = evaluate_schedule(f"odb_l{lmax}", steps, MODEL_8B, prep_rate=prep, depth=cfg.depth)
            rows.append(dict(rep.row(), dataset=dataset, l_max=lmax))
    return rows


def depth_ablation(scale=0.03):
    """Table 3 + App. P: depth D controls input overlap; clamp at buffer."""
    rows = []
    for dataset in DATASETS:
        ds = get_dataset(dataset, scale=scale)
        lengths = ds.lengths()
        prep = PREP_RATE.get(dataset, PREP_RATE["default"])
        for model, tag in ((MODEL_2B, "2b"), (MODEL_8B, "8b")):
            for pf in (32, 64, 128, 256, 512, 1024, 2048):
                cfg = OdbConfig(l_max=12288, buffer_size=1024, prefetch_factor=pf, num_workers=4)
                steps, _ = odb_schedule(lengths, WORLD, cfg)
                rep = evaluate_schedule(
                    f"odb_pf{pf}", steps, model, prep_rate=prep, depth=cfg.depth
                )
                rows.append(
                    dict(rep.row(), dataset=dataset, model=tag, pf=pf, depth=cfg.depth)
                )
    return rows


def buffer_ablation(scale=0.03):
    """Table 17: grouping buffer size vs padding/throughput (ShareGPT4o)."""
    rows = []
    ds = get_dataset("sharegpt4o", scale=scale)
    lengths = ds.lengths()
    prep = PREP_RATE["sharegpt4o"]
    for model, tag, lmax in ((MODEL_2B, "2b", 4096), (MODEL_8B, "8b", 8192)):
        for buffer in (10, 50, 100, 500, 1024, 2000):
            cfg = OdbConfig(l_max=lmax, buffer_size=buffer, prefetch_factor=256, num_workers=4)
            steps, _ = odb_schedule(lengths, WORLD, cfg)
            rep = evaluate_schedule(
                f"odb_buf{buffer}", steps, model, prep_rate=prep, depth=cfg.depth
            )
            rows.append(dict(rep.row(), model=tag, buffer=buffer, l_max=lmax))
    return rows


def main(argv=None) -> list[str]:
    outdir = pathlib.Path("artifacts/bench")
    outdir.mkdir(parents=True, exist_ok=True)
    lines = []

    lm = lmax_ablation()
    (outdir / "lmax_ablation.json").write_text(json.dumps(lm, indent=1))
    for dataset in DATASETS:
        sub = [r for r in lm if r["dataset"] == dataset]
        best = max(sub, key=lambda r: r["sam_per_s"])
        lines.append(
            f"lmax_ablation/{dataset},0.0,best_lmax={best['l_max']};"
            f"sam_s={best['sam_per_s']:.2f};pad%={best['padding_pct']:.2f}"
        )

    dp = depth_ablation()
    (outdir / "depth_ablation.json").write_text(json.dumps(dp, indent=1))
    clamp = [r for r in dp if r["pf"] in (32, 64, 128) and r["dataset"] == "sharegpt4o" and r["model"] == "8b"]
    spread = max(r["sam_per_s"] for r in clamp) - min(r["sam_per_s"] for r in clamp)
    lines.append(f"depth_ablation/clamp_validation,0.0,pf32-128_spread={spread:.4f};depth={clamp[0]['depth']}")

    bu = buffer_ablation()
    (outdir / "buffer_ablation.json").write_text(json.dumps(bu, indent=1))
    b8 = [r for r in bu if r["model"] == "8b"]
    best = max(b8, key=lambda r: r["sam_per_s"])
    lines.append(
        f"buffer_ablation/8b,0.0,best_buffer={best['buffer']};"
        f"pad%={best['padding_pct']:.2f};sam_s={best['sam_per_s']:.2f}"
    )
    return lines


if __name__ == "__main__":
    for line in main():
        print(line)
