"""Tables 4 / 5 + Corollary 1 — protocol correctness audits.

η_quota / η_identity / terminal-epoch across the six synthetic distributions
(App. I) and the dataset clones, in both termination modes, plus the
Lemma-4 η_logical envelopes for the paper's representative configurations.
"""

from __future__ import annotations

import json
import pathlib

from repro.core import OdbConfig
from repro.data import SYNTHETIC_DISTRIBUTIONS, get_dataset, odb_schedule

WORLD = 8


def audit_rows():
    rows = []
    cases = [(name, ds.lengths(), 2048) for name, ds in SYNTHETIC_DISTRIBUTIONS.items()]
    for name in ("ultrachat", "llava", "sharegpt4o"):
        ds = get_dataset(name, scale=0.02)
        cases.append((name, ds.lengths(), 12288))
    for name, lengths, lmax in cases:
        for join in (True, False):
            cfg = OdbConfig(
                l_max=lmax, buffer_size=128, prefetch_factor=64,
                num_workers=4, join_mode=join,
            )
            steps, audit = odb_schedule(lengths, WORLD, cfg)
            rows.append(
                {
                    "distribution": name,
                    "mode": "join" if join else "non_join",
                    "N": audit.dataset_identities,
                    "emitted": audit.emitted_views,
                    "eta_quota": audit.eta_quota,
                    "eta_identity": audit.eta_identity,
                    "terminal_epoch": round(audit.terminal_epoch, 4),
                    "surplus": audit.surplus_emits,
                    "rounds": audit.rounds,
                    "iterations": audit.logical_iterations,
                }
            )
    return rows


def eta_logical_envelopes():
    """Table 4: worst-case per-iteration bounds W·D/N for paper configs."""
    configs = [
        ("LLaVA 8B (D=4096)", 157_712, 8, 4096),
        ("UltraChat 8B (ml8k pf256 buf256)", 207_865, 8, 1024),
        ("UltraChat 8B (ml8k pf1024 buf1024)", 207_865, 8, 4096),
        ("UltraChat 8B (ml16k pf512 buf1024)", 207_865, 8, 2048),
        ("ShareGPT4o 8B (ml4k pf1024)", 54_424, 8, 4096),
        ("MM-Mix 8B (ml8k pf256)", 545_178, 8, 1024),
        ("MM-Mix 8B (extreme, ml4k pf2048)", 545_178, 8, 8192),
    ]
    paper_values = [0.208, 0.039, 0.158, 0.079, 0.602, 0.015, 0.120]
    rows = []
    for (name, n, w, d), paper in zip(configs, paper_values):
        bound = w * d / n
        rows.append(
            {"config": name, "N": n, "W": w, "D": d,
             "eta_logical_bound": round(bound, 4), "paper_bound": paper,
             "matches_paper": abs(bound - paper) < 5e-3}
        )
    return rows


def main(argv=None) -> list[str]:
    outdir = pathlib.Path("artifacts/bench")
    outdir.mkdir(parents=True, exist_ok=True)
    rows = audit_rows()
    env = eta_logical_envelopes()
    (outdir / "protocol_audit.json").write_text(
        json.dumps({"audits": rows, "eta_logical": env}, indent=1)
    )
    n_zero = sum(1 for r in rows if r["eta_quota"] == 0.0)
    n_id = sum(1 for r in rows if r["mode"] == "join" and r["eta_identity"] == 0.0)
    n_join = sum(1 for r in rows if r["mode"] == "join")
    worst_epoch = max(r["terminal_epoch"] for r in rows)
    env_ok = all(r["matches_paper"] for r in env)
    return [
        f"protocol_audit/quota,0.0,eta_quota_zero={n_zero}/{len(rows)};worst_terminal_epoch={worst_epoch}",
        f"protocol_audit/identity,0.0,join_eta_identity_zero={n_id}/{n_join}",
        f"protocol_audit/table4_envelopes,0.0,all_match_paper={env_ok}",
    ]


if __name__ == "__main__":
    for line in main():
        print(line)
