"""Fault-injection benchmark — the chaos harness as a measured acceptance lane.

Runs every scenario in :mod:`repro.chaos` (gather delay/drop, slow rank,
poison sample, worker kill, torn checkpoint) over a small seed matrix and
reports, per (kind, seed):

  * ``wall``   — scenario wall time (the faults themselves are simulated
    against the deadline, so this stays CPU-cheap);
  * ``ok``     — the scenario's acceptance rail: terminated, within its
    Theorem-4 round envelope, and bit-exact (or divergence fully accounted
    by the (R, Q, B, E, X) audit — DESIGN.md §15.5).

The artifact's ``rails`` block is the bench-smoke acceptance contract:
``all_ok`` must be true and ``bounded_termination`` asserts no scenario
exceeded its round bound.

Artifacts: ``<out>/faults.json`` plus the top-level ``BENCH_faults.json``.
"""

from __future__ import annotations

import argparse
import json
import pathlib

from benchmarks.common import csv_line
from repro.chaos import FAULT_KINDS, run_all


def main(argv=None) -> list[str]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts/bench")
    ap.add_argument(
        "--seeds", type=int, nargs="*", default=[0, 1],
        help="chaos plan seeds; each seed is a distinct deterministic "
             "fault schedule",
    )
    ap.add_argument(
        "--kinds", nargs="*", default=None, choices=FAULT_KINDS,
        help="restrict to these fault kinds (default: all six)",
    )
    args = ap.parse_args(argv)  # None -> sys.argv (standalone CLI)

    lines: list[str] = []
    scenarios: dict[str, dict] = {}
    for seed in args.seeds:
        results = run_all(seed, kinds=args.kinds)
        for kind, res in results.items():
            scenarios[f"{kind}_s{seed}"] = res.as_dict()
            lines.append(
                csv_line(
                    f"faults/{kind}_s{seed}",
                    1e6 * res.wall_s,
                    {
                        "ok": int(res.ok),
                        "rounds": res.rounds,
                        "bound": res.bound,
                        "bit_exact": int(res.bit_exact),
                        "accounted": int(res.accounted),
                    },
                )
            )

    rails = {
        "all_ok": all(s["ok"] for s in scenarios.values()),
        "bounded_termination": all(
            s["within_bound"] for s in scenarios.values()
        ),
        "failed": sorted(k for k, s in scenarios.items() if not s["ok"]),
    }
    artifact = {
        "config": {"seeds": args.seeds, "kinds": args.kinds or list(FAULT_KINDS)},
        "scenarios": scenarios,
        "rails": rails,
    }
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    (outdir / "faults.json").write_text(json.dumps(artifact, indent=1))
    pathlib.Path("BENCH_faults.json").write_text(json.dumps(artifact, indent=1))
    if not rails["all_ok"]:
        raise RuntimeError(f"chaos rails failed: {rails['failed']}")
    return lines


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for line in main():
        print(line)
