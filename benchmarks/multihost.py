"""Multi-host sharded-window benchmark — the §16 digest-equality rail.

For each (world, hosts, lookahead) cell the smoke runs the same epoch twice:
once through the single-process W-rank loopback window (the reference every
prior subsystem was proven against) and once through P sharded host windows
behind the router, then reports:

  * ``wall``          — sharded-path wall time for the epoch;
  * ``overhead``      — sharded / single-process wall ratio (the router and
    payload fold must be protocol-bookkeeping-cheap);
  * ``digest_equal``  — the acceptance rail: the delivered stream digest is
    bit-identical, Theorem-1 coverage and the Theorem-4 round envelope hold.

One cell additionally cuts the epoch mid-stream, checkpoints at P hosts and
resumes at a different host count — the elastic-restart rail the v4
per-rank checkpoint schema exists for.

Artifacts: ``<out>/multihost.json`` plus the top-level
``BENCH_multihost.json`` (CI asserts over its ``rails`` block).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import random
import time

from benchmarks.common import csv_line
from repro.chaos import stream_digest
from repro.chaos.harness import round_bound
from repro.core import OdbConfig
from repro.data.datasets import _records_from_lengths
from repro.data.pipeline import PipelinePolicy
from repro.stream import StreamCheckpoint, StreamExecutor

POLICY = PipelinePolicy()

# (world, hosts, lookahead): host-count sweep at W=8 plus a tight-lookahead
# cell where the partitioned sub-budgets actually bind.
CELLS = [
    (8, 2, None),
    (8, 4, None),
    (8, 8, None),
    (8, 2, 16),
    (4, 4, 8),
]


def make_records(n: int, seed: int = 0):
    rng = random.Random(seed)
    return _records_from_lengths([rng.randint(16, 900) for _ in range(n)])


def _drain(ex: StreamExecutor) -> list:
    steps = []
    while True:
        step = ex.step()
        if step is None:
            return steps
        steps.append(step)


def _run(records, world, hosts, lookahead, cfg, seed):
    t0 = time.perf_counter()
    ex = StreamExecutor(
        records, POLICY, world, cfg, seed=seed, lookahead=lookahead,
        num_hosts=hosts,
    )
    steps = _drain(ex)
    return ex, steps, time.perf_counter() - t0


def main(argv=None) -> list[str]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts/bench")
    ap.add_argument("--records", type=int, default=192)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)  # None -> sys.argv (standalone CLI)

    cfg = OdbConfig(l_max=1024, buffer_size=16, prefetch_factor=8, num_workers=1)
    records = make_records(args.records, args.seed)

    lines: list[str] = []
    cells: dict[str, dict] = {}
    for world, hosts, lookahead in CELLS:
        ref_ex, ref_steps, ref_wall = _run(
            records, world, 1, lookahead, cfg, args.seed
        )
        ex, steps, wall = _run(records, world, hosts, lookahead, cfg, args.seed)
        audit = ex.audit()
        cell = {
            "world": world,
            "hosts": hosts,
            "lookahead": lookahead,
            "steps": len(steps),
            "wall_s": wall,
            "single_process_wall_s": ref_wall,
            "overhead_x": wall / ref_wall if ref_wall > 0 else 0.0,
            "digest_equal": stream_digest(steps) == stream_digest(ref_steps),
            "eta_identity": audit.eta_identity,
            "rounds": ex.runner.rounds,
            "round_bound": round_bound(ex),
        }
        cells[f"w{world}_p{hosts}_l{lookahead or 'full'}"] = cell
        lines.append(
            csv_line(
                f"multihost/w{world}_p{hosts}_l{lookahead or 'full'}",
                1e6 * wall,
                {
                    "digest_equal": int(cell["digest_equal"]),
                    "overhead_x": round(cell["overhead_x"], 3),
                    "steps": len(steps),
                },
            )
        )

    # Elastic resume rail: checkpoint at P=2, resume at P=4 and P=1.
    world, hosts, lookahead = 4, 2, 24
    ref_steps = _drain(
        StreamExecutor(records, POLICY, world, cfg, seed=args.seed,
                       lookahead=lookahead)
    )
    resume = {}
    for resume_hosts in (4, 1):
        ex = StreamExecutor(
            records, POLICY, world, cfg, seed=args.seed, lookahead=lookahead,
            num_hosts=hosts,
        )
        head = [ex.step() for _ in range(max(2, len(ref_steps) // 3))]
        blob = ex.checkpoint().to_json()
        resumed = StreamExecutor.resume(
            StreamCheckpoint.from_json(blob), records, POLICY,
            num_hosts=resume_hosts,
        )
        tail = _drain(resumed)
        resume[f"p{hosts}_to_p{resume_hosts}"] = {
            "digest_equal": stream_digest(head + tail)
            == stream_digest(ref_steps),
            "checkpoint_bytes": len(blob),
        }

    rails = {
        "digest_equal": all(c["digest_equal"] for c in cells.values()),
        "identity_coverage": all(
            c["eta_identity"] == 0.0 for c in cells.values()
        ),
        "bounded_termination": all(
            c["rounds"] <= c["round_bound"] for c in cells.values()
        ),
        "elastic_resume": all(r["digest_equal"] for r in resume.values()),
        "failed": sorted(
            k for k, c in cells.items() if not c["digest_equal"]
        ),
    }
    artifact = {
        "config": {"records": args.records, "seed": args.seed},
        "cells": cells,
        "resume": resume,
        "rails": rails,
    }
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    (outdir / "multihost.json").write_text(json.dumps(artifact, indent=1))
    pathlib.Path("BENCH_multihost.json").write_text(
        json.dumps(artifact, indent=1)
    )
    if not (rails["digest_equal"] and rails["elastic_resume"]):
        raise RuntimeError(f"multihost digest rails failed: {rails}")
    return lines


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for line in main():
        print(line)
