"""Tables 18 / 21 + Fig. 2b — loss-scaling modes, join-vs-non-join, CV sweep.

These three use *measured* quantities (real protocol execution, real tiny-
model training on CPU for the loss-mode comparison), not the cost model.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import time

from benchmarks.common import MODEL_2B, PREP_RATE, evaluate_schedule
from repro.core import IDLE, OdbConfig, RankLossStats, ddp_scaled_loss, reference_per_token_loss
from repro.data import get_dataset, odb_schedule
from repro.data.pipeline import length_cv, short_sample_fraction

WORLD = 8


def join_mode_bench(scale=0.02):
    """Table 21: default join vs opt-in non-join — protocol-side cost.

    On real hardware the difference is the drain-before-finish barrier; here
    we measure its protocol-side proxies (rounds, emitted views, host wall
    time of the collate/alignment engine) plus cost-model throughput.
    """
    rows = []
    for dataset in ("ultrachat", "llava", "sharegpt4o"):
        ds = get_dataset(dataset, scale=scale)
        lengths = ds.lengths()
        prep = PREP_RATE.get(dataset, PREP_RATE["default"])
        per_mode = {}
        for join in (True, False):
            cfg = OdbConfig(
                l_max=12288, buffer_size=512, prefetch_factor=128,
                num_workers=4, join_mode=join,
            )
            t0 = time.perf_counter()
            steps, audit = odb_schedule(lengths, WORLD, cfg)
            host_s = time.perf_counter() - t0
            rep = evaluate_schedule(
                "odb", steps, MODEL_2B, prep_rate=prep, depth=cfg.depth
            )
            per_mode["join" if join else "non_join"] = {
                "rounds": audit.rounds,
                "emitted": audit.emitted_views,
                "host_s": host_s,
                "sam_per_s": rep.sam_per_s,
                "eta_identity": audit.eta_identity,
            }
        ratio = per_mode["join"]["sam_per_s"] / per_mode["non_join"]["sam_per_s"]
        rows.append({"dataset": dataset, **per_mode, "join_over_nonjoin": ratio})
    return rows


def loss_scaling_bench(scale=0.01):
    """Table 18: three scaling modes on a real ODB schedule.

    Per aligned step, build per-rank (loss_sum, tokens) from a synthetic
    per-token loss field and compare each mode's DDP output to the per-token
    reference; also count the extra second-gather rounds of exact mode.
    """
    import numpy as np

    ds = get_dataset("sharegpt4o", scale=scale)
    lengths = ds.lengths()
    rng = np.random.default_rng(0)

    out = {}
    for exact in (True, False):
        cfg = OdbConfig(
            l_max=4096, buffer_size=256, prefetch_factor=64, num_workers=4,
            exact_token_scaling=exact,
        )
        steps, audit = odb_schedule(lengths, WORLD, cfg)
        errs = {"sample": [], "approx_token": [], "exact_token": []}
        for step in steps:
            stats = []
            for g in step:
                if g is IDLE:
                    stats.append(RankLossStats(0.0, 0, 0))
                else:
                    tok = g.real_tokens
                    loss_sum = float(rng.normal(1.3, 0.05) * tok)
                    stats.append(
                        RankLossStats(
                            loss_sum=loss_sum, tokens=tok, samples=g.size,
                            tokens_pre_alignment=tok, samples_pre_alignment=g.size,
                        )
                    )
            ref = reference_per_token_loss(stats)
            for mode in errs:
                errs[mode].append(abs(ddp_scaled_loss(stats, mode) - ref))
        out["exact" if exact else "approx"] = {
            mode: float(np.mean(v)) for mode, v in errs.items()
        }
        out.setdefault("rounds", {})["exact" if exact else "approx"] = audit.rounds
    return out


def cv_sweep(scale=0.02):
    """Fig. 2b + App. K: speedup vs CV, plus the two-anchor (CV, f_s) fit."""
    from repro.data import standard_schedule

    rows = []
    for dataset in ("llava", "ultrachat", "mmmix", "sharegpt4o"):
        ds = get_dataset(dataset, scale=scale)
        lengths = ds.lengths()
        prep = PREP_RATE.get(dataset, PREP_RATE["default"])
        lmax = 12288
        std_bs = 1 if dataset in ("sharegpt4o", "mmmix") else 8
        std = evaluate_schedule(
            "standard", standard_schedule(lengths, WORLD, std_bs), MODEL_2B,
            prep_rate=prep,
        )
        cfg = OdbConfig(l_max=lmax, buffer_size=1024, prefetch_factor=256, num_workers=4)
        steps, _ = odb_schedule(lengths, WORLD, cfg)
        odb = evaluate_schedule("odb", steps, MODEL_2B, prep_rate=prep, depth=cfg.depth)
        rows.append(
            {
                "dataset": dataset,
                "cv": round(length_cv(lengths), 3),
                "f_s": round(short_sample_fraction(lengths, lmax), 3),
                "speedup": odb.sam_per_s / std.sam_per_s,
                "odb_pad_pct": odb.padding_pct,
                "std_pad_pct": std.padding_pct,
            }
        )
    # App. K two-anchor pinning on (sharegpt4o, mmmix):
    a = next(r for r in rows if r["dataset"] == "sharegpt4o")
    b = next(r for r in rows if r["dataset"] == "mmmix")
    d = (a["cv"] * b["f_s"] - b["cv"] * a["f_s"])
    alpha = beta = float("nan")
    if abs(d) > 1e-9:
        alpha = ((a["speedup"] - 1) * b["f_s"] - (b["speedup"] - 1) * a["f_s"]) / d
        beta = (a["cv"] * (b["speedup"] - 1) - b["cv"] * (a["speedup"] - 1)) / d
    return rows, {"alpha": alpha, "beta": beta}


def main(argv=None) -> list[str]:
    outdir = pathlib.Path("artifacts/bench")
    outdir.mkdir(parents=True, exist_ok=True)
    jm = join_mode_bench()
    ls = loss_scaling_bench()
    cv, fit = cv_sweep()
    (outdir / "join_mode.json").write_text(json.dumps(jm, indent=1))
    (outdir / "loss_scaling.json").write_text(json.dumps(ls, indent=1))
    (outdir / "cv_sweep.json").write_text(json.dumps({"rows": cv, "fit": fit}, indent=1))
    mean_ratio = sum(r["join_over_nonjoin"] for r in jm) / len(jm)
    exact_err = ls["exact"]["exact_token"]
    sample_err = ls["exact"]["sample"]
    return [
        f"join_mode/summary,0.0,mean_join_over_nonjoin={mean_ratio:.4f}",
        f"loss_scaling/summary,0.0,exact_err={exact_err:.2e};sample_err={sample_err:.2e}",
        f"cv_sweep/fit,0.0,alpha={fit['alpha']:.2f};beta={fit['beta']:.2f};"
        + ";".join(f"{r['dataset']}={r['speedup']:.2f}x" for r in cv),
    ]


if __name__ == "__main__":
    for line in main():
        print(line)
