"""Shared benchmark machinery: schedule evaluation + H20 step-cost model.

This container has no GPU, so full-scale throughput rows are produced by an
*explicit, documented cost model* applied to the real batch schedules that
the batching systems (ODB / Standard / Sorted / Packing / GMT / BMT / HFG)
actually emit — the batching logic, alignment protocol, padding, and update
geometry are all real; only the per-step wall time is modeled:

    t_step = flops(padded area + attention) / (peak · MFU(useful tokens))
             + max(0, t_comm - overlap_bwd) + t_fixed + dl_wait(D)

  * MFU saturates with useful tokens per step (condition (2) of §1):
    MFU(x) = mfu_max · x / (x + x_half) — small batches underfill the GPU;
  * t_comm models the ZeRO-2 gradient reduce over NVLink, overlapped with
    the backward pass;
  * dl_wait models input-pipeline starvation hidden by the outstanding
    depth D (condition (3)); per-dataset host prep rates follow App. I's
    measured tokenization/image-decode rates.

Absolute numbers are indicative; *ratios* (speedups, method ordering) are
the reproduction target (EXPERIMENTS.md §Paper-fidelity compares them to
Table 1/13/14).  Additionally, tiny-model REAL throughput is measured on CPU
in ``loss_scaling_bench``/examples as a second, fully-measured datapoint.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Sequence

from repro.core import IDLE, Group
from repro.core.metadata import step_metadata

H20_PEAK = 148e12  # bf16 dense FLOP/s per GPU
NVLINK_BW = 700e9  # effective all-reduce bytes/s
MFU_MAX = 0.42
X_HALF = 6144.0  # tokens/step at which MFU reaches half of max
T_FIXED = 0.035  # optimizer + launch + sync overhead (s)


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    name: str
    n_params: float
    n_layers: int
    d_model: int

    @property
    def grad_bytes(self) -> float:
        return 2.0 * self.n_params  # bf16 grads


MODEL_8B = ModelProfile("qwen3vl-8b", 8.0e9, 36, 4096)
MODEL_2B = ModelProfile("qwen3vl-2b", 2.0e9, 28, 2048)

# Host preprocessing rates (samples/s/worker), from App. I cache-build rates.
PREP_RATE = {
    "ultrachat": 6700.0 / 4,
    "llava": 48.0,
    "sharegpt4o": 418.0 / 4,
    "mmmix": 200.0,
    "default": 500.0,
}


def step_flops(group: Group | None, model: ModelProfile, packed: bool = False) -> float:
    """Training FLOPs of one rank's batch: 6·N per padded token + attention."""
    if group is None:
        return 0.0
    if packed:
        area = group.real_tokens
        attn = sum(6.0 * model.n_layers * model.d_model * (s.length**2) for s in group.samples)
    else:
        area = group.padded_tokens
        attn = 6.0 * model.n_layers * model.d_model * group.size * (group.max_length**2)
    return 6.0 * model.n_params * area + attn


def step_time(
    step: Sequence[Group | None],
    model: ModelProfile,
    *,
    prep_rate: float = PREP_RATE["default"],
    num_workers: int = 4,
    depth: int = 1024,
    packed: bool = False,
) -> float:
    """Wall time of one aligned step across W ranks (slowest rank binds)."""
    flops = max(step_flops(g, model, packed) for g in step)
    useful = max((g.real_tokens if g else 0) for g in step)
    mfu = MFU_MAX * useful / (useful + X_HALF)
    compute = flops / (H20_PEAK * max(mfu, 1e-3))
    comm = model.grad_bytes * 2.0 / NVLINK_BW
    bwd_overlap = compute * 2.0 / 3.0
    samples = max((g.size if g else 0) for g in step)
    prep = samples / (prep_rate * num_workers)
    hidden = min(1.0, depth / max(samples * 4.0, 1.0))
    dl_wait = max(0.0, prep - compute) * (1.0 - hidden)
    return compute + max(0.0, comm - bwd_overlap) + T_FIXED + dl_wait


@dataclasses.dataclass
class ScheduleReport:
    method: str
    sam_per_s: float
    tok_per_s: float
    upd_per_epoch: int
    sam_per_upd: float
    tok_per_upd: float
    padding_pct: float
    dl_wait_pct: float
    wall_s: float

    def row(self) -> dict:
        return dataclasses.asdict(self)


def evaluate_schedule(
    method: str,
    steps: list[list[Group | None]],
    model: ModelProfile,
    *,
    prep_rate: float = PREP_RATE["default"],
    depth: int = 1024,
    num_workers: int = 4,
    packed: bool = False,
) -> ScheduleReport:
    total_time = 0.0
    total_wait = 0.0
    samples = 0
    real_tokens = 0
    padded_tokens = 0
    for i, step in enumerate(steps):
        t = step_time(
            step, model, prep_rate=prep_rate, depth=depth,
            num_workers=num_workers, packed=packed,
        )
        total_time += t
        md = step_metadata(i, step)
        samples += md.emitted_samples
        real_tokens += md.total_tokens
        padded_tokens += md.total_padded_tokens
    upd = len(steps)
    return ScheduleReport(
        method=method,
        sam_per_s=samples / total_time if total_time else 0.0,
        tok_per_s=real_tokens / total_time if total_time else 0.0,
        upd_per_epoch=upd,
        sam_per_upd=samples / upd if upd else 0.0,
        tok_per_upd=real_tokens / upd if upd else 0.0,
        padding_pct=100.0 * (1 - real_tokens / padded_tokens) if padded_tokens else 0.0,
        dl_wait_pct=100.0 * total_wait / total_time if total_time else 0.0,
        wall_s=total_time,
    )


def csv_line(name: str, wall_us: float, derived: dict) -> str:
    """`name,us_per_call,derived` contract for benchmarks.run."""
    derived_str = ";".join(f"{k}={v}" for k, v in derived.items())
    return f"{name},{wall_us:.1f},{derived_str}"


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.elapsed = time.perf_counter() - self.t0


class timed_section:
    """Timed ``with``-scope backed by ``obs.trace`` (DESIGN.md §13.2).

    The shared replacement for the benchmarks' hand-rolled
    ``time.perf_counter()`` bookkeeping: ``.elapsed`` carries the wall time
    for the benchmark's own arithmetic, and the same interval lands in the
    process tracer as a ``bench/...`` span when tracing is enabled — so a
    telemetry-enabled bench run renders its phases on the identical timeline
    as the instrumented runtime it measures.
    """

    def __init__(self, name: str, **args) -> None:
        self.name = name
        self.args = args
        self.elapsed = 0.0

    def __enter__(self) -> "timed_section":
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        from repro import obs

        self.elapsed = time.perf_counter() - self.t0
        obs.default_tracer().complete(
            self.name, self.t0, self.elapsed, cat="bench", **self.args
        )
